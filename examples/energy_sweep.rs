//! Fig.-1 style motivation sweep: throughput and end-system power across the
//! (cc, p) grid under light/medium/heavy background traffic.
//!
//! ```bash
//! cargo run --release --example energy_sweep [testbed]
//! ```

use sparta::experiments::{default_jobs, fig1};
use sparta::net::Testbed;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "chameleon".into());
    let tb = Testbed::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown testbed '{name}', using chameleon");
        Testbed::chameleon()
    });
    let grid = [1u32, 2, 4, 8, 16];
    println!(
        "sweeping (cc, p) ∈ {{1,2,4,8,16}}² x 3 background regimes on {} ({} Gbps)...",
        tb.name, tb.capacity_gbps
    );
    let pts = fig1::sweep(&tb, &grid, &["low", "medium", "high"], 7, default_jobs());
    fig1::print(&pts, &grid);

    // The paper's observation: the optimum moves with background traffic.
    for regime in ["low", "medium", "high"] {
        let best = pts
            .iter()
            .filter(|p| p.regime == regime)
            .max_by(|a, b| a.throughput_gbps.partial_cmp(&b.throughput_gbps).unwrap())
            .unwrap();
        let base = pts
            .iter()
            .find(|p| p.regime == regime && p.cc == 1 && p.p == 1)
            .unwrap();
        println!(
            "background={regime}: best=(cc={}, p={}) at {:.1} Gbps / {:.0} W  ({:.1}x over (1,1))",
            best.cc,
            best.p,
            best.throughput_gbps,
            best.power_w,
            best.throughput_gbps / base.throughput_gbps
        );
    }
}
