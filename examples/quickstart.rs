//! End-to-end quickstart: train (if needed) and run SPARTA against a static
//! baseline on a real small workload, printing the paper's headline metrics.
//!
//! ```bash
//! make artifacts                 # once: AOT-lower the networks
//! cargo run --release --example quickstart
//! ```
//!
//! This is the full-system driver: exploration transfers on the simulated
//! Chameleon testbed → k-means emulator → offline R_PPO training through the
//! AOT-compiled HLO train step → evaluation transfers (SPARTA-FE, SPARTA-T,
//! rclone) with energy metering — all three stack layers composing.
//!
//! Evaluation runs through the step-driven `Session` API: each method's
//! transfer is admitted as a lane, the session is stepped MI by MI, and a
//! `ReportSink` rebuilds the summary from the event stream. The same
//! session can admit lanes mid-run, pause/resume them externally, or cancel
//! them — see `sparta fleet` for the dynamic-workload experiment.

use anyhow::Result;
use sparta::config::Paths;
use sparta::coordinator::{Event, LaneSpec, RewardKind, Session, DEFAULT_MAX_MIS};
use sparta::experiments::{make_optimizer, train_pipeline, Scale, SpartaCtx, TrainSource};
use sparta::net::Testbed;
use sparta::telemetry::{ReportSink, Table, TelemetrySink};
use sparta::transfer::TransferJob;

fn main() -> Result<()> {
    let mut ctx = SpartaCtx::load(Paths::resolve())?;
    let tb = Testbed::chameleon();
    let scale = Scale::Quick;
    let seed = 2026;

    // 1. Make sure both SPARTA variants are trained (offline, emulated).
    let store = ctx.weight_store();
    for reward in [RewardKind::FairnessEfficiency, RewardKind::ThroughputEnergy] {
        let name = SpartaCtx::weight_name("rppo", reward);
        if !store.exists(&name) {
            println!("training {name} (offline, cluster emulator)...");
            let stats =
                train_pipeline(&ctx, "rppo", reward, TrainSource::Testbed(&tb), scale, seed)?;
            println!(
                "  {:.0}s, {} env steps, converged at step {}",
                stats.wall_s, stats.env_steps, stats.steps_to_converge
            );
        }
    }
    // Evaluation reads trained weights through the context's read-only
    // snapshot; refresh it so it sees anything trained above.
    ctx.refresh_snapshot()?;

    // 2. Move the quick-scale workload from TACC to UC (simulated 10 Gbps
    //    shared WAN) with each method and compare. One step-driven session
    //    per method: admit the lane, step to completion, rebuild the report
    //    from the event stream.
    let (files, bytes) = scale.workload();
    println!(
        "\ntransferring {} x {} MiB on {} ({} Gbps, shared)...",
        files,
        bytes >> 20,
        tb.name,
        tb.capacity_gbps
    );
    let mut table = Table::new(&["method", "Gbps", "duration s", "energy kJ", "J per GB"]);
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    for method in ["rclone", "sparta-t", "sparta-fe"] {
        let (opt, engine, reward) = make_optimizer(&ctx, method, seed)?;
        let mut session = Session::builder(tb.clone()).seed(seed).build();
        let lane_id = session.admit(
            LaneSpec::new(opt, TransferJob::files(files, bytes)).engine(engine).reward(reward),
        );
        let mut sink = ReportSink::new();
        let mut mi_events = 0usize;
        while session.mi() < DEFAULT_MAX_MIS && !session.is_idle() {
            for ev in session.step() {
                if matches!(ev, Event::MiCompleted { .. }) {
                    mi_events += 1;
                }
                sink.on_event(&ev);
            }
        }
        let report = sink.finish(session.time_s());
        let lane = report.lane();
        assert!(lane.completed, "{method}: transfer did not complete");
        assert_eq!(mi_events, lane.records.len());
        assert_eq!(session.lane_name(lane_id), Some(lane.name.as_str()));
        table.row(vec![
            method.to_string(),
            format!("{:.2}", lane.avg_throughput_gbps()),
            format!("{:.0}", lane.duration_s),
            format!("{:.1}", lane.total_energy_j / 1000.0),
            format!("{:.1}", lane.energy_per_gb()),
        ]);
        results.push((method.to_string(), lane.avg_throughput_gbps(), lane.total_energy_j));
    }
    table.print();

    let baseline = &results[0];
    let best_thr = results[1..].iter().map(|r| r.1).fold(0.0, f64::max);
    let best_energy = results[1..].iter().map(|r| r.2).fold(f64::MAX, f64::min);
    println!(
        "\nSPARTA vs rclone: {:+.0}% throughput, {:+.0}% energy",
        (best_thr - baseline.1) / baseline.1 * 100.0,
        (best_energy - baseline.2) / baseline.2 * 100.0,
    );
    Ok(())
}
