//! Fig.-7 style fairness demo: three concurrent transfers share a 10 Gbps
//! bottleneck; compare JFI under SPARTA-T, SPARTA-FE, and the mixed scenario.
//!
//! ```bash
//! cargo run --release --example fairness_demo
//! ```
//! (Requires trained weights: `sparta train-all --scale quick` or the
//! quickstart example.)

use anyhow::Result;
use sparta::config::Paths;
use sparta::experiments::{default_jobs, fig7, Scale};

fn main() -> Result<()> {
    let scenarios = fig7::run(&Paths::resolve(), Scale::Quick, 99, default_jobs())?;
    fig7::print(&scenarios);

    // The paper's finding: the F&E reward (loss-aware) yields higher, more
    // stable fairness than the T/E reward.
    let t = scenarios.iter().find(|s| s.name.contains("sparta-t")).unwrap();
    let fe = scenarios.iter().find(|s| s.name.contains("sparta-fe")).unwrap();
    println!(
        "\nSPARTA-FE converged JFI {:.3} (±{:.3}) vs SPARTA-T {:.3} (±{:.3})",
        fe.converged_jfi(),
        fe.jfi_std(),
        t.converged_jfi(),
        t.jfi_std()
    );
    Ok(())
}
