//! Fig.-5 style transfer-learning demo: an R_PPO agent trained on the
//! Chameleon preset keeps learning after deployment on CloudLab, whose RTT,
//! capacity and congestion dynamics differ.
//!
//! ```bash
//! cargo run --release --example online_tuning [episodes]
//! ```

use anyhow::Result;
use sparta::agents::make_agent;
use sparta::config::Paths;
use sparta::coordinator::{ParamBounds, RewardKind};
use sparta::emulator::Env;
use sparta::experiments::SpartaCtx;
use sparta::net::Testbed;
use sparta::trainer::LiveEnv;
use sparta::util::stats;

fn main() -> Result<()> {
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(80);
    let ctx = SpartaCtx::load(Paths::resolve())?;
    let store = ctx.weight_store();
    let n = ctx.runtime.manifest.algo("rppo")?.n_params;
    let weights = store.load(&SpartaCtx::weight_name("rppo", RewardKind::ThroughputEnergy), n)?;
    let mut agent = make_agent(&ctx.runtime, "rppo", 5, Some(weights))?;

    let mut env = LiveEnv::new(
        Testbed::cloudlab(),
        RewardKind::ThroughputEnergy,
        ParamBounds::default(),
        8,
        30,
        77,
    );
    println!("tuning Chameleon-trained R_PPO on CloudLab for {episodes} episodes...");
    let mut rewards = Vec::new();
    let mut throughputs = Vec::new();
    for ep in 0..episodes {
        let mut state = env.reset();
        let mut total = 0.0;
        let mut thr = 0.0;
        let mut steps = 0;
        loop {
            let a = agent.act(&state, true);
            let out = env.step(a);
            agent.observe(&state, a, out.reward, &out.state, out.done);
            total += out.reward;
            thr += out.throughput_gbps;
            steps += 1;
            state = out.state;
            if out.done {
                break;
            }
        }
        rewards.push(total);
        throughputs.push(thr / steps as f64);
        if (ep + 1) % 20 == 0 {
            let w = &rewards[rewards.len() - 20..];
            let t = &throughputs[throughputs.len() - 20..];
            println!(
                "  episodes {:>3}-{:>3}: mean reward {:+.2}, mean throughput {:.1} Gbps",
                ep + 1 - 19,
                ep + 1,
                stats::mean(w),
                stats::mean(t)
            );
        }
    }
    let early = stats::mean(&rewards[..20.min(rewards.len())]);
    let late = stats::mean(&rewards[rewards.len().saturating_sub(20)..]);
    println!("adaptation: early-phase reward {early:+.2} → late-phase {late:+.2}");
    Ok(())
}
