//! Build-time metadata for `sparta bench` reports.
//!
//! BENCH schema v2 records the compiler that produced the binary so that
//! anchor-vs-current comparisons can tell a code regression from a
//! toolchain change. The version string is baked in at compile time via
//! `SPARTA_RUSTC_VERSION` (read with `option_env!`, so the crate still
//! builds if this script is ever bypassed).

use std::process::Command;

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string());
    println!("cargo:rustc-env=SPARTA_RUSTC_VERSION={version}");
    // Re-run only when the compiler changes, not on every source edit.
    println!("cargo:rerun-if-env-changed=RUSTC");
}
