//! The serve checkpoint/restore contract, exhaustively: a churn script
//! (mid-run admissions, a pause window, a cancel, a lane lifetime) is run
//! uninterrupted while snapshotting at **every** MI boundary; each
//! snapshot is then restored into a fresh engine and replayed to
//! completion. The restored event stream must be byte-identical to the
//! uninterrupted run's remainder, and the final lane table / energy
//! totals must match bit-for-bit — for a single-host `Session` and a
//! 3-host incast `Cluster` alike.

use std::path::{Path, PathBuf};

use sparta::config::Paths;
use sparta::experiments::SpartaCtx;
use sparta::serve::{AdmitRec, OpKind, ServeEngine, ServeSpec};
use sparta::telemetry::event_json;
use sparta::util::json::Json;

const TOTAL_MIS: usize = 24;

fn fresh_root(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("sparta_it_serve_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn ctx_at(root: &Path) -> SpartaCtx {
    SpartaCtx::load(Paths::with_root(root)).expect("context loads")
}

fn spec(hosts: usize) -> ServeSpec {
    ServeSpec {
        scenario: "calm".to_string(),
        schedule: None,
        methods: vec!["rclone".to_string()],
        hosts,
        seed: 23,
        mi_s: 1.0,
        max_mis: TOTAL_MIS,
        observe_paused: true,
        faults: None,
    }
}

fn admit(method: &str, files: usize, life: Option<usize>) -> OpKind {
    OpKind::Admit(AdmitRec {
        method: method.to_string(),
        files,
        // 2 GiB files: big enough that every lane is still moving bytes
        // when its pause window or cancel boundary arrives.
        file_bytes: 2 << 30,
        name: None,
        seed: None,
        max_lifetime_mis: life,
    })
}

/// The churn script every run replays: admissions land mid-run, lane 0
/// takes a pause window, lane 2 carries a lifetime that fires at MI 16,
/// lane 1 takes an explicit cancel.
fn churn(engine: &mut ServeEngine) {
    engine.enqueue(admit("rclone", 2, None), Some(0)).unwrap();
    engine.enqueue(admit("2-phase", 2, Some(16)), Some(3)).unwrap();
    engine.enqueue(admit("rclone", 6, Some(9)), Some(7)).unwrap();
    engine.enqueue(OpKind::Pause(0), Some(10)).unwrap();
    engine.enqueue(OpKind::Resume(0), Some(14)).unwrap();
    engine.enqueue(OpKind::Cancel(1), Some(18)).unwrap();
}

fn step_lines(engine: &mut ServeEngine) -> Vec<String> {
    let mut events = Vec::new();
    engine.step(&mut events).unwrap();
    events.iter().map(|ev| event_json(ev).to_string()).collect()
}

/// The parts of `status` that summarize the whole run (the "final
/// report"): MI/time cursor, host energy, and the full lane table. The
/// epoch-JFI series is deliberately excluded — it tracks fairness since
/// (re)start, so a restored engine reports only its own tail.
fn report(engine: &ServeEngine) -> String {
    let s = engine.status_json();
    let mut parts = Vec::new();
    for key in ["mi", "time_s", "host_energy_j", "lanes", "rails"] {
        if let Some(v) = s.get(key) {
            parts.push(format!("{key}={v}"));
        }
    }
    parts.join(" ")
}

/// Run the script uninterrupted, snapshotting at every boundary; restore
/// every snapshot and demand byte-identity of the remaining stream and of
/// the final report.
fn snapshot_everywhere_roundtrip(hosts: usize, tag: &str) {
    let root = fresh_root(tag);
    let mut reference = ServeEngine::new(ctx_at(&root), spec(hosts), 1).unwrap();
    churn(&mut reference);

    let mut snaps = vec![reference.snapshot().unwrap()];
    let mut per_mi: Vec<Vec<String>> = Vec::new();
    for _ in 0..TOTAL_MIS {
        per_mi.push(step_lines(&mut reference));
        snaps.push(reference.snapshot().unwrap());
    }
    let final_report = report(&reference);
    let total_events: usize = per_mi.iter().map(Vec::len).sum();
    assert!(total_events > 0, "churn script produced no events");

    for (boundary, snap) in snaps.into_iter().enumerate() {
        let mut restored = ServeEngine::restore(ctx_at(&root), snap, 1).unwrap();
        assert_eq!(restored.mi(), boundary, "restore landed on the wrong boundary");
        let mut tail = Vec::new();
        for _ in boundary..TOTAL_MIS {
            tail.extend(step_lines(&mut restored));
        }
        let expected: Vec<String> = per_mi[boundary..].concat();
        assert_eq!(
            tail, expected,
            "hosts={hosts}: stream diverged after restoring at MI {boundary}"
        );
        assert_eq!(
            report(&restored),
            final_report,
            "hosts={hosts}: final report diverged after restoring at MI {boundary}"
        );
    }
}

#[test]
fn session_snapshot_at_every_boundary_replays_bit_identically() {
    snapshot_everywhere_roundtrip(1, "session_everywhere");
}

#[test]
fn cluster_snapshot_at_every_boundary_replays_bit_identically() {
    snapshot_everywhere_roundtrip(3, "cluster_everywhere");
}

/// Snapshots survive the disk: save → load → restore stays bit-identical,
/// and the file round-trips every `f64` through the hex-bits codec (a
/// reload of the saved file re-serializes to the same bytes).
#[test]
fn snapshot_file_roundtrip_is_lossless() {
    let root = fresh_root("file_roundtrip");
    let mut reference = ServeEngine::new(ctx_at(&root), spec(1), 1).unwrap();
    churn(&mut reference);
    let mut head = Vec::new();
    for _ in 0..12 {
        head.extend(step_lines(&mut reference));
    }
    assert!(!head.is_empty());

    let path = root.join("mid.snap.json");
    let snap = reference.snapshot().unwrap();
    snap.save(&path).unwrap();
    let loaded = sparta::serve::ServeSnapshot::load(&path).unwrap();
    assert_eq!(loaded.to_json().to_string(), snap.to_json().to_string());
    let reparsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(reparsed.to_string(), snap.to_json().to_string());

    let mut tail_ref = Vec::new();
    for _ in 12..TOTAL_MIS {
        tail_ref.extend(step_lines(&mut reference));
    }
    let mut restored = ServeEngine::restore(ctx_at(&root), loaded, 1).unwrap();
    let mut tail = Vec::new();
    for _ in 12..TOTAL_MIS {
        tail.extend(step_lines(&mut restored));
    }
    assert_eq!(tail, tail_ref, "disk round-trip changed the stream");
}
