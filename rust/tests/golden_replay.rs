//! Golden replay: the arena hot-loop rewrite changes **speed, not bytes**.
//!
//! The pre-arena `Flow → Task → Vec<CubicStream>` loop is kept in-tree,
//! frozen, as `net::baseline::BaselineSim`. These tests drive the real
//! report pipelines on both loops over identical seeded workloads and
//! assert the serialized reports are **byte-for-byte** equal:
//!
//! * fleet `churn-heavy` at 128 lanes, trials sharded over `--jobs 4`
//!   (mid-run admission, forced departures, shared host-ledger energy with
//!   the per-trial conservation assert live);
//! * `compare --scenario all` over the artifact-free methods (every
//!   registered scenario topology through the batch/Controller path);
//! * a raw session churn script with external pause/resume and observed
//!   paused MIs, compared event-by-event.
//!
//! Any float produced in a different order, any skipped or extra RNG draw,
//! any reordered event breaks these comparisons. CI's bench lane runs this
//! suite as its report-drift gate.

use sparta::baselines::{FalconMp, StaticTool, TwoPhase};
use sparta::config::Paths;
use sparta::coordinator::{Event, LaneId, LaneSpec, Session, SessionBuilder};
use sparta::experiments::runner::cell_seed;
use sparta::experiments::{fig6, fleet, make_optimizer, Scale, SpartaCtx};
use sparta::net::baseline::BaselineSim;
use sparta::scenarios::{ArrivalSchedule, Scenario};
use sparta::transfer::TransferJob;

/// Methods that need no trained weights or AOT artifacts.
const METHODS: [&str; 4] = ["rclone", "escp", "falcon_mp", "2-phase"];

fn methods() -> Vec<String> {
    METHODS.iter().map(|m| m.to_string()).collect()
}

/// Fleet churn-heavy at 128 lanes, 160-MI horizon, trials over 4 workers —
/// the arena loop and the frozen baseline loop must serialize identically.
#[test]
fn fleet_churn_heavy_128_lanes_jobs4_is_byte_identical_to_pre_arena_loop() {
    let sched = ArrivalSchedule::churn_heavy_scaled(128, 160);
    let run = |baseline_loop: bool| {
        let opts = fleet::FleetOpts { baseline_loop, ..fleet::FleetOpts::default() };
        let report =
            fleet::run(&Paths::resolve(), &sched, &methods(), Scale::Quick, 42, 4, opts)
                .expect("fleet run");
        let lanes = report.trials.iter().map(|t| t.lanes.len()).max().unwrap_or(0);
        assert!(lanes >= 100, "scaled schedule admitted only {lanes} lanes");
        fleet::to_json(&report).to_string()
    };
    let arena = run(false);
    let baseline = run(true);
    assert!(
        arena == baseline,
        "fleet report bytes drifted from the pre-arena loop (len {} vs {})",
        arena.len(),
        baseline.len()
    );
}

/// `compare --scenario all` (the fig6 matrix) on the arena loop vs the
/// same cells replayed one by one on the baseline loop through the same
/// Controller path with identity-derived seeds.
#[test]
fn compare_all_scenarios_is_byte_identical_to_pre_arena_loop() {
    let paths = Paths::resolve();
    let scenarios = Scenario::all();
    let methods = methods();
    let arena = fig6::run(&paths, &scenarios, &methods, Scale::Quick, 42, 4).expect("fig6 run");
    let arena_bytes = fig6::to_json(&arena).to_string();

    // Replay every (scenario, method, trial) cell on the baseline loop —
    // the same workload, seeding and report assembly as fig6::run.
    let ctx = SpartaCtx::load(paths).expect("ctx");
    let (files, bytes) = Scale::Quick.workload();
    let mut cells: Vec<fig6::Cell> = Vec::new();
    for sc in &scenarios {
        for method in &methods {
            let mut cell = fig6::Cell {
                method: method.clone(),
                scenario: sc.name.to_string(),
                throughput_gbps: Vec::new(),
                energy_kj: Vec::new(),
                duration_s: Vec::new(),
            };
            for trial in 0..Scale::Quick.trials() {
                let seed = cell_seed(42, &format!("{}/{}", sc.name, method), trial as u64);
                let (opt, engine, reward) = make_optimizer(&ctx, method, seed).expect("optimizer");
                let mut ctl = sc
                    .controller()
                    .job(TransferJob::files(files, bytes))
                    .engine(engine)
                    .reward(reward)
                    .seed(seed)
                    .substrate(Box::new(BaselineSim::from_topology(
                        sc.testbed.clone(),
                        &sc.topology,
                        seed,
                    )))
                    .build();
                let report = ctl.run(opt, seed);
                let lane = report.lane();
                cell.throughput_gbps.push(lane.avg_throughput_gbps());
                cell.duration_s.push(lane.duration_s);
                if sc.testbed.has_energy_counters {
                    cell.energy_kj.push(lane.total_energy_j / 1000.0);
                }
            }
            cells.push(cell);
        }
    }
    let baseline_bytes = fig6::to_json(&cells).to_string();
    assert!(
        arena_bytes == baseline_bytes,
        "compare report bytes drifted from the pre-arena loop (len {} vs {})",
        arena_bytes.len(),
        baseline_bytes.len()
    );
}

/// A session churn script — staggered admits, external pause/resume,
/// cancel, observed paused MIs on host-resolved rails — replays the exact
/// event stream on both loops.
#[test]
fn session_churn_script_event_streams_are_identical() {
    let sc = Scenario::by_name("chameleon").expect("chameleon scenario");
    let build = |baseline_loop: bool| -> Session {
        let mut b: SessionBuilder =
            sc.session_host_resolved().observe_paused(true).seed(1234);
        if baseline_loop {
            b = b.substrate(Box::new(BaselineSim::from_topology(
                sc.testbed.clone(),
                &sc.topology,
                1234,
            )));
        }
        b.build()
    };
    let script = |mut s: Session| -> Vec<Event> {
        let mut all = Vec::new();
        let mut events = Vec::new();
        // Jobs sized so no lane can complete before its scripted pause/
        // cancel point even at full line rate (10 Gbps = 1.25 GB/MI).
        let a = s.admit(
            LaneSpec::new(Box::new(StaticTool::rclone()), TransferJob::files(48, 256 << 20))
                .named("a"),
        );
        let b = s.admit(
            LaneSpec::new(Box::new(FalconMp::new()), TransferJob::files(160, 256 << 20))
                .named("b"),
        );
        for mi in 0..60 {
            if mi == 5 {
                s.admit(
                    LaneSpec::new(Box::new(TwoPhase::new()), TransferJob::files(24, 256 << 20))
                        .named("late"),
                );
            }
            if mi == 8 {
                assert!(s.pause(a));
            }
            if mi == 14 {
                assert!(s.resume(a));
            }
            if mi == 20 {
                assert!(s.cancel(b));
            }
            s.step_into(&mut events);
            all.extend(events.drain(..));
            if s.is_idle() {
                break;
            }
        }
        all
    };
    let arena = script(build(false));
    let baseline = script(build(true));
    assert_eq!(arena.len(), baseline.len(), "event counts diverged");
    for (i, (x, y)) in arena.iter().zip(baseline.iter()).enumerate() {
        assert_eq!(x, y, "event {i} diverged between arena and baseline loops");
    }
    // The script must actually have exercised the interesting paths.
    assert!(arena.iter().any(|e| matches!(e, Event::Paused { .. })));
    assert!(arena
        .iter()
        .any(|e| matches!(e, Event::MiCompleted { record, .. } if record.paused)));
    assert!(arena.iter().any(|e| matches!(e, Event::Departed { lane, .. } if *lane == LaneId(1))));
}
