//! End-to-end integration: the full pipeline — exploration on the simulated
//! testbed → cluster emulator → offline DRL training through AOT-compiled
//! HLO → evaluation transfer — composes and beats the static baseline.
//!
//! Uses DQN (the fastest-training agent) with a reduced budget so the whole
//! test completes in well under a minute. Skipped when artifacts are absent.

use sparta::agents::{make_agent, DrlOptimizer};
use sparta::baselines::StaticTool;
use sparta::config::Paths;
use sparta::coordinator::{Controller, ParamBounds, RewardKind};
use sparta::emulator::ClusterEnv;
use sparta::net::Testbed;
use sparta::runtime::Runtime;
use sparta::trainer::{collect_transitions, train_offline, TrainConfig};
use sparta::transfer::{EngineProfile, TransferJob};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn full_pipeline_trains_and_transfers() {
    let Some(rt) = runtime() else { return };
    let tb = Testbed::chameleon();

    // 1. Exploration phase on the live substrate.
    let transitions = collect_transitions(&tb, 2, 120, 91);
    assert!(transitions.len() > 150, "too few transitions: {}", transitions.len());

    // 2. Cluster-lookup emulator.
    let mut env = ClusterEnv::new(
        transitions,
        32,
        ParamBounds::default(),
        RewardKind::ThroughputEnergy,
        8,
        48,
        91,
    );
    assert!(env.n_clusters() > 1);

    // 3. Offline training through the AOT HLO train step.
    let mut agent = make_agent(&rt, "dqn", 91, None).unwrap();
    let cfg = TrainConfig { max_env_steps: 6_000, ..TrainConfig::default() };
    let stats = train_offline(&mut agent, &mut env, &cfg);
    assert!(stats.train_calls > 100, "agent barely trained: {}", stats.train_calls);
    // Reward trend: later episodes no worse than the earliest ones.
    let k = stats.reward_curve.len() / 4;
    let early: f64 = stats.reward_curve[..k].iter().sum::<f64>() / k as f64;
    let late: f64 = stats.reward_curve[stats.reward_curve.len() - k..].iter().sum::<f64>() / k as f64;
    assert!(
        late >= early - 3.0,
        "training degraded the policy: early={early:.2} late={late:.2}"
    );

    // 4. Evaluation transfer vs the static baseline on the same conditions.
    let trained = agent.params().to_vec();
    let run = |opt: Box<dyn sparta::coordinator::Optimizer>, engine: EngineProfile| {
        let mut ctl = Controller::builder(tb.clone())
            .job(TransferJob::files(16, 256 << 20))
            .engine(engine)
            .reward(RewardKind::ThroughputEnergy)
            .seed(17)
            .build();
        let report = ctl.run(opt, 17);
        let lane = report.lane();
        assert!(lane.completed);
        (lane.avg_throughput_gbps(), lane.energy_per_gb())
    };

    let agent_eval = make_agent(&rt, "dqn", 5, Some(trained)).unwrap();
    let (sparta_thr, sparta_jpg) =
        run(Box::new(DrlOptimizer::new(agent_eval, "dqn-te")), EngineProfile::efficient());
    let (rclone_thr, rclone_jpg) = run(Box::new(StaticTool::rclone()), EngineProfile::rclone());

    // The paper's qualitative claim at miniature scale: the DRL agent beats
    // the static tool on throughput and energy-per-byte.
    assert!(
        sparta_thr > rclone_thr,
        "DRL {sparta_thr:.2} Gbps should beat rclone {rclone_thr:.2} Gbps"
    );
    assert!(
        sparta_jpg < rclone_jpg * 1.05,
        "DRL J/GB {sparta_jpg:.0} should not exceed rclone {rclone_jpg:.0}"
    );
}

#[test]
fn fabric_transfer_reports_throughput_only() {
    let Some(rt) = runtime() else { return };
    let agent = make_agent(&rt, "dqn", 3, None).unwrap();
    let mut ctl = Controller::builder(Testbed::fabric())
        .job(TransferJob::files(8, 256 << 20))
        .seed(3)
        .build();
    let report = ctl.run(Box::new(DrlOptimizer::new(agent, "dqn")), 3);
    let lane = report.lane();
    assert!(lane.completed);
    assert!(lane.avg_throughput_gbps() > 0.0);
    assert_eq!(lane.total_energy_j, 0.0, "FABRIC has no energy counters");
}
