//! Integration: the read-only `WeightSnapshot` matches the `WeightStore`
//! bit-for-bit, the artifact-free `linq` fallback trains through the full
//! scenario-aware pipeline, and the snapshot-backed experiments (Fig. 4,
//! the generalization matrix) are bit-identical at any `--jobs` count.
//!
//! Everything here runs without AOT artifacts — that is the point: the
//! train → snapshot → evaluate plumbing must be exercisable on a fresh
//! checkout (and in CI).

use sparta::config::Paths;
use sparta::coordinator::{Optimizer as _, RewardKind};
use sparta::experiments::{
    fig4, generalize, make_optimizer, train_pipeline, Scale, SpartaCtx, TrainSource,
};
use sparta::net::Testbed;
use sparta::runtime::{WeightSnapshot, WeightStore};
use sparta::scenarios::Scenario;

fn temp_root(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sparta_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Snapshot returns bit-identical params to `WeightStore::load` for every
/// saved name, including scenario-scoped (`@`) ones.
#[test]
fn snapshot_equals_store_for_all_saved_names() {
    let root = temp_root("snap_vs_store");
    let store = WeightStore::new(root.join("data/weights"));
    let names = ["linq_te", "linq_fe@lossy-wan", "rppo_te@calm"];
    for (k, name) in names.iter().enumerate() {
        let data: Vec<f32> = (0..120 + k).map(|i| ((i * 7 + k) as f32 * 0.123).cos()).collect();
        store.save(name, &data).unwrap();
    }
    let snap = WeightSnapshot::of_store(&store).unwrap();
    assert_eq!(snap.len(), names.len());
    for name in names {
        let a = store.load(name, 0).unwrap();
        let b = snap.params(name, 0).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a), bits(&b), "{name}");
    }
}

/// The artifact-free pipeline end to end: train `linq` on a bare testbed,
/// then regenerate Fig. 4 for it at 1 and 4 workers — the `AlgoCell`
/// vectors must be identical (the snapshot is shared, per-cell seeding is
/// identity-derived).
#[test]
fn fig4_cells_identical_across_jobs() {
    let root = temp_root("fig4_jobs");
    let paths = Paths::with_root(&root);
    let ctx = SpartaCtx::load(paths.clone()).unwrap();
    let tb = Testbed::chameleon();
    train_pipeline(
        &ctx,
        "linq",
        RewardKind::ThroughputEnergy,
        TrainSource::Testbed(&tb),
        Scale::Quick,
        42,
    )
    .unwrap();

    let run = |jobs: usize| {
        fig4::run(&paths, RewardKind::ThroughputEnergy, &["linq"], Scale::Quick, 7, jobs).unwrap()
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial, parallel, "fig4 diverged between --jobs 1 and --jobs 4");
    // Sanity: one sim + one real cell, with real work in both.
    assert_eq!(serial.len(), 2);
    for cell in &serial {
        assert_eq!(cell.algo, "linq");
        assert!(!cell.throughput_gbps.is_empty());
        assert!(cell.throughput_gbps.iter().all(|t| t.is_finite() && *t >= 0.0));
    }
}

/// Scenario-aware training writes scoped weights, and the generalization
/// matrix covers every requested (train × eval) cell identically at any
/// thread count.
#[test]
fn generalize_matrix_is_jobs_invariant() {
    let root = temp_root("gen_jobs");
    let paths = Paths::with_root(&root);
    let train_on = vec![
        Scenario::by_name("calm").unwrap(),
        Scenario::by_name("nic-limited").unwrap(),
    ];
    let eval_on = vec![
        Scenario::by_name("calm").unwrap(),
        Scenario::by_name("nic-limited").unwrap(),
        Scenario::by_name("receiver-limited").unwrap(),
    ];
    let run = |jobs: usize| {
        generalize::run(
            &paths,
            "linq",
            RewardKind::ThroughputEnergy,
            &train_on,
            &eval_on,
            Scale::Quick,
            9,
            jobs,
        )
        .unwrap()
    };
    let a = run(1);
    let b = run(3);
    assert_eq!(a, b, "generalize diverged between --jobs 1 and --jobs 3");
    assert_eq!(a.cells.len(), train_on.len() * eval_on.len());
    for sc in &eval_on {
        assert!(a.eval_scenarios.contains(&sc.name.to_string()));
    }
    // Scenario training persisted scoped weight files, visible to a fresh
    // snapshot.
    let snap = WeightSnapshot::load_dir(paths.weights()).unwrap();
    for sc in &train_on {
        let name = sparta::experiments::scoped_weight_name(
            "linq",
            RewardKind::ThroughputEnergy,
            sc.name,
        );
        assert!(snap.contains(&name), "missing {name}");
    }
    // Cells did real work: throughput is non-negative and finite.
    for c in &a.cells {
        assert!(c.mean_throughput_gbps.is_finite() && c.mean_throughput_gbps >= 0.0);
    }
}

/// `make_optimizer` resolves DRL-style method names through the shared
/// snapshot (never the disk store) — the path `sparta compare
/// --methods linq:te` takes in CI.
#[test]
fn method_lane_loads_from_snapshot() {
    let root = temp_root("lane");
    let paths = Paths::with_root(&root);
    let ctx = SpartaCtx::load(paths.clone()).unwrap();
    let tb = Testbed::chameleon();
    train_pipeline(
        &ctx,
        "linq",
        RewardKind::ThroughputEnergy,
        TrainSource::Testbed(&tb),
        Scale::Quick,
        3,
    )
    .unwrap();
    // The pre-training snapshot must not see the new weights (read-only,
    // load-once semantics)...
    assert!(make_optimizer(&ctx, "linq:te", 5).is_err());
    // ...while a fresh context does.
    let ctx = SpartaCtx::load(paths).unwrap();
    let (opt, _engine, reward) = make_optimizer(&ctx, "linq:te", 5).unwrap();
    assert_eq!(reward, RewardKind::ThroughputEnergy);
    assert_eq!(opt.name(), "linq-te");
}
