//! Integration: the scenario registry drives full control-loop runs through
//! the `Substrate` trait, and the parallel trial runner reproduces serial
//! results bit-for-bit at any thread count. Artifact-free: baselines only.

use sparta::baselines::StaticTool;
use sparta::coordinator::{RewardKind, RunReport};
use sparta::experiments::parallel_map;
use sparta::net::Substrate;
use sparta::scenarios::Scenario;
use sparta::transfer::{EngineProfile, TransferJob};

/// One full (scenario, trial) transfer with a static baseline.
fn run_trial(scenario: &Scenario, trial_seed: u64) -> RunReport {
    let mut ctl = scenario
        .controller()
        .job(TransferJob::files(16, 256 << 20))
        .engine(EngineProfile::efficient())
        .reward(RewardKind::ThroughputEnergy)
        .max_mis(600)
        .seed(trial_seed)
        .build();
    ctl.run(Box::new(StaticTool::efficient_static(4, 4)), trial_seed)
}

/// Every registered scenario builds a substrate and runs 5 MIs
/// deterministically under each of two seeds.
#[test]
fn registry_scenarios_run_deterministically_through_the_trait() {
    for sc in Scenario::all() {
        for seed in [11u64, 12] {
            let run = |s: u64| {
                let mut sub: Box<dyn Substrate> = sc.substrate(s);
                let id = sub.add_flow(4, 4, None);
                (0..5).map(|_| sub.run_mi(1.0)[id.0]).collect::<Vec<_>>()
            };
            assert_eq!(run(seed), run(seed), "{} seed {}", sc.name, seed);
        }
    }
}

/// The (scenario × trial) grid produces bit-identical `RunReport`s whether
/// sharded over 1 worker or several.
#[test]
fn parallel_runner_reports_are_bit_identical_across_thread_counts() {
    let scenarios = [
        Scenario::by_name("calm").unwrap(),
        Scenario::by_name("receiver-limited").unwrap(),
    ];
    let mut cells = Vec::new();
    for sc in &scenarios {
        for trial in 0..2u64 {
            cells.push((sc.clone(), 1000 + trial));
        }
    }
    let serial = parallel_map(&cells, 1, |_, (sc, seed)| run_trial(sc, *seed));
    for jobs in [2, 4] {
        let parallel = parallel_map(&cells, jobs, |_, (sc, seed)| run_trial(sc, *seed));
        assert_eq!(serial, parallel, "jobs={jobs} diverged from serial");
    }
    // Sanity: the runs did real work.
    for report in &serial {
        assert!(report.lane().completed);
        assert!(report.avg_throughput_gbps() > 0.0);
    }
}

/// Scenario conditions actually differ: a receiver-limited path cannot match
/// the calm single-bottleneck path's throughput for the same workload.
#[test]
fn scenarios_shape_observed_performance() {
    let calm = run_trial(&Scenario::by_name("calm").unwrap(), 7);
    let nic = run_trial(&Scenario::by_name("nic-limited").unwrap(), 7);
    assert!(calm.lane().completed && nic.lane().completed);
    assert!(
        nic.avg_throughput_gbps() < calm.avg_throughput_gbps(),
        "nic-limited {:.2} should trail calm {:.2}",
        nic.avg_throughput_gbps(),
        calm.avg_throughput_gbps()
    );
    // The 4 Gbps NIC stage is a hard ceiling.
    assert!(nic.avg_throughput_gbps() <= 4.0 + 1e-6);
}
