//! Integration: the Rust runtime loads and executes every AOT artifact.
//!
//! Requires `make artifacts` to have run; tests are skipped (pass with a
//! note) when the artifacts directory is missing so `cargo test` stays
//! usable on a fresh checkout.

use sparta::agents::{self, DrlAgent};
use sparta::runtime::Runtime;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn manifest_lists_all_graphs() {
    let Some(rt) = runtime() else { return };
    for algo in agents::ALGOS {
        assert!(rt.manifest.algo(algo).is_ok(), "missing algo {algo}");
        assert!(rt.manifest.graph(&format!("{algo}_forward")).is_ok());
        assert!(rt.manifest.graph(&format!("{algo}_train")).is_ok());
    }
    assert!(rt.manifest.graph("kmeans_assign").is_ok());
    assert_eq!(rt.manifest.global("features").unwrap() as usize, sparta::coordinator::FEATURES);
}

#[test]
fn forward_graphs_execute_and_are_finite() {
    let Some(rt) = runtime() else { return };
    for algo in agents::ALGOS {
        let exe = rt.compile(&format!("{algo}_forward")).expect(algo);
        let params = agents::init_params(&rt, algo).expect(algo);
        let obs = vec![0.1f32; exe.spec.arg_len(1)];
        let out = exe.call(&[&params, &obs]).expect(algo);
        assert!(!out.is_empty());
        for o in &out {
            assert!(o.iter().all(|x| x.is_finite()), "{algo}: non-finite output");
        }
        // Q/logit heads emit N_ACTIONS values; DDPG emits the action pair.
        let head = &out[0];
        if algo == "ddpg" {
            assert_eq!(head.len(), 2);
            assert!(head.iter().all(|x| x.abs() <= 2.0 + 1e-5));
        } else {
            assert_eq!(head.len(), sparta::coordinator::N_ACTIONS);
        }
    }
}

#[test]
fn dqn_train_step_changes_params_and_reduces_td_loss() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("dqn_train").unwrap();
    let fwd = rt.compile("dqn_forward").unwrap();
    let params = agents::init_params(&rt, "dqn").unwrap();
    let n = params.len();
    let batch = rt.manifest.algo("dqn").unwrap().hparam("batch").unwrap() as usize;
    let obs_len = fwd.spec.arg_len(1);

    let obs = vec![0.2f32; batch * obs_len];
    let act = vec![1.0f32; batch];
    let rew = vec![1.0f32; batch];
    let done = vec![1.0f32; batch]; // terminal: target = reward exactly
    let (mut p, mut m, mut v) = (params.clone(), vec![0.0f32; n], vec![0.0f32; n]);
    let mut losses = Vec::new();
    for step in 1..=50 {
        let s = [step as f32];
        let out = exe
            .call(&[&p, &params, &m, &v, &s, &obs, &act, &rew, &obs, &done])
            .unwrap();
        let mut it = out.into_iter();
        p = it.next().unwrap();
        m = it.next().unwrap();
        v = it.next().unwrap();
        losses.push(it.next().unwrap()[0]);
    }
    assert_ne!(p, params, "params unchanged after training");
    assert!(
        losses[49] < losses[0] * 0.5,
        "TD loss should fall: first={} last={}",
        losses[0],
        losses[49]
    );
    // After training toward target=1 for action 1, Q(s, 1) should approach 1.
    let q = fwd.call(&[&p, &obs[0..obs_len]]).unwrap();
    assert!((q[0][1] - 1.0).abs() < 0.35, "q1={}", q[0][1]);
}

#[test]
fn kmeans_artifact_matches_rust_kmeans() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("kmeans_assign").unwrap();
    let n = rt.manifest.global("kmeans_n").unwrap() as usize;
    let k = rt.manifest.global("kmeans_k").unwrap() as usize;
    let d = rt.manifest.global("kmeans_d").unwrap() as usize;

    let mut rng = sparta::util::Rng::new(5);
    let points: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let centroids: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
    let out = exe.call(&[&points, &centroids]).unwrap();
    let assign = &out[0];
    assert_eq!(assign.len(), n);

    // Compare against the Rust emulator's own assignment.
    let km = sparta::emulator::KMeans {
        centroids: centroids.clone(),
        k,
        dim: d,
        assignments: vec![],
    };
    for i in 0..n {
        let rust_a = km.assign(&points[i * d..(i + 1) * d]);
        assert_eq!(assign[i] as usize, rust_a, "disagreement at point {i}");
    }
}

#[test]
fn agents_act_and_learn_through_pjrt() {
    let Some(rt) = runtime() else { return };
    for algo in agents::ALGOS {
        let mut agent = agents::make_agent(&rt, algo, 7, None).expect(algo);
        let state_len = rt
            .compile(&format!("{algo}_forward"))
            .unwrap()
            .spec
            .arg_len(1);
        let s0 = vec![0.1f32; state_len];
        let s1 = vec![0.2f32; state_len];
        let mut acted = [false; 5];
        // Enough steps to trigger at least one HLO train call for the
        // off-policy agents (learn_start is 100-200).
        for i in 0..260 {
            let a = agent.act(&s0, true);
            assert!(a < 5, "{algo}: action out of range");
            acted[a] = true;
            agent.observe(&s0, a, if a == 1 { 1.0 } else { -0.1 }, &s1, i % 20 == 19);
        }
        assert!(agent.xla_seconds() > 0.0, "{algo}: no XLA time recorded");
        if algo != "ppo" && algo != "rppo" {
            assert!(agent.train_steps() > 0, "{algo}: never trained");
        }
    }
}
