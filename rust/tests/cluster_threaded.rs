//! The parallel-cluster contract on the real driver paths: pooled
//! intra-step execution (`--step-threads N`) must be a pure wall-clock
//! knob. A churn-heavy 4-host fleet run must produce a byte-identical
//! report at any worker count, and a threaded serve cluster must
//! snapshot mid-churn and restore — at a *different* thread count — into
//! a byte-identical event-stream tail.

use std::path::{Path, PathBuf};

use sparta::config::Paths;
use sparta::experiments::{fleet, Scale, SpartaCtx};
use sparta::scenarios::ArrivalSchedule;
use sparta::serve::{AdmitRec, OpKind, ServeEngine, ServeSpec};
use sparta::telemetry::event_json;

fn fresh_root(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("sparta_it_threaded_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    root
}

/// Churn-heavy incast fleet, serial vs pooled: the report (lane tables,
/// per-host rails, epoch JFI, completion distribution — everything
/// `fleet::to_json` serializes) must not move by a byte when the 4-host
/// step fans out over 4 workers.
#[test]
fn fleet_report_identical_across_step_threads() {
    let root = fresh_root("fleet");
    let paths = Paths::with_root(&root);
    let schedule = ArrivalSchedule::by_name("churn-heavy").unwrap();
    let methods: Vec<String> = vec!["2-phase".into(), "rclone".into()];
    let run = |step_threads: usize| {
        let opts = fleet::FleetOpts {
            observe_paused: true,
            hosts: 4,
            step_threads,
            ..fleet::FleetOpts::default()
        };
        let report = fleet::run(&paths, &schedule, &methods, Scale::Quick, 9, 1, opts).unwrap();
        fleet::to_json(&report).to_string()
    };
    let serial = run(1);
    let pooled = run(4);
    assert_eq!(
        serial, pooled,
        "fleet report differs between --step-threads 1 and --step-threads 4"
    );
    // Oversubscribed pools are clamped per MI, never divergent.
    assert_eq!(serial, run(16), "report differs at --step-threads 16");
}

fn ctx_at(root: &Path) -> SpartaCtx {
    SpartaCtx::load(Paths::with_root(root)).expect("context loads")
}

const TOTAL_MIS: usize = 20;
const SNAP_AT: usize = 10;

fn spec() -> ServeSpec {
    ServeSpec {
        scenario: "calm".to_string(),
        schedule: None,
        methods: vec!["rclone".to_string()],
        hosts: 3,
        seed: 31,
        mi_s: 1.0,
        max_mis: TOTAL_MIS,
        observe_paused: true,
        faults: None,
    }
}

/// Mid-run admissions, a pause window and a cancel — enough churn that
/// the snapshot boundary lands with lanes in every state.
fn churn(engine: &mut ServeEngine) {
    let admit = |method: &str, files: usize, life: Option<usize>| {
        OpKind::Admit(AdmitRec {
            method: method.to_string(),
            files,
            file_bytes: 2 << 30,
            name: None,
            seed: None,
            max_lifetime_mis: life,
        })
    };
    engine.enqueue(admit("rclone", 3, None), Some(0)).unwrap();
    engine.enqueue(admit("2-phase", 2, Some(14)), Some(2)).unwrap();
    engine.enqueue(admit("rclone", 4, Some(8)), Some(5)).unwrap();
    engine.enqueue(OpKind::Pause(0), Some(7)).unwrap();
    engine.enqueue(OpKind::Resume(0), Some(12)).unwrap();
    engine.enqueue(OpKind::Cancel(1), Some(15)).unwrap();
}

fn step_lines(engine: &mut ServeEngine) -> Vec<String> {
    let mut events = Vec::new();
    engine.step(&mut events).unwrap();
    events.iter().map(|ev| event_json(ev).to_string()).collect()
}

/// A 3-host cluster stepped by a 4-worker pool, snapshotted mid-churn and
/// restored with 2 workers: head + restored tail must equal the serial
/// uninterrupted stream byte-for-byte. The thread count is deliberately
/// different on every leg — it lives outside the snapshot.
#[test]
fn threaded_serve_snapshot_restores_bit_identically() {
    let root = fresh_root("serve");

    // Serial uninterrupted reference.
    let mut reference = ServeEngine::new(ctx_at(&root), spec(), 1).unwrap();
    churn(&mut reference);
    let mut full: Vec<String> = Vec::new();
    for _ in 0..TOTAL_MIS {
        full.extend(step_lines(&mut reference));
    }
    assert!(!full.is_empty(), "churn script produced no events");

    // Threaded run, interrupted at SNAP_AT.
    let mut threaded = ServeEngine::new(ctx_at(&root), spec(), 4).unwrap();
    churn(&mut threaded);
    let mut head: Vec<String> = Vec::new();
    for _ in 0..SNAP_AT {
        head.extend(step_lines(&mut threaded));
    }
    let snap = threaded.snapshot().unwrap();
    drop(threaded); // the pool dies with the engine

    let mut restored = ServeEngine::restore(ctx_at(&root), snap, 2).unwrap();
    assert_eq!(restored.mi(), SNAP_AT, "restore landed on the wrong boundary");
    let mut tail: Vec<String> = Vec::new();
    for _ in SNAP_AT..TOTAL_MIS {
        tail.extend(step_lines(&mut restored));
    }

    head.extend(tail);
    assert_eq!(
        head, full,
        "threaded snapshot/restore stream diverged from the serial uninterrupted run"
    );
}
