//! The fault-plane contract on the real driver paths: seeded chaos must
//! be a *deterministic* input, never a source of divergence. Every
//! preset's churn fleet must produce a byte-identical report at any
//! `--jobs` count; the host-crash preset must additionally survive the
//! full `--jobs` × `--step-threads` matrix. And a direct cluster drive
//! under host crashes must land every admitted lane — migrated off the
//! dead hosts with its transferred bytes intact — while Σ per-lane
//! energy still equals the host-truth ledger at 1e-9.

use std::path::{Path, PathBuf};

use sparta::baselines::StaticTool;
use sparta::config::Paths;
use sparta::coordinator::{Cluster, Event, LaneId, LaneSpec, Session, INCAST_RX_OVER_WAN};
use sparta::experiments::{fleet, Scale};
use sparta::faults::{FaultEvent, FaultOp, FaultPlan, FaultSchedule};
use sparta::net::{Testbed, Topology};
use sparta::scenarios::ArrivalSchedule;
use sparta::telemetry::event_json;
use sparta::transfer::TransferJob;

fn fresh_root(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("sparta_it_faults_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    root
}

/// One churn fleet run under `preset`, serialized the way `sparta fleet
/// --out` writes it. The fault plan is resolved per trial from the trial
/// seed, so the report must not move by a byte across worker layouts.
fn fleet_json(
    root: &Path,
    schedule: &ArrivalSchedule,
    preset: &'static FaultSchedule,
    jobs: usize,
    step_threads: usize,
) -> String {
    let paths = Paths::with_root(root);
    let methods: Vec<String> = vec!["2-phase".into(), "rclone".into()];
    let opts = fleet::FleetOpts {
        observe_paused: true,
        hosts: 4,
        step_threads,
        faults: Some(preset),
        ..fleet::FleetOpts::default()
    };
    let report = fleet::run(&paths, schedule, &methods, Scale::Quick, 9, jobs, opts).unwrap();
    fleet::to_json(&report).to_string()
}

/// Every registry preset, churn fleet, `--jobs 1` vs `--jobs 4`: the
/// failure history is identity-derived, so sharding trials across
/// workers must not change a byte of the report.
#[test]
fn every_preset_is_byte_identical_across_jobs() {
    let root = fresh_root("jobs");
    let schedule = ArrivalSchedule::by_name("churn-light").unwrap();
    for preset in FaultSchedule::all() {
        let serial = fleet_json(&root, &schedule, preset, 1, 1);
        let sharded = fleet_json(&root, &schedule, preset, 4, 1);
        assert_eq!(
            serial, sharded,
            "{}: report differs between --jobs 1 and --jobs 4",
            preset.name
        );
    }
}

/// The hardest preset gets the full matrix: host crashes force mid-run
/// lane migration, and the report must still be byte-identical across
/// `--jobs 1/4` × `--step-threads 1/4`. Also pins the recovery story:
/// every trial actually migrated lanes and quarantined both victims.
#[test]
fn host_crash_fleet_is_byte_identical_across_jobs_and_step_threads() {
    let root = fresh_root("matrix");
    let schedule = ArrivalSchedule::by_name("churn-heavy").unwrap();
    let preset = FaultSchedule::by_name("host-crash").unwrap();
    let base = fleet_json(&root, &schedule, preset, 1, 1);
    for (jobs, step_threads) in [(4, 1), (1, 4), (4, 4)] {
        assert_eq!(
            base,
            fleet_json(&root, &schedule, preset, jobs, step_threads),
            "host-crash report differs at --jobs {jobs} --step-threads {step_threads}"
        );
    }

    // Re-run once keeping the structured report to assert the recovery
    // counters (the byte-compares above prove this run equals them all).
    let paths = Paths::with_root(&root);
    let methods: Vec<String> = vec!["2-phase".into(), "rclone".into()];
    let opts = fleet::FleetOpts {
        observe_paused: true,
        hosts: 4,
        step_threads: 1,
        faults: Some(preset),
        ..fleet::FleetOpts::default()
    };
    let report = fleet::run(&paths, &schedule, &methods, Scale::Quick, 9, 1, opts).unwrap();
    assert_eq!(report.faults, Some("host-crash"));
    for trial in &report.trials {
        assert!(
            trial.migrated >= 1,
            "trial {}: host crashes produced no migrations",
            trial.trial
        );
        assert_eq!(
            trial.quarantined_hosts, 2,
            "trial {}: expected both crash victims quarantined",
            trial.trial
        );
        // Per-lane attributions still sum to the per-host ledger with two
        // hosts frozen mid-run: the crashed ledgers stop, and the migrated
        // lanes carry their spent energy to the surviving hosts' books.
        let lanes_j: f64 = trial.lanes.iter().map(|l| l.energy_kj * 1_000.0).sum();
        let hosts_j: f64 = trial.hosts.iter().map(|h| h.energy_j).sum();
        assert!(
            (lanes_j - hosts_j).abs() <= 1e-9 * hosts_j.max(1.0),
            "trial {}: lane energy {lanes_j} J != host ledger {hosts_j} J",
            trial.trial
        );
    }
}

/// Direct cluster drive, no lane lifetimes: 8 lanes on 4 hosts, two
/// hosts crash mid-transfer. Every lane must complete (the migrated ones
/// on their new hosts, bytes conserved), the event stream must be
/// byte-identical across step-thread counts, and Σ per-lane energy must
/// equal the host-truth ledger at 1e-9.
#[test]
fn host_crash_migration_completes_every_lane_and_conserves_energy() {
    const LANES: usize = 8;
    const FILES: usize = 16;
    const FILE_BYTES: u64 = 256 << 20;
    let total_bytes = (FILES as f64) * (FILE_BYTES as f64);

    let drive = |step_threads: usize| -> Vec<String> {
        let tb = Testbed::chameleon();
        let hosts = 4;
        let mut cluster = Cluster::build(hosts, 77, |h, host_seed| {
            Session::builder(tb.clone())
                .energy(tb.energy_hosts_of(h, hosts))
                .seed(host_seed)
                .topology(Topology::incast_host(&tb, hosts, INCAST_RX_OVER_WAN))
                .build()
        });
        cluster.set_step_threads(step_threads);
        for k in 0..LANES {
            cluster.admit(
                LaneSpec::new(
                    Box::new(StaticTool::efficient_static(4, 4)),
                    TransferJob::files(FILES, FILE_BYTES),
                )
                .named(format!("lane{k}")),
            );
        }
        // Hosts 1 and 2 die while every lane is still moving bytes; their
        // round-robin residents (lanes 1/5 and 2/6) must migrate.
        cluster.install_faults(FaultPlan {
            events: vec![
                FaultEvent { at_mi: 3, op: FaultOp::HostCrash { host: 2 } },
                FaultEvent { at_mi: 6, op: FaultOp::HostCrash { host: 1 } },
            ],
        });

        let mut events = Vec::new();
        let mut lines = Vec::new();
        let mut done = [false; LANES];
        let mut migrated = 0usize;
        for _ in 0..600 {
            cluster.step_into(&mut events);
            for ev in &events {
                lines.push(event_json(ev).to_string());
                match ev {
                    Event::Completed { lane, bytes_delivered, .. } => {
                        assert!(
                            *bytes_delivered >= total_bytes * 0.999,
                            "lane {} completed with bytes missing: {} < {}",
                            lane.0,
                            bytes_delivered,
                            total_bytes
                        );
                        done[lane.0] = true;
                    }
                    Event::Migrated { .. } => migrated += 1,
                    _ => {}
                }
            }
            if cluster.is_idle() {
                break;
            }
        }

        assert!(
            done.iter().all(|&d| d),
            "a lane never completed after the crashes (done = {done:?})"
        );
        assert!(migrated >= 2, "two host crashes produced {migrated} migrations");
        assert_eq!(cluster.quarantined_hosts(), 2);

        // Conservation: per-lane attributions (live + carried-from-crashed)
        // must reproduce the host-truth ledger exactly.
        let lanes_j: f64 = (0..LANES)
            .map(|k| cluster.lane_energy_j(LaneId(k)).expect("lane ledger survives migration"))
            .sum();
        let truth_j = cluster.host_energy_j();
        assert!(
            (lanes_j - truth_j).abs() <= 1e-9 * truth_j.max(1.0),
            "lane energy {lanes_j} J != host truth {truth_j} J after migration"
        );
        lines
    };

    let serial = drive(1);
    assert!(!serial.is_empty());
    assert_eq!(
        serial,
        drive(4),
        "crash-recovery event stream differs between step-threads 1 and 4"
    );
}
