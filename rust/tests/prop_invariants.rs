//! Property-based invariant tests (hand-rolled: seeded generators + many
//! random cases per property; proptest is unavailable offline).

use sparta::agents::rollout::{Rollout, RolloutStep};
use sparta::coordinator::reward::{diff_reward, utility, RewardConfig};
use sparta::coordinator::{FeatureWindow, Observation, ParamBounds, N_ACTIONS};
use sparta::emulator::{KMeans, Transition, TransitionStore};
use sparta::net::background::Background;
use sparta::net::{Link, NetworkSim, Testbed};
use sparta::util::stats::jain_fairness;
use sparta::util::Rng;

const CASES: usize = 200;

#[test]
fn prop_link_conserves_and_bounds_drops() {
    let mut rng = Rng::new(0xA1);
    for _ in 0..CASES {
        let cap = rng.range_f64(1.0, 100.0);
        let rtt = rng.range_f64(0.005, 0.2);
        let mut link = Link::new(cap, rtt, rng.range_f64(0.3, 2.0));
        for _ in 0..50 {
            let offered = rng.range_f64(0.0, cap * 4.0);
            let out = link.tick(offered, 0.05);
            assert!((0.0..=1.0).contains(&out.drop_frac), "drop={}", out.drop_frac);
            assert!((out.accept_frac + out.drop_frac - 1.0).abs() < 1e-9);
            assert!(out.queue_delay_s >= 0.0);
            assert!(link.queue_fill() <= 1.0 + 1e-9);
        }
    }
}

#[test]
fn prop_sim_goodput_never_exceeds_capacity() {
    let mut rng = Rng::new(0xB2);
    for case in 0..30 {
        let tb = match case % 3 {
            0 => Testbed::chameleon(),
            1 => Testbed::cloudlab(),
            _ => Testbed::fabric(),
        };
        let cap = tb.capacity_gbps;
        let mut sim = NetworkSim::new(tb, rng.next_u64())
            .with_background(Background::Constant { gbps: rng.range_f64(0.0, cap * 0.4) });
        let n_flows = 1 + rng.below(3);
        let ids: Vec<_> = (0..n_flows)
            .map(|_| sim.add_flow(1 + rng.below(16) as u32, 1 + rng.below(16) as u32, None))
            .collect();
        for _ in 0..15 {
            let m = sim.run_mi(1.0);
            let total: f64 = ids.iter().map(|id| m[id.0].throughput_gbps).sum();
            assert!(total <= cap * 1.02, "goodput {total} > capacity {cap}");
            for id in &ids {
                assert!(m[id.0].plr >= 0.0 && m[id.0].plr <= 1.0);
                assert!(m[id.0].rtt_s > 0.0);
            }
        }
    }
}

#[test]
fn prop_jfi_in_unit_interval_and_extremes() {
    let mut rng = Rng::new(0xC3);
    for _ in 0..CASES {
        let n = 1 + rng.below(10);
        let thr: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 50.0)).collect();
        let j = jain_fairness(&thr);
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jfi={j}");
        // Equal flows -> exactly 1.
        let eq = vec![rng.range_f64(0.1, 10.0); n];
        assert!((jain_fairness(&eq) - 1.0).abs() < 1e-9);
    }
}

#[test]
fn prop_action_sequences_stay_in_bounds() {
    let mut rng = Rng::new(0xD4);
    for _ in 0..CASES {
        let bounds = ParamBounds {
            cc_min: 1 + rng.below(3) as u32,
            cc_max: 8 + rng.below(24) as u32,
            p_min: 1 + rng.below(3) as u32,
            p_max: 8 + rng.below(24) as u32,
            cc0: 4,
            p0: 4,
        };
        let (mut cc, mut p) = bounds.clamp(4, 4);
        for _ in 0..100 {
            let a = rng.below(N_ACTIONS);
            let (ncc, np) = bounds.apply(cc, p, a);
            assert!((bounds.cc_min..=bounds.cc_max).contains(&ncc));
            assert!((bounds.p_min..=bounds.p_max).contains(&np));
            cc = ncc;
            p = np;
        }
    }
}

#[test]
fn prop_feature_window_outputs_bounded() {
    let mut rng = Rng::new(0xE5);
    for _ in 0..50 {
        let mut w = FeatureWindow::new(1 + rng.below(12), 16, 16);
        for _ in 0..60 {
            let obs = Observation {
                throughput_gbps: rng.range_f64(0.0, 30.0),
                plr: rng.range_f64(0.0, 1.0),
                rtt_s: rng.range_f64(0.001, 0.5),
                energy_j: rng.range_f64(0.0, 500.0),
                cc: 1 + rng.below(16) as u32,
                p: 1 + rng.below(16) as u32,
                duration_s: 1.0,
            };
            let x = w.push(&obs);
            assert!((0.0..=1.0).contains(&x[0]), "plr feature");
            assert!((-1.0..=1.0).contains(&x[1]), "gradient clipped");
            assert!(x[2] >= 1.0 - 1e-6 && x[2] <= 8.0, "ratio bounded: {}", x[2]);
            assert!((0.0..=1.0).contains(&x[3]) && (0.0..=1.0).contains(&x[4]));
            assert!(w.state().iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn prop_reward_shaping_is_ternary_and_utility_monotone() {
    let mut rng = Rng::new(0xF6);
    let cfg = RewardConfig::default();
    for _ in 0..CASES {
        let cur = rng.range_f64(-10.0, 10.0);
        let prev = rng.range_f64(-10.0, 10.0);
        let r = diff_reward(&cfg, cur, prev);
        assert!(r == cfg.x || r == -cfg.y || r == 0.0);
        // Utility is monotone in throughput at fixed loss/params...
        let (cc, p) = (1 + rng.below(16) as u32, 1 + rng.below(16) as u32);
        let l = rng.range_f64(0.0, 0.02);
        let t = rng.range_f64(0.1, 20.0);
        // ...as long as the loss penalty doesn't dominate (B·L < 1/K^n).
        let cfg_ok = 1.0 / cfg.k.powf((cc * p) as f64) > cfg.b * l;
        if cfg_ok {
            assert!(utility(&cfg, t + 1.0, l, cc, p) > utility(&cfg, t, l, cc, p));
        }
        // And decreasing in loss at fixed throughput.
        assert!(utility(&cfg, t, l + 0.01, cc, p) < utility(&cfg, t, l, cc, p));
    }
}

#[test]
fn prop_gae_matches_bruteforce_montecarlo() {
    let mut rng = Rng::new(0x17);
    for _ in 0..CASES {
        let n = 2 + rng.below(20);
        let mut r = Rollout::new();
        let rewards: Vec<f32> = (0..n).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect();
        for i in 0..n {
            r.push(RolloutStep {
                state: vec![0.0],
                action: 0,
                reward: rewards[i],
                value: 0.0,
                logp: 0.0,
                done: false,
            });
        }
        // gamma = lambda = 1, values = 0: advantage = suffix sum of rewards.
        let (adv, ret) = r.gae(1.0, 1.0, 0.0);
        for i in 0..n {
            let want: f32 = rewards[i..].iter().sum();
            assert!((adv[i] - want).abs() < 1e-4, "i={i} adv={} want={want}", adv[i]);
            assert!((ret[i] - adv[i]).abs() < 1e-6);
        }
    }
}

#[test]
fn prop_kmeans_assign_is_argmin() {
    let mut rng = Rng::new(0x28);
    for _ in 0..30 {
        let dim = 1 + rng.below(8);
        let k = 1 + rng.below(12);
        let n = 10 + rng.below(100);
        let pts: Vec<f32> = (0..n * dim).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let km = KMeans::fit(&pts, dim, k, 15, rng.next_u64());
        for i in 0..n {
            let x = &pts[i * dim..(i + 1) * dim];
            let a = km.assign(x);
            let d_a = dist2(x, &km.centroids[a * dim..(a + 1) * dim]);
            for c in 0..km.k {
                let d_c = dist2(x, &km.centroids[c * dim..(c + 1) * dim]);
                assert!(d_a <= d_c + 1e-6, "assign not argmin");
            }
        }
    }
}

fn dist2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
}

#[test]
fn prop_transition_store_roundtrips_random_data() {
    let mut rng = Rng::new(0x39);
    let dir = std::env::temp_dir().join("sparta_prop_store");
    for case in 0..20 {
        let n = 1 + rng.below(50);
        let ts: Vec<Transition> = (0..n)
            .map(|_| Transition {
                features: [rng.f32(), rng.f32() * 2.0 - 1.0, 1.0 + rng.f32(), rng.f32(), rng.f32()],
                action: rng.below(5),
                next_features: [rng.f32(), 0.0, 1.0, rng.f32(), rng.f32()],
                throughput_gbps: rng.range_f64(0.0, 30.0),
                plr: rng.range_f64(0.0, 0.2),
                rtt_s: rng.range_f64(0.01, 0.2),
                energy_j: if rng.chance(0.1) { f64::NAN } else { rng.range_f64(0.0, 400.0) },
                score: rng.range_f64(-5.0, 10.0),
                cc: 1 + rng.below(16) as u32,
                p: 1 + rng.below(16) as u32,
            })
            .collect();
        let path = dir.join(format!("case{case}"));
        TransitionStore::save(&path, &ts).unwrap();
        let back = TransitionStore::load(&path).unwrap();
        assert_eq!(back.len(), ts.len());
        for (a, b) in ts.iter().zip(&back) {
            assert_eq!(a.action, b.action);
            assert_eq!(a.cc, b.cc);
            assert!((a.throughput_gbps - b.throughput_gbps).abs() < 1e-4);
            assert_eq!(a.energy_j.is_nan(), b.energy_j.is_nan());
        }
    }
}

#[test]
fn prop_pause_resume_preserves_stream_accounting() {
    let mut rng = Rng::new(0x4A);
    for _ in 0..30 {
        let mut sim = NetworkSim::new(Testbed::chameleon(), rng.next_u64());
        let id = sim.add_flow(4, 4, None);
        for _ in 0..40 {
            let cc = 1 + rng.below(16) as u32;
            let p = 1 + rng.below(16) as u32;
            sim.set_cc_p(id, cc, p);
            assert_eq!(sim.active_streams(id), (cc * p) as usize);
            sim.run_mi(1.0);
        }
    }
}
