//! The step-driven `Session` API: compat parity with the pre-redesign
//! batch controller, event-stream determinism under mid-run admit/pause/
//! cancel, and fleet `--jobs` invariance.
//!
//! The parity test is the redesign's golden check: `reference_run`
//! reimplements the seed repo's `Controller::run_all` monitoring-interval
//! loop verbatim (same arithmetic, same call order, same meter seeding),
//! and the session-backed compat path must reproduce it bit-for-bit —
//! including the serialized JSON report.

use sparta::baselines::{StaticTool, TwoPhase};
use sparta::config::Paths;
use sparta::coordinator::{
    Event, FeatureWindow, LaneId, LaneReport, LaneSpec, MiContext, MiRecord, Observation,
    Optimizer, ParamBounds, RewardConfig, RewardKind, RewardTracker, Session,
};
use sparta::energy::EnergyMeter;
use sparta::experiments::{fleet, Scale};
use sparta::net::{NetworkSim, Testbed};
use sparta::scenarios::ArrivalSchedule;
use sparta::telemetry::report::lane_json;
use sparta::telemetry::EventLog;
use sparta::transfer::{EngineProfile, TransferJob};

/// The pre-redesign `Controller::run_all` loop for one lane, reimplemented
/// against the raw simulator exactly as the seed repo ran it.
fn reference_run(
    testbed: &Testbed,
    seed: u64,
    job: TransferJob,
    engine: EngineProfile,
    kind: RewardKind,
    mut optimizer: Box<dyn Optimizer>,
) -> LaneReport {
    let bounds = ParamBounds::default();
    let mi_s = 1.0;
    let history = 8;
    let max_mis = 3000;
    let has_energy = testbed.has_energy_counters;

    let mut sim = NetworkSim::new(testbed.clone(), seed);
    let (cc0, p0) = optimizer.start(&bounds);
    let (mut cc, mut p) = bounds.clamp(cc0, p0);
    let io = engine.task_io_gbps(testbed.task_io_gbps);
    let flow = sim.add_flow(cc, p, Some(io));
    let mut window = FeatureWindow::new(history, bounds.cc_max, bounds.p_max);
    let mut tracker = RewardTracker::new(kind, RewardConfig::default());
    // Seed-era meter seeding: seed * 0x9E37 + lane index (0).
    let mut meter = EnergyMeter::new(engine.power.clone(), seed.wrapping_mul(0x9E37));
    let mut job = job;
    let mut has_pending = false;
    let mut records: Vec<MiRecord> = Vec::new();
    let mut done = false;
    let mut done_at_s = 0.0;

    for mi in 0..max_mis {
        if done {
            break;
        }
        let cap = job.remaining_bytes() * 8.0 / mi_s / 1e9;
        sim.set_demand_cap(flow, cap.max(0.05));
        let metrics = sim.run_mi(mi_s);
        let time_s = sim.time_s();
        let m = &metrics[flow.0];
        job.advance(m.bytes_delivered);
        let energy = if has_energy {
            meter.record_mi(m.active_streams, m.throughput_gbps, m.duration_s)
        } else {
            f64::NAN
        };
        let obs = Observation {
            throughput_gbps: m.throughput_gbps,
            plr: m.plr,
            rtt_s: m.rtt_s,
            energy_j: energy,
            cc,
            p,
            duration_s: m.duration_s,
        };
        window.push(&obs);
        let out = tracker.update(&obs);
        let done_now = job.is_complete();
        if has_pending {
            optimizer.learn(out.reward, window.state(), done_now);
        }
        let mut action = None;
        let mut decision = None;
        if done_now {
            done = true;
            done_at_s = time_s;
            has_pending = false;
        } else {
            let ctx = MiContext {
                state: window.state(),
                obs: &obs,
                cc,
                p,
                bounds: &bounds,
                mi_index: mi,
            };
            let d = optimizer.decide(&ctx);
            action = d.action;
            decision = Some(d);
            has_pending = true;
        }
        records.push(MiRecord {
            mi,
            time_s,
            throughput_gbps: m.throughput_gbps,
            plr: m.plr,
            rtt_s: m.rtt_s,
            energy_j: energy,
            cc,
            p,
            metric: out.metric,
            reward: out.reward,
            action,
            state: window.state().to_vec(),
            bytes_total: job.delivered_bytes(),
            energy_total_j: meter.total_j(),
            paused: false,
            rails: None,
        });
        if let Some(d) = decision {
            let (ncc, np) = bounds.clamp(d.cc, d.p);
            if ncc != cc || np != p {
                sim.set_cc_p(flow, ncc, np);
                cc = ncc;
                p = np;
            }
        }
    }
    LaneReport {
        name: optimizer.name().to_string(),
        records,
        completed: done,
        duration_s: if done { done_at_s } else { sim.time_s() },
        total_energy_j: meter.total_j(),
        bytes_delivered: job.delivered_bytes(),
    }
}

/// The session-backed compat path (`Controller::run` is this exact call
/// chain) must reproduce the pre-redesign loop bit-for-bit for a static
/// tool, including the serialized JSON report.
#[test]
fn compat_path_matches_pre_redesign_golden_report() {
    let tb = Testbed::chameleon();
    let job = TransferJob::files(8, 256 << 20);
    let golden = reference_run(
        &tb,
        7,
        job.clone(),
        EngineProfile::rclone(),
        RewardKind::ThroughputEnergy,
        Box::new(StaticTool::rclone()),
    );

    let mut ctl = sparta::coordinator::Controller::builder(tb)
        .job(job)
        .engine(EngineProfile::rclone())
        .reward(RewardKind::ThroughputEnergy)
        .seed(7)
        .build();
    let report = ctl.run(Box::new(StaticTool::rclone()), 7);
    let lane = report.lane();

    assert_eq!(lane, &golden, "session compat path diverged from the pre-redesign loop");
    assert_eq!(
        lane_json(lane).to_string(),
        lane_json(&golden).to_string(),
        "serialized reports differ"
    );
    assert!(golden.completed);
}

/// Same parity for an adaptive baseline (exercises the learn/decide/apply
/// ordering, not just pass-through observation).
#[test]
fn compat_path_matches_golden_for_adaptive_baseline() {
    let tb = Testbed::chameleon();
    let job = TransferJob::files(8, 256 << 20);
    let golden = reference_run(
        &tb,
        11,
        job.clone(),
        EngineProfile::efficient(),
        RewardKind::ThroughputEnergy,
        Box::new(TwoPhase::new()),
    );

    let mut ctl = sparta::coordinator::Controller::builder(tb)
        .job(job)
        .seed(11)
        .build();
    let report = ctl.run(Box::new(TwoPhase::new()), 11);
    assert_eq!(report.lane(), &golden);
    // The adaptive tool must actually have moved (cc, p) at least once,
    // or this parity test proves nothing about decision application.
    let first = (golden.records[0].cc, golden.records[0].p);
    let moved = golden.records.iter().any(|r| (r.cc, r.p) != first);
    assert!(moved, "TwoPhase never changed (cc, p)");
}

/// A churny session — mid-run admission, pause/resume, cancel — replays the
/// identical event stream under the same seed and diverges across seeds.
fn churny_run(seed: u64) -> Vec<Event> {
    let mut s = Session::builder(Testbed::chameleon()).seed(seed).build();
    let mut log = EventLog::default();
    // Sizes chosen so the 10 Gbps capacity bound (1.25 GB/MI) guarantees
    // lane 0 (16 GB) cannot finish before the pause at MI 12 and lane 1
    // (64 GB, admitted at MI 5) cannot finish before the cancel at MI 40.
    let first = s.admit(LaneSpec::new(
        Box::new(StaticTool::efficient_static(4, 4)),
        TransferJob::files(64, 256 << 20),
    ));
    for mi in 0..400 {
        if mi == 5 {
            s.admit(
                LaneSpec::new(Box::new(TwoPhase::new()), TransferJob::files(256, 256 << 20))
                    .named("late-joiner"),
            );
        }
        if mi == 12 {
            assert!(s.pause(first));
        }
        if mi == 24 {
            assert!(s.resume(first));
        }
        if mi == 40 {
            assert!(s.cancel(LaneId(1)));
        }
        s.step_with(&mut log);
        if s.is_idle() {
            break;
        }
    }
    log.events
}

#[test]
fn event_stream_is_seed_deterministic_under_churn() {
    let a = churny_run(3);
    let b = churny_run(3);
    assert_eq!(a, b, "same seed must replay the identical event stream");
    let c = churny_run(4);
    assert_ne!(a, c, "different seeds should diverge");

    // The stream must contain the full lifecycle vocabulary.
    let admitted = a.iter().filter(|e| matches!(e, Event::Admitted { .. })).count();
    assert_eq!(admitted, 2);
    assert!(a.iter().any(|e| matches!(e, Event::Paused { lane, .. } if *lane == LaneId(0))));
    assert!(a.iter().any(|e| matches!(e, Event::Resumed { lane, .. } if *lane == LaneId(0))));
    let lane1_departed = a.iter().any(|e| match e {
        Event::Departed { lane, bytes_delivered, .. } => {
            *lane == LaneId(1) && *bytes_delivered > 0.0
        }
        _ => false,
    });
    assert!(lane1_departed);
    assert!(a.iter().any(|e| matches!(e, Event::Completed { lane, .. } if *lane == LaneId(0))));
    // While lane 0 was paused, it must not have produced MI records.
    let paused_mis: Vec<usize> = a
        .iter()
        .filter_map(|e| match e {
            Event::MiCompleted { lane, record } if *lane == LaneId(0) => Some(record.mi),
            _ => None,
        })
        .collect();
    assert!(paused_mis.iter().all(|&mi| !(12..24).contains(&mi)));
}

/// Fleet reports must be bit-identical at any `--jobs` count (the arrival
/// process, lane seeding and trial sharding are all identity-derived).
#[test]
fn fleet_report_identical_across_jobs() {
    let root = std::env::temp_dir().join("sparta_it_fleet_jobs");
    let _ = std::fs::remove_dir_all(&root);
    let paths = Paths::with_root(&root);
    let schedule = ArrivalSchedule::by_name("churn-heavy").unwrap();
    let methods: Vec<String> = vec!["2-phase".into(), "rclone".into()];
    let opts = fleet::FleetOpts {
        observe_paused: true,
        yield_policy: true,
        ..fleet::FleetOpts::default()
    };
    let r1 = fleet::run(&paths, &schedule, &methods, Scale::Quick, 9, 1, opts).unwrap();
    let r4 = fleet::run(&paths, &schedule, &methods, Scale::Quick, 9, 4, opts).unwrap();
    let j1 = fleet::to_json(&r1).to_string();
    let j4 = fleet::to_json(&r4).to_string();
    assert_eq!(j1, j4, "fleet report differs between --jobs 1 and --jobs 4");
    // Sanity: the workload actually churned.
    assert!(!r1.trials.is_empty());
    for t in &r1.trials {
        assert!(t.lanes.len() >= 2, "trial {} admitted only {} lanes", t.trial, t.lanes.len());
        assert!(!t.epoch_jfi.is_empty());
    }
}

/// Forced departures in churn-heavy actually happen and are accounted.
#[test]
fn churn_heavy_fleet_forces_departures() {
    let root = std::env::temp_dir().join("sparta_it_fleet_churn");
    let _ = std::fs::remove_dir_all(&root);
    let paths = Paths::with_root(&root);
    let schedule = ArrivalSchedule::by_name("churn-heavy").unwrap();
    let methods: Vec<String> = vec!["rclone".into()];
    let opts = fleet::FleetOpts::default();
    let report = fleet::run(&paths, &schedule, &methods, Scale::Quick, 21, 2, opts).unwrap();
    let departed: usize = report
        .trials
        .iter()
        .map(|t| t.lanes.iter().filter(|l| l.departed_early).count())
        .sum();
    assert!(departed > 0, "churn-heavy should force at least one departure");
    // Energy accounting stays finite and positive on chameleon.
    for t in &report.trials {
        assert!(t.energy_per_gb_j.is_finite() && t.energy_per_gb_j > 0.0);
    }
}
