//! Host-ledger energy accounting: conservation under churn, fixed power
//! paid once per host, lumped-rail compat, and the pause-cost observation
//! comparison.
//!
//! The conservation invariant — Σ per-lane attributed energy == host-truth
//! total — must hold across admissions, pauses, resumes, cancels and
//! completions, at any `--jobs` count (fleet trials also assert it
//! internally on every run).

use sparta::baselines::StaticTool;
use sparta::config::Paths;
use sparta::coordinator::{Event, LaneSpec, Session};
use sparta::energy::{EnergyConfig, HostSpec, PowerModel};
use sparta::experiments::{fleet, Scale};
use sparta::net::background::Background;
use sparta::net::Testbed;
use sparta::scenarios::ArrivalSchedule;
use sparta::transfer::TransferJob;

fn static_lane(files: usize) -> LaneSpec {
    LaneSpec::new(
        Box::new(StaticTool::efficient_static(4, 4)),
        TransferJob::files(files, 256 << 20),
    )
}

/// Drive a churny host-resolved session — mid-run admission, pause/resume,
/// cancel — and return (Σ attributed, host total).
fn churny_conservation_run(observe_paused: bool, seed: u64) -> (f64, f64) {
    let tb = Testbed::chameleon();
    let mut s = Session::builder(tb.clone())
        .background(Background::Idle)
        .energy(tb.energy_hosts())
        .observe_paused(observe_paused)
        .seed(seed)
        .build();
    let a = s.admit(static_lane(64));
    let mut b = None;
    let mut c = None;
    for mi in 0..120 {
        match mi {
            // 64 GB: cannot complete before the cancel at MI 46 even with
            // the whole 10 Gbps link (1.25 GB/MI bound).
            5 => b = Some(s.admit(static_lane(256))),
            10 => {
                assert!(s.pause(a));
            }
            18 => c = Some(s.admit(static_lane(8))),
            30 => {
                assert!(s.resume(a));
            }
            46 => {
                assert!(s.cancel(b.unwrap()));
            }
            _ => {}
        }
        s.step();
    }
    let lanes = [Some(a), b, c];
    let attributed: f64 = lanes
        .iter()
        .flatten()
        .map(|id| s.lane_energy_j(*id).unwrap())
        .sum();
    (attributed, s.host_energy_j())
}

/// Conservation holds under churn, with and without paused-MI observation.
#[test]
fn attribution_conserves_host_truth_under_churn() {
    for observe in [false, true] {
        for seed in [3u64, 17, 91] {
            let (attributed, host) = churny_conservation_run(observe, seed);
            assert!(host > 0.0);
            assert!(
                (attributed - host).abs() <= 1e-9 * host,
                "observe={observe} seed={seed}: lanes {attributed} J vs host {host} J"
            );
        }
    }
}

/// Fixed power is paid once per host, not once per lane: a 4-lane session
/// accrues the same fixed-rail energy as a 1-lane session over the same
/// MIs (± measurement noise), so fleet J/GB no longer multiply-counts it.
#[test]
fn fleet_of_lanes_pays_fixed_power_once() {
    let run = |n_lanes: usize| {
        let tb = Testbed::chameleon();
        let mut s = Session::builder(tb.clone())
            .background(Background::Idle)
            .energy(tb.energy_hosts())
            .seed(7)
            .build();
        for _ in 0..n_lanes {
            // 64 GB each: nothing can complete within 40 MIs (capacity
            // bound 1.25 GB/MI), so every lane stays billed throughout.
            s.admit(static_lane(256));
        }
        for _ in 0..40 {
            s.step();
        }
        s.energy_rails().expect("host-resolved session has rails")
    };
    let one = run(1);
    let four = run(4);
    // 2 Xeon hosts × 24 W × 40 MIs = 1920 J of fixed energy either way;
    // noise perturbs the reading by a few joules at most.
    let expect = 2.0 * 24.0 * 40.0;
    for (label, rails) in [("one", &one), ("four", &four)] {
        assert!(
            (rails.fixed_j - expect).abs() < 0.05 * expect,
            "{label}: fixed {} J vs expected {expect} J",
            rails.fixed_j
        );
    }
    assert!(
        (four.fixed_j - one.fixed_j).abs() < 0.05 * expect,
        "fixed power scaled with lane count: one={} four={}",
        one.fixed_j,
        four.fixed_j
    );
    // The lumped rail, by contrast, bills fixed power per lane — the
    // multiply-counting this refactor removes (kept only for single-lane
    // compat).
    let lumped = |n_lanes: usize| {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(7)
            .build();
        for _ in 0..n_lanes {
            s.admit(static_lane(256));
        }
        for _ in 0..40 {
            s.step();
        }
        s.host_energy_j()
    };
    let ratio = (lumped(4) - lumped(1)) / lumped(1);
    assert!(ratio > 0.5, "lumped should multiply-count per-lane costs: {ratio}");
}

/// The lumped compat rail bills a single lane exactly like the seed-era
/// per-lane meter: re-running the same sim trace through a fresh
/// `EnergyMeter` (seed-era seeding, same demand-cap loop) reproduces every
/// per-MI energy bit. (Full-loop parity including reports lives in
/// tests/session_api.rs; this pins the billing arithmetic itself.)
#[test]
fn lumped_compat_reproduces_meter_bits() {
    use sparta::energy::EnergyMeter;
    use sparta::net::NetworkSim;
    use sparta::transfer::EngineProfile;
    let seed = 11u64;
    let tb = Testbed::chameleon();
    let mut s = Session::builder(tb.clone()).seed(seed).build();
    let id = s.admit(static_lane(8));
    let mut records = Vec::new();
    for _ in 0..200 {
        for ev in s.step() {
            if let Event::MiCompleted { lane, record } = ev {
                if lane == id {
                    records.push(record);
                }
            }
        }
        if s.is_idle() {
            break;
        }
    }
    assert!(!records.is_empty());
    // Reference: the raw sim + seed-era meter (seeded seed * 0x9E37 + 0),
    // same StaticTool(4,4) flow and demand-cap loop.
    let mut sim = NetworkSim::new(tb.clone(), seed);
    let io = EngineProfile::efficient().task_io_gbps(tb.task_io_gbps);
    let flow = sim.add_flow(4, 4, Some(io));
    let mut meter = EnergyMeter::new(PowerModel::efficient(), seed.wrapping_mul(0x9E37));
    let mut job = TransferJob::files(8, 256 << 20);
    let mut want = Vec::new();
    for _ in 0..records.len() {
        let cap = job.remaining_bytes() * 8.0 / 1.0 / 1e9;
        sim.set_demand_cap(flow, cap.max(0.05));
        let m = sim.run_mi(1.0)[flow.0];
        job.advance(m.bytes_delivered);
        want.push(meter.record_mi(m.active_streams, m.throughput_gbps, m.duration_s));
    }
    for (r, w) in records.iter().zip(&want) {
        assert_eq!(r.energy_j.to_bits(), w.to_bits(), "MI {}", r.mi);
        assert!(r.rails.is_none(), "lumped records must not carry rails");
    }
    assert_eq!(s.lane_energy_j(id).unwrap().to_bits(), meter.total_j().to_bits());
}

/// With `observe_paused`, the decision pending at pause time is credited
/// with the collapsed metric of the first paused MI — the negative reward
/// that teaches optimizers what preemption costs.
#[test]
fn observed_pause_delivers_negative_reward() {
    let tb = Testbed::chameleon();
    let mut s = Session::builder(tb.clone())
        .background(Background::Idle)
        .energy(tb.energy_hosts())
        .observe_paused(true)
        .seed(13)
        .build();
    let id = s.admit(static_lane(64));
    for _ in 0..6 {
        s.step();
    }
    assert!(s.pause(id));
    let events = s.step();
    // Sinks receive `&Event`; borrow the record instead of cloning it.
    let rec = events
        .iter()
        .find_map(|e| match e {
            Event::MiCompleted { lane, record } if *lane == id => Some(record),
            _ => None,
        })
        .expect("paused lane must emit an observed record");
    assert!(rec.paused);
    assert!(
        rec.reward < 0.0,
        "pause collapse must read as a regression, got reward {}",
        rec.reward
    );
    assert!(rec.energy_j > 0.0, "paused MI must carry idle energy");
}

/// The churn-heavy comparison: lanes that observe their idle bills consent
/// to fewer yield pauses than blind ones (which model preemption as free).
#[test]
fn observing_fleets_pause_less_eagerly_than_blind() {
    let root = std::env::temp_dir().join("sparta_it_observe_cmp");
    let _ = std::fs::remove_dir_all(&root);
    let paths = Paths::with_root(&root);
    let schedule = ArrivalSchedule::by_name("churn-heavy").unwrap();
    let methods: Vec<String> = vec!["2-phase".into(), "falcon_mp".into(), "rclone".into()];
    let (blind, observing) =
        fleet::run_observe_comparison(&paths, &schedule, &methods, Scale::Quick, 5, 2).unwrap();
    assert!(blind.total_pauses() > 0, "yield policy never fired under churn-heavy");
    assert!(
        observing.total_pauses() < blind.total_pauses(),
        "observing fleets should pause less eagerly: {} vs {}",
        observing.total_pauses(),
        blind.total_pauses()
    );
    let refused: usize = observing.trials.iter().map(|t| t.yields_refused).sum();
    assert!(refused > 0, "no lane ever refused after seeing its idle bills");
    // Both sides still conserve (asserted inside every trial) and report
    // host-truth rails.
    for t in blind.trials.iter().chain(observing.trials.iter()) {
        let rails = t.rails.as_ref().expect("fleet trials are host-resolved");
        assert!(rails.fixed_j > 0.0);
    }
}

/// Cluster-scale conservation: a 3-sender-host incast cluster under churn
/// — mid-run admissions, a pause window, a cancel — conserves energy at
/// every level of the hierarchy: Σ global lane attribution == each host's
/// ledger total (per host) == Σ per-host totals == cluster total, with
/// paused lanes still billing idle rails while preempted.
#[test]
fn cluster_attribution_conserves_across_hosts_under_churn() {
    use sparta::coordinator::{Cluster, LaneId, INCAST_RX_OVER_WAN};
    use sparta::net::Topology;
    let tb = Testbed::chameleon();
    let hosts = 3usize;
    for seed in [3u64, 41] {
        let mut c = Cluster::build(hosts, seed, |h, host_seed| {
            Session::builder(tb.clone())
                .topology(Topology::incast_host(&tb, hosts, INCAST_RX_OVER_WAN))
                .energy(tb.energy_hosts_of(h, hosts))
                .observe_paused(true)
                .seed(host_seed)
                .build()
        });
        // Churn across all three hosts (round-robin placement): two lanes
        // up front, three admitted mid-run, one paused through a window,
        // one cancelled before it can complete.
        let a = c.admit(static_lane(64));
        let b = c.admit(static_lane(256));
        let mut lanes = vec![a, b];
        let mut at_pause = 0.0;
        // One reused event buffer — the cluster stepping surface is the
        // same buffer-taking primitive sessions expose.
        let mut events = Vec::new();
        for mi in 0..90 {
            match mi {
                4 => lanes.push(c.admit(static_lane(128))),
                7 => lanes.push(c.admit(static_lane(16))),
                12 => lanes.push(c.admit(static_lane(96))),
                20 => {
                    assert!(c.pause(a));
                    at_pause = c.lane_energy_j(a).unwrap();
                }
                40 => {
                    // The paused lane kept billing its idle rails.
                    assert!(
                        c.lane_energy_j(a).unwrap() > at_pause,
                        "seed {seed}: no idle energy accrued while paused"
                    );
                    assert!(c.resume(a));
                }
                55 => {
                    assert!(c.cancel(b));
                }
                _ => {}
            }
            c.step_into(&mut events);
        }
        // Per-host conservation: each host session's lanes sum to that
        // host's ledger truth.
        let mut per_host_sum = 0.0;
        for s in c.hosts() {
            let host_j = s.host_energy_j();
            let host_attr: f64 =
                (0..s.lane_count()).map(|k| s.lane_energy_j(LaneId(k)).unwrap()).sum();
            assert!(
                (host_attr - host_j).abs() <= 1e-9 * host_j.max(1.0),
                "seed {seed}: host attribution leaked: {host_attr} vs {host_j}"
            );
            per_host_sum += host_j;
        }
        // Cluster-level conservation: global lane attribution and the
        // per-host totals both equal the cluster truth.
        let cluster_j = c.host_energy_j();
        let attributed: f64 = lanes.iter().map(|&id| c.lane_energy_j(id).unwrap()).sum();
        assert!(cluster_j > 0.0);
        assert!(
            (per_host_sum - cluster_j).abs() <= 1e-9 * cluster_j,
            "seed {seed}: per-host totals {per_host_sum} J vs cluster {cluster_j} J"
        );
        assert!(
            (attributed - cluster_j).abs() <= 1e-9 * cluster_j,
            "seed {seed}: lane attribution {attributed} J vs cluster {cluster_j} J"
        );
        // Rails resolve cluster-wide too, and the pause window left its
        // mark on the idle rail.
        let rails = c.energy_rails().expect("host-resolved cluster has rails");
        assert!((rails.total_j() - cluster_j).abs() <= 1e-6 * cluster_j);
        assert!(rails.idle_j > 0.0, "seed {seed}: paused window billed no idle rail");
    }
}

/// Sanity on the host definitions themselves: the efficient host spec's
/// single-lane power equals the lumped curve (compat anchor used by both
/// fig1's rail columns and the testbed presets).
#[test]
fn host_spec_matches_lumped_curve_at_operating_points() {
    let spec = HostSpec::efficient("x");
    let lumped = PowerModel::efficient();
    for (n, t) in [(1usize, 0.5), (16, 5.0), (64, 8.0), (256, 9.5)] {
        let a = spec.power_w(n, t);
        let b = lumped.power_w(n, t);
        assert!((a - b).abs() <= 1e-9 * b, "({n},{t}): {a} vs {b}");
    }
    assert!(matches!(
        Testbed::chameleon().energy_hosts(),
        EnergyConfig::Hosts { .. }
    ));
}
