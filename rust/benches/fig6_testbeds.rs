//! Bench: regenerate Fig. 6 — six methods x evaluation scenarios (headline).
use sparta::config::Paths;
use sparta::experiments::{common, default_jobs, fig6, Scale};
use sparta::scenarios::Scenario;

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let methods: Vec<String> = common::METHODS.iter().map(|m| m.to_string()).collect();
    let cells =
        fig6::run(&Paths::resolve(), &Scenario::defaults(), &methods, scale, 42, default_jobs())
            .expect("fig6 (needs `make artifacts` + `sparta train-all`)");
    fig6::print(&cells);
    let (thr, en) = fig6::headline(&cells);
    println!("\nheadline: +{thr:.0}% throughput, -{en:.0}% energy vs static tools");
    println!("(paper: up to +25% throughput, up to -40% energy)");
    println!("\n[bench fig6_testbeds: {:.1}s]", t0.elapsed().as_secs_f64());
}
