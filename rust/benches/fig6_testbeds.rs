//! Bench: regenerate Fig. 6 — six methods x three testbeds (headline).
use sparta::config::Paths;
use sparta::experiments::{fig6, Scale, SpartaCtx};
use sparta::net::Testbed;

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let cells = fig6::run(&ctx, &Testbed::all(), scale, 42)
        .expect("fig6 (train SPARTA first: `sparta train-all`)");
    fig6::print(&cells);
    let (thr, en) = fig6::headline(&cells);
    println!("\nheadline: +{thr:.0}% throughput, -{en:.0}% energy vs static tools");
    println!("(paper: up to +25% throughput, up to -40% energy)");
    println!("\n[bench fig6_testbeds: {:.1}s]", t0.elapsed().as_secs_f64());
}
