//! Bench: regenerate Fig. 1 — throughput/power vs (cc, p) x background.
use sparta::experiments::{default_jobs, fig1};
use sparta::net::Testbed;

fn main() {
    let t0 = std::time::Instant::now();
    let tb = Testbed::chameleon();
    let grid = [1u32, 2, 4, 8, 16];
    let pts = fig1::sweep(&tb, &grid, &["low", "medium", "high"], 7, default_jobs());
    fig1::print(&pts, &grid);
    println!("\n[bench fig1_sweep: {} points in {:.1}s]", pts.len(), t0.elapsed().as_secs_f64());
}
