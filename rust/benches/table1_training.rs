//! Bench: regenerate Table 1 — per-algorithm training/inference cost.
use sparta::config::Paths;
use sparta::experiments::{table1, Scale, SpartaCtx};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let rows = table1::run(&ctx, &sparta::agents::ALGOS, scale, 42).expect("table1");
    table1::print(&rows);
    println!("\n[bench table1_training: {:.1}s]", t0.elapsed().as_secs_f64());
}
