//! Bench: regenerate Table 1 — per-algorithm training/inference cost.
use sparta::config::Paths;
use sparta::experiments::{default_jobs, table1, Scale};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let rows = table1::run(&Paths::resolve(), &sparta::agents::ALGOS, scale, 42, default_jobs())
        .expect("table1 (run `make artifacts` first)");
    table1::print(&rows, false);
    println!("\n[bench table1_training: {:.1}s]", t0.elapsed().as_secs_f64());
}
