//! Microbenchmarks of the hot paths: simulator MI rate (arena loop vs the
//! frozen pre-arena baseline), zero-alloc `Session::step`, HLO inference
//! latency per algorithm, and k-means assignment (Rust scalar vs AOT
//! Pallas kernel).
//!
//! The simulator and session rows are the same measurements `sparta bench`
//! folds into `BENCH_5.json` (shared helpers in
//! [`sparta::experiments::bench`]); this standalone binary adds the
//! artifact-dependent HLO rows.
use sparta::agents;
use sparta::config::Paths;
use sparta::emulator::KMeans;
use sparta::experiments::bench::{bench_loop, session_step_micro, sim_mi_micro};
use sparta::experiments::SpartaCtx;
use sparta::telemetry::Table;
use sparta::util::Rng;

fn main() {
    let mut table = Table::new(&["benchmark", "per-op", "ops/s"]);
    let fmt = |s: f64| {
        if s < 1e-6 {
            format!("{:.0} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:.1} us", s * 1e6)
        } else {
            format!("{:.2} ms", s * 1e3)
        }
    };

    // Simulator: one MI (20 ticks) with a 16x16-stream flow — arena loop
    // and the frozen pre-arena baseline, same workload.
    let s = sim_mi_micro(200, false);
    table.row(vec!["net sim MI (256 streams)".into(), fmt(s), format!("{:.0}", 1.0 / s)]);
    let s = sim_mi_micro(200, true);
    table.row(vec![
        "net sim MI (256 streams, pre-arena baseline)".into(),
        fmt(s),
        format!("{:.0}", 1.0 / s),
    ]);

    // Zero-alloc session stepping (static lanes, jobs sized to never
    // complete mid-measurement).
    for lanes in [1usize, 8] {
        let s = session_step_micro(lanes, 200);
        table.row(vec![
            format!("session step ({lanes} lane{})", if lanes == 1 { "" } else { "s" }),
            fmt(s),
            format!("{:.0}", 1.0 / s),
        ]);
    }

    // k-means assignment: Rust scalar.
    let mut rng = Rng::new(3);
    let (n, k, d) = (1024usize, 64usize, 6usize);
    let points: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let centroids: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
    let km = KMeans { centroids: centroids.clone(), k, dim: d, assignments: vec![] };
    let s = bench_loop(200, || {
        for i in 0..n {
            std::hint::black_box(km.assign(&points[i * d..(i + 1) * d]));
        }
    });
    table.row(vec![format!("kmeans assign {n} pts (rust)"), fmt(s), format!("{:.0}", 1.0 / s)]);

    // HLO paths (need artifacts).
    match SpartaCtx::load(Paths::resolve()) {
        Err(e) => eprintln!("skipping HLO benches: {e}"),
        Ok(ctx) => {
            let exe = ctx.runtime.compile("kmeans_assign").unwrap();
            let s = bench_loop(100, || {
                std::hint::black_box(exe.call(&[&points, &centroids]).unwrap());
            });
            table.row(vec![
                format!("kmeans assign {n} pts (pallas HLO)"),
                fmt(s),
                format!("{:.0}", 1.0 / s),
            ]);

            for algo in agents::ALGOS {
                let mut agent = agents::make_agent(&ctx.runtime, algo, 7, None).unwrap();
                let state_len = ctx
                    .runtime
                    .compile(&format!("{algo}_forward"))
                    .unwrap()
                    .spec
                    .arg_len(1);
                let state = vec![0.1f32; state_len];
                for _ in 0..10 {
                    agent.act(&state, false);
                }
                let s = bench_loop(200, || {
                    std::hint::black_box(agent.act(&state, false));
                });
                table.row(vec![format!("{algo} inference"), fmt(s), format!("{:.0}", 1.0 / s)]);
            }
        }
    }
    table.print();
}
