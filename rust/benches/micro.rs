//! Microbenchmarks of the hot paths: simulator tick rate, HLO inference
//! latency per algorithm, k-means assignment (Rust scalar vs AOT Pallas
//! kernel), and the full MI control-loop step.
use sparta::agents;
use sparta::config::Paths;
use sparta::emulator::KMeans;
use sparta::experiments::SpartaCtx;
use sparta::net::{background::Background, NetworkSim, Testbed};
use sparta::telemetry::Table;
use sparta::util::Rng;
use std::time::Instant;

fn bench<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let mut table = Table::new(&["benchmark", "per-op", "ops/s"]);
    let fmt = |s: f64| {
        if s < 1e-6 {
            format!("{:.0} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:.1} us", s * 1e6)
        } else {
            format!("{:.2} ms", s * 1e3)
        }
    };

    // Simulator: one MI (20 ticks) with a 16x16-stream flow.
    let mut sim = NetworkSim::new(Testbed::chameleon(), 1)
        .with_background(Background::regime("medium", 10.0));
    sim.add_flow(16, 16, None);
    for _ in 0..10 {
        sim.run_mi(1.0);
    }
    let s = bench(200, || {
        sim.run_mi(1.0);
    });
    table.row(vec!["net sim MI (256 streams)".into(), fmt(s), format!("{:.0}", 1.0 / s)]);

    // k-means assignment: Rust scalar.
    let mut rng = Rng::new(3);
    let (n, k, d) = (1024usize, 64usize, 6usize);
    let points: Vec<f32> = (0..n * d).map(|_| rng.f32()).collect();
    let centroids: Vec<f32> = (0..k * d).map(|_| rng.f32()).collect();
    let km = KMeans { centroids: centroids.clone(), k, dim: d, assignments: vec![] };
    let s = bench(200, || {
        for i in 0..n {
            std::hint::black_box(km.assign(&points[i * d..(i + 1) * d]));
        }
    });
    table.row(vec![format!("kmeans assign {n} pts (rust)"), fmt(s), format!("{:.0}", 1.0 / s)]);

    // HLO paths (need artifacts).
    match SpartaCtx::load(Paths::resolve()) {
        Err(e) => eprintln!("skipping HLO benches: {e}"),
        Ok(ctx) => {
            let exe = ctx.runtime.compile("kmeans_assign").unwrap();
            let s = bench(100, || {
                std::hint::black_box(exe.call(&[&points, &centroids]).unwrap());
            });
            table.row(vec![format!("kmeans assign {n} pts (pallas HLO)"), fmt(s), format!("{:.0}", 1.0 / s)]);

            for algo in agents::ALGOS {
                let mut agent = agents::make_agent(&ctx.runtime, algo, 7, None).unwrap();
                let state_len = ctx
                    .runtime
                    .compile(&format!("{algo}_forward"))
                    .unwrap()
                    .spec
                    .arg_len(1);
                let state = vec![0.1f32; state_len];
                for _ in 0..10 {
                    agent.act(&state, false);
                }
                let s = bench(200, || {
                    std::hint::black_box(agent.act(&state, false));
                });
                table.row(vec![format!("{algo} inference"), fmt(s), format!("{:.0}", 1.0 / s)]);
            }
        }
    }
    table.print();
}
