//! Bench: regenerate Fig. 7 — concurrent-transfer fairness (JFI).
use sparta::config::Paths;
use sparta::experiments::{fig7, Scale, SpartaCtx};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let scenarios = fig7::run(&ctx, scale, 42).expect("fig7 (train SPARTA first)");
    fig7::print(&scenarios);
    println!("\n[bench fig7_fairness: {:.1}s]", t0.elapsed().as_secs_f64());
}
