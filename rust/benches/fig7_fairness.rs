//! Bench: regenerate Fig. 7 — concurrent-transfer fairness (JFI).
use sparta::config::Paths;
use sparta::experiments::{default_jobs, fig7, Scale};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let scenarios = fig7::run(&Paths::resolve(), scale, 42, default_jobs())
        .expect("fig7 (needs `make artifacts` + trained SPARTA weights)");
    fig7::print(&scenarios);
    println!("\n[bench fig7_fairness: {:.1}s]", t0.elapsed().as_secs_f64());
}
