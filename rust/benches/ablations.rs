//! Ablations of SPARTA's design choices (DESIGN.md §6):
//!  A. reward shaping: difference-based f(.) vs raw-metric reward
//!  B. state history length n in {1, 4, 8}
//!  C. emulated vs online-only training (training cost & resulting policy)
//!  D. action granularity: 5-action (+-1, +-2) vs 3-action (+-1 only)
//!
//! Each ablation retrains a DQN variant (fast to train) and evaluates on the
//! live simulator; differences in eval throughput/energy quantify the
//! contribution of each design choice.
use sparta::agents::make_agent;
use sparta::config::Paths;
use sparta::coordinator::{ParamBounds, RewardKind};
use sparta::emulator::{ClusterEnv, Env};
use sparta::experiments::common::transitions_for;
use sparta::experiments::{Scale, SpartaCtx};
use sparta::net::Testbed;
use sparta::telemetry::Table;
use sparta::trainer::{train_offline, LiveEnv, TrainConfig};
use std::time::Instant;

fn eval_live(ctx: &SpartaCtx, weights: Vec<f32>, episodes: usize) -> (f64, f64) {
    let mut agent = make_agent(&ctx.runtime, "dqn", 9, Some(weights)).unwrap();
    let mut env = LiveEnv::new(
        Testbed::chameleon(),
        RewardKind::ThroughputEnergy,
        ParamBounds::default(),
        8,
        30,
        123,
    );
    let (mut thr, mut en, mut n) = (0.0, 0.0, 0);
    for _ in 0..episodes {
        let mut state = env.reset();
        loop {
            let a = agent.act(&state, false);
            let out = env.step(a);
            thr += out.throughput_gbps;
            en += out.energy_j;
            n += 1;
            state = out.state;
            if out.done {
                break;
            }
        }
    }
    (thr / n as f64, en / n as f64)
}

fn train_variant(
    ctx: &SpartaCtx,
    history: usize,
    steps: usize,
    seed: u64,
) -> (Vec<f32>, f64, usize) {
    // NOTE: the HLO graphs are compiled for history=8; shorter histories are
    // emulated by zero-padding the window (the agent simply sees zeros for
    // the missing MIs), which isolates the information content of history.
    let transitions = transitions_for(ctx, &Testbed::chameleon(), Scale::Quick, 42).unwrap();
    let mut env = ClusterEnv::new(
        transitions,
        48,
        ParamBounds::default(),
        RewardKind::ThroughputEnergy,
        history,
        64,
        seed,
    );
    let mut agent = make_agent(&ctx.runtime, "dqn", seed, None).unwrap();
    let cfg = TrainConfig { max_env_steps: steps, ..TrainConfig::default() };
    let t0 = Instant::now();
    // Pad/truncate states to the compiled window of 8 x FEATURES.
    struct PadEnv<'a> {
        inner: &'a mut ClusterEnv,
        target: usize,
    }
    impl Env for PadEnv<'_> {
        fn reset(&mut self) -> Vec<f32> {
            pad(self.inner.reset(), self.target)
        }
        fn step(&mut self, a: usize) -> sparta::emulator::StepOut {
            let mut out = self.inner.step(a);
            out.state = pad(out.state, self.target);
            out
        }
        fn state_len(&self) -> usize {
            self.target
        }
    }
    fn pad(mut s: Vec<f32>, target: usize) -> Vec<f32> {
        while s.len() < target {
            s.insert(0, 0.0);
        }
        s
    }
    let target = 8 * sparta::coordinator::FEATURES;
    let mut padded = PadEnv { inner: &mut env, target };
    let stats = train_offline(&mut agent, &mut padded, &cfg);
    (agent.params().to_vec(), t0.elapsed().as_secs_f64(), stats.steps_to_converge)
}

fn main() {
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let steps = scale.train_steps() / 2;
    let eval_eps = 5;

    println!("Ablation B/C — state history + emulated training (DQN core):");
    let mut table = Table::new(&["variant", "train s", "conv step", "eval Gbps", "eval J/MI"]);
    for history in [1usize, 4, 8] {
        let (w, secs, conv) = train_variant(&ctx, history, steps, 77);
        let (thr, en) = eval_live(&ctx, w, eval_eps);
        table.row(vec![
            format!("emulated, n={history}"),
            format!("{secs:.1}"),
            format!("{conv}"),
            format!("{thr:.2}"),
            format!("{en:.0}"),
        ]);
    }
    // Online-only training: same budget of env steps but on the live sim
    // (each step costs a real MI -> the paper's training-cost argument).
    {
        let mut agent = make_agent(&ctx.runtime, "dqn", 77, None).unwrap();
        let mut env = LiveEnv::new(
            Testbed::chameleon(),
            RewardKind::ThroughputEnergy,
            ParamBounds::default(),
            8,
            64,
            321,
        );
        let cfg = TrainConfig { max_env_steps: steps / 4, ..TrainConfig::default() };
        let t0 = Instant::now();
        let stats = train_offline(&mut agent, &mut env, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        let (thr, en) = eval_live(&ctx, agent.params().to_vec(), eval_eps);
        table.row(vec![
            format!("online-only (1/4 steps)"),
            format!("{secs:.1}"),
            format!("{}", stats.steps_to_converge),
            format!("{thr:.2}"),
            format!("{en:.0}"),
        ]);
        // The key point: online training would additionally burn one real MI
        // (1 s wall + transfer energy) per step on the testbed.
        println!(
            "  online-only would cost {} live MIs ≈ {:.1} h testbed time (emulated: seconds)",
            steps / 4,
            (steps / 4) as f64 / 3600.0
        );
    }
    table.print();
}
