//! Bench: regenerate Fig. 5 — online tuning Chameleon -> CloudLab.
use sparta::config::Paths;
use sparta::experiments::{default_jobs, fig5, Scale};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let curves = fig5::run(&Paths::resolve(), &sparta::agents::ALGOS, scale, 42, default_jobs())
        .expect("fig5 (train all algos with --reward te first: `sparta train-all`)");
    fig5::print(&curves);
    println!("\n[bench fig5_tuning: {:.1}s]", t0.elapsed().as_secs_f64());
}
