//! Bench: regenerate Fig. 5 — online tuning Chameleon -> CloudLab.
use sparta::config::Paths;
use sparta::experiments::{fig5, Scale, SpartaCtx};

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let curves = fig5::run(&ctx, &sparta::agents::ALGOS, scale, 42)
        .expect("fig5 (train all algos with --reward te first: `sparta train-all`)");
    fig5::print(&curves);
    println!("\n[bench fig5_tuning: {:.1}s]", t0.elapsed().as_secs_f64());
}
