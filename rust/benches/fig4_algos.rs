//! Bench: regenerate Fig. 4 — five DRL algorithms x two rewards, sim + real.
use sparta::config::Paths;
use sparta::coordinator::RewardKind;
use sparta::experiments::{default_jobs, fig4, train_pipeline, Scale, SpartaCtx, TrainSource};
use sparta::net::Testbed;

fn main() {
    let scale = Scale::by_name(&std::env::var("SPARTA_BENCH_SCALE").unwrap_or_default());
    let t0 = std::time::Instant::now();
    let ctx = SpartaCtx::load(Paths::resolve()).expect("run `make artifacts` first");
    let tb = Testbed::chameleon();
    for reward in [RewardKind::FairnessEfficiency, RewardKind::ThroughputEnergy] {
        // Ensure weights exist for every algorithm under this reward.
        for algo in sparta::agents::ALGOS {
            let name = SpartaCtx::weight_name(algo, reward);
            if !ctx.weight_store().exists(&name) {
                eprintln!("training {name}...");
                train_pipeline(&ctx, algo, reward, TrainSource::Testbed(&tb), scale, 42)
                    .expect("train");
            }
        }
        // fig4::run loads its own context, so it snapshots any weights
        // trained above.
        let cells = fig4::run(
            &Paths::resolve(),
            reward,
            &sparta::agents::ALGOS,
            scale,
            42,
            default_jobs(),
        )
        .expect("fig4");
        fig4::print(&cells);
    }
    println!("\n[bench fig4_algos: {:.1}s]", t0.elapsed().as_secs_f64());
}
