//! Dynamic power model of an end system during a transfer.

use crate::util::Rng;

/// Coefficients of the end-system dynamic power model (watts).
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Fixed dynamic power while the engine is active, W.
    pub p_fixed_w: f64,
    /// Per-stream coefficient, W per stream^0.9.
    pub c_stream_w: f64,
    /// Per-throughput coefficient, W per Gbps of goodput.
    pub c_gbps_w: f64,
    /// Extra per-Gbps CPU cost of the engine (checksums/encryption), W/Gbps.
    /// 0 for an efficient zero-copy engine; >0 for rclone/escp-style tools.
    pub engine_overhead_w_per_gbps: f64,
    /// NIC idle floor this engine holds the link to when nothing moves, W.
    /// A zero-copy engine lets the NIC reach its deepest LPI state; engines
    /// that poll or keep connections chatty (rclone's HTTP keepalives,
    /// escp's control channel) hold it in a shallower — hungrier — state.
    /// Consumed by the host-rail ledger, not by the lumped curve.
    pub nic_lpi_idle_w: f64,
    /// Measurement noise std-dev, W (RAPL sampling jitter).
    pub noise_w: f64,
}

impl PowerModel {
    /// Calibrated default for the efficient transfer engine used by SPARTA,
    /// Falcon_MP and 2-phase in our reproduction. Produces the Fig.-1b
    /// power range (~25–130 W above baseline on the Chameleon preset).
    pub fn efficient() -> PowerModel {
        PowerModel {
            p_fixed_w: 18.0,
            c_stream_w: 0.85,
            c_gbps_w: 6.0,
            engine_overhead_w_per_gbps: 0.0,
            nic_lpi_idle_w: 1.0,
            noise_w: 0.8,
        }
    }

    /// rclone-style engine: per-chunk hashing + HTTP framing. Keepalive
    /// chatter holds the NIC out of deep LPI between chunks.
    pub fn rclone() -> PowerModel {
        PowerModel {
            engine_overhead_w_per_gbps: 3.5,
            nic_lpi_idle_w: 1.6,
            ..PowerModel::efficient()
        }
    }

    /// escp-style engine: encryption on the wire, plus a control channel
    /// that keeps the NIC in a shallow idle state.
    pub fn escp() -> PowerModel {
        PowerModel {
            engine_overhead_w_per_gbps: 4.5,
            nic_lpi_idle_w: 1.8,
            ..PowerModel::efficient()
        }
    }

    /// Instantaneous dynamic power for `streams` active streams moving
    /// `throughput_gbps` of goodput. Deterministic part only.
    pub fn power_w(&self, streams: usize, throughput_gbps: f64) -> f64 {
        self.p_fixed_w
            + self.c_stream_w * (streams as f64).powf(0.9)
            + (self.c_gbps_w + self.engine_overhead_w_per_gbps) * throughput_gbps
    }

    /// Power with measurement noise, clamped non-negative.
    pub fn sample_power_w(&self, streams: usize, throughput_gbps: f64, rng: &mut Rng) -> f64 {
        (self.power_w(streams, throughput_gbps) + rng.normal_mean_sd(0.0, self.noise_w)).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_monotone_in_streams() {
        let m = PowerModel::efficient();
        assert!(m.power_w(64, 5.0) > m.power_w(16, 5.0));
        assert!(m.power_w(16, 5.0) > m.power_w(1, 5.0));
    }

    #[test]
    fn power_monotone_in_throughput() {
        let m = PowerModel::efficient();
        assert!(m.power_w(16, 9.0) > m.power_w(16, 2.0));
    }

    #[test]
    fn sublinear_stream_scaling() {
        let m = PowerModel::efficient();
        let p1 = m.power_w(10, 0.0) - m.power_w(0, 0.0);
        let p2 = m.power_w(20, 0.0) - m.power_w(0, 0.0);
        assert!(p2 < 2.0 * p1);
    }

    #[test]
    fn overhead_engines_burn_more() {
        let eff = PowerModel::efficient();
        let rcl = PowerModel::rclone();
        let esc = PowerModel::escp();
        assert!(rcl.power_w(16, 5.0) > eff.power_w(16, 5.0));
        assert!(esc.power_w(16, 5.0) > rcl.power_w(16, 5.0));
    }

    /// Engines carry their own NIC idle states: the efficient engine lets
    /// the NIC reach the hardware LPI floor, the chatty tools hold it
    /// shallower. The lumped curve ignores the field (compat).
    #[test]
    fn nic_idle_floors_rank_by_engine_chatter() {
        let eff = PowerModel::efficient();
        let rcl = PowerModel::rclone();
        let esc = PowerModel::escp();
        assert!(eff.nic_lpi_idle_w < rcl.nic_lpi_idle_w);
        assert!(rcl.nic_lpi_idle_w < esc.nic_lpi_idle_w);
        assert_eq!(eff.power_w(0, 0.0), eff.p_fixed_w);
    }

    #[test]
    fn calibration_range_matches_fig1b() {
        let m = PowerModel::efficient();
        // (1,1) at ~1 Gbps: small double-digit watts.
        let low = m.power_w(1, 1.0);
        assert!(low > 15.0 && low < 40.0, "low={low}");
        // (16,16) at ~8 Gbps: order 130-200 W.
        let high = m.power_w(256, 8.0);
        assert!(high > 100.0 && high < 250.0, "high={high}");
    }

    #[test]
    fn sampled_power_nonnegative() {
        let m = PowerModel::efficient();
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..1000 {
            assert!(m.sample_power_w(0, 0.0, &mut rng) >= 0.0);
        }
    }
}
