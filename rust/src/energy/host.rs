//! Host-scoped, component-resolved energy accounting.
//!
//! One [`HostLedger`] is shared by every transfer lane colocated on an end
//! host. Each monitoring interval the ledger integrates **host-truth**
//! power once — from the aggregate of all active lanes, over the component
//! rails of [`super::rail`] — and *attributes* the energy back to lanes:
//!
//! * CPU stream bookkeeping — proportional to each lane's stream count
//!   (the sublinear total is shared, so colocated lanes are cheaper per
//!   stream than isolated ones);
//! * NIC per-bit cost — proportional to each lane's delivered bytes;
//! * fixed engine-residency — equal share across every hosted lane, paid
//!   once per host (an N-lane fleet no longer counts it N times);
//! * paused lanes are billed the idle rail (session keepalive) instead of
//!   vanishing from the books, so preemption has a visible energy price.
//!
//! Measurement noise (the RAPL-jitter analogue) is drawn once per host per
//! MI and folded into each lane's bill proportionally, so per-lane
//! attributed energy always sums to the host total — the conservation
//! invariant `tests/energy_ledger.rs` checks under churn.
//!
//! The **lumped** compat mode reproduces the retired per-lane
//! `EnergyMeter` arithmetic bit-for-bit (per-lane noise RNG, full lumped
//! curve per lane, `ends` = sender+receiver): every pre-refactor
//! single-transfer report regenerates byte-identically through it.
//!
//! [`EnergyPlane`] bundles what a session owns: one lumped ledger, or a
//! sender + receiver ledger pair built from the testbed's host definitions.

use super::power::PowerModel;
use super::rail::{CpuRail, FixedRail, NicRail, RailEnergy};
use crate::util::rng::mix_seed;
use crate::util::Rng;

/// Component-rail definition of one end host (see [`super::rail`]).
#[derive(Debug, Clone, PartialEq)]
pub struct HostSpec {
    /// Display name, e.g. `chameleon-tx`.
    pub name: String,
    pub cpu: CpuRail,
    pub nic: NicRail,
    pub fixed: FixedRail,
    /// Measurement-noise std-dev on the host power reading, W.
    pub noise_w: f64,
}

impl HostSpec {
    /// The efficient-engine host calibration: rails re-sum to the lumped
    /// [`PowerModel::efficient`] curve for a single active lane.
    pub fn efficient(name: impl Into<String>) -> HostSpec {
        HostSpec {
            name: name.into(),
            cpu: CpuRail::efficient(),
            nic: NicRail::efficient(),
            fixed: FixedRail::efficient(),
            noise_w: 0.8,
        }
    }

    /// This spec's static `1/n` slice of a physical host shared by `n`
    /// cluster shards — the incast receiver every sender-host session
    /// bills independently. Rails paid *once per host* (fixed engine
    /// residency, NIC LPI idle) and the noise scale divide by `n`, so
    /// summing the slices over all shards pays the physical host's
    /// residency exactly once; traffic-proportional rails (per-Gbps
    /// CPU/NIC, per-stream CPU, per-paused-lane idle) stay untouched —
    /// they already sum naturally across shards.
    pub fn share(mut self, n: usize) -> HostSpec {
        let n = n.max(1) as f64;
        self.fixed.active_w /= n;
        self.nic.lpi_idle_w /= n;
        self.noise_w /= n;
        self
    }

    /// Deterministic host power with `streams` total active streams moving
    /// `gbps` of goodput (no engine overhead, no paused lanes), W. For a
    /// single lane this equals the lumped efficient curve.
    pub fn power_w(&self, streams: usize, gbps: f64) -> f64 {
        self.fixed.active_w
            + self.cpu.stream_power_w(streams)
            + self.cpu.c_gbps_w * gbps
            + self.nic.c_gbps_w * gbps
    }

    /// The host-truth rail decomposition of [`HostSpec::power_w`] at one
    /// operating point (the Fig.-1b per-rail columns), W.
    pub fn rails_w(&self, streams: usize, gbps: f64) -> (f64, f64, f64) {
        (
            self.cpu.stream_power_w(streams) + self.cpu.c_gbps_w * gbps,
            self.nic.c_gbps_w * gbps,
            self.fixed.active_w,
        )
    }
}

/// One lane's footprint on a host during one MI, as observed by the
/// substrate. `streams`/`throughput_gbps`/`bytes` must be zero for paused
/// lanes (threads parked).
#[derive(Debug, Clone, Copy)]
pub struct LaneActivity {
    /// Lane index (admission order) — the ledger account id.
    pub lane: usize,
    pub streams: usize,
    pub throughput_gbps: f64,
    pub bytes: f64,
    pub duration_s: f64,
    pub paused: bool,
}

/// Energy attributed to one lane for one MI.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneBill {
    pub lane: usize,
    pub energy_j: f64,
    /// Component breakdown (None on the lumped compat rail).
    pub rails: Option<RailEnergy>,
}

/// Per-lane running account inside a ledger.
#[derive(Debug, Clone)]
struct Account {
    power: PowerModel,
    seed: u64,
    /// Per-lane noise RNG — only drawn from in lumped mode, where it
    /// reproduces the retired `EnergyMeter` draw sequence bit-for-bit.
    rng: Rng,
    total_j: f64,
    rails: RailEnergy,
}

#[derive(Debug, Clone)]
enum Mode {
    /// Compat: the full lumped curve billed per lane, `ends` hosts at once.
    Lumped { ends: f64 },
    /// Host truth: component rails integrated once per host, attributed.
    Rails(HostSpec),
}

/// The shared energy ledger of one end host (or, in lumped compat mode, of
/// the sender+receiver pair folded into one `ends`-scaled ledger).
#[derive(Debug, Clone)]
pub struct HostLedger {
    mode: Mode,
    seed: u64,
    /// Host-level noise RNG (rails mode).
    rng: Rng,
    accounts: Vec<Account>,
    total_j: f64,
    rails: RailEnergy,
}

impl HostLedger {
    /// Lumped compat ledger: per-lane `EnergyMeter` arithmetic, both ends.
    pub fn lumped(seed: u64) -> HostLedger {
        HostLedger {
            mode: Mode::Lumped { ends: 2.0 },
            seed,
            rng: Rng::new(seed),
            accounts: Vec::new(),
            total_j: 0.0,
            rails: RailEnergy::default(),
        }
    }

    /// Component-resolved ledger for one host.
    pub fn rails(spec: HostSpec, seed: u64) -> HostLedger {
        HostLedger {
            mode: Mode::Rails(spec),
            seed,
            rng: Rng::new(seed),
            accounts: Vec::new(),
            total_j: 0.0,
            rails: RailEnergy::default(),
        }
    }

    /// Open a lane account. `lane_seed` seeds the lane's noise RNG (lumped
    /// mode) and must derive from the admission index so replays reproduce
    /// the same draws.
    pub fn open_lane(&mut self, power: PowerModel, lane_seed: u64) -> usize {
        self.accounts.push(Account {
            power,
            seed: lane_seed,
            rng: Rng::new(lane_seed),
            total_j: 0.0,
            rails: RailEnergy::default(),
        });
        self.accounts.len() - 1
    }

    pub fn lane_count(&self) -> usize {
        self.accounts.len()
    }

    /// Energy attributed to a lane so far, joules.
    pub fn lane_total_j(&self, lane: usize) -> f64 {
        self.accounts[lane].total_j
    }

    pub fn lane_rails(&self, lane: usize) -> RailEnergy {
        self.accounts[lane].rails
    }

    /// Host-truth total so far, joules (integrated once per MI).
    pub fn total_j(&self) -> f64 {
        self.total_j
    }

    pub fn rails_total(&self) -> RailEnergy {
        self.rails
    }

    /// Clear totals *and* re-seed every noise RNG, so reset + rerun
    /// reproduces the same noise draws (the seed-era meter left its RNG
    /// advanced across resets).
    pub fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
        self.total_j = 0.0;
        self.rails = RailEnergy::default();
        for a in &mut self.accounts {
            a.rng = Rng::new(a.seed);
            a.total_j = 0.0;
            a.rails = RailEnergy::default();
        }
    }

    /// Settle one MI: integrate host power from the aggregate activity and
    /// return one bill per activity entry (same order). `bill_paused_lumped`
    /// gates whether the lumped compat mode bills paused lanes an idle
    /// sample (rails mode always bills paused lanes — host truth).
    pub fn settle_mi(
        &mut self,
        activity: &[LaneActivity],
        dur_s: f64,
        bill_paused_lumped: bool,
    ) -> Vec<LaneBill> {
        match &self.mode {
            Mode::Lumped { ends } => {
                let ends = *ends;
                let mut bills = Vec::with_capacity(activity.len());
                for a in activity {
                    let acct = &mut self.accounts[a.lane];
                    let e = if a.paused {
                        if bill_paused_lumped {
                            // Engine resident, nothing moving: the lumped
                            // curve at (0 streams, 0 Gbps).
                            acct.power.sample_power_w(0, 0.0, &mut acct.rng) * a.duration_s * ends
                        } else {
                            0.0
                        }
                    } else {
                        // Bit-identical to the seed-era EnergyMeter: sample
                        // per lane, scale by duration and ends.
                        acct.power.sample_power_w(a.streams, a.throughput_gbps, &mut acct.rng)
                            * a.duration_s
                            * ends
                    };
                    acct.total_j += e;
                    self.total_j += e;
                    bills.push(LaneBill { lane: a.lane, energy_j: e, rails: None });
                }
                bills
            }
            Mode::Rails(spec) => Self::settle_rails(
                spec,
                &mut self.accounts,
                &mut self.rng,
                &mut self.total_j,
                &mut self.rails,
                activity,
                dur_s,
            ),
        }
    }

    /// Rails-mode settlement (free of `&mut self` so the spec can stay
    /// borrowed from `self.mode` while accounts/totals are mutated — no
    /// per-MI clone of the spec).
    fn settle_rails(
        spec: &HostSpec,
        accounts: &mut [Account],
        rng: &mut Rng,
        ledger_total_j: &mut f64,
        ledger_rails: &mut RailEnergy,
        activity: &[LaneActivity],
        dur_s: f64,
    ) -> Vec<LaneBill> {
        if activity.is_empty() {
            return Vec::new();
        }
        let n_present = activity.len() as f64;
        let total_streams: usize = activity.iter().map(|a| a.streams).sum();
        let total_gbps: f64 = activity.iter().map(|a| a.throughput_gbps).sum();
        let total_bytes: f64 = activity.iter().map(|a| a.bytes).sum();
        let stream_w = spec.cpu.stream_power_w(total_streams);
        let nic_active = total_gbps > 0.0;

        // Deterministic per-lane rail watts first (they sum to host truth
        // by construction), then fold one host-level noise draw into each
        // lane proportionally so attribution still sums to the host total.
        let mut det: Vec<RailEnergy> = Vec::with_capacity(activity.len());
        for a in activity {
            let overhead_w = accounts[a.lane].power.engine_overhead_w_per_gbps;
            let stream_share_w = if total_streams > 0 {
                stream_w * a.streams as f64 / total_streams as f64
            } else {
                0.0
            };
            let cpu_w = stream_share_w + (spec.cpu.c_gbps_w + overhead_w) * a.throughput_gbps;
            let nic_w = if nic_active {
                if total_bytes > 0.0 {
                    // Proportional-to-bytes attribution of the NIC rail.
                    spec.nic.c_gbps_w * total_gbps * (a.bytes / total_bytes)
                } else {
                    0.0
                }
            } else {
                // Nothing moving anywhere: the NIC sits in LPI, shared.
                // An engine that keeps the link chatty holds the NIC out of
                // its deepest idle state, raising the floor for its lane.
                let engine_floor_w = accounts[a.lane].power.nic_lpi_idle_w;
                spec.nic.lpi_idle_w.max(engine_floor_w) / n_present
            };
            let fixed_w = spec.fixed.active_w / n_present;
            let idle_w = if a.paused { spec.fixed.lane_idle_w } else { 0.0 };
            det.push(RailEnergy {
                cpu_j: cpu_w * dur_s,
                nic_j: nic_w * dur_s,
                fixed_j: fixed_w * dur_s,
                idle_j: idle_w * dur_s,
            });
        }
        let det_total_j: f64 = det.iter().map(RailEnergy::total_j).sum();
        // One noise draw per host per MI, clamped so host power stays
        // non-negative (same guarantee the lumped sampler gives).
        let noise_j = (rng.normal_mean_sd(0.0, spec.noise_w) * dur_s).max(-det_total_j);
        // Fold the noise into every lane's rails proportionally (a RAPL
        // counter's jitter lands on component readings too), keeping
        // attribution summed exactly to the host total.
        let scale = if det_total_j > 0.0 { 1.0 + noise_j / det_total_j } else { 1.0 };

        let mut bills = Vec::with_capacity(activity.len());
        for (a, d) in activity.iter().zip(&det) {
            let billed = RailEnergy {
                cpu_j: d.cpu_j * scale,
                nic_j: d.nic_j * scale,
                fixed_j: d.fixed_j * scale,
                idle_j: d.idle_j * scale,
            };
            let e = billed.total_j();
            let acct = &mut accounts[a.lane];
            acct.total_j += e;
            acct.rails.add(&billed);
            *ledger_total_j += e;
            ledger_rails.add(&billed);
            bills.push(LaneBill { lane: a.lane, energy_j: e, rails: Some(billed) });
        }
        bills
    }
}

/// A captured lane account: accumulated energy plus the lane-noise RNG
/// position (advanced only in lumped mode). `power` and `seed` are
/// rebuild-time constants restored by replaying `open_lane`.
#[derive(Debug, Clone, PartialEq)]
pub struct AccountState {
    pub rng: [u64; 4],
    pub total_j: f64,
    pub rails: RailEnergy,
}

/// A captured [`HostLedger`]: host totals, the host-noise RNG position,
/// and one [`AccountState`] per opened lane. The mode and seeds are
/// rebuild-time constants.
#[derive(Debug, Clone, PartialEq)]
pub struct LedgerState {
    pub rng: [u64; 4],
    pub total_j: f64,
    pub rails: RailEnergy,
    pub accounts: Vec<AccountState>,
}

impl HostLedger {
    /// Capture the ledger's mutable state for checkpointing.
    pub fn export_state(&self) -> LedgerState {
        LedgerState {
            rng: self.rng.state(),
            total_j: self.total_j,
            rails: self.rails,
            accounts: self
                .accounts
                .iter()
                .map(|a| AccountState { rng: a.rng.state(), total_j: a.total_j, rails: a.rails })
                .collect(),
        }
    }

    /// Restore a [`HostLedger::export_state`] capture into a ledger rebuilt
    /// with the same mode and `open_lane` sequence. Returns `false` (ledger
    /// untouched) when the account counts disagree.
    pub fn import_state(&mut self, state: &LedgerState) -> bool {
        if self.accounts.len() != state.accounts.len() {
            return false;
        }
        self.rng = Rng::from_state(state.rng);
        self.total_j = state.total_j;
        self.rails = state.rails;
        for (a, s) in self.accounts.iter_mut().zip(&state.accounts) {
            a.rng = Rng::from_state(s.rng);
            a.total_j = s.total_j;
            a.rails = s.rails;
        }
        true
    }
}

/// What a session owns: one lumped compat ledger, or a sender + receiver
/// ledger pair resolved from the testbed's host definitions.
#[derive(Debug, Clone, Default)]
pub enum EnergyConfig {
    /// Per-lane lumped billing — the pre-refactor arithmetic, bit-for-bit.
    #[default]
    Lumped,
    /// Host-truth rails on both end hosts.
    Hosts { sender: HostSpec, receiver: HostSpec },
}

/// The session-side energy plane: every lane bills through it; it hides
/// whether accounting is lumped (one ledger, both ends folded) or
/// host-resolved (sender + receiver ledgers).
#[derive(Debug, Clone)]
pub struct EnergyPlane {
    ledgers: Vec<HostLedger>,
    host_resolved: bool,
}

impl EnergyPlane {
    pub fn new(cfg: EnergyConfig, seed: u64) -> EnergyPlane {
        match cfg {
            EnergyConfig::Lumped => EnergyPlane {
                ledgers: vec![HostLedger::lumped(seed)],
                host_resolved: false,
            },
            EnergyConfig::Hosts { sender, receiver } => EnergyPlane {
                ledgers: vec![
                    HostLedger::rails(sender, mix_seed(seed, "host/tx", 0)),
                    HostLedger::rails(receiver, mix_seed(seed, "host/rx", 0)),
                ],
                host_resolved: true,
            },
        }
    }

    pub fn host_resolved(&self) -> bool {
        self.host_resolved
    }

    /// Open a lane account on every ledger. `lane_seed` must derive from
    /// the admission index (see [`HostLedger::open_lane`]). Every ledger
    /// gets the same seed: account RNGs are only ever drawn in lumped mode,
    /// where there is exactly one ledger (so no two drawn RNGs can share a
    /// seed), and rails-mode ledgers draw host-level noise from their own
    /// ledger seeds instead.
    pub fn open_lane(&mut self, power: &PowerModel, lane_seed: u64) -> usize {
        let mut id = 0;
        for ledger in &mut self.ledgers {
            id = ledger.open_lane(power.clone(), lane_seed);
        }
        id
    }

    /// Settle one MI across all hosts; bills are summed per activity entry.
    pub fn settle_mi(
        &mut self,
        activity: &[LaneActivity],
        dur_s: f64,
        bill_paused_lumped: bool,
    ) -> Vec<LaneBill> {
        let mut out: Vec<LaneBill> = Vec::new();
        for ledger in &mut self.ledgers {
            let bills = ledger.settle_mi(activity, dur_s, bill_paused_lumped);
            if out.is_empty() {
                out = bills;
            } else {
                for (acc, b) in out.iter_mut().zip(&bills) {
                    acc.energy_j += b.energy_j;
                    match (&mut acc.rails, &b.rails) {
                        (Some(r), Some(br)) => r.add(br),
                        (None, Some(br)) => acc.rails = Some(*br),
                        _ => {}
                    }
                }
            }
        }
        out
    }

    /// Energy attributed to a lane so far across all hosts, joules.
    pub fn lane_total_j(&self, lane: usize) -> f64 {
        self.ledgers.iter().map(|l| l.lane_total_j(lane)).sum()
    }

    /// Host-truth total across all hosts, joules.
    pub fn host_total_j(&self) -> f64 {
        self.ledgers.iter().map(HostLedger::total_j).sum()
    }

    /// Combined rail breakdown (None on the lumped compat rail).
    pub fn rails_total(&self) -> Option<RailEnergy> {
        if !self.host_resolved {
            return None;
        }
        let mut total = RailEnergy::default();
        for l in &self.ledgers {
            total.add(&l.rails_total());
        }
        Some(total)
    }

    /// Per-lane combined rail breakdown (None on the lumped compat rail).
    pub fn lane_rails(&self, lane: usize) -> Option<RailEnergy> {
        if !self.host_resolved {
            return None;
        }
        let mut total = RailEnergy::default();
        for l in &self.ledgers {
            total.add(&l.lane_rails(lane));
        }
        Some(total)
    }

    /// Reset all ledgers, re-seeding every noise RNG (see
    /// [`HostLedger::reset`]).
    pub fn reset(&mut self) {
        for l in &mut self.ledgers {
            l.reset();
        }
    }

    /// Capture every ledger's mutable state, in ledger order (lumped: one;
    /// host-resolved: sender then receiver).
    pub fn export_state(&self) -> Vec<LedgerState> {
        self.ledgers.iter().map(HostLedger::export_state).collect()
    }

    /// Restore an [`EnergyPlane::export_state`] capture into a plane rebuilt
    /// with the same config and `open_lane` sequence. Returns `false` when
    /// the ledger or account shapes disagree (partially-restored ledgers are
    /// possible only on a shape mismatch, which callers treat as fatal).
    pub fn import_state(&mut self, state: &[LedgerState]) -> bool {
        if self.ledgers.len() != state.len() {
            return false;
        }
        self.ledgers.iter_mut().zip(state).all(|(l, s)| l.import_state(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyMeter;

    fn active(lane: usize, streams: usize, gbps: f64) -> LaneActivity {
        LaneActivity {
            lane,
            streams,
            throughput_gbps: gbps,
            bytes: gbps * 1e9 / 8.0,
            duration_s: 1.0,
            paused: false,
        }
    }

    fn paused(lane: usize) -> LaneActivity {
        LaneActivity {
            lane,
            streams: 0,
            throughput_gbps: 0.0,
            bytes: 0.0,
            duration_s: 1.0,
            paused: true,
        }
    }

    /// The lumped ledger reproduces the retired `EnergyMeter` bit-for-bit:
    /// same seed, same draw sequence, same arithmetic.
    #[test]
    fn lumped_ledger_matches_energy_meter_bits() {
        let mut ledger = HostLedger::lumped(1);
        ledger.open_lane(PowerModel::efficient(), 77);
        let mut meter = EnergyMeter::new(PowerModel::efficient(), 77);
        for mi in 0..20 {
            let gbps = (mi % 7) as f64;
            let bills = ledger.settle_mi(&[active(0, 4 + mi, gbps)], 1.0, false);
            let want = meter.record_mi(4 + mi, gbps, 1.0);
            assert_eq!(bills[0].energy_j.to_bits(), want.to_bits(), "mi {mi}");
        }
        assert_eq!(ledger.lane_total_j(0).to_bits(), meter.total_j().to_bits());
        assert_eq!(ledger.total_j().to_bits(), meter.total_j().to_bits());
    }

    /// Rails mode: per-lane attributed energy sums exactly to the host
    /// total, including paused lanes and the noise fold-in.
    #[test]
    fn rails_attribution_conserves_energy() {
        let mut ledger = HostLedger::rails(HostSpec::efficient("tx"), 3);
        for k in 0..4 {
            ledger.open_lane(PowerModel::efficient(), 100 + k);
        }
        for mi in 0..50 {
            let acts = vec![
                active(0, 16, 3.0 + (mi % 3) as f64),
                active(1, 4, 1.0),
                paused(2),
                active(3, 8, 0.5),
            ];
            ledger.settle_mi(&acts, 1.0, false);
        }
        let attributed: f64 = (0..4).map(|l| ledger.lane_total_j(l)).sum();
        let host = ledger.total_j();
        assert!(
            (attributed - host).abs() <= 1e-9 * host.max(1.0),
            "attributed={attributed} host={host}"
        );
        // Rail breakdown also conserves.
        assert!((ledger.rails_total().total_j() - host).abs() <= 1e-9 * host.max(1.0));
        // The paused lane was billed the idle rail, not nothing.
        assert!(ledger.lane_total_j(2) > 0.0);
        assert!(ledger.lane_rails(2).idle_j > 0.0);
        assert_eq!(ledger.lane_rails(2).cpu_j, 0.0);
    }

    /// Fixed power is paid once per host: the fixed-rail energy of an MI is
    /// independent of how many lanes share the host.
    #[test]
    fn fixed_rail_not_multiplied_by_lane_count() {
        let run = |n: usize| {
            let mut ledger = HostLedger::rails(HostSpec::efficient("tx"), 5);
            for k in 0..n {
                ledger.open_lane(PowerModel::efficient(), k as u64);
            }
            let acts: Vec<LaneActivity> = (0..n).map(|l| active(l, 4, 2.0)).collect();
            ledger.settle_mi(&acts, 1.0, false);
            ledger.rails_total()
        };
        let one = run(1);
        let four = run(4);
        // Noise perturbs the reading; compare within a few sigma.
        assert!(
            (four.fixed_j - one.fixed_j).abs() < 5.0,
            "one={} four={}",
            one.fixed_j,
            four.fixed_j
        );
        assert!(one.fixed_j > 10.0 && four.fixed_j < 2.0 * 18.0);
    }

    /// Reset re-seeds the noise RNGs: reset + rerun reproduces the same
    /// draws (the seed-era meter kept its RNG advanced).
    #[test]
    fn reset_reseeds_noise_rng() {
        let mut ledger = HostLedger::rails(HostSpec::efficient("tx"), 9);
        ledger.open_lane(PowerModel::efficient(), 1);
        let first: Vec<f64> = (0..5)
            .map(|_| ledger.settle_mi(&[active(0, 8, 2.0)], 1.0, false)[0].energy_j)
            .collect();
        ledger.reset();
        assert_eq!(ledger.total_j(), 0.0);
        let second: Vec<f64> = (0..5)
            .map(|_| ledger.settle_mi(&[active(0, 8, 2.0)], 1.0, false)[0].energy_j)
            .collect();
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.to_bits(), b.to_bits(), "reset did not re-seed the noise RNG");
        }
    }

    /// An all-paused host drops to LPI + fixed + per-lane idle keepalive —
    /// far below an active host, but not zero.
    #[test]
    fn paused_host_draws_idle_not_zero() {
        let mut ledger = HostLedger::rails(HostSpec::efficient("tx"), 11);
        ledger.open_lane(PowerModel::efficient(), 1);
        ledger.open_lane(PowerModel::efficient(), 2);
        let bills = ledger.settle_mi(&[paused(0), paused(1)], 1.0, false);
        let total: f64 = bills.iter().map(|b| b.energy_j).sum();
        // fixed 18 + LPI 1 + 2×2.5 idle ≈ 24 J, ± noise.
        assert!(total > 10.0 && total < 40.0, "total={total}");
        let active_total: f64 = {
            let mut l2 = HostLedger::rails(HostSpec::efficient("tx"), 11);
            l2.open_lane(PowerModel::efficient(), 1);
            l2.open_lane(PowerModel::efficient(), 2);
            l2.settle_mi(&[active(0, 16, 4.0), active(1, 16, 4.0)], 1.0, false)
                .iter()
                .map(|b| b.energy_j)
                .sum()
        };
        assert!(active_total > 2.0 * total, "active={active_total} idle={total}");
    }

    /// The plane folds sender + receiver hosts; lumped stays single-ledger.
    #[test]
    fn plane_sums_both_hosts() {
        let cfg = EnergyConfig::Hosts {
            sender: HostSpec::efficient("tx"),
            receiver: HostSpec::efficient("rx"),
        };
        let mut plane = EnergyPlane::new(cfg, 7);
        assert!(plane.host_resolved());
        plane.open_lane(&PowerModel::efficient(), 42);
        let bills = plane.settle_mi(&[active(0, 8, 2.0)], 1.0, false);
        // Two hosts ≈ twice one host's deterministic power (±noise).
        let one_host = HostSpec::efficient("tx").power_w(8, 2.0);
        assert!((bills[0].energy_j - 2.0 * one_host).abs() < 6.0 * 0.8 * 2.0 + 1.0);
        assert!((plane.host_total_j() - plane.lane_total_j(0)).abs() < 1e-12);
        let mut lumped = EnergyPlane::new(EnergyConfig::Lumped, 7);
        assert!(!lumped.host_resolved());
        lumped.open_lane(&PowerModel::efficient(), 42);
        assert!(lumped.rails_total().is_none());
    }
}
