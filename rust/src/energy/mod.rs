//! End-system energy model — the RAPL analogue.
//!
//! The paper measures sender/receiver energy with Intel RAPL and subtracts
//! each system's baseline power to isolate transfer energy. Physical counters
//! are unavailable here, so this module models the *dynamic* (above-baseline)
//! power of an end host during a transfer:
//!
//! ```text
//! P_dyn = P_fixed + c_stream · N^0.9 + c_gbps · T + noise
//! ```
//!
//! * `P_fixed` — cost of having the transfer engine running at all (event
//!   loops, timers, page cache churn).
//! * `c_stream · N^0.9` — per-active-stream CPU cost (interrupts, context
//!   switches, TCP bookkeeping); mildly sub-linear because cores batch work.
//! * `c_gbps · T` — per-bit cost of moving data (copies, checksums, DMA,
//!   NIC + memory power).
//!
//! The model keeps the two gradients the paper's T/E reward learns from:
//! excess streams burn power without adding goodput, and slow transfers burn
//! fixed power for longer. `EnergyMeter` integrates power per monitoring
//! interval exactly as a RAPL poller would.

pub mod meter;
pub mod power;

pub use meter::EnergyMeter;
pub use power::PowerModel;
