//! End-system energy model — the RAPL analogue, host-scoped and
//! component-resolved.
//!
//! The paper measures sender/receiver energy with Intel RAPL and subtracts
//! each system's baseline power to isolate transfer energy. Physical
//! counters are unavailable here, so this module models the *dynamic*
//! (above-baseline) power of the end hosts during transfers, at two levels
//! of resolution:
//!
//! **Component rails + host ledger** ([`rail`], [`host`]) — the accounting
//! substrate for multi-lane hosts. Each end host carries three rails:
//!
//! ```text
//! P_host = fixed.active_w                      (engine resident, once per host)
//!        + c_stream · (Σ_l N_l)^0.9            (CPU: shared stream bookkeeping)
//!        + (c_gbps_cpu + overhead_l) · T_l     (CPU: data-touching, per lane)
//!        + c_gbps_nic · Σ_l T_l  |  LPI idle   (NIC: per-bit, or low-power idle)
//!        + lane_idle_w · #paused               (idle rail: paused-lane keepalive)
//! ```
//!
//! A [`HostLedger`] shared by all colocated lanes integrates that host
//! truth once per monitoring interval and *attributes* it back to lanes —
//! CPU proportional to streams, NIC proportional to bytes, fixed rail as
//! an equal share, paused lanes billed the idle rail instead of vanishing.
//! Attributed lane energy always sums to the host total (the conservation
//! invariant), and an N-lane fleet pays fixed power once, not N times.
//!
//! **Lumped compat curve** ([`power`], [`meter`]) — the seed model
//! `P_dyn = P_fixed + c_stream·N^0.9 + c_gbps·T + noise`, billed per lane.
//! [`HostLedger`] in lumped mode (the session default) reproduces the
//! per-lane [`EnergyMeter`] arithmetic bit-for-bit, which keeps every
//! pre-refactor single-transfer report byte-identical. The rail
//! calibration re-sums to this curve for a single active lane, so the two
//! resolutions agree where they overlap.
//!
//! Both keep the gradients the paper's T/E reward learns from: excess
//! streams burn power without adding goodput, slow transfers burn fixed
//! power for longer — and, new with the ledger, pausing is *not* free.

pub mod host;
pub mod meter;
pub mod power;
pub mod rail;

pub use host::{
    AccountState, EnergyConfig, EnergyPlane, HostLedger, HostSpec, LaneActivity, LaneBill,
    LedgerState,
};
pub use meter::EnergyMeter;
pub use power::PowerModel;
pub use rail::{CpuRail, FixedRail, NicRail, RailEnergy};
