//! Component power rails of an end host.
//!
//! The lumped [`crate::energy::PowerModel`] curve folds every power
//! consumer of an end system into one polynomial. The rail model splits it
//! into the components the related DVFS/core-scaling literature tunes
//! independently:
//!
//! * [`CpuRail`] — per-stream bookkeeping cost (interrupts, context
//!   switches, TCP state), sublinear in the *host's total* stream count
//!   because cores batch work across transfer applications, plus the
//!   data-touching CPU cost (copies, checksums) per Gbps;
//! * [`NicRail`] — per-bit cost of moving data through the NIC + memory
//!   subsystem, with a low-power-idle (LPI) state when no lane is moving
//!   bytes;
//! * [`FixedRail`] — cost of having the transfer engine resident at all
//!   (event loops, timers, page-cache churn), paid **once per host** no
//!   matter how many lanes are colocated, plus the per-lane idle cost of
//!   holding a *paused* lane's session open (sockets, timers, pinned
//!   buffers) — the energy price of preemption.
//!
//! The default calibration ([`CpuRail::efficient`] etc.) is chosen so that
//! a single-lane host resolves to exactly the same deterministic power as
//! the lumped curve: `fixed.active_w + cpu.c_stream_w·N^0.9 +
//! (cpu.c_gbps_w + nic.c_gbps_w)·T` with `cpu.c_gbps_w + nic.c_gbps_w =
//! PowerModel::efficient().c_gbps_w`.

/// Energy split by component rail, joules (one MI or accumulated).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RailEnergy {
    /// CPU rail: stream bookkeeping + data-touching cycles + engine overhead.
    pub cpu_j: f64,
    /// NIC rail: per-bit transport cost (or LPI idle when nothing moves).
    pub nic_j: f64,
    /// Fixed rail: engine-resident cost, shared equally by colocated lanes.
    pub fixed_j: f64,
    /// Idle rail: per-paused-lane session-keepalive cost.
    pub idle_j: f64,
}

impl RailEnergy {
    pub fn total_j(&self) -> f64 {
        self.cpu_j + self.nic_j + self.fixed_j + self.idle_j
    }

    pub fn add(&mut self, other: &RailEnergy) {
        self.cpu_j += other.cpu_j;
        self.nic_j += other.nic_j;
        self.fixed_j += other.fixed_j;
        self.idle_j += other.idle_j;
    }
}

/// CPU rail: transfer-thread bookkeeping plus data-touching cycles.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CpuRail {
    /// W per (total host streams)^`stream_exp`.
    pub c_stream_w: f64,
    /// Sublinearity of stream cost in the host's total stream count.
    pub stream_exp: f64,
    /// Data-touching CPU cost (copies, checksums), W per Gbps.
    pub c_gbps_w: f64,
}

impl CpuRail {
    pub fn efficient() -> CpuRail {
        CpuRail { c_stream_w: 0.85, stream_exp: 0.9, c_gbps_w: 2.5 }
    }

    /// Shared stream-bookkeeping power for `total_streams` active streams
    /// across *all* lanes on the host, W.
    pub fn stream_power_w(&self, total_streams: usize) -> f64 {
        if total_streams == 0 {
            return 0.0;
        }
        self.c_stream_w * (total_streams as f64).powf(self.stream_exp)
    }
}

/// NIC + memory-subsystem rail.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NicRail {
    /// Per-bit transport cost, W per Gbps of goodput.
    pub c_gbps_w: f64,
    /// Low-power-idle (LPI) draw when lanes are present but nothing moves, W.
    pub lpi_idle_w: f64,
}

impl NicRail {
    pub fn efficient() -> NicRail {
        NicRail { c_gbps_w: 3.5, lpi_idle_w: 1.0 }
    }
}

/// Fixed/idle rail: engine residency (per host) and paused-lane keepalive
/// (per paused lane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FixedRail {
    /// Engine-resident power while any lane is hosted, W — paid once per
    /// host, never once per lane.
    pub active_w: f64,
    /// Keepalive power of one externally-paused lane (sockets, timers,
    /// pinned buffers), W.
    pub lane_idle_w: f64,
}

impl FixedRail {
    pub fn efficient() -> FixedRail {
        FixedRail { active_w: 18.0, lane_idle_w: 2.5 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rail_energy_totals_and_adds() {
        let mut a = RailEnergy { cpu_j: 1.0, nic_j: 2.0, fixed_j: 3.0, idle_j: 4.0 };
        assert_eq!(a.total_j(), 10.0);
        a.add(&RailEnergy { cpu_j: 0.5, ..RailEnergy::default() });
        assert_eq!(a.cpu_j, 1.5);
        assert_eq!(a.total_j(), 10.5);
    }

    #[test]
    fn cpu_stream_power_sublinear_and_zero_safe() {
        let cpu = CpuRail::efficient();
        assert_eq!(cpu.stream_power_w(0), 0.0);
        let p10 = cpu.stream_power_w(10);
        let p20 = cpu.stream_power_w(20);
        assert!(p20 > p10 && p20 < 2.0 * p10, "p10={p10} p20={p20}");
    }

    /// The rail calibration re-sums to the lumped efficient curve's
    /// coefficients (what keeps single-lane host truth aligned with the
    /// compat rail).
    #[test]
    fn efficient_rails_resum_to_lumped_curve() {
        let lumped = crate::energy::PowerModel::efficient();
        let cpu = CpuRail::efficient();
        let nic = NicRail::efficient();
        let fixed = FixedRail::efficient();
        assert_eq!(cpu.c_gbps_w + nic.c_gbps_w, lumped.c_gbps_w);
        assert_eq!(cpu.c_stream_w, lumped.c_stream_w);
        assert_eq!(fixed.active_w, lumped.p_fixed_w);
    }
}
