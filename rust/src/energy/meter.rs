//! Per-monitoring-interval energy integration (the RAPL poller analogue).

use super::power::PowerModel;
use crate::util::Rng;

/// Integrates end-system energy over monitoring intervals.
///
/// The paper reports *combined* sender + receiver energy with baseline power
/// subtracted; we model both ends with the same dynamic-power curve, so the
/// reported energy is `2 × ∫ P_dyn dt` (configurable via `ends`).
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    model: PowerModel,
    /// Number of end systems accounted (2 = sender + receiver).
    pub ends: f64,
    total_j: f64,
    seed: u64,
    rng: Rng,
}

impl EnergyMeter {
    pub fn new(model: PowerModel, seed: u64) -> EnergyMeter {
        EnergyMeter { model, ends: 2.0, total_j: 0.0, seed, rng: Rng::new(seed) }
    }

    pub fn model(&self) -> &PowerModel {
        &self.model
    }

    /// Record one MI: returns the energy consumed during it (joules).
    pub fn record_mi(&mut self, streams: usize, throughput_gbps: f64, dur_s: f64) -> f64 {
        let p = self.model.sample_power_w(streams, throughput_gbps, &mut self.rng);
        let e = p * dur_s * self.ends;
        self.total_j += e;
        e
    }

    /// Total energy so far, joules.
    pub fn total_j(&self) -> f64 {
        self.total_j
    }

    /// Clear the total *and* re-seed the noise RNG, so reset + rerun
    /// reproduces the same noise draws (previously only `total_j` was
    /// cleared, leaving the RNG advanced and resets non-reproducible).
    pub fn reset(&mut self) {
        self.total_j = 0.0;
        self.rng = Rng::new(self.seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_over_intervals() {
        let mut m = EnergyMeter::new(PowerModel::efficient(), 1);
        let e1 = m.record_mi(16, 5.0, 1.0);
        let e2 = m.record_mi(16, 5.0, 1.0);
        assert!(e1 > 0.0 && e2 > 0.0);
        assert!((m.total_j() - (e1 + e2)).abs() < 1e-9);
    }

    #[test]
    fn both_ends_counted() {
        let mut two = EnergyMeter::new(PowerModel::efficient(), 2);
        let mut one = EnergyMeter::new(PowerModel::efficient(), 2);
        one.ends = 1.0;
        let e2 = two.record_mi(4, 2.0, 1.0);
        let e1 = one.record_mi(4, 2.0, 1.0);
        // Same seed -> same noise draw; exactly double.
        assert!((e2 - 2.0 * e1).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_total() {
        let mut m = EnergyMeter::new(PowerModel::efficient(), 3);
        m.record_mi(4, 2.0, 1.0);
        m.reset();
        assert_eq!(m.total_j(), 0.0);
    }

    /// Reset re-seeds the noise RNG: the same record sequence after reset
    /// reproduces the same draws bit-for-bit.
    #[test]
    fn reset_reseeds_noise_rng() {
        let mut m = EnergyMeter::new(PowerModel::efficient(), 5);
        let first: Vec<u64> = (0..5).map(|i| m.record_mi(4 + i, 2.0, 1.0).to_bits()).collect();
        m.reset();
        let second: Vec<u64> = (0..5).map(|i| m.record_mi(4 + i, 2.0, 1.0).to_bits()).collect();
        assert_eq!(first, second, "reset left the RNG advanced");
    }

    #[test]
    fn idle_slow_transfer_still_burns_fixed_power() {
        let mut m = EnergyMeter::new(PowerModel::efficient(), 4);
        let e = m.record_mi(1, 0.1, 1.0);
        assert!(e > 10.0, "fixed power should dominate: {e}");
    }
}
