//! File-set transfer job with byte-accurate progress.

/// A transfer job: an ordered set of files to deliver.
#[derive(Debug, Clone)]
pub struct TransferJob {
    /// File sizes in bytes.
    pub file_bytes: Vec<u64>,
    /// Bytes delivered so far (monotone).
    delivered: f64,
}

impl TransferJob {
    /// `count` files of `size_bytes` each (the paper's 1000 × 1 GB workload).
    pub fn files(count: usize, size_bytes: u64) -> TransferJob {
        TransferJob { file_bytes: vec![size_bytes; count], delivered: 0.0 }
    }

    /// A job from explicit file sizes (for mixed workloads).
    pub fn from_sizes(sizes: Vec<u64>) -> TransferJob {
        TransferJob { file_bytes: sizes, delivered: 0.0 }
    }

    pub fn total_bytes(&self) -> f64 {
        self.file_bytes.iter().map(|&b| b as f64).sum()
    }

    pub fn delivered_bytes(&self) -> f64 {
        self.delivered
    }

    pub fn remaining_bytes(&self) -> f64 {
        (self.total_bytes() - self.delivered).max(0.0)
    }

    /// Record progress; returns the bytes actually credited (clamped so the
    /// job never over-delivers).
    pub fn advance(&mut self, bytes: f64) -> f64 {
        let credit = bytes.min(self.remaining_bytes()).max(0.0);
        self.delivered += credit;
        credit
    }

    pub fn is_complete(&self) -> bool {
        self.remaining_bytes() <= 0.5 // sub-byte residue counts as done
    }

    /// Fraction complete in [0, 1].
    pub fn progress(&self) -> f64 {
        let t = self.total_bytes();
        if t <= 0.0 { 1.0 } else { (self.delivered / t).min(1.0) }
    }

    /// Number of files fully delivered (files complete in order).
    pub fn files_complete(&self) -> usize {
        let mut acc = 0.0;
        let mut n = 0;
        for &b in &self.file_bytes {
            acc += b as f64;
            if self.delivered + 0.5 >= acc {
                n += 1;
            } else {
                break;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_accumulates_and_clamps() {
        let mut j = TransferJob::files(2, 100);
        assert_eq!(j.total_bytes(), 200.0);
        assert_eq!(j.advance(150.0), 150.0);
        assert!(!j.is_complete());
        // Over-delivery clamps.
        assert_eq!(j.advance(100.0), 50.0);
        assert!(j.is_complete());
        assert_eq!(j.progress(), 1.0);
    }

    #[test]
    fn files_complete_counts_in_order() {
        let mut j = TransferJob::from_sizes(vec![100, 200, 300]);
        j.advance(250.0);
        assert_eq!(j.files_complete(), 1);
        j.advance(50.0);
        assert_eq!(j.files_complete(), 2);
        j.advance(1000.0);
        assert_eq!(j.files_complete(), 3);
    }

    #[test]
    fn negative_advance_ignored() {
        let mut j = TransferJob::files(1, 100);
        assert_eq!(j.advance(-5.0), 0.0);
        assert_eq!(j.delivered_bytes(), 0.0);
    }

    #[test]
    fn empty_job_is_complete() {
        let j = TransferJob::from_sizes(vec![]);
        assert!(j.is_complete());
        assert_eq!(j.progress(), 1.0);
    }
}
