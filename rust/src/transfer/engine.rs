//! Transfer-engine profiles.
//!
//! The tools the paper compares differ in their data-path efficiency: rclone
//! and escp spend CPU on per-chunk hashing / encryption, which caps each
//! file-task's I/O rate and raises per-bit power; SPARTA, Falcon_MP and
//! 2-phase share an efficient zero-copy engine. The profile carries both the
//! I/O cap (consumed by the network simulator) and the power model (consumed
//! by the energy meter).

use crate::energy::PowerModel;

/// Engine characteristics of a transfer tool.
#[derive(Debug, Clone)]
pub struct EngineProfile {
    pub name: &'static str,
    /// Per-file-task application I/O rate cap, as a fraction of the
    /// testbed's efficient-engine `task_io_gbps` (1.0 = full speed).
    pub io_efficiency: f64,
    /// Dynamic power model for this engine.
    pub power: PowerModel,
}

impl EngineProfile {
    /// The efficient engine (SPARTA, Falcon_MP, 2-phase).
    pub fn efficient() -> EngineProfile {
        EngineProfile { name: "efficient", io_efficiency: 1.0, power: PowerModel::efficient() }
    }

    /// rclone: chunked HTTP with hashing — task I/O capped at ~45%.
    pub fn rclone() -> EngineProfile {
        EngineProfile { name: "rclone", io_efficiency: 0.45, power: PowerModel::rclone() }
    }

    /// escp: encrypted transport — task I/O capped at ~40%.
    pub fn escp() -> EngineProfile {
        EngineProfile { name: "escp", io_efficiency: 0.40, power: PowerModel::escp() }
    }

    /// Task I/O cap in Gbps on a testbed whose efficient-engine rate is
    /// `testbed_task_io_gbps`.
    pub fn task_io_gbps(&self, testbed_task_io_gbps: f64) -> f64 {
        self.io_efficiency * testbed_task_io_gbps
    }

    /// The NIC idle floor this engine holds the link to when none of its
    /// lanes move bytes, W. Chatty engines (rclone keepalives, escp's
    /// control channel) keep the NIC out of deep LPI; the host-rail ledger
    /// bills whichever is shallower — this floor or the host NIC's own
    /// LPI draw.
    pub fn nic_lpi_idle_w(&self) -> f64 {
        self.power.nic_lpi_idle_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tools_slower_than_efficient() {
        let e = EngineProfile::efficient();
        let r = EngineProfile::rclone();
        let s = EngineProfile::escp();
        assert!(r.task_io_gbps(3.0) < e.task_io_gbps(3.0));
        assert!(s.task_io_gbps(3.0) < r.task_io_gbps(3.0) + 0.2);
    }

    #[test]
    fn rclone_static_44_lands_in_paper_band() {
        // 4 tasks x 1.35 Gbps I/O cap = 5.4 Gbps max on chameleon — the
        // paper's 4-6 Gbps band for static tools.
        let r = EngineProfile::rclone();
        let cap = 4.0 * r.task_io_gbps(3.0);
        assert!(cap > 4.0 && cap < 6.5, "cap={cap}");
    }

    #[test]
    fn engines_carry_their_own_nic_idle_states() {
        let e = EngineProfile::efficient();
        let r = EngineProfile::rclone();
        let s = EngineProfile::escp();
        assert!(e.nic_lpi_idle_w() < r.nic_lpi_idle_w());
        assert!(r.nic_lpi_idle_w() < s.nic_lpi_idle_w());
    }
}
