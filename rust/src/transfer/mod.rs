//! Transfer-job model: the file set being moved and the engine moving it.
//!
//! A [`TransferJob`] is the unit the paper evaluates: a set of files (e.g.
//! 1000 × 1 GB) pushed from a sender to a receiver by an engine holding `cc`
//! concurrent file-tasks with `p` parallel streams each. Byte progress is
//! integrated from the simulator's per-MI goodput; the job completes when
//! every file is delivered.

pub mod engine;
pub mod job;

pub use engine::EngineProfile;
pub use job::TransferJob;
