//! `sparta` — the CLI entry point / launcher.
//!
//! ```text
//! sparta info                         # artifacts, testbeds, trained weights
//! sparta scenarios                    # list registered evaluation scenarios
//! sparta collect  --testbed chameleon --scale quick
//! sparta train    --algo rppo --reward te --scale quick
//! sparta train    --algo linq --scenario lossy-wan  # scenario-scoped weights
//! sparta train-all --scale quick      # all 5 algos x both rewards
//! sparta generalize --scale quick     # train x eval scenario matrix
//! sparta transfer --method sparta-fe --scenario lossy-wan
//! sparta fleet    --schedule churn-heavy           # arrivals/departures
//! sparta serve    --schedule open-loop --events ev.jsonl  # resident daemon
//! sparta serve-ctl '{"cmd":"status"}'              # poke the daemon
//! sparta sweep    --testbed chameleon             # Fig 1
//! sparta algos    --reward te                     # Fig 4
//! sparta tune                                      # Fig 5
//! sparta compare  --scenario receiver-limited      # Fig 6
//! sparta fairness                                  # Fig 7
//! sparta table1                                    # Table 1
//! ```

use anyhow::{anyhow, Result};
use sparta::config::Paths;
use sparta::coordinator::{LaneSpec, RewardKind, Session, SessionBuilder, DEFAULT_MAX_MIS};
use sparta::experiments::{self, make_optimizer, Scale, SpartaCtx, TrainSource};
use sparta::faults::FaultSchedule;
use sparta::net::Testbed;
use sparta::scenarios::{ArrivalSchedule, Scenario};
use sparta::telemetry::report::lane_json;
use sparta::telemetry::{save_report, FanoutSink, JsonlSink, ReportSink, Table};
use sparta::transfer::TransferJob;
use sparta::util::cli::Args;
use sparta::util::json::Json;
use std::io::Write;
use std::path::Path;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        sparta::util::log::set_level(0);
    }
    if args.flag("verbose") {
        sparta::util::log::set_level(2);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn testbed_arg(args: &Args) -> Result<Testbed> {
    let name = args.get_or("testbed", "chameleon");
    Testbed::by_name(name).ok_or_else(|| anyhow!("unknown testbed '{name}'"))
}

/// `--scenario <name>` when given (see `sparta scenarios` for the registry).
/// A scenario pins its own testbed, so combining it with `--testbed` is
/// rejected rather than silently ignoring one of the two.
fn scenario_arg(args: &Args) -> Result<Option<Scenario>> {
    match args.get("scenario") {
        None => Ok(None),
        Some(name) => {
            if args.get("testbed").is_some() {
                return Err(anyhow!(
                    "--scenario and --testbed conflict: scenario '{name}' already \
                     pins its testbed (drop one of the two flags)"
                ));
            }
            Scenario::by_name(name).map(Some).ok_or_else(|| {
                anyhow!("unknown scenario '{name}' — `sparta scenarios` lists the registry")
            })
        }
    }
}

/// Parse a comma-separated scenario list against the registry.
fn parse_scenarios(list: &str) -> Result<Vec<Scenario>> {
    list.split(',')
        .map(|n| {
            let n = n.trim();
            Scenario::by_name(n).ok_or_else(|| {
                anyhow!("unknown scenario '{n}' — `sparta scenarios` lists the registry")
            })
        })
        .collect()
}

/// `--scenario a,b,c` as a list, defaulting to the three testbed presets;
/// `--scenario all` iterates the full registry.
fn scenario_list_arg(args: &Args) -> Result<Vec<Scenario>> {
    match args.get("scenario") {
        None => Ok(Scenario::defaults()),
        Some("all") => Ok(Scenario::all()),
        Some(list) => parse_scenarios(list),
    }
}

fn ctx() -> Result<SpartaCtx> {
    SpartaCtx::load(Paths::resolve())
}

/// The flag surface the experiment arms share — `--scenario`, `--jobs`,
/// `--out`, `--events`, `--observe-paused`, `--step-threads` — parsed
/// once, in one place, so
/// `compare`/`sweep`/`fleet`/`transfer`/`bench`/`serve` can't drift apart
/// in spelling or defaults. Arms consume the subset that applies and
/// [`CommonOpts::forbid`] the rest: a flag a subcommand cannot honor is a
/// loud error, never silently ignored.
struct CommonOpts<'a> {
    scenario: Option<&'a str>,
    jobs: usize,
    /// Whether `--jobs` was given explicitly (vs the all-cores default) —
    /// lets `bench`, which always times at jobs 1, reject it loudly.
    jobs_given: bool,
    out: Option<&'a str>,
    events: Option<&'a str>,
    observe_paused: bool,
    /// Intra-step cluster workers for multi-host stepping (fleet/serve/
    /// bench); `None` = flag not given (auto / serial per arm).
    step_threads: Option<usize>,
    /// Seeded fault preset (fleet/serve/bench chaos runs).
    faults: Option<&'a str>,
}

impl<'a> CommonOpts<'a> {
    fn parse(args: &'a Args) -> Result<CommonOpts<'a>> {
        Ok(CommonOpts {
            scenario: args.get("scenario"),
            jobs: args.get_usize("jobs", experiments::default_jobs()).map_err(|e| anyhow!(e))?,
            jobs_given: args.get("jobs").is_some(),
            out: args.get("out"),
            events: args.get("events"),
            observe_paused: args.flag("observe-paused"),
            step_threads: match args.get("step-threads") {
                None => None,
                Some(_) => {
                    Some(args.get_usize("step-threads", 0).map_err(|e| anyhow!(e))?)
                }
            },
            faults: args.get("faults"),
        })
    }

    /// Resolve `--faults` against the preset registry (None when the flag
    /// was not given; a loud error on an unknown name).
    fn fault_schedule(&self) -> Result<Option<&'static FaultSchedule>> {
        match self.faults {
            None => Ok(None),
            Some(name) => FaultSchedule::by_name(name).map(Some).ok_or_else(|| {
                anyhow!(
                    "unknown fault preset '{name}' (have: {})",
                    FaultSchedule::names().join(", ")
                )
            }),
        }
    }

    /// Write the machine-readable report when `--out` was given — the one
    /// save path every arm shares.
    fn save(&self, json: &Json) -> Result<()> {
        if let Some(out) = self.out {
            save_report(Path::new(out), json)?;
            println!("report written to {out}");
        }
        Ok(())
    }

    /// Reject common flags this subcommand cannot honor, with uniform
    /// error text.
    fn forbid(&self, cmd: &str, flags: &[&str]) -> Result<()> {
        for f in flags {
            let given = match *f {
                "scenario" => self.scenario.is_some(),
                "jobs" => self.jobs_given,
                "out" => self.out.is_some(),
                "events" => self.events.is_some(),
                "observe-paused" => self.observe_paused,
                "step-threads" => self.step_threads.is_some(),
                "faults" => self.faults.is_some(),
                other => unreachable!("unknown common flag '{other}'"),
            };
            if given {
                return Err(anyhow!("--{f} is not supported by `sparta {cmd}`"));
            }
        }
        Ok(())
    }
}

/// `--methods a,b,c` on `compare`, defaulting to the paper's six methods.
fn methods_arg(args: &Args) -> Vec<String> {
    match args.get("methods") {
        None => experiments::common::METHODS.iter().map(|m| m.to_string()).collect(),
        Some(list) => list.split(',').map(|m| m.trim().to_string()).collect(),
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let scale = Scale::by_name(args.get_or("scale", "quick"));
    let seed = args.get_u64("seed", 42).map_err(|e| anyhow!(e))?;
    let common = CommonOpts::parse(args)?;
    let jobs = common.jobs;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{}", HELP);
            Ok(())
        }
        Some("info") => info(),
        Some("scenarios") => {
            println!("registered scenarios (use with --scenario <name>):");
            let mut t = Table::new(&["name", "testbed", "path", "description"]);
            for sc in Scenario::all() {
                let path = sc
                    .topology
                    .segments
                    .iter()
                    .map(|s| format!("{} {:.0}G", s.name, s.capacity_gbps))
                    .collect::<Vec<_>>()
                    .join(" -> ");
                t.row(vec![
                    sc.name.into(),
                    sc.testbed.name.into(),
                    path,
                    sc.summary.into(),
                ]);
            }
            t.print();
            println!("\narrival schedules (use with `sparta fleet`/`sparta serve --schedule <name>`):");
            let mut t = Table::new(&["name", "scenario", "horizon", "description"]);
            for sched in ArrivalSchedule::all() {
                t.row(vec![
                    sched.name.into(),
                    sched.scenario.name.into(),
                    format!("{} MIs", sched.horizon_mis),
                    sched.summary.into(),
                ]);
            }
            t.print();
            println!("\nfault presets (use with `sparta fleet`/`serve`/`bench --faults <name>`):");
            let mut t = Table::new(&["name", "description"]);
            for sched in FaultSchedule::all() {
                t.row(vec![sched.name.into(), sched.summary.into()]);
            }
            t.print();
            Ok(())
        }
        Some("collect") => {
            common.forbid("collect", &["step-threads", "faults"])?;
            let c = ctx()?;
            match scenario_arg(args)? {
                Some(sc) => {
                    let ts = experiments::transitions_for_scenario(&c, &sc, scale, seed)?;
                    println!("{} transitions cached for scenario {}", ts.len(), sc.name);
                }
                None => {
                    let tb = testbed_arg(args)?;
                    let ts = experiments::common::transitions_for(&c, &tb, scale, seed)?;
                    println!("{} transitions cached for {}", ts.len(), tb.name);
                }
            }
            Ok(())
        }
        Some("train") => {
            common.forbid("train", &["step-threads", "faults"])?;
            let c = ctx()?;
            let algo = args.get_or("algo", "rppo").to_string();
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let scenario = scenario_arg(args)?;
            let (stats, weight_name) = match &scenario {
                Some(sc) => {
                    let src = TrainSource::Scenario(sc);
                    let name = src.weight_name(&algo, reward);
                    (experiments::train_pipeline(&c, &algo, reward, src, scale, seed)?, name)
                }
                None => {
                    let tb = testbed_arg(args)?;
                    let src = TrainSource::Testbed(&tb);
                    let name = src.weight_name(&algo, reward);
                    (experiments::train_pipeline(&c, &algo, reward, src, scale, seed)?, name)
                }
            };
            println!(
                "trained {algo} ({}) in {:.1}s: {} env steps, {} train calls, converged@{} -> {weight_name}",
                reward.short(),
                stats.wall_s,
                stats.env_steps,
                stats.train_calls,
                stats.steps_to_converge
            );
            Ok(())
        }
        Some("train-all") => {
            common.forbid("train-all", &["step-threads", "faults"])?;
            let c = ctx()?;
            let scenario = scenario_arg(args)?;
            let tb = if scenario.is_none() { Some(testbed_arg(args)?) } else { None };
            for algo in sparta::agents::ALGOS {
                for reward in [RewardKind::ThroughputEnergy, RewardKind::FairnessEfficiency] {
                    let src = match (&scenario, &tb) {
                        (Some(sc), _) => TrainSource::Scenario(sc),
                        (None, Some(t)) => TrainSource::Testbed(t),
                        (None, None) => unreachable!(),
                    };
                    let stats = experiments::train_pipeline(&c, algo, reward, src, scale, seed)?;
                    println!(
                        "{algo}-{}: {:.1}s, {} steps, converged@{}",
                        reward.short(),
                        stats.wall_s,
                        stats.env_steps,
                        stats.steps_to_converge
                    );
                }
            }
            Ok(())
        }
        Some("generalize") => {
            // Train one agent per training scenario, then deploy each
            // trained policy greedily on every registered scenario — the
            // cross-scenario generalization matrix. Defaults to the
            // artifact-free `linq` core so it runs on a fresh checkout;
            // pass `--algo rppo` (etc.) once artifacts are built.
            common.forbid("generalize", &["step-threads", "faults"])?;
            let algo = args.get_or("algo", sparta::agents::FALLBACK_ALGO).to_string();
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let train_on = match args.get("scenario") {
                None => Scenario::all(),
                Some(list) => parse_scenarios(list)?,
            };
            let eval_on = Scenario::all();
            let report = experiments::generalize::run(
                &Paths::resolve(),
                &algo,
                reward,
                &train_on,
                &eval_on,
                scale,
                seed,
                jobs,
            )?;
            experiments::generalize::print(&report);
            common.save(&experiments::generalize::to_json(&report))?;
            Ok(())
        }
        Some("transfer") => {
            common.forbid("transfer", &["step-threads", "faults"])?;
            let c = ctx()?;
            let scenario = scenario_arg(args)?;
            let method = args.get_or("method", "sparta-fe");
            let (files, bytes) = scale.workload();
            let files = args.get_usize("files", files).map_err(|e| anyhow!(e))?;
            let (opt, engine, reward) = make_optimizer(&c, method, seed)?;
            let builder: SessionBuilder = match &scenario {
                Some(sc) => sc.session(),
                None => Session::builder(testbed_arg(args)?),
            };
            // --observe-paused: externally-paused lanes emit zero-throughput
            // records carrying idle energy (a single batch transfer is never
            // paused, but the knob is plumbed for session-driving callers).
            let mut session = builder
                .observe_paused(common.observe_paused)
                .seed(seed)
                .build();
            session.admit(
                LaneSpec::new(opt, TransferJob::files(files, bytes))
                    .engine(engine)
                    .reward(reward),
            );
            // Stream MI-granular events to --events FILE while the report
            // sink rebuilds the summary from the same stream.
            let mut report_sink = ReportSink::new();
            match common.events {
                Some(path) => {
                    let f = std::fs::File::create(path)
                        .map_err(|e| anyhow!("creating {path}: {e}"))?;
                    let mut jsonl = JsonlSink::new(std::io::BufWriter::new(f));
                    let mut fan = FanoutSink { sinks: vec![&mut report_sink, &mut jsonl] };
                    session.run_to_completion(DEFAULT_MAX_MIS, &mut fan);
                    // A write that failed mid-run is a failed run, not a
                    // silently truncated event log.
                    if let Some(e) = jsonl.take_error() {
                        return Err(anyhow!("writing event stream {path}: {e}"));
                    }
                    let mut w = jsonl.into_inner();
                    w.flush().map_err(|e| anyhow!("flushing event stream: {e}"))?;
                    println!("event stream written to {path}");
                }
                None => session.run_to_completion(DEFAULT_MAX_MIS, &mut report_sink),
            }
            let report = report_sink.finish(session.time_s());
            let lane = report.lane();
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["method".into(), method.into()]);
            if let Some(sc) = &scenario {
                t.row(vec!["scenario".into(), sc.name.into()]);
            }
            t.row(vec!["completed".into(), lane.completed.to_string()]);
            t.row(vec!["avg throughput (Gbps)".into(), format!("{:.2}", lane.avg_throughput_gbps())]);
            t.row(vec!["duration (s)".into(), format!("{:.0}", lane.duration_s)]);
            t.row(vec!["energy (kJ)".into(), format!("{:.1}", lane.total_energy_j / 1000.0)]);
            t.row(vec!["energy/GB (J)".into(), format!("{:.1}", lane.energy_per_gb())]);
            t.row(vec!["avg plr".into(), format!("{:.5}", lane.avg_plr())]);
            t.print();
            common.save(&lane_json(lane))?;
            Ok(())
        }
        Some("sweep") => {
            common.forbid("sweep", &["events", "observe-paused", "step-threads", "faults"])?;
            let grid = [1u32, 2, 4, 8, 16];
            // `--scenario all`: iterate the full registry and emit one
            // combined report.
            if args.get("scenario") == Some("all") {
                let mut combined = Vec::new();
                for sc in Scenario::all() {
                    let pts = experiments::fig1::sweep_scenario(&sc, &grid, seed, jobs);
                    experiments::fig1::print(&pts, &grid);
                    combined.extend(pts);
                }
                common.save(&experiments::fig1::to_json(&combined))?;
                return Ok(());
            }
            let pts = match scenario_arg(args)? {
                Some(sc) => experiments::fig1::sweep_scenario(&sc, &grid, seed, jobs),
                None => {
                    let tb = testbed_arg(args)?;
                    experiments::fig1::sweep(&tb, &grid, &["low", "medium", "high"], seed, jobs)
                }
            };
            experiments::fig1::print(&pts, &grid);
            common.save(&experiments::fig1::to_json(&pts))?;
            Ok(())
        }
        Some("algos") => {
            common.forbid("algos", &["step-threads", "faults"])?;
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let cells = experiments::fig4::run(
                &Paths::resolve(),
                reward,
                &sparta::agents::ALGOS,
                scale,
                seed,
                jobs,
            )?;
            experiments::fig4::print(&cells);
            common.save(&experiments::fig4::to_json(&cells))?;
            Ok(())
        }
        Some("tune") => {
            common.forbid("tune", &["step-threads", "faults"])?;
            let curves = experiments::fig5::run(
                &Paths::resolve(),
                &sparta::agents::ALGOS,
                scale,
                seed,
                jobs,
            )?;
            experiments::fig5::print(&curves);
            common.save(&experiments::fig5::to_json(&curves))?;
            Ok(())
        }
        Some("compare") => {
            common.forbid("compare", &["events", "observe-paused", "step-threads", "faults"])?;
            let scenarios = scenario_list_arg(args)?;
            let methods = methods_arg(args);
            let cells = experiments::fig6::run(
                &Paths::resolve(),
                &scenarios,
                &methods,
                scale,
                seed,
                jobs,
            )?;
            experiments::fig6::print(&cells);
            // The headline compares the paper's six methods; it is
            // meaningless for a custom --methods subset.
            if args.get("methods").is_none() {
                let (thr, en) = experiments::fig6::headline(&cells);
                println!("\nheadline: +{thr:.0}% throughput, -{en:.0}% energy vs static tools");
            }
            common.save(&experiments::fig6::to_json(&cells))?;
            Ok(())
        }
        Some("fairness") => {
            common.forbid("fairness", &["step-threads", "faults"])?;
            let scenarios = experiments::fig7::run(&Paths::resolve(), scale, seed, jobs)?;
            experiments::fig7::print(&scenarios);
            Ok(())
        }
        Some("table1") => {
            // `--algos a,b` restricts the rows (e.g. `--algos linq` for the
            // artifact-free core); `--deterministic` keeps/emits only the
            // simulation-derived columns so table1 joins the CI
            // byte-identity job.
            common.forbid("table1", &["step-threads", "faults"])?;
            let algo_list: Vec<String> = match args.get("algos") {
                None => sparta::agents::ALGOS.iter().map(|a| a.to_string()).collect(),
                Some(list) => list.split(',').map(|a| a.trim().to_string()).collect(),
            };
            let algos: Vec<&str> = algo_list.iter().map(|a| a.as_str()).collect();
            let deterministic = args.flag("deterministic");
            let rows = experiments::table1::run(&Paths::resolve(), &algos, scale, seed, jobs)?;
            experiments::table1::print(&rows, deterministic);
            let json = if deterministic {
                experiments::table1::to_json_deterministic(&rows)
            } else {
                experiments::table1::to_json(&rows)
            };
            common.save(&json)?;
            Ok(())
        }
        Some("bench") => {
            // Perf trajectory: fleet churn-heavy scale curve (single-host
            // sizes, the incast cluster points, and the giant 16k–65k-lane
            // threaded points) + hot-path microbenches, emitted as
            // BENCH_8.json (schema v4 in `experiments::bench`). `--quick`
            // is the CI lane; `--against` turns the run into the
            // perf-trend ratchet. Bench always times at jobs 1, so an
            // explicit --jobs is rejected; `--step-threads` caps the
            // threaded column's worker count.
            common.forbid("bench", &["scenario", "jobs", "events", "observe-paused"])?;
            let lanes = match args.get("lanes") {
                None => None,
                Some(s) => {
                    let parsed: Result<Vec<usize>, _> =
                        s.split(',').map(|x| x.trim().parse::<usize>()).collect();
                    Some(parsed.map_err(|_| {
                        anyhow!("--lanes: expected comma-separated fleet sizes, got '{s}'")
                    })?)
                }
            };
            let opts = experiments::bench::BenchOpts {
                quick: args.flag("quick"),
                iters: args.get_usize("iters", 1).map_err(|e| anyhow!(e))?,
                inject_slowdown: args.get_f64("inject-slowdown", 0.0).map_err(|e| anyhow!(e))?,
                lanes,
                step_threads: common.step_threads.unwrap_or(0),
                // --faults NAME: time the curve with the recovery path hot
                // (skips the baseline column — no fault plane there).
                faults: common.fault_schedule()?,
            };
            let report = experiments::bench::run(&Paths::resolve(), opts)?;
            experiments::bench::print(&report);
            let out = common.out.unwrap_or("BENCH_8.json");
            save_report(Path::new(out), &experiments::bench::to_json(&report))?;
            println!("bench report written to {out}");
            if let Some(anchor_path) = args.get("against") {
                let text = std::fs::read_to_string(anchor_path)
                    .map_err(|e| anyhow!("--against {anchor_path}: {e}"))?;
                let anchor = Json::parse(&text)
                    .map_err(|e| anyhow!("--against {anchor_path}: {e}"))?;
                let trend = experiments::bench::trend_gate(
                    &report,
                    &anchor,
                    experiments::bench::TREND_MAX_REGRESS_FRAC,
                )?;
                experiments::bench::trend_print(&trend);
                if let Some(md_path) = args.get("summary") {
                    std::fs::write(md_path, experiments::bench::trend_markdown(&trend))
                        .map_err(|e| anyhow!("--summary {md_path}: {e}"))?;
                }
                if trend.failed() {
                    return Err(anyhow!(
                        "perf-trend gate: arena/baseline ratio regressed more than {:.0}% \
                         vs {anchor_path}",
                        experiments::bench::TREND_MAX_REGRESS_FRAC * 100.0
                    ));
                }
            }
            Ok(())
        }
        Some("fleet") => {
            common.forbid("fleet", &["events"])?;
            // --schedule is the precise spelling (an arrival schedule pins
            // its own scenario); --scenario stays as the historical alias.
            let name = match (args.get("schedule"), common.scenario) {
                (Some(_), Some(_)) => {
                    return Err(anyhow!(
                        "--schedule and --scenario conflict on fleet (they are aliases)"
                    ));
                }
                (Some(s), None) | (None, Some(s)) => s,
                (None, None) => {
                    return Err(anyhow!(
                        "fleet needs --schedule <name> (one of: {})",
                        ArrivalSchedule::names().join(", ")
                    ));
                }
            };
            let schedule = ArrivalSchedule::by_name(name).ok_or_else(|| {
                anyhow!(
                    "unknown arrival schedule '{name}' (one of: {})",
                    ArrivalSchedule::names().join(", ")
                )
            })?;
            // Default lanes cycle through the artifact-free baselines so a
            // fresh checkout can run a fleet; mix in trained agents with
            // e.g. --methods sparta-fe,falcon_mp or --methods linq:te.
            let methods: Vec<String> = match args.get("methods") {
                None => ["falcon_mp", "2-phase", "rclone"].iter().map(|m| m.to_string()).collect(),
                Some(list) => list.split(',').map(|m| m.trim().to_string()).collect(),
            };
            // --hosts N: run every trial as an incast cluster of N sender
            // hosts sharing the schedule testbed's WAN and one receiver.
            let hosts = args.get_usize("hosts", 1).map_err(|e| anyhow!(e))?;
            // --compare-observe: run the yield-policy fleet blind and with
            // pause-cost observation, side by side (lanes that see their
            // idle bills pause less eagerly).
            if args.flag("compare-observe") {
                if hosts > 1 {
                    return Err(anyhow!(
                        "--compare-observe runs single-host fleets (drop --hosts)"
                    ));
                }
                if common.step_threads.is_some() {
                    return Err(anyhow!(
                        "--compare-observe runs single-host fleets (drop --step-threads)"
                    ));
                }
                if common.faults.is_some() {
                    return Err(anyhow!(
                        "--compare-observe compares the yield policy, not the fault \
                         plane (drop --faults)"
                    ));
                }
                let (blind, observing) = experiments::fleet::run_observe_comparison(
                    &Paths::resolve(),
                    &schedule,
                    &methods,
                    scale,
                    seed,
                    jobs,
                )?;
                experiments::fleet::print(&blind);
                experiments::fleet::print(&observing);
                experiments::fleet::print_comparison(&blind, &observing);
                common.save(&Json::obj(vec![
                    ("blind", experiments::fleet::to_json(&blind)),
                    ("observing", experiments::fleet::to_json(&observing)),
                ]))?;
                return Ok(());
            }
            // --step-threads N: intra-step cluster workers per trial
            // (0 = auto: serial under --jobs sharding, else one per
            // host up to the core count). Resolved in
            // `experiments::fleet::run` so serve/bench share the policy.
            let opts = experiments::fleet::FleetOpts {
                observe_paused: common.observe_paused,
                hosts,
                step_threads: common.step_threads.unwrap_or(0),
                // --faults NAME: install a seeded fault plan per trial
                // (same failure history at any --jobs / --step-threads).
                faults: common.fault_schedule()?,
                ..experiments::fleet::FleetOpts::default()
            };
            let report = experiments::fleet::run(
                &Paths::resolve(),
                &schedule,
                &methods,
                scale,
                seed,
                jobs,
                opts,
            )?;
            experiments::fleet::print(&report);
            common.save(&experiments::fleet::to_json(&report))?;
            Ok(())
        }
        Some("serve") => {
            common.forbid("serve", &["jobs", "out"])?;
            serve_cmd(args, &common, seed)
        }
        Some("serve-ctl") => {
            common.forbid("serve-ctl", &["step-threads", "faults"])?;
            serve_ctl_cmd(args)
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' — try `sparta help`")),
    }
}

/// `sparta serve`: boot the resident transfer service (unix only — the
/// control plane is a unix-domain socket).
#[cfg(unix)]
fn serve_cmd(args: &Args, common: &CommonOpts, seed: u64) -> Result<()> {
    use sparta::serve::daemon::{run_daemon, Boot, ServeOptions};
    use sparta::serve::ServeSpec;
    use std::path::PathBuf;

    let opts = ServeOptions {
        socket: PathBuf::from(args.get_or("socket", "sparta-serve.sock")),
        events: common.events.map(PathBuf::from),
        time_scale: args.get_f64("time-scale", 0.0).map_err(|e| anyhow!(e))?,
        hold: args.flag("hold"),
        // Wall-clock only (multi-host fleets); a restore may pick a
        // different count than the interrupted run.
        step_threads: common.step_threads.unwrap_or(1),
    };
    let boot = match args.get("restore") {
        Some(path) => {
            if common.scenario.is_some() || args.get("schedule").is_some() {
                return Err(anyhow!(
                    "--restore conflicts with --scenario/--schedule: the snapshot \
                     carries its own spec"
                ));
            }
            if common.faults.is_some() {
                // Faulted services refuse to snapshot, so a snapshot is by
                // construction fault-free; arming the restore would fork
                // its stream from the interrupted run.
                return Err(anyhow!(
                    "--restore conflicts with --faults: snapshots are taken at \
                     fault-free boundaries and restore bit-identically"
                ));
            }
            Boot::Restore(PathBuf::from(path))
        }
        None => {
            let schedule = args.get("schedule");
            let scenario = match (common.scenario, schedule) {
                (Some(_), Some(_)) => {
                    return Err(anyhow!(
                        "--scenario and --schedule conflict on serve: the schedule \
                         pins its own scenario"
                    ));
                }
                (Some(sc), None) => sc.to_string(),
                (None, Some(name)) => {
                    let sched = ArrivalSchedule::by_name(name).ok_or_else(|| {
                        anyhow!(
                            "unknown arrival schedule '{name}' (one of: {})",
                            ArrivalSchedule::names().join(", ")
                        )
                    })?;
                    sched.scenario.name.to_string()
                }
                (None, None) => "calm".to_string(),
            };
            let methods: Vec<String> = match args.get("methods") {
                None => ["falcon_mp", "2-phase", "rclone"].iter().map(|m| m.to_string()).collect(),
                Some(list) => list.split(',').map(|m| m.trim().to_string()).collect(),
            };
            Boot::Fresh(ServeSpec {
                scenario,
                schedule: schedule.map(str::to_string),
                methods,
                hosts: args.get_usize("hosts", 1).map_err(|e| anyhow!(e))?,
                seed,
                mi_s: args.get_f64("mi", 1.0).map_err(|e| anyhow!(e))?,
                max_mis: args.get_usize("max-mis", DEFAULT_MAX_MIS).map_err(|e| anyhow!(e))?,
                observe_paused: common.observe_paused,
                // Validated at boot by `build_fleet` (unknown names fail
                // before the socket binds); the validated name rides in
                // the spec so `status` can report the active preset.
                faults: common.fault_schedule()?.map(|f| f.name.to_string()),
            })
        }
    };
    run_daemon(ctx()?, boot, opts)
}

#[cfg(not(unix))]
fn serve_cmd(_args: &Args, _common: &CommonOpts, _seed: u64) -> Result<()> {
    Err(anyhow!("`sparta serve` needs unix-domain sockets (unix only)"))
}

/// `sparta serve-ctl 'JSON' ...`: send request lines to a running serve
/// daemon and print each reply; `--stdin` pipes request lines instead.
#[cfg(unix)]
fn serve_ctl_cmd(args: &Args) -> Result<()> {
    use std::io::BufRead;

    let socket = Path::new(args.get_or("socket", "sparta-serve.sock"));
    let mut lines: Vec<String> = args.positional.clone();
    if args.flag("stdin") {
        for line in std::io::stdin().lock().lines() {
            let line = line.map_err(|e| anyhow!("reading stdin: {e}"))?;
            if !line.trim().is_empty() {
                lines.push(line);
            }
        }
    }
    if lines.is_empty() {
        lines.push(r#"{"cmd":"status"}"#.to_string());
    }
    sparta::serve::daemon::run_ctl(socket, &lines)
}

#[cfg(not(unix))]
fn serve_ctl_cmd(_args: &Args) -> Result<()> {
    Err(anyhow!("`sparta serve-ctl` needs unix-domain sockets (unix only)"))
}

fn info() -> Result<()> {
    println!("sparta {} — DRL-optimized data transfers (SPARTA reproduction)", sparta::VERSION);
    let paths = Paths::resolve();
    match SpartaCtx::load(paths) {
        Ok(c) => {
            println!(
                "artifacts: {} graphs, {} algorithms",
                c.runtime.manifest.graphs.len(),
                c.runtime.manifest.algos.len()
            );
            // The snapshot is the evaluation read path: everything under
            // data/weights, including scenario-scoped names (`rppo_te@calm`).
            let trained = c.snapshot.names();
            println!(
                "trained weights (snapshot): {}",
                if trained.is_empty() {
                    "none (`sparta train-all`; `--algo linq` needs no artifacts)".into()
                } else {
                    trained.join(", ")
                }
            );
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    let mut t = Table::new(&["testbed", "capacity", "RTT ms", "energy counters"]);
    for tb in Testbed::all() {
        t.row(vec![
            tb.name.into(),
            format!("{:.0} Gbps", tb.capacity_gbps),
            format!("{:.0}", tb.base_rtt_s * 1000.0),
            tb.has_energy_counters.to_string(),
        ]);
    }
    t.print();
    println!("\n{} scenarios registered (see `sparta scenarios`)", Scenario::all().len());
    Ok(())
}

const HELP: &str = "\
sparta — SPARTA reproduction CLI

subcommands:
  info                      artifacts / testbeds / trained-weights status
  scenarios                 list registered evaluation scenarios
  collect   --testbed T|--scenario S --scale X     cache exploration transitions
  train     --algo A --reward fe|te [--scenario S] offline-train one agent
                                           (--scenario explores/fine-tunes under
                                           S and saves scoped weights, A_te@S)
  train-all [--scenario S]                 train all 5 algos x 2 rewards
  generalize [--algo A] [--scenario S1,..] train per scenario (default: all),
                                           then deploy each policy greedily on
                                           every registered scenario and print
                                           the train x eval matrix. Default
                                           algo 'linq' (pure-Rust fallback)
                                           runs without artifacts
  transfer  --method M [--scenario S]      run one transfer (M: rclone, escp,
                                           falcon_mp, 2-phase, sparta-t, sparta-fe)
            [--events FILE]                (stream MI-granular session events
                                           as JSON lines while it runs)
            [--observe-paused]             (paused lanes emit zero-throughput
                                           records carrying idle energy)
  fleet     --schedule churn-light|churn-heavy|flash-crowd|open-loop|timed-burst
            (--scenario is an alias)       (open-loop/timed-burst index arrivals
                                           in wall-clock seconds, not MIs)
            [--methods M1,M2,...]          N transfers joining/leaving a shared
                                           bottleneck (seeded arrival process;
                                           per-epoch JFI, host-truth J/GB +
                                           per-rail breakdown, completion-time
                                           distribution). Default methods are
                                           artifact-free baselines. Energy is
                                           host-resolved: colocated lanes share
                                           one ledger per end host, so fixed
                                           power is paid once per host
            [--observe-paused]             (optimizers see paused MIs: idle
                                           energy bills, preemption cost)
            [--hosts N]                    (incast cluster: shard the lanes
                                           round-robin over N sender hosts,
                                           each with its own ledgers, feeding
                                           a shared WAN + receiver; reports
                                           gain per-host rail rows and stay
                                           bit-identical at any --jobs)
            [--compare-observe]            (yield-policy churn comparison:
                                           blind vs pause-cost-observing lanes;
                                           observing lanes pause less eagerly)
            [--step-threads N]             (intra-step cluster workers: each
                                           trial's N-host step fans out over a
                                           persistent pool, merged in host
                                           order — byte-identical to serial at
                                           any count. 0 = auto: serial when
                                           --jobs shards trials, else one per
                                           host up to the core count; default
                                           1 = serial)
            [--faults PRESET]              (seeded chaos: install a fault plan
                                           per trial — link flaps/brownouts,
                                           host stalls/crashes, stream errors.
                                           Lanes retry with backoff; crashed
                                           hosts quarantine and migrate their
                                           lanes, bytes intact. Same seed =>
                                           same failure history at any --jobs
                                           / --step-threads; `sparta
                                           scenarios` lists the presets)
  serve     [--scenario S|--schedule A]    resident transfer service (unix):
                                           daemon owns a session (--hosts N:
                                           an incast cluster), steps it on a
                                           pacer, and takes live admit/pause/
                                           resume/cancel over a local socket
            [--socket PATH]                (default sparta-serve.sock)
            [--events FILE]                (stream events as JSON lines)
            [--time-scale F]               (0 = flat out, 1 = real time,
                                           10 = 10 sim seconds per wall s)
            [--hold]                       (boot paused until a `go` request)
            [--mi SECS] [--max-mis N]      (MI length / run horizon)
            [--restore FILE]               (resume a snapshot; the continued
                                           event stream is byte-identical to
                                           an uninterrupted run)
            [--step-threads N]             (intra-step workers for multi-host
                                           fleets; wall-clock only, not in
                                           snapshots — a restore may pick a
                                           different count)
            [--faults PRESET]              (run degraded under a seeded fault
                                           plan: lanes retry, crashed hosts
                                           migrate; `status` reports fault/
                                           recovery counters. Conflicts with
                                           --restore — a faulted service
                                           refuses to checkpoint)
  serve-ctl ['JSON' ... | --stdin]         send request lines to the daemon
                                           and print each reply; `subscribe`
                                           then streams live events to stdout
                                           (cmds: admit, pause, resume, cancel,
                                           status, snapshot, subscribe, go,
                                           shutdown; default: status)
            [--socket PATH]                (default sparta-serve.sock)
  bench     [--quick] [--out FILE]        perf trajectory: fleet churn-heavy
                                           at 16/64/256 lanes single-host plus
                                           incast cluster points (1024 lanes x
                                           8 hosts; full mode adds 4096 x 16)
                                           and giant threaded points (16384 x
                                           32; full mode adds 65536 x 64) with
                                           threaded-vs-serial wall columns
                                           + simulator-MI and Session-step
                                           microbenches, written as
                                           BENCH_8.json, schema v4 (the CI
                                           bench lane uploads it; speedups are
                                           vs the recorded pre-arena baseline
                                           where it fits, threaded-vs-serial
                                           on the giant points; always times
                                           at --jobs 1)
            [--step-threads N]             (cap the threaded column's worker
                                           count; default: one per host up to
                                           the core count)
            [--iters N]                    (stable mode: keep the min wall of
                                           N timing repetitions per point)
            [--lanes L1,L2,...]            (restrict the curve to these
                                           fleet sizes)
            [--against FILE]               (perf-trend ratchet: compare the
                                           arena/baseline ratio per lane vs
                                           the committed anchor, fail >15%
                                           regression; unmeasured anchors
                                           are seed-only)
            [--summary FILE]               (write the trend delta table as
                                           markdown, for CI job summaries)
            [--inject-slowdown F]          (test flag: sleep F x each arena
                                           timing so CI can prove the gate
                                           trips on a synthetic slowdown)
            [--faults PRESET]              (time the curve with the recovery
                                           path hot; skips the baseline
                                           column — no fault plane there)
  sweep     --testbed T|--scenario S|--scenario all   Fig 1 (cc,p) sweep
  algos     --reward fe|te                 Fig 4   DRL algorithm comparison
  tune                                     Fig 5   online tuning on CloudLab
  compare   [--scenario S1,S2,...|all]     Fig 6   methods x scenarios
            [--methods M1,M2,...]          (subset/extend the method lanes,
                                           e.g. linq:te for the fallback core)
  fairness                                 Fig 7   concurrent-transfer JFI
  table1    [--algos A1,A2,...]            Table 1 training/inference cost
            [--deterministic]              (keep only simulation-derived
                                           columns; joins the CI byte-identity
                                           check)

common flags: --scale quick|paper  --seed N  --jobs N  --quiet --verbose
  --scenario takes names from `sparta scenarios` (e.g. calm, diurnal-bg,
  bursty-incast, lossy-wan, receiver-limited, nic-limited, contended-peers);
  `all` on compare/sweep iterates the full registry into one combined report
  --jobs N shards experiment cells over N worker threads (default: all
  cores); every experiment evaluates over one shared read-only weight
  snapshot and seeds each cell from its own identity, so reports are
  bit-identical at any jobs count for a fixed seed
  --out FILE (sweep/algos/tune/compare/table1/generalize/fleet/transfer/
  bench) writes a JSON report
  --scenario/--jobs/--out/--events/--observe-paused/--step-threads/--faults
  are parsed by one shared helper with one spelling and one default
  everywhere; a subcommand that cannot honor one of them rejects it loudly
  (e.g. --events outside transfer, --jobs on bench, --step-threads and
  --faults outside fleet/serve/bench) instead of silently ignoring it
  --jobs N and --step-threads T multiply: fleet warns once when J x T
  oversubscribes the machine and suggests a budget that fits
";
