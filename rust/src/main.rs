//! `sparta` — the CLI entry point / launcher.
//!
//! ```text
//! sparta info                         # artifacts, testbeds, trained weights
//! sparta collect  --testbed chameleon --scale quick
//! sparta train    --algo rppo --reward te --scale quick
//! sparta train-all --scale quick      # all 5 algos x both rewards
//! sparta transfer --method sparta-fe --testbed chameleon
//! sparta sweep    --testbed chameleon             # Fig 1
//! sparta algos    --reward te                     # Fig 4
//! sparta tune                                      # Fig 5
//! sparta compare                                   # Fig 6
//! sparta fairness                                  # Fig 7
//! sparta table1                                    # Table 1
//! ```

use anyhow::{anyhow, Result};
use sparta::config::Paths;
use sparta::coordinator::{Controller, RewardKind};
use sparta::experiments::{self, make_optimizer, Scale, SpartaCtx};
use sparta::net::Testbed;
use sparta::telemetry::report::lane_json;
use sparta::telemetry::Table;
use sparta::transfer::TransferJob;
use sparta::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        sparta::util::log::set_level(0);
    }
    if args.flag("verbose") {
        sparta::util::log::set_level(2);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn testbed_arg(args: &Args) -> Result<Testbed> {
    let name = args.get_or("testbed", "chameleon");
    Testbed::by_name(name).ok_or_else(|| anyhow!("unknown testbed '{name}'"))
}

fn ctx() -> Result<SpartaCtx> {
    SpartaCtx::load(Paths::resolve())
}

fn dispatch(args: &Args) -> Result<()> {
    let scale = Scale::by_name(args.get_or("scale", "quick"));
    let seed = args.get_u64("seed", 42).map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{}", HELP);
            Ok(())
        }
        Some("info") => info(),
        Some("collect") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            let ts = experiments::common::transitions_for(&c, &tb, scale, seed)?;
            println!("{} transitions cached for {}", ts.len(), tb.name);
            Ok(())
        }
        Some("train") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            let algo = args.get_or("algo", "rppo").to_string();
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let stats = experiments::train_pipeline(&c, &algo, reward, &tb, scale, seed)?;
            println!(
                "trained {algo} ({}) in {:.1}s: {} env steps, {} train calls, converged@{}",
                reward.short(),
                stats.wall_s,
                stats.env_steps,
                stats.train_calls,
                stats.steps_to_converge
            );
            Ok(())
        }
        Some("train-all") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            for algo in sparta::agents::ALGOS {
                for reward in [RewardKind::ThroughputEnergy, RewardKind::FairnessEfficiency] {
                    let stats = experiments::train_pipeline(&c, algo, reward, &tb, scale, seed)?;
                    println!(
                        "{algo}-{}: {:.1}s, {} steps, converged@{}",
                        reward.short(),
                        stats.wall_s,
                        stats.env_steps,
                        stats.steps_to_converge
                    );
                }
            }
            Ok(())
        }
        Some("transfer") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            let method = args.get_or("method", "sparta-fe");
            let (files, bytes) = scale.workload();
            let files = args.get_usize("files", files).map_err(|e| anyhow!(e))?;
            let (opt, engine, reward) = make_optimizer(&c, method, seed)?;
            let mut ctl = Controller::builder(tb)
                .job(TransferJob::files(files, bytes))
                .engine(engine)
                .reward(reward)
                .seed(seed)
                .build();
            let report = ctl.run(opt, seed);
            let lane = report.lane();
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["method".into(), method.into()]);
            t.row(vec!["completed".into(), lane.completed.to_string()]);
            t.row(vec!["avg throughput (Gbps)".into(), format!("{:.2}", lane.avg_throughput_gbps())]);
            t.row(vec!["duration (s)".into(), format!("{:.0}", lane.duration_s)]);
            t.row(vec!["energy (kJ)".into(), format!("{:.1}", lane.total_energy_j / 1000.0)]);
            t.row(vec!["energy/GB (J)".into(), format!("{:.1}", lane.energy_per_gb())]);
            t.row(vec!["avg plr".into(), format!("{:.5}", lane.avg_plr())]);
            t.print();
            if let Some(out) = args.get("out") {
                sparta::telemetry::save_report(std::path::Path::new(out), &lane_json(lane))?;
            }
            Ok(())
        }
        Some("sweep") => {
            let tb = testbed_arg(args)?;
            let grid = [1u32, 2, 4, 8, 16];
            let pts = experiments::fig1::sweep(&tb, &grid, &["low", "medium", "high"], seed);
            experiments::fig1::print(&pts, &grid);
            Ok(())
        }
        Some("algos") => {
            let c = ctx()?;
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let cells = experiments::fig4::run(&c, reward, &sparta::agents::ALGOS, scale, seed)?;
            experiments::fig4::print(&cells);
            Ok(())
        }
        Some("tune") => {
            let c = ctx()?;
            let curves = experiments::fig5::run(&c, &sparta::agents::ALGOS, scale, seed)?;
            experiments::fig5::print(&curves);
            Ok(())
        }
        Some("compare") => {
            let c = ctx()?;
            let testbeds = Testbed::all();
            let cells = experiments::fig6::run(&c, &testbeds, scale, seed)?;
            experiments::fig6::print(&cells);
            let (thr, en) = experiments::fig6::headline(&cells);
            println!("\nheadline: +{thr:.0}% throughput, -{en:.0}% energy vs static tools");
            Ok(())
        }
        Some("fairness") => {
            let c = ctx()?;
            let scenarios = experiments::fig7::run(&c, scale, seed)?;
            experiments::fig7::print(&scenarios);
            Ok(())
        }
        Some("table1") => {
            let c = ctx()?;
            let rows = experiments::table1::run(&c, &sparta::agents::ALGOS, scale, seed)?;
            experiments::table1::print(&rows);
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' — try `sparta help`")),
    }
}

fn info() -> Result<()> {
    println!("sparta {} — DRL-optimized data transfers (SPARTA reproduction)", sparta::VERSION);
    let paths = Paths::resolve();
    match SpartaCtx::load(paths) {
        Ok(c) => {
            println!(
                "artifacts: {} graphs, {} algorithms",
                c.runtime.manifest.graphs.len(),
                c.runtime.manifest.algos.len()
            );
            let store = c.weight_store();
            let mut trained = Vec::new();
            for algo in sparta::agents::ALGOS {
                for r in ["te", "fe"] {
                    let name = format!("{algo}_{r}");
                    if store.exists(&name) {
                        trained.push(name);
                    }
                }
            }
            println!(
                "trained weights: {}",
                if trained.is_empty() {
                    "none (run `sparta train-all`)".into()
                } else {
                    trained.join(", ")
                }
            );
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    let mut t = Table::new(&["testbed", "capacity", "RTT ms", "energy counters"]);
    for tb in Testbed::all() {
        t.row(vec![
            tb.name.into(),
            format!("{:.0} Gbps", tb.capacity_gbps),
            format!("{:.0}", tb.base_rtt_s * 1000.0),
            tb.has_energy_counters.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

const HELP: &str = "\
sparta — SPARTA reproduction CLI

subcommands:
  info                      artifacts / testbeds / trained-weights status
  collect   --testbed T --scale S          cache exploration transitions
  train     --algo A --reward fe|te        offline-train one agent
  train-all                                train all 5 algos x 2 rewards
  transfer  --method M --testbed T         run one transfer (M: rclone, escp,
                                           falcon_mp, 2-phase, sparta-t, sparta-fe)
  sweep     --testbed T                    Fig 1   (cc,p) x background sweep
  algos     --reward fe|te                 Fig 4   DRL algorithm comparison
  tune                                     Fig 5   online tuning on CloudLab
  compare                                  Fig 6   methods x testbeds
  fairness                                 Fig 7   concurrent-transfer JFI
  table1                                   Table 1 training/inference cost

common flags: --scale quick|paper  --seed N  --quiet --verbose
";
