//! `sparta` — the CLI entry point / launcher.
//!
//! ```text
//! sparta info                         # artifacts, testbeds, trained weights
//! sparta scenarios                    # list registered evaluation scenarios
//! sparta collect  --testbed chameleon --scale quick
//! sparta train    --algo rppo --reward te --scale quick
//! sparta train-all --scale quick      # all 5 algos x both rewards
//! sparta transfer --method sparta-fe --scenario lossy-wan
//! sparta sweep    --testbed chameleon             # Fig 1
//! sparta algos    --reward te                     # Fig 4
//! sparta tune                                      # Fig 5
//! sparta compare  --scenario receiver-limited      # Fig 6
//! sparta fairness                                  # Fig 7
//! sparta table1                                    # Table 1
//! ```

use anyhow::{anyhow, Result};
use sparta::config::Paths;
use sparta::coordinator::{Controller, ControllerBuilder, RewardKind};
use sparta::experiments::{self, make_optimizer, Scale, SpartaCtx};
use sparta::net::Testbed;
use sparta::scenarios::Scenario;
use sparta::telemetry::report::lane_json;
use sparta::telemetry::Table;
use sparta::transfer::TransferJob;
use sparta::util::cli::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("quiet") {
        sparta::util::log::set_level(0);
    }
    if args.flag("verbose") {
        sparta::util::log::set_level(2);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn testbed_arg(args: &Args) -> Result<Testbed> {
    let name = args.get_or("testbed", "chameleon");
    Testbed::by_name(name).ok_or_else(|| anyhow!("unknown testbed '{name}'"))
}

/// `--scenario <name>` when given (see `sparta scenarios` for the registry).
/// A scenario pins its own testbed, so combining it with `--testbed` is
/// rejected rather than silently ignoring one of the two.
fn scenario_arg(args: &Args) -> Result<Option<Scenario>> {
    match args.get("scenario") {
        None => Ok(None),
        Some(name) => {
            if args.get("testbed").is_some() {
                return Err(anyhow!(
                    "--scenario and --testbed conflict: scenario '{name}' already \
                     pins its testbed (drop one of the two flags)"
                ));
            }
            Scenario::by_name(name).map(Some).ok_or_else(|| {
                anyhow!("unknown scenario '{name}' — `sparta scenarios` lists the registry")
            })
        }
    }
}

/// `--scenario a,b,c` as a list, defaulting to the three testbed presets.
fn scenario_list_arg(args: &Args) -> Result<Vec<Scenario>> {
    match args.get("scenario") {
        None => Ok(Scenario::defaults()),
        Some(list) => list
            .split(',')
            .map(|n| {
                let n = n.trim();
                Scenario::by_name(n).ok_or_else(|| {
                    anyhow!("unknown scenario '{n}' — `sparta scenarios` lists the registry")
                })
            })
            .collect(),
    }
}

fn ctx() -> Result<SpartaCtx> {
    SpartaCtx::load(Paths::resolve())
}

fn dispatch(args: &Args) -> Result<()> {
    let scale = Scale::by_name(args.get_or("scale", "quick"));
    let seed = args.get_u64("seed", 42).map_err(|e| anyhow!(e))?;
    let jobs = args
        .get_usize("jobs", experiments::default_jobs())
        .map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{}", HELP);
            Ok(())
        }
        Some("info") => info(),
        Some("scenarios") => {
            println!("registered scenarios (use with --scenario <name>):");
            let mut t = Table::new(&["name", "testbed", "path", "description"]);
            for sc in Scenario::all() {
                let path = sc
                    .topology
                    .segments
                    .iter()
                    .map(|s| format!("{} {:.0}G", s.name, s.capacity_gbps))
                    .collect::<Vec<_>>()
                    .join(" -> ");
                t.row(vec![
                    sc.name.into(),
                    sc.testbed.name.into(),
                    path,
                    sc.summary.into(),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("collect") => {
            let c = ctx()?;
            match scenario_arg(args)? {
                Some(sc) => {
                    let ts = experiments::transitions_for_scenario(&c, &sc, scale, seed)?;
                    println!("{} transitions cached for scenario {}", ts.len(), sc.name);
                }
                None => {
                    let tb = testbed_arg(args)?;
                    let ts = experiments::common::transitions_for(&c, &tb, scale, seed)?;
                    println!("{} transitions cached for {}", ts.len(), tb.name);
                }
            }
            Ok(())
        }
        Some("train") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            let algo = args.get_or("algo", "rppo").to_string();
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let stats = experiments::train_pipeline(&c, &algo, reward, &tb, scale, seed)?;
            println!(
                "trained {algo} ({}) in {:.1}s: {} env steps, {} train calls, converged@{}",
                reward.short(),
                stats.wall_s,
                stats.env_steps,
                stats.train_calls,
                stats.steps_to_converge
            );
            Ok(())
        }
        Some("train-all") => {
            let c = ctx()?;
            let tb = testbed_arg(args)?;
            for algo in sparta::agents::ALGOS {
                for reward in [RewardKind::ThroughputEnergy, RewardKind::FairnessEfficiency] {
                    let stats = experiments::train_pipeline(&c, algo, reward, &tb, scale, seed)?;
                    println!(
                        "{algo}-{}: {:.1}s, {} steps, converged@{}",
                        reward.short(),
                        stats.wall_s,
                        stats.env_steps,
                        stats.steps_to_converge
                    );
                }
            }
            Ok(())
        }
        Some("transfer") => {
            let c = ctx()?;
            let scenario = scenario_arg(args)?;
            let method = args.get_or("method", "sparta-fe");
            let (files, bytes) = scale.workload();
            let files = args.get_usize("files", files).map_err(|e| anyhow!(e))?;
            let (opt, engine, reward) = make_optimizer(&c, method, seed)?;
            let builder: ControllerBuilder = match &scenario {
                Some(sc) => sc.controller(),
                None => Controller::builder(testbed_arg(args)?),
            };
            let mut ctl = builder
                .job(TransferJob::files(files, bytes))
                .engine(engine)
                .reward(reward)
                .seed(seed)
                .build();
            let report = ctl.run(opt, seed);
            let lane = report.lane();
            let mut t = Table::new(&["metric", "value"]);
            t.row(vec!["method".into(), method.into()]);
            if let Some(sc) = &scenario {
                t.row(vec!["scenario".into(), sc.name.into()]);
            }
            t.row(vec!["completed".into(), lane.completed.to_string()]);
            t.row(vec!["avg throughput (Gbps)".into(), format!("{:.2}", lane.avg_throughput_gbps())]);
            t.row(vec!["duration (s)".into(), format!("{:.0}", lane.duration_s)]);
            t.row(vec!["energy (kJ)".into(), format!("{:.1}", lane.total_energy_j / 1000.0)]);
            t.row(vec!["energy/GB (J)".into(), format!("{:.1}", lane.energy_per_gb())]);
            t.row(vec!["avg plr".into(), format!("{:.5}", lane.avg_plr())]);
            t.print();
            if let Some(out) = args.get("out") {
                sparta::telemetry::save_report(std::path::Path::new(out), &lane_json(lane))?;
            }
            Ok(())
        }
        Some("sweep") => {
            let grid = [1u32, 2, 4, 8, 16];
            let pts = match scenario_arg(args)? {
                Some(sc) => experiments::fig1::sweep_scenario(&sc, &grid, seed, jobs),
                None => {
                    let tb = testbed_arg(args)?;
                    experiments::fig1::sweep(&tb, &grid, &["low", "medium", "high"], seed, jobs)
                }
            };
            experiments::fig1::print(&pts, &grid);
            Ok(())
        }
        Some("algos") => {
            let c = ctx()?;
            let reward = RewardKind::by_name(args.get_or("reward", "te"))
                .ok_or_else(|| anyhow!("--reward must be fe|te"))?;
            let cells = experiments::fig4::run(&c, reward, &sparta::agents::ALGOS, scale, seed)?;
            experiments::fig4::print(&cells);
            Ok(())
        }
        Some("tune") => {
            let c = ctx()?;
            let curves = experiments::fig5::run(&c, &sparta::agents::ALGOS, scale, seed)?;
            experiments::fig5::print(&curves);
            Ok(())
        }
        Some("compare") => {
            let scenarios = scenario_list_arg(args)?;
            let cells = experiments::fig6::run(&Paths::resolve(), &scenarios, scale, seed, jobs)?;
            experiments::fig6::print(&cells);
            let (thr, en) = experiments::fig6::headline(&cells);
            println!("\nheadline: +{thr:.0}% throughput, -{en:.0}% energy vs static tools");
            Ok(())
        }
        Some("fairness") => {
            let scenarios = experiments::fig7::run(&Paths::resolve(), scale, seed, jobs)?;
            experiments::fig7::print(&scenarios);
            Ok(())
        }
        Some("table1") => {
            let c = ctx()?;
            let rows = experiments::table1::run(&c, &sparta::agents::ALGOS, scale, seed)?;
            experiments::table1::print(&rows);
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}' — try `sparta help`")),
    }
}

fn info() -> Result<()> {
    println!("sparta {} — DRL-optimized data transfers (SPARTA reproduction)", sparta::VERSION);
    let paths = Paths::resolve();
    match SpartaCtx::load(paths) {
        Ok(c) => {
            println!(
                "artifacts: {} graphs, {} algorithms",
                c.runtime.manifest.graphs.len(),
                c.runtime.manifest.algos.len()
            );
            let store = c.weight_store();
            let mut trained = Vec::new();
            for algo in sparta::agents::ALGOS {
                for r in ["te", "fe"] {
                    let name = format!("{algo}_{r}");
                    if store.exists(&name) {
                        trained.push(name);
                    }
                }
            }
            println!(
                "trained weights: {}",
                if trained.is_empty() {
                    "none (run `sparta train-all`)".into()
                } else {
                    trained.join(", ")
                }
            );
        }
        Err(e) => println!("artifacts: not loaded ({e})"),
    }
    let mut t = Table::new(&["testbed", "capacity", "RTT ms", "energy counters"]);
    for tb in Testbed::all() {
        t.row(vec![
            tb.name.into(),
            format!("{:.0} Gbps", tb.capacity_gbps),
            format!("{:.0}", tb.base_rtt_s * 1000.0),
            tb.has_energy_counters.to_string(),
        ]);
    }
    t.print();
    println!("\n{} scenarios registered (see `sparta scenarios`)", Scenario::all().len());
    Ok(())
}

const HELP: &str = "\
sparta — SPARTA reproduction CLI

subcommands:
  info                      artifacts / testbeds / trained-weights status
  scenarios                 list registered evaluation scenarios
  collect   --testbed T|--scenario S --scale X     cache exploration transitions
  train     --algo A --reward fe|te        offline-train one agent
  train-all                                train all 5 algos x 2 rewards
  transfer  --method M [--scenario S]      run one transfer (M: rclone, escp,
                                           falcon_mp, 2-phase, sparta-t, sparta-fe)
  sweep     --testbed T|--scenario S       Fig 1   (cc,p) x background sweep
  algos     --reward fe|te                 Fig 4   DRL algorithm comparison
  tune                                     Fig 5   online tuning on CloudLab
  compare   [--scenario S1,S2,...]         Fig 6   methods x scenarios
  fairness                                 Fig 7   concurrent-transfer JFI
  table1                                   Table 1 training/inference cost

common flags: --scale quick|paper  --seed N  --jobs N  --quiet --verbose
  --scenario takes names from `sparta scenarios` (e.g. calm, diurnal-bg,
  bursty-incast, lossy-wan, receiver-limited, nic-limited, contended-peers)
  --jobs N shards experiment cells over N worker threads (default: all
  cores); reports are bit-identical at any jobs count for a fixed seed
";
