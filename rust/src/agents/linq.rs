//! Linear Q-learning fallback core (no HLO runtime required).
//!
//! The five paper agents execute their networks as AOT-compiled HLO, which
//! needs the artifacts plus the `xla`-feature runtime. `linq` is a
//! deliberately small pure-Rust stand-in — a linear state-action value
//! function trained by TD(0) with ε-greedy exploration — so the full
//! train → snapshot → evaluate pipeline (`sparta train`, `sparta
//! generalize`, the figure runners and CI) is exercisable on a fresh
//! checkout with no artifacts at all. It is not a paper algorithm: use it
//! to drive plumbing and determinism checks, not to reproduce figures.

use crate::agents::DrlAgent;
use crate::coordinator::N_ACTIONS;
use crate::util::Rng;

/// Linear Q(s, a) = w_a · s + b_a, updated by TD(0).
pub struct LinQAgent {
    /// Flat parameters: per action, `state_len` weights then one bias —
    /// `N_ACTIONS * (state_len + 1)` values total. Sized lazily from the
    /// first state seen (or from loaded weights), since the state length
    /// is owned by the environment, not a manifest.
    w: Vec<f32>,
    state_len: usize,
    rng: Rng,
    /// ε-greedy exploration probability, annealed per observed transition.
    eps: f64,
    alpha: f32,
    gamma: f32,
    train_calls: u64,
}

impl LinQAgent {
    pub fn new(seed: u64) -> LinQAgent {
        LinQAgent {
            w: Vec::new(),
            state_len: 0,
            rng: Rng::new(seed),
            eps: 0.3,
            alpha: 0.01,
            gamma: 0.95,
            train_calls: 0,
        }
    }

    fn ensure_init(&mut self, state_len: usize) {
        if self.state_len == 0 && state_len > 0 {
            self.state_len = state_len;
            let n = N_ACTIONS * (state_len + 1);
            if self.w.len() != n {
                // Tiny symmetric init so argmax ties break deterministically
                // per seed rather than always favoring action 0.
                let mut w = Vec::with_capacity(n);
                for _ in 0..n {
                    w.push((self.rng.f32() - 0.5) * 1e-3);
                }
                self.w = w;
            }
        }
    }

    fn q(&self, a: usize, s: &[f32]) -> f32 {
        let base = a * (self.state_len + 1);
        let mut acc = self.w[base + self.state_len]; // bias
        for (i, x) in s.iter().take(self.state_len).enumerate() {
            acc += self.w[base + i] * x;
        }
        acc
    }

    fn greedy(&self, s: &[f32]) -> usize {
        let mut best = 0;
        let mut best_q = f32::NEG_INFINITY;
        for a in 0..N_ACTIONS {
            let q = self.q(a, s);
            if q > best_q {
                best_q = q;
                best = a;
            }
        }
        best
    }
}

impl DrlAgent for LinQAgent {
    fn name(&self) -> &str {
        "linq"
    }

    fn act(&mut self, state: &[f32], explore: bool) -> usize {
        self.ensure_init(state.len());
        if self.state_len == 0 {
            return 0;
        }
        if explore && self.rng.chance(self.eps) {
            self.rng.below(N_ACTIONS)
        } else {
            self.greedy(state)
        }
    }

    fn observe(
        &mut self,
        state: &[f32],
        action: usize,
        reward: f64,
        next_state: &[f32],
        done: bool,
    ) {
        self.ensure_init(state.len());
        if self.state_len == 0 || action >= N_ACTIONS {
            return;
        }
        let bootstrap = if done {
            0.0
        } else {
            self.gamma * self.q(self.greedy(next_state), next_state)
        };
        let delta = (reward as f32 + bootstrap - self.q(action, state)).clamp(-10.0, 10.0);
        let base = action * (self.state_len + 1);
        let step = self.alpha * delta;
        for (i, x) in state.iter().take(self.state_len).enumerate() {
            self.w[base + i] += step * x;
        }
        self.w[base + self.state_len] += step;
        self.train_calls += 1;
        self.eps = (self.eps * 0.9995).max(0.05);
    }

    fn params(&self) -> &[f32] {
        &self.w
    }

    fn set_params(&mut self, params: Vec<f32>) {
        if !params.is_empty() && params.len() % N_ACTIONS == 0 {
            self.state_len = params.len() / N_ACTIONS - 1;
        }
        self.w = params;
    }

    fn train_steps(&self) -> u64 {
        self.train_calls
    }

    fn xla_seconds(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 2-feature contextual bandit: the best action is 1 when feature 0 is
    /// high, 2 when it is low.
    fn best_action(s: &[f32]) -> usize {
        if s[0] > 0.5 {
            1
        } else {
            2
        }
    }

    #[test]
    fn learns_a_contextual_bandit() {
        let mut agent = LinQAgent::new(7);
        let mut rng = Rng::new(99);
        for _ in 0..6000 {
            let s = vec![rng.f32(), rng.f32()];
            let a = agent.act(&s, true);
            let reward = if a == best_action(&s) { 1.0 } else { -0.5 };
            let next = vec![rng.f32(), rng.f32()];
            agent.observe(&s, a, reward, &next, true);
        }
        // Greedy policy should now match the bandit's optimum on both sides.
        let mut correct = 0;
        for k in 0..100 {
            let s = vec![(k as f32) / 100.0, 0.3];
            if agent.act(&s, false) == best_action(&s) {
                correct += 1;
            }
        }
        assert!(correct >= 70, "only {correct}/100 greedy actions optimal");
        assert_eq!(agent.train_steps(), 6000);
    }

    #[test]
    fn params_roundtrip_preserves_policy() {
        let mut a = LinQAgent::new(3);
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let s = vec![rng.f32(), rng.f32(), rng.f32()];
            let act = a.act(&s, true);
            a.observe(&s, act, rng.f64(), &[0.1, 0.2, 0.3], false);
        }
        let saved = a.params().to_vec();
        assert_eq!(saved.len(), N_ACTIONS * 4);
        let mut b = LinQAgent::new(1234);
        b.set_params(saved.clone());
        for k in 0..20 {
            let s = vec![k as f32 * 0.05, 0.5, 0.9];
            assert_eq!(a.act(&s, false), b.act(&s, false), "state {k}");
        }
        assert_eq!(b.params(), &saved[..]);
    }

    #[test]
    fn deterministic_under_a_fixed_seed() {
        let run = |seed: u64| {
            let mut agent = LinQAgent::new(seed);
            let mut rng = Rng::new(11);
            for _ in 0..500 {
                let s = vec![rng.f32(), rng.f32()];
                let a = agent.act(&s, true);
                agent.observe(&s, a, rng.f64() - 0.5, &[rng.f32(), rng.f32()], false);
            }
            agent.params().iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
