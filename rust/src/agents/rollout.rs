//! On-policy rollout buffer with Generalized Advantage Estimation.

/// One on-policy step.
#[derive(Debug, Clone)]
pub struct RolloutStep {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub value: f32,
    pub logp: f32,
    pub done: bool,
}

/// Fixed-horizon rollout buffer; finalized into GAE advantages/returns.
#[derive(Debug, Default)]
pub struct Rollout {
    pub steps: Vec<RolloutStep>,
}

impl Rollout {
    pub fn new() -> Rollout {
        Rollout { steps: Vec::new() }
    }

    pub fn push(&mut self, s: RolloutStep) {
        self.steps.push(s);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn clear(&mut self) {
        self.steps.clear();
    }

    /// GAE(γ, λ): returns (advantages, returns) with `last_value`
    /// bootstrapping the value beyond the horizon.
    pub fn gae(&self, gamma: f32, lambda: f32, last_value: f32) -> (Vec<f32>, Vec<f32>) {
        let n = self.steps.len();
        let mut adv = vec![0.0f32; n];
        let mut gae = 0.0f32;
        for i in (0..n).rev() {
            let s = &self.steps[i];
            let not_done = if s.done { 0.0 } else { 1.0 };
            let next_v = if i + 1 < n {
                // Value after a terminal step is 0 regardless of the stored value.
                if s.done { 0.0 } else { self.steps[i + 1].value }
            } else {
                not_done * last_value
            };
            let delta = s.reward + gamma * next_v - s.value;
            gae = delta + gamma * lambda * not_done * gae;
            adv[i] = gae;
        }
        let ret: Vec<f32> = adv.iter().zip(&self.steps).map(|(a, s)| a + s.value).collect();
        (adv, ret)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(reward: f32, value: f32, done: bool) -> RolloutStep {
        RolloutStep { state: vec![0.0; 4], action: 0, reward, value, logp: -1.6, done }
    }

    #[test]
    fn single_step_terminal() {
        let mut r = Rollout::new();
        r.push(step(1.0, 0.5, true));
        let (adv, ret) = r.gae(0.99, 0.95, 42.0); // bootstrap ignored: done
        assert!((adv[0] - (1.0 - 0.5)).abs() < 1e-6);
        assert!((ret[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn bootstrap_used_when_not_done() {
        let mut r = Rollout::new();
        r.push(step(0.0, 0.0, false));
        let (adv, _) = r.gae(0.99, 0.95, 2.0);
        assert!((adv[0] - 0.99 * 2.0).abs() < 1e-6);
    }

    #[test]
    fn constant_reward_gae_matches_closed_form() {
        // With values = 0 and rewards = 1, adv[last] = 1, and each earlier
        // step adds gamma*lambda discounting.
        let mut r = Rollout::new();
        for _ in 0..4 {
            r.push(step(1.0, 0.0, false));
        }
        let (adv, ret) = r.gae(1.0, 1.0, 0.0);
        assert!((adv[3] - 1.0).abs() < 1e-6);
        assert!((adv[0] - 4.0).abs() < 1e-6);
        assert_eq!(adv, ret);
    }

    #[test]
    fn done_breaks_credit_assignment() {
        let mut r = Rollout::new();
        r.push(step(0.0, 0.0, false));
        r.push(step(0.0, 0.0, true)); // episode boundary
        r.push(step(100.0, 0.0, false));
        let (adv, _) = r.gae(0.99, 0.95, 0.0);
        // Step 0 must not see the 100 reward beyond the boundary.
        assert!(adv[0].abs() < 1e-6, "adv0={}", adv[0]);
        assert!((adv[2] - 100.0).abs() < 1e-6);
    }
}
