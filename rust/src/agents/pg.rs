//! Policy-gradient agents: PPO and Recurrent PPO.
//!
//! Rollouts are collected on-policy; GAE(γ, λ) advantages are computed in
//! Rust; the clipped-surrogate Adam update runs as the AOT-compiled
//! `{ppo,rppo}_train` graph over shuffled minibatches for several epochs
//! (appendix Tables 3 and 5).

use super::rollout::{Rollout, RolloutStep};
use super::{init_params, timed_call, DrlAgent};
use crate::runtime::{Executable, Runtime};
use crate::util::Rng;
use anyhow::Result;

const GAMMA: f32 = 0.99;
const GAE_LAMBDA: f32 = 0.95;
const N_EPOCHS: usize = 10;
/// Rollout horizon before an update (Table 3 uses 2048; scaled down so
/// online tuning updates fire within a transfer's monitoring intervals —
/// documented in DESIGN.md §1).
const N_STEPS: usize = 64;

/// PPO / R_PPO agent core (`algo` ∈ {"ppo", "rppo"}).
pub struct PgAgent {
    algo: String,
    forward: Executable,
    train: Executable,
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    adam_step: f32,
    batch: usize,
    rollout: Rollout,
    /// (value, logp) of the action just taken, awaiting its observe().
    pending: Option<(f32, f32)>,
    rng: Rng,
    train_steps: u64,
    xla_s: f64,
    pub learning: bool,
}

impl PgAgent {
    pub fn new(runtime: &Runtime, algo: &str, seed: u64) -> Result<PgAgent> {
        let forward = runtime.compile(&format!("{algo}_forward"))?;
        let train = runtime.compile(&format!("{algo}_train"))?;
        let params = init_params(runtime, algo)?;
        let batch = runtime.manifest.algo(algo)?.hparam_or("batch", 64.0) as usize;
        let n = params.len();
        Ok(PgAgent {
            algo: algo.to_string(),
            forward,
            train,
            params,
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_step: 0.0,
            batch,
            rollout: Rollout::new(),
            pending: None,
            rng: Rng::new(seed),
            train_steps: 0,
            xla_s: 0.0,
            learning: true,
        })
    }

    /// (logits, value) for a state.
    fn policy(&mut self, state: &[f32]) -> (Vec<f32>, f32) {
        let out = timed_call(&self.forward, &[&self.params, state], &mut self.xla_s)
            .expect("forward execution failed");
        let mut it = out.into_iter();
        let logits = it.next().unwrap();
        let value = it.next().unwrap()[0];
        (logits, value)
    }

    fn update(&mut self, last_state: &[f32], last_done: bool) {
        let bootstrap = if last_done { 0.0 } else { self.policy(last_state).1 };
        let (adv, ret) = self.rollout.gae(GAMMA, GAE_LAMBDA, bootstrap);
        let n = self.rollout.len();
        let state_len = self.rollout.steps[0].state.len();
        let mut idx: Vec<usize> = (0..n).collect();
        for _ in 0..N_EPOCHS {
            self.rng.shuffle(&mut idx);
            for chunk in idx.chunks(self.batch) {
                if chunk.len() < self.batch {
                    continue; // the train graph has a fixed batch dimension
                }
                let mut obs = vec![0.0f32; self.batch * state_len];
                let mut act = vec![0.0f32; self.batch];
                let mut old_logp = vec![0.0f32; self.batch];
                let mut badv = vec![0.0f32; self.batch];
                let mut bret = vec![0.0f32; self.batch];
                for (row, &i) in chunk.iter().enumerate() {
                    let s = &self.rollout.steps[i];
                    obs[row * state_len..(row + 1) * state_len].copy_from_slice(&s.state);
                    act[row] = s.action as f32;
                    old_logp[row] = s.logp;
                    badv[row] = adv[i];
                    bret[row] = ret[i];
                }
                self.adam_step += 1.0;
                let step = [self.adam_step];
                let out = timed_call(
                    &self.train,
                    &[&self.params, &self.m, &self.v, &step, &obs, &act, &old_logp, &badv, &bret],
                    &mut self.xla_s,
                )
                .expect("train execution failed");
                let mut it = out.into_iter();
                self.params = it.next().unwrap();
                self.m = it.next().unwrap();
                self.v = it.next().unwrap();
                self.train_steps += 1;
            }
        }
        self.rollout.clear();
    }
}

impl DrlAgent for PgAgent {
    fn name(&self) -> &str {
        &self.algo
    }

    fn act(&mut self, state: &[f32], explore: bool) -> usize {
        let (logits, value) = self.policy(state);
        let action = if explore {
            self.rng.categorical_logits(&logits)
        } else {
            logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        // log-prob of the chosen action under the current policy.
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = max + logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln();
        let logp = logits[action] - lse;
        self.pending = Some((value, logp));
        action
    }

    fn observe(&mut self, state: &[f32], action: usize, reward: f64, next_state: &[f32], done: bool) {
        if !self.learning {
            return;
        }
        let (value, logp) = self.pending.take().unwrap_or((0.0, -(5.0f32.ln())));
        self.rollout.push(RolloutStep {
            state: state.to_vec(),
            action,
            reward: reward as f32,
            value,
            logp,
            done,
        });
        if self.rollout.len() >= N_STEPS {
            self.update(next_state, done);
        }
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn xla_seconds(&self) -> f64 {
        self.xla_s
    }
}
