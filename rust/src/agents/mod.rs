//! The five DRL agents (DQN, DRQN, PPO, R_PPO, DDPG).
//!
//! Every agent executes its policy network and its Adam training step as
//! AOT-compiled HLO through the PJRT runtime — no Python anywhere. The
//! algorithm-side logic that naturally lives on the host stays in Rust:
//! replay buffers, GAE, ε-greedy/noise exploration, target-network copies
//! and soft updates, and rollout bookkeeping.
//!
//! DQN/DRQN share [`td::TdAgent`] (TD(0) with a frozen target network);
//! PPO/R_PPO share [`pg::PgAgent`] (clipped-surrogate policy gradient);
//! DDPG has its own actor-critic flow in [`ddpg::DdpgAgent`].

pub mod ddpg;
pub mod linq;
pub mod pg;
pub mod replay;
pub mod rollout;
pub mod td;
pub mod wrapper;

pub use ddpg::DdpgAgent;
pub use linq::LinQAgent;
pub use pg::PgAgent;
pub use replay::Replay;
pub use rollout::Rollout;
pub use td::TdAgent;
pub use wrapper::DrlOptimizer;

use crate::runtime::Runtime;
use anyhow::{anyhow, Result};

/// Common interface of the learning cores (distinct from
/// [`crate::coordinator::Optimizer`], which adds the (cc, p) mapping —
/// see [`wrapper::DrlOptimizer`]).
///
/// `Send` because boxed agents ride inside per-lane optimizers that move to
/// cluster worker threads (never shared, only moved with the owning host).
pub trait DrlAgent: Send {
    fn name(&self) -> &str;

    /// Select an action for `state`; `explore` enables ε/noise exploration.
    fn act(&mut self, state: &[f32], explore: bool) -> usize;

    /// Record a transition and (depending on the algorithm's schedule) run
    /// one or more HLO training steps.
    fn observe(&mut self, state: &[f32], action: usize, reward: f64, next_state: &[f32], done: bool);

    /// Flat parameter vector (for persistence).
    fn params(&self) -> &[f32];

    /// Replace the parameter vector (e.g. with trained weights).
    fn set_params(&mut self, params: Vec<f32>);

    /// Number of HLO train-step executions so far.
    fn train_steps(&self) -> u64;

    /// Cumulative wall-clock seconds spent inside HLO executions (used for
    /// the Table-1 "GPU%" analogue — the XLA share of process time).
    fn xla_seconds(&self) -> f64;
}

/// The paper's algorithm names understood by [`make_agent`].
pub const ALGOS: [&str; 5] = ["dqn", "drqn", "ppo", "rppo", "ddpg"];

/// The artifact-free fallback core ([`linq`]): trains and evaluates without
/// the HLO runtime, so pipelines and CI run on a fresh checkout. Also
/// accepted by [`make_agent`], but deliberately not part of [`ALGOS`].
pub const FALLBACK_ALGO: &str = "linq";

/// Construct an agent core by algorithm name, with freshly-initialized
/// parameters from the artifacts (or `weights` if provided).
pub fn make_agent(
    runtime: &Runtime,
    algo: &str,
    seed: u64,
    weights: Option<Vec<f32>>,
) -> Result<Box<dyn DrlAgent>> {
    let mut agent: Box<dyn DrlAgent> = match algo {
        "dqn" => Box::new(TdAgent::new(runtime, td::TdConfig::dqn(), seed)?),
        "drqn" => Box::new(TdAgent::new(runtime, td::TdConfig::drqn(), seed)?),
        "ppo" => Box::new(PgAgent::new(runtime, "ppo", seed)?),
        "rppo" => Box::new(PgAgent::new(runtime, "rppo", seed)?),
        "ddpg" => Box::new(DdpgAgent::new(runtime, seed)?),
        // The pure-Rust fallback needs no runtime at all.
        "linq" => Box::new(LinQAgent::new(seed)),
        other => {
            return Err(anyhow!(
                "unknown algorithm '{other}' (expected one of {ALGOS:?}, or '{FALLBACK_ALGO}')"
            ))
        }
    };
    if let Some(w) = weights {
        agent.set_params(w);
    }
    Ok(agent)
}

/// Load an algorithm's freshly-initialized parameters from the artifacts.
pub fn init_params(runtime: &Runtime, algo: &str) -> Result<Vec<f32>> {
    let spec = runtime.manifest.algo(algo)?;
    crate::runtime::weights::load_f32(&runtime.manifest.init_params_path(algo), spec.n_params)
}

/// Timed HLO call helper shared by the agent implementations.
pub(crate) fn timed_call(
    exe: &crate::runtime::Executable,
    args: &[&[f32]],
    acc_s: &mut f64,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let t0 = std::time::Instant::now();
    let out = exe.call(args)?;
    *acc_s += t0.elapsed().as_secs_f64();
    Ok(out)
}
