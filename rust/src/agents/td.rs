//! Temporal-difference agents: DQN and DRQN.
//!
//! Both optimize a Q-network against a frozen target network via the
//! AOT-compiled `*_train` graph; they differ in architecture (handled
//! entirely on the Python side) and in schedule constants (appendix
//! Tables 2 and 6).

use super::replay::{Replay, Stored};
use super::{init_params, timed_call, DrlAgent};
use crate::runtime::{Executable, Runtime};
use crate::util::Rng;
use anyhow::Result;

/// Schedule constants distinguishing DQN from DRQN.
#[derive(Debug, Clone)]
pub struct TdConfig {
    pub algo: &'static str,
    pub buffer: usize,
    pub batch: usize,
    pub train_freq: u64,
    pub learn_start: usize,
    /// Hard target copy period in train steps (None = soft updates).
    pub hard_update: Option<u64>,
    /// Soft update (period, tau).
    pub soft_update: Option<(u64, f32)>,
    pub eps_start: f64,
    pub eps_end: f64,
    /// Multiplicative ε decay per environment step.
    pub eps_decay: f64,
}

impl TdConfig {
    /// Table 2: buffer 10000, batch 32, train freq 4, update interval 1000,
    /// final ε 0.02.
    pub fn dqn() -> TdConfig {
        TdConfig {
            algo: "dqn",
            buffer: 10_000,
            batch: 32,
            train_freq: 4,
            learn_start: 200,
            hard_update: Some(1000),
            soft_update: None,
            eps_start: 1.0,
            eps_end: 0.02,
            eps_decay: 0.9995,
        }
    }

    /// Table 6: ε 0.1 → 0.001 (decay 0.995), target update period 4 with
    /// τ = 0.01; batch reduced 256 → 64 for the CPU budget (DESIGN.md §1).
    pub fn drqn() -> TdConfig {
        TdConfig {
            algo: "drqn",
            buffer: 100_000,
            batch: 64,
            train_freq: 4,
            learn_start: 200,
            hard_update: None,
            soft_update: Some((4, 0.01)),
            eps_start: 0.1,
            eps_end: 0.001,
            eps_decay: 0.995,
        }
    }
}

/// DQN / DRQN agent core.
pub struct TdAgent {
    cfg: TdConfig,
    forward: Executable,
    train: Executable,
    params: Vec<f32>,
    tparams: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    adam_step: f32,
    epsilon: f64,
    replay: Replay,
    rng: Rng,
    env_steps: u64,
    train_steps: u64,
    xla_s: f64,
    state_len: usize,
    /// When false (evaluation), observe() neither stores nor trains.
    pub learning: bool,
}

impl TdAgent {
    pub fn new(runtime: &Runtime, cfg: TdConfig, seed: u64) -> Result<TdAgent> {
        let forward = runtime.compile(&format!("{}_forward", cfg.algo))?;
        let train = runtime.compile(&format!("{}_train", cfg.algo))?;
        let params = init_params(runtime, cfg.algo)?;
        let state_len = forward.spec.arg_len(1);
        let batch = runtime.manifest.algo(cfg.algo)?.hparam_or("batch", cfg.batch as f64) as usize;
        let n = params.len();
        Ok(TdAgent {
            epsilon: cfg.eps_start,
            replay: Replay::new(cfg.buffer),
            cfg: TdConfig { batch, ..cfg },
            forward,
            train,
            tparams: params.clone(),
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_step: 0.0,
            params,
            rng: Rng::new(seed),
            env_steps: 0,
            train_steps: 0,
            xla_s: 0.0,
            state_len,
            learning: true,
        })
    }

    fn q_values(&mut self, state: &[f32]) -> Vec<f32> {
        let out = timed_call(&self.forward, &[&self.params, state], &mut self.xla_s)
            .expect("forward execution failed");
        out.into_iter().next().unwrap()
    }

    fn train_step(&mut self) {
        let b = self.replay.sample_batch(self.cfg.batch, self.state_len, &mut self.rng);
        self.adam_step += 1.0;
        let step = [self.adam_step];
        let out = timed_call(
            &self.train,
            &[
                &self.params,
                &self.tparams,
                &self.m,
                &self.v,
                &step,
                &b.obs,
                &b.act,
                &b.rew,
                &b.next_obs,
                &b.done,
            ],
            &mut self.xla_s,
        )
        .expect("train execution failed");
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
        self.train_steps += 1;

        // Target-network maintenance.
        if let Some(period) = self.cfg.hard_update {
            if self.train_steps % period == 0 {
                self.tparams.copy_from_slice(&self.params);
            }
        }
        if let Some((period, tau)) = self.cfg.soft_update {
            if self.train_steps % period == 0 {
                for (t, p) in self.tparams.iter_mut().zip(&self.params) {
                    *t = tau * p + (1.0 - tau) * *t;
                }
            }
        }
    }
}

impl DrlAgent for TdAgent {
    fn name(&self) -> &str {
        self.cfg.algo
    }

    fn act(&mut self, state: &[f32], explore: bool) -> usize {
        if explore && self.rng.chance(self.epsilon) {
            return self.rng.below(crate::coordinator::N_ACTIONS);
        }
        let q = self.q_values(state);
        q.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn observe(&mut self, state: &[f32], action: usize, reward: f64, next_state: &[f32], done: bool) {
        if !self.learning {
            return;
        }
        self.replay.push(Stored {
            state: state.to_vec(),
            action,
            cont: [0.0, 0.0],
            reward: reward as f32,
            next_state: next_state.to_vec(),
            done,
        });
        self.env_steps += 1;
        self.epsilon = (self.epsilon * self.cfg.eps_decay).max(self.cfg.eps_end);
        if self.replay.len() >= self.cfg.learn_start && self.env_steps % self.cfg.train_freq == 0 {
            self.train_step();
        }
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len());
        self.tparams.copy_from_slice(&params);
        self.params = params;
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn xla_seconds(&self) -> f64 {
        self.xla_s
    }
}
