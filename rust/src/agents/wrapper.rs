//! Adapter from a [`DrlAgent`] learning core to the coordinator's
//! [`Optimizer`] interface (the paper's five-action (cc, p) mapping).

use super::DrlAgent;
use crate::coordinator::{Decision, MiContext, Optimizer, ParamBounds};

/// Wraps a DRL agent as a transfer-parameter optimizer.
pub struct DrlOptimizer {
    agent: Box<dyn DrlAgent>,
    display_name: String,
    /// Exploration on the transfer path (off for pure evaluation).
    pub explore: bool,
    /// Online learning on the transfer path (the paper's "online tuning").
    pub online_learning: bool,
    last_state: Vec<f32>,
    last_action: Option<usize>,
    start_cc: u32,
    start_p: u32,
    /// Consecutive MIs of an idle network with under-committed (cc, p) —
    /// drives the paper's "resume threads when resources are available"
    /// guardrail (§1, §5: agents pause *and resume* transfer threads).
    idle_underuse: u32,
}

impl DrlOptimizer {
    /// `display_name` lets SPARTA variants label themselves (e.g.
    /// "sparta-fe" is the R_PPO core with the F&E reward).
    pub fn new(agent: Box<dyn DrlAgent>, display_name: impl Into<String>) -> DrlOptimizer {
        DrlOptimizer {
            agent,
            display_name: display_name.into(),
            explore: false,
            online_learning: false,
            last_state: Vec::new(),
            last_action: None,
            start_cc: 0,
            start_p: 0,
            idle_underuse: 0,
        }
    }

    pub fn exploring(mut self, on: bool) -> Self {
        self.explore = on;
        self
    }

    pub fn learning(mut self, on: bool) -> Self {
        self.online_learning = on;
        self
    }

    /// Override the initial (cc, p) (0 = use the bounds' default).
    pub fn start_at(mut self, cc: u32, p: u32) -> Self {
        self.start_cc = cc;
        self.start_p = p;
        self
    }

    pub fn agent(&self) -> &dyn DrlAgent {
        self.agent.as_ref()
    }

    pub fn agent_mut(&mut self) -> &mut Box<dyn DrlAgent> {
        &mut self.agent
    }
}

impl Optimizer for DrlOptimizer {
    fn name(&self) -> &str {
        &self.display_name
    }

    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32) {
        self.last_action = None;
        self.last_state.clear();
        if self.start_cc > 0 && self.start_p > 0 {
            (self.start_cc, self.start_p)
        } else {
            (bounds.cc0, bounds.p0)
        }
    }

    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision {
        let mut action = self.agent.act(ctx.state, self.explore);

        // Resume guardrail: a frozen policy can drive (cc, p) into the lower
        // bound and then face a state it never saw offline (perfectly calm
        // link), where a wrong argmax becomes absorbing. The paper's
        // coordinator explicitly "resumes transfer threads when resources
        // are available" — if the network has been loss-free and queue-free
        // for several MIs while we hold fewer streams than the starting
        // configuration, force an increase.
        let ratio_calm = {
            // newest feature row: [plr, gradient, ratio, cc, p]
            let f = &ctx.state[ctx.state.len() - crate::coordinator::FEATURES..];
            f[2] < 1.15
        };
        let underused = ctx.cc * ctx.p < ctx.bounds.cc0 * ctx.bounds.p0;
        if ctx.obs.plr < 1e-4 && ratio_calm && underused {
            self.idle_underuse += 1;
        } else {
            self.idle_underuse = 0;
        }
        if self.idle_underuse >= 3 && matches!(action, 0 | 2 | 4) {
            action = 1; // +1/+1: resume capacity
            self.idle_underuse = 0;
        }

        self.last_state = ctx.state.to_vec();
        self.last_action = Some(action);
        let (cc, p) = ctx.bounds.apply(ctx.cc, ctx.p, action);
        Decision { cc, p, action: Some(action) }
    }

    fn learn(&mut self, reward: f64, next_state: &[f32], done: bool) {
        if !self.online_learning {
            return;
        }
        if let Some(action) = self.last_action.take() {
            self.agent.observe(&self.last_state, action, reward, next_state, done);
        }
    }

    fn is_learning(&self) -> bool {
        self.online_learning
    }

    fn state_vec(&self) -> Vec<f64> {
        // A frozen policy net is a rebuild-time constant; only the wrapper's
        // decision bookkeeping is captured (last_state length-prefixed).
        let mut v = vec![
            self.idle_underuse as f64,
            if self.last_action.is_some() { 1.0 } else { 0.0 },
            self.last_action.unwrap_or(0) as f64,
            self.last_state.len() as f64,
        ];
        v.extend(self.last_state.iter().map(|&x| x as f64));
        v
    }

    fn restore_state(&mut self, state: &[f64]) {
        if state.len() < 4 {
            return;
        }
        let n = state[3] as usize;
        if state.len() != 4 + n {
            return;
        }
        self.idle_underuse = state[0] as u32;
        self.last_action = (state[1] != 0.0).then_some(state[2] as usize);
        self.last_state = state[4..].iter().map(|&x| x as f32).collect();
    }
}
