//! DDPG: continuous-control actor-critic (appendix Table 4).
//!
//! The actor emits (x₁, x₂) ∈ [−2, 2]²; Gaussian exploration noise is added
//! in Rust and the pair is floored/capped onto the paper's five discrete
//! actions (§3.3.2). Soft target updates (τ = 0.005) are flat-vector lerps.

use super::replay::{Replay, Stored};
use super::{init_params, timed_call, DrlAgent};
use crate::coordinator::ParamBounds;
use crate::runtime::{Executable, Runtime};
use crate::util::Rng;
use anyhow::Result;

const TAU: f32 = 0.005;
const BUFFER: usize = 100_000;
const LEARN_START: usize = 100; // Table 4: learning starts
const TRAIN_FREQ: u64 = 1; // Table 4: train frequency 1
/// Exploration noise std-dev (decayed multiplicatively per step).
const NOISE_START: f64 = 0.8;
const NOISE_END: f64 = 0.05;
const NOISE_DECAY: f64 = 0.999;

/// DDPG agent core.
pub struct DdpgAgent {
    forward: Executable,
    train: Executable,
    params: Vec<f32>,
    tparams: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    adam_step: f32,
    batch: usize,
    replay: Replay,
    /// Continuous action actually taken, awaiting observe().
    pending_cont: [f32; 2],
    noise: f64,
    rng: Rng,
    env_steps: u64,
    train_steps: u64,
    xla_s: f64,
    state_len: usize,
    pub learning: bool,
}

impl DdpgAgent {
    pub fn new(runtime: &Runtime, seed: u64) -> Result<DdpgAgent> {
        let forward = runtime.compile("ddpg_forward")?;
        let train = runtime.compile("ddpg_train")?;
        let params = init_params(runtime, "ddpg")?;
        let batch = runtime.manifest.algo("ddpg")?.hparam_or("batch", 64.0) as usize;
        let state_len = forward.spec.arg_len(1);
        let n = params.len();
        Ok(DdpgAgent {
            forward,
            train,
            tparams: params.clone(),
            m: vec![0.0; n],
            v: vec![0.0; n],
            adam_step: 0.0,
            params,
            batch,
            replay: Replay::new(BUFFER),
            pending_cont: [0.0, 0.0],
            noise: NOISE_START,
            rng: Rng::new(seed),
            env_steps: 0,
            train_steps: 0,
            xla_s: 0.0,
            state_len,
            learning: true,
        })
    }

    fn actor(&mut self, state: &[f32]) -> [f32; 2] {
        let out = timed_call(&self.forward, &[&self.params, state], &mut self.xla_s)
            .expect("forward execution failed");
        let a = &out[0];
        [a[0], a[1]]
    }

    fn train_step(&mut self) {
        let b = self.replay.sample_batch(self.batch, self.state_len, &mut self.rng);
        self.adam_step += 1.0;
        let step = [self.adam_step];
        let out = timed_call(
            &self.train,
            &[
                &self.params,
                &self.tparams,
                &self.m,
                &self.v,
                &step,
                &b.obs,
                &b.cont,
                &b.rew,
                &b.next_obs,
                &b.done,
            ],
            &mut self.xla_s,
        )
        .expect("train execution failed");
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.m = it.next().unwrap();
        self.v = it.next().unwrap();
        self.train_steps += 1;
        // Soft target update.
        for (t, p) in self.tparams.iter_mut().zip(&self.params) {
            *t = TAU * p + (1.0 - TAU) * *t;
        }
    }
}

impl DrlAgent for DdpgAgent {
    fn name(&self) -> &str {
        "ddpg"
    }

    fn act(&mut self, state: &[f32], explore: bool) -> usize {
        let mut a = self.actor(state);
        if explore {
            a[0] = (a[0] as f64 + self.rng.normal_mean_sd(0.0, self.noise * 2.0)) as f32;
            a[1] = (a[1] as f64 + self.rng.normal_mean_sd(0.0, self.noise * 2.0)) as f32;
        }
        a[0] = a[0].clamp(-2.0, 2.0);
        a[1] = a[1].clamp(-2.0, 2.0);
        self.pending_cont = a;
        ParamBounds::continuous_to_action(a[0], a[1])
    }

    fn observe(&mut self, state: &[f32], action: usize, reward: f64, next_state: &[f32], done: bool) {
        if !self.learning {
            return;
        }
        self.replay.push(Stored {
            state: state.to_vec(),
            action,
            cont: self.pending_cont,
            reward: reward as f32,
            next_state: next_state.to_vec(),
            done,
        });
        self.env_steps += 1;
        self.noise = (self.noise * NOISE_DECAY).max(NOISE_END);
        if self.replay.len() >= LEARN_START && self.env_steps % TRAIN_FREQ == 0 {
            self.train_step();
        }
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, params: Vec<f32>) {
        assert_eq!(params.len(), self.params.len());
        self.tparams.copy_from_slice(&params);
        self.params = params;
    }

    fn train_steps(&self) -> u64 {
        self.train_steps
    }

    fn xla_seconds(&self) -> f64 {
        self.xla_s
    }
}
