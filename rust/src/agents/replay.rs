//! Experience replay buffer for the off-policy agents.

use crate::util::Rng;

/// One stored transition (flattened states).
#[derive(Debug, Clone)]
pub struct Stored {
    pub state: Vec<f32>,
    /// Discrete action index (TD agents) — DDPG stores the continuous pair
    /// separately in `cont`.
    pub action: usize,
    pub cont: [f32; 2],
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
}

/// Ring-buffer replay memory with uniform sampling.
#[derive(Debug)]
pub struct Replay {
    buf: Vec<Stored>,
    capacity: usize,
    head: usize,
}

impl Replay {
    pub fn new(capacity: usize) -> Replay {
        assert!(capacity > 0);
        Replay { buf: Vec::with_capacity(capacity.min(1 << 20)), capacity, head: 0 }
    }

    pub fn push(&mut self, t: Stored) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Sample a minibatch (with replacement when the buffer is small) into
    /// flat column arrays ready for the HLO train step.
    pub fn sample_batch(&self, batch: usize, state_len: usize, rng: &mut Rng) -> Batch {
        let mut b = Batch::zeros(batch, state_len);
        for i in 0..batch {
            let t = &self.buf[rng.below(self.buf.len())];
            b.obs[i * state_len..(i + 1) * state_len].copy_from_slice(&t.state);
            b.next_obs[i * state_len..(i + 1) * state_len].copy_from_slice(&t.next_state);
            b.act[i] = t.action as f32;
            b.cont[i * 2] = t.cont[0];
            b.cont[i * 2 + 1] = t.cont[1];
            b.rew[i] = t.reward;
            b.done[i] = if t.done { 1.0 } else { 0.0 };
        }
        b
    }
}

/// Column-major minibatch matching the training-graph argument layout.
#[derive(Debug, Clone)]
pub struct Batch {
    pub obs: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub act: Vec<f32>,
    pub cont: Vec<f32>,
    pub rew: Vec<f32>,
    pub done: Vec<f32>,
}

impl Batch {
    fn zeros(batch: usize, state_len: usize) -> Batch {
        Batch {
            obs: vec![0.0; batch * state_len],
            next_obs: vec![0.0; batch * state_len],
            act: vec![0.0; batch],
            cont: vec![0.0; batch * 2],
            rew: vec![0.0; batch],
            done: vec![0.0; batch],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stored(v: f32) -> Stored {
        Stored {
            state: vec![v; 4],
            action: v as usize % 5,
            cont: [v, -v],
            reward: v,
            next_state: vec![v + 1.0; 4],
            done: false,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = Replay::new(3);
        for i in 0..5 {
            r.push(stored(i as f32));
        }
        assert_eq!(r.len(), 3);
        // Values 0 and 1 were overwritten by 3 and 4.
        let rewards: Vec<f32> = r.buf.iter().map(|t| t.reward).collect();
        assert!(rewards.contains(&2.0) && rewards.contains(&3.0) && rewards.contains(&4.0));
    }

    #[test]
    fn batch_shapes() {
        let mut r = Replay::new(100);
        for i in 0..10 {
            r.push(stored(i as f32));
        }
        let mut rng = Rng::new(1);
        let b = r.sample_batch(8, 4, &mut rng);
        assert_eq!(b.obs.len(), 32);
        assert_eq!(b.act.len(), 8);
        assert_eq!(b.cont.len(), 16);
        // Sampled rows are coherent: next = state + 1.
        for i in 0..8 {
            assert_eq!(b.next_obs[i * 4], b.obs[i * 4] + 1.0);
        }
    }
}
