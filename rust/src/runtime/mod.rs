//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! `make artifacts` (build-time Python) lowers every policy/value network and
//! training step to HLO *text* — the interchange format that round-trips
//! through xla_extension 0.5.1 (serialized jax ≥ 0.5 protos are rejected;
//! see DESIGN.md). This module wraps the `xla` crate's PJRT CPU client to
//! compile those artifacts once and execute them from the transfer hot path
//! with flat `f32` buffers; Python is never involved at run time.

pub mod executable;
pub mod manifest;
pub mod weights;

pub use executable::{Executable, Runtime};
pub use manifest::{GraphSpec, Manifest};
pub use weights::{WeightSnapshot, WeightStore};
