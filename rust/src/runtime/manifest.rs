//! `artifacts/manifest.json` — the contract between the Python AOT step and
//! the Rust runtime.
//!
//! The manifest records, per exported graph: the HLO file, the ordered
//! argument list with shapes, and the outputs; plus, per algorithm, the flat
//! parameter-vector length and hyperparameters both sides must agree on
//! (state window, feature count, hidden sizes, γ, learning rate, ...).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One exported HLO graph.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Ordered argument names.
    pub arg_names: Vec<String>,
    /// Ordered argument shapes (row-major dims; scalar = empty).
    pub arg_shapes: Vec<Vec<usize>>,
    /// Number of outputs in the result tuple.
    pub n_outputs: usize,
}

impl GraphSpec {
    /// Flat element count of argument `i`.
    pub fn arg_len(&self, i: usize) -> usize {
        self.arg_shapes[i].iter().product::<usize>().max(1)
    }

    pub fn arg_index(&self, name: &str) -> Option<usize> {
        self.arg_names.iter().position(|n| n == name)
    }
}

/// Per-algorithm metadata from the manifest.
#[derive(Debug, Clone)]
pub struct AlgoSpec {
    pub name: String,
    /// Flat parameter-vector length.
    pub n_params: usize,
    /// Scalar hyperparameters exported by the Python side.
    pub hparams: BTreeMap<String, f64>,
    /// Graph names owned by this algorithm (e.g. "dqn_forward", "dqn_train").
    pub graphs: Vec<String>,
}

impl AlgoSpec {
    pub fn hparam(&self, key: &str) -> Result<f64> {
        self.hparams
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("algorithm '{}' missing hparam '{key}'", self.name))
    }

    pub fn hparam_or(&self, key: &str, default: f64) -> f64 {
        self.hparams.get(key).copied().unwrap_or(default)
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub graphs: BTreeMap<String, GraphSpec>,
    pub algos: BTreeMap<String, AlgoSpec>,
    /// Global settings the state construction must match (window, features).
    pub globals: BTreeMap<String, f64>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let mut graphs = BTreeMap::new();
        for (name, g) in root
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'graphs'"))?
        {
            let arg_names = g
                .get("arg_names")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("graph {name}: missing arg_names"))?
                .iter()
                .map(|v| v.as_str().unwrap_or("").to_string())
                .collect();
            let arg_shapes = g
                .get("arg_shapes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("graph {name}: missing arg_shapes"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect()
                })
                .collect();
            graphs.insert(
                name.clone(),
                GraphSpec {
                    name: name.clone(),
                    file: g
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("graph {name}: missing file"))?
                        .to_string(),
                    arg_names,
                    arg_shapes,
                    n_outputs: g.get("n_outputs").and_then(Json::as_usize).unwrap_or(1),
                },
            );
        }

        let mut algos = BTreeMap::new();
        if let Some(obj) = root.get("algos").and_then(Json::as_obj) {
            for (name, a) in obj {
                let hparams = a
                    .get("hparams")
                    .and_then(Json::as_obj)
                    .map(|m| {
                        m.iter()
                            .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                            .collect()
                    })
                    .unwrap_or_default();
                let graphs_list = a
                    .get("graphs")
                    .and_then(Json::as_arr)
                    .map(|v| v.iter().filter_map(|g| g.as_str().map(str::to_string)).collect())
                    .unwrap_or_default();
                algos.insert(
                    name.clone(),
                    AlgoSpec {
                        name: name.clone(),
                        n_params: a
                            .get("n_params")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| anyhow!("algo {name}: missing n_params"))?,
                        hparams,
                        graphs: graphs_list,
                    },
                );
            }
        }

        let globals = root
            .get("globals")
            .and_then(Json::as_obj)
            .map(|m| {
                m.iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect()
            })
            .unwrap_or_default();

        Ok(Manifest { dir: dir.to_path_buf(), graphs, algos, globals })
    }

    /// Like [`Manifest::load`], but a missing `manifest.json` yields an
    /// empty manifest (no graphs, no algorithms) instead of an error, so
    /// artifact-free consumers — the baselines, the scenario registry and
    /// the pure-Rust `linq` fallback agent — run on a fresh checkout.
    /// A *present but malformed* manifest is still an error.
    pub fn load_or_empty(dir: &Path) -> Result<Manifest> {
        if !dir.join("manifest.json").exists() {
            crate::log_info!(
                "no artifacts under {} — HLO agents unavailable (run `make artifacts`); \
                 baselines and the linq fallback agent still work",
                dir.display()
            );
            return Ok(Manifest {
                dir: dir.to_path_buf(),
                graphs: BTreeMap::new(),
                algos: BTreeMap::new(),
                globals: BTreeMap::new(),
            });
        }
        Manifest::load(dir)
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no graph '{name}'"))
    }

    pub fn algo(&self, name: &str) -> Result<&AlgoSpec> {
        self.algos
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no algorithm '{name}'"))
    }

    pub fn global(&self, key: &str) -> Result<f64> {
        self.globals
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("manifest missing global '{key}'"))
    }

    /// Absolute path of a graph's HLO file.
    pub fn hlo_path(&self, spec: &GraphSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Path of an algorithm's initial flat-parameter binary.
    pub fn init_params_path(&self, algo: &str) -> PathBuf {
        self.dir.join(format!("{algo}_init.f32"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("sparta_manifest_test1");
        write_manifest(
            &dir,
            r#"{
              "graphs": {
                "dqn_forward": {
                  "file": "dqn_forward.hlo.txt",
                  "arg_names": ["params", "obs"],
                  "arg_shapes": [[100], [8, 5]],
                  "n_outputs": 1
                }
              },
              "algos": {
                "dqn": {"n_params": 100, "hparams": {"gamma": 0.99}, "graphs": ["dqn_forward"]}
              },
              "globals": {"window": 8, "features": 5}
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let g = m.graph("dqn_forward").unwrap();
        assert_eq!(g.arg_len(0), 100);
        assert_eq!(g.arg_len(1), 40);
        assert_eq!(g.arg_index("obs"), Some(1));
        assert_eq!(m.algo("dqn").unwrap().hparam("gamma").unwrap(), 0.99);
        assert_eq!(m.global("window").unwrap(), 8.0);
        assert!(m.graph("nope").is_err());
    }

    #[test]
    fn missing_file_is_friendly_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
