//! Compiled-HLO execution on the PJRT CPU client.
//!
//! The PJRT path needs the external `xla` crate, which the offline build
//! environment does not provide; it is gated behind the `xla` cargo feature.
//! Without the feature, [`Runtime::load`] still loads the manifest (so
//! `sparta info` and manifest-only consumers work), but [`Runtime::compile`]
//! returns a descriptive error and no agent can execute HLO.

use super::manifest::{GraphSpec, Manifest};
use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::anyhow;
#[cfg(feature = "xla")]
use std::sync::Arc;

/// Shared PJRT client + compiled executables for one artifacts directory.
pub struct Runtime {
    #[cfg(feature = "xla")]
    client: Arc<xla::PjRtClient>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client (when built with the `xla` feature) and load
    /// the manifest. A missing artifacts directory yields an empty manifest
    /// (see [`Manifest::load_or_empty`]) so artifact-free paths — baselines
    /// and the pure-Rust `linq` agent — work on a fresh checkout.
    pub fn load(artifacts_dir: &std::path::Path) -> Result<Runtime> {
        let manifest = Manifest::load_or_empty(artifacts_dir)?;
        #[cfg(feature = "xla")]
        {
            // Perf (EXPERIMENTS.md §Perf): the agent graphs are small; Eigen's
            // intra-op threading costs ~2x wall time in thread churn at these
            // sizes. Respect a user-provided XLA_FLAGS, otherwise disable it.
            if std::env::var_os("XLA_FLAGS").is_none() {
                std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
            }
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(Runtime { client: Arc::new(client), manifest })
        }
        #[cfg(not(feature = "xla"))]
        Ok(Runtime { manifest })
    }

    /// Compile one exported graph by manifest name.
    #[cfg(feature = "xla")]
    pub fn compile(&self, graph: &str) -> Result<Executable> {
        let spec = self.manifest.graph(graph)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {graph}: {e:?}"))?;
        Ok(Executable { spec, exe, client: self.client.clone() })
    }

    /// Compile one exported graph by manifest name (stub: always errors).
    #[cfg(not(feature = "xla"))]
    pub fn compile(&self, graph: &str) -> Result<Executable> {
        let spec = self.manifest.graph(graph)?;
        anyhow::bail!(
            "cannot compile '{}': sparta was built without the `xla` feature, \
             so HLO execution is unavailable (rebuild with `--features xla` in \
             an environment that provides the xla crate)",
            spec.name
        )
    }
}

/// One compiled HLO graph, callable with flat `f32` argument buffers.
pub struct Executable {
    pub spec: GraphSpec,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
    #[cfg(feature = "xla")]
    client: Arc<xla::PjRtClient>,
}

impl Executable {
    /// Execute with one flat `f32` slice per argument (lengths must match
    /// the manifest's shapes). Returns the flattened outputs, in tuple order.
    ///
    /// All SPARTA graphs are exported with `return_tuple=True`, so the
    /// result is always a tuple literal — even for single outputs.
    ///
    /// NOTE: this deliberately uses `execute_b` with caller-owned device
    /// buffers. The crate's `execute(&[Literal])` path leaks every input
    /// device buffer on the C++ side (`buffer.release()` without a matching
    /// free) — at DDPG's training rate that OOM-kills the process within
    /// minutes (EXPERIMENTS.md §Perf).
    #[cfg(feature = "xla")]
    pub fn call(&self, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.arg_names.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                self.spec.name,
                self.spec.arg_names.len(),
                args.len()
            ));
        }
        let mut buffers = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let want = self.spec.arg_len(i);
            if a.len() != want {
                return Err(anyhow!(
                    "{}: arg {} ({}) expected {} elements, got {}",
                    self.spec.name,
                    i,
                    self.spec.arg_names[i],
                    want,
                    a.len()
                ));
            }
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(a, &self.spec.arg_shapes[i], None)
                .map_err(|e| anyhow!("{}: arg {i} upload: {e:?}", self.spec.name))?;
            buffers.push(buf);
        }
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.spec.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.spec.name))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose: {e:?}", self.spec.name))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            // Convert any non-f32 outputs (e.g. argmax indices) to f32.
            let p32 = match p.ty() {
                Ok(xla::ElementType::F32) => p,
                _ => p
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("{}: convert: {e:?}", self.spec.name))?,
            };
            out.push(
                p32.to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: to_vec: {e:?}", self.spec.name))?,
            );
        }
        Ok(out)
    }

    /// Stub: the `xla` feature is off, so nothing can execute. Unreachable in
    /// practice because [`Runtime::compile`] never constructs an [`Executable`]
    /// in stub builds.
    #[cfg(not(feature = "xla"))]
    pub fn call(&self, _args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("{}: built without the `xla` feature", self.spec.name)
    }

    /// Per-call argument validation helper used by agents in debug builds.
    pub fn n_args(&self) -> usize {
        self.spec.arg_names.len()
    }
}
