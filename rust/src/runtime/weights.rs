//! Flat-parameter persistence: init params from the AOT step, trained
//! weights saved/loaded by the trainer.
//!
//! Format: raw little-endian `f32` array, no header — the length is checked
//! against the manifest's `n_params`, which catches architecture drift
//! between Python and Rust.

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Reads/writes flat f32 parameter vectors under a directory.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub dir: PathBuf,
}

impl WeightStore {
    pub fn new(dir: impl Into<PathBuf>) -> WeightStore {
        WeightStore { dir: dir.into() }
    }

    /// `<dir>/<name>.f32`
    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.f32"))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    /// Load a flat vector, verifying the expected length (0 = any).
    pub fn load(&self, name: &str, expect_len: usize) -> Result<Vec<f32>> {
        load_f32(&self.path(name), expect_len)
    }

    /// Save a flat vector (creates the directory). Writes to a temp file
    /// and renames so an interrupted save never leaves a truncated `.f32`
    /// behind — [`WeightSnapshot`] loads the whole directory at startup.
    pub fn save(&self, name: &str, data: &[f32]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let tmp = self.dir.join(format!("{name}.f32.tmp"));
        std::fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.path(name))
            .with_context(|| format!("renaming into {}", self.path(name).display()))
    }
}

/// Immutable, load-once view of every trained parameter vector under a
/// weights directory.
///
/// [`WeightStore`] is the *write* path (the trainer saves through it);
/// `WeightSnapshot` is the *read* path for evaluation: all `<name>.f32`
/// files are read into memory exactly once at construction, and the
/// snapshot is then shared across experiment workers behind an [`Arc`], so
/// concurrent evaluation cells never touch the filesystem. Weights saved
/// after the snapshot was taken are invisible to it — take a fresh
/// snapshot after a training phase (see `sparta generalize`).
#[derive(Debug, Clone, Default)]
pub struct WeightSnapshot {
    dir: PathBuf,
    by_name: BTreeMap<String, Arc<Vec<f32>>>,
}

impl WeightSnapshot {
    /// Snapshot every `<name>.f32` under `dir`. A missing directory yields
    /// an empty snapshot (nothing has been trained yet). Unreadable or
    /// malformed files are skipped with a warning rather than failing the
    /// whole snapshot — one damaged weight file must not brick every CLI
    /// command; the damage surfaces as a "no trained weights" error only
    /// for consumers of that name.
    pub fn load_dir(dir: impl Into<PathBuf>) -> Result<WeightSnapshot> {
        let dir = dir.into();
        let mut by_name = BTreeMap::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(_) => return Ok(WeightSnapshot { dir, by_name }),
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("f32") {
                continue;
            }
            let Some(name) = path.file_stem().and_then(|s| s.to_str()) else {
                continue;
            };
            match load_f32(&path, 0) {
                Ok(v) => {
                    by_name.insert(name.to_string(), Arc::new(v));
                }
                Err(e) => {
                    crate::log_warn!("snapshot: skipping {}: {e:#}", path.display());
                }
            }
        }
        Ok(WeightSnapshot { dir, by_name })
    }

    /// Snapshot the directory a [`WeightStore`] writes to.
    pub fn of_store(store: &WeightStore) -> Result<WeightSnapshot> {
        WeightSnapshot::load_dir(store.dir.clone())
    }

    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    /// Saved names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.by_name.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// A cloned parameter vector for `name` (agents own and mutate their
    /// copy), with the same length check as [`WeightStore::load`]
    /// (`expect_len == 0` skips it).
    pub fn params(&self, name: &str, expect_len: usize) -> Result<Vec<f32>> {
        let v = self.by_name.get(name).ok_or_else(|| {
            anyhow!("no trained weights '{name}' in the snapshot of {}", self.dir.display())
        })?;
        if expect_len > 0 && v.len() != expect_len {
            return Err(anyhow!(
                "{name}: expected {expect_len} f32 values, snapshot holds {} — \
                 artifacts out of date? (re-run `make artifacts` and retrain)",
                v.len()
            ));
        }
        Ok(v.as_ref().clone())
    }
}

/// Load a raw little-endian f32 file, checking length when `expect_len > 0`.
pub fn load_f32(path: &Path, expect_len: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("{}: size {} not a multiple of 4", path.display(), bytes.len()));
    }
    let n = bytes.len() / 4;
    if expect_len > 0 && n != expect_len {
        return Err(anyhow!(
            "{}: expected {} f32 values, found {} — artifacts out of date? (re-run `make artifacts`)",
            path.display(),
            expect_len,
            n
        ));
    }
    let mut out = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sparta_weights_test");
        let store = WeightStore::new(&dir);
        let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 7.0).collect();
        store.save("unit", &data).unwrap();
        assert!(store.exists("unit"));
        let back = store.load("unit", 100).unwrap();
        assert_eq!(data, back);
    }

    #[test]
    fn length_mismatch_is_error() {
        let dir = std::env::temp_dir().join("sparta_weights_test2");
        let store = WeightStore::new(&dir);
        store.save("short", &[1.0, 2.0]).unwrap();
        let err = store.load("short", 3).unwrap_err();
        assert!(err.to_string().contains("expected 3"));
        // expect_len = 0 skips the check.
        assert_eq!(store.load("short", 0).unwrap().len(), 2);
    }

    #[test]
    fn missing_file_is_error() {
        let store = WeightStore::new(std::env::temp_dir().join("sparta_weights_test3"));
        assert!(store.load("nope", 0).is_err());
    }

    /// The snapshot returns bit-identical params to `WeightStore::load` for
    /// every saved name.
    #[test]
    fn snapshot_matches_store_bit_for_bit() {
        let dir = std::env::temp_dir().join("sparta_weights_snap");
        let _ = std::fs::remove_dir_all(&dir);
        let store = WeightStore::new(&dir);
        let vecs: Vec<(String, Vec<f32>)> = (0..4)
            .map(|k| {
                let name = format!("algo{k}_te");
                let data: Vec<f32> =
                    (0..50 + k).map(|i| (i as f32 * 0.37 - k as f32).sin()).collect();
                (name, data)
            })
            .collect();
        for (name, data) in &vecs {
            store.save(name, data).unwrap();
        }
        let snap = WeightSnapshot::of_store(&store).unwrap();
        assert_eq!(snap.len(), vecs.len());
        for (name, data) in &vecs {
            assert!(snap.contains(name));
            let from_store = store.load(name, data.len()).unwrap();
            let from_snap = snap.params(name, data.len()).unwrap();
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&from_store), bits(&from_snap), "{name}");
        }
        assert_eq!(snap.names(), vecs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>());
    }

    /// One damaged file (size not a multiple of 4) is skipped; the rest of
    /// the snapshot still loads.
    #[test]
    fn snapshot_skips_corrupt_files() {
        let dir = std::env::temp_dir().join("sparta_weights_snap_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        let store = WeightStore::new(&dir);
        store.save("good", &[1.0, 2.0]).unwrap();
        std::fs::write(dir.join("bad.f32"), [0u8; 5]).unwrap();
        let snap = WeightSnapshot::of_store(&store).unwrap();
        assert!(snap.contains("good"));
        assert!(!snap.contains("bad"));
        assert_eq!(snap.len(), 1);
    }

    #[test]
    fn snapshot_of_missing_dir_is_empty() {
        let snap =
            WeightSnapshot::load_dir(std::env::temp_dir().join("sparta_no_such_dir")).unwrap();
        assert!(snap.is_empty());
        assert!(snap.params("anything", 0).is_err());
    }

    #[test]
    fn snapshot_length_mismatch_is_error() {
        let dir = std::env::temp_dir().join("sparta_weights_snap_len");
        let _ = std::fs::remove_dir_all(&dir);
        let store = WeightStore::new(&dir);
        store.save("w", &[1.0, 2.0, 3.0]).unwrap();
        let snap = WeightSnapshot::of_store(&store).unwrap();
        assert!(snap.params("w", 4).is_err());
        assert_eq!(snap.params("w", 0).unwrap().len(), 3);
        assert_eq!(snap.params("w", 3).unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
