//! Flat-parameter persistence: init params from the AOT step, trained
//! weights saved/loaded by the trainer.
//!
//! Format: raw little-endian `f32` array, no header — the length is checked
//! against the manifest's `n_params`, which catches architecture drift
//! between Python and Rust.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Reads/writes flat f32 parameter vectors under a directory.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub dir: PathBuf,
}

impl WeightStore {
    pub fn new(dir: impl Into<PathBuf>) -> WeightStore {
        WeightStore { dir: dir.into() }
    }

    /// `<dir>/<name>.f32`
    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.f32"))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.path(name).exists()
    }

    /// Load a flat vector, verifying the expected length (0 = any).
    pub fn load(&self, name: &str, expect_len: usize) -> Result<Vec<f32>> {
        load_f32(&self.path(name), expect_len)
    }

    /// Save a flat vector (creates the directory).
    pub fn save(&self, name: &str, data: &[f32]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(self.path(name), bytes)
            .with_context(|| format!("writing {}", self.path(name).display()))
    }
}

/// Load a raw little-endian f32 file, checking length when `expect_len > 0`.
pub fn load_f32(path: &Path, expect_len: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        return Err(anyhow!("{}: size {} not a multiple of 4", path.display(), bytes.len()));
    }
    let n = bytes.len() / 4;
    if expect_len > 0 && n != expect_len {
        return Err(anyhow!(
            "{}: expected {} f32 values, found {} — artifacts out of date? (re-run `make artifacts`)",
            path.display(),
            expect_len,
            n
        ));
    }
    let mut out = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sparta_weights_test");
        let store = WeightStore::new(&dir);
        let data: Vec<f32> = (0..100).map(|i| i as f32 * 0.5 - 7.0).collect();
        store.save("unit", &data).unwrap();
        assert!(store.exists("unit"));
        let back = store.load("unit", 100).unwrap();
        assert_eq!(data, back);
    }

    #[test]
    fn length_mismatch_is_error() {
        let dir = std::env::temp_dir().join("sparta_weights_test2");
        let store = WeightStore::new(&dir);
        store.save("short", &[1.0, 2.0]).unwrap();
        let err = store.load("short", 3).unwrap_err();
        assert!(err.to_string().contains("expected 3"));
        // expect_len = 0 skips the check.
        assert_eq!(store.load("short", 0).unwrap().len(), 2);
    }

    #[test]
    fn missing_file_is_error() {
        let store = WeightStore::new(std::env::temp_dir().join("sparta_weights_test3"));
        assert!(store.load("nope", 0).is_err());
    }
}
