//! Leveled stderr logging plus the paper's transition-log line format.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity: 0 = quiet (warnings only), 1 = info, 2 = debug.
static LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn info(msg: &str) {
    if level() >= 1 {
        let _ = writeln!(std::io::stderr(), "[sparta] {msg}");
    }
}

pub fn debug(msg: &str) {
    if level() >= 2 {
        let _ = writeln!(std::io::stderr(), "[sparta:debug] {msg}");
    }
}

pub fn warn(msg: &str) {
    let _ = writeln!(std::io::stderr(), "[sparta:warn] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => { $crate::util::log::info(&format!($($t)*)) }
}

#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => { $crate::util::log::debug(&format!($($t)*)) }
}

#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => { $crate::util::log::warn(&format!($($t)*)) }
}
