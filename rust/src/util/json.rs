//! Minimal JSON reader/writer.
//!
//! Used for `artifacts/manifest.json` (written by the build-time Python AOT
//! step) and for machine-readable experiment reports. Supports the full JSON
//! grammar except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null-ish None when missing.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json { Json::Num(x) }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json { Json::Num(x as f64) }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json { Json::Str(s.to_string()) }
}
impl From<String> for Json {
    fn from(s: String) -> Json { Json::Str(s) }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json { Json::Bool(b) }
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"x","é"],"m":{"n":false}}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo"));
    }
}
