//! Descriptive statistics used by the telemetry layer and the bench harness.

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns an all-zero summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, p25: 0.0, median: 0.0, p75: 0.0, p95: 0.0, max: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p25: percentile_sorted(&sorted, 0.25),
            median: percentile_sorted(&sorted, 0.50),
            p75: percentile_sorted(&sorted, 0.75),
            p95: percentile_sorted(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }

    /// One-line rendering used by the bench tables.
    pub fn line(&self) -> String {
        format!(
            "n={:<4} mean={:<8.3} std={:<8.3} min={:<8.3} p50={:<8.3} p95={:<8.3} max={:<8.3}",
            self.n, self.mean, self.std, self.min, self.median, self.p95, self.max
        )
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Arithmetic mean (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Exponentially-weighted moving average accumulator.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Jain's Fairness Index over per-flow throughputs (Eq. 18 of the paper).
///
/// JFI = (sum T_k)^2 / (n * sum T_k^2); 1.0 = perfectly fair. Defined as 1.0
/// for an empty set or an all-zero set (no flow is being disadvantaged).
pub fn jain_fairness(throughputs: &[f64]) -> f64 {
    let n = throughputs.len();
    if n == 0 {
        return 1.0;
    }
    let s: f64 = throughputs.iter().sum();
    let s2: f64 = throughputs.iter().map(|x| x * x).sum();
    if s2 <= 0.0 {
        return 1.0;
    }
    (s * s) / (n as f64 * s2)
}

/// Simple online mean/min/max accumulator for hot loops (no allocation).
#[derive(Debug, Clone, Default)]
pub struct Acc {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Acc {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            if x < self.min { self.min = x; }
            if x > self.max { self.max = x; }
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.median - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn jfi_equal_flows_is_one() {
        assert!((jain_fairness(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jfi_single_hog() {
        // One flow takes everything among n flows -> JFI = 1/n.
        let j = jain_fairness(&[9.0, 0.0, 0.0]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn jfi_bounds() {
        let j = jain_fairness(&[1.0, 2.0, 3.0, 4.0]);
        assert!(j > 0.25 && j <= 1.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn acc_tracks_min_max_mean() {
        let mut a = Acc::default();
        for x in [4.0, -1.0, 7.5] {
            a.push(x);
        }
        assert_eq!(a.min, -1.0);
        assert_eq!(a.max, 7.5);
        assert!((a.mean() - 3.5).abs() < 1e-12);
    }
}
