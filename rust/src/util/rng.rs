//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via SplitMix64 — the standard small, fast, high-quality
//! generator pair. Every stochastic component in SPARTA (simulator noise,
//! exploration, emulator sampling, property tests) takes an explicit seed so
//! that runs, tests and benches are exactly reproducible.

/// Stable 64-bit mix of a base seed, a label and an index: FNV-1a over the
/// label bytes and the index, XORed into the base. The single shared
/// implementation behind identity-derived seeding — experiment cells
/// ([`crate::experiments::runner::cell_seed`]) and arrival-schedule
/// workloads derive their seeds purely from what they are, never from
/// execution order, which is what keeps reports bit-identical at any
/// `--jobs` count.
pub fn mix_seed(base: u64, label: &str, index: u64) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in label.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h = (h ^ index).wrapping_mul(0x0000_0100_0000_01B3);
    base ^ h
}

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent child generator (for sub-components).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_5A5A_5A5A)
    }

    /// The raw xoshiro256** state word, for checkpointing. Restoring it
    /// with [`Rng::from_state`] resumes the exact draw sequence — the
    /// serve snapshot codec round-trips every generator this way.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`] word.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill `out` with uniform f64 in [0, 1) — the batched form of
    /// [`Rng::f64`]. Draws exactly `out.len()` variates from the same
    /// underlying `next_u64` sequence, in the same order, producing
    /// bit-identical values: a caller that pre-draws a phase's variates
    /// into a buffer consumes the generator exactly as a per-item
    /// `f64()` loop would (the simulator's batched loss phase relies on
    /// this; see `fill_f64_matches_sequential_draws`).
    pub fn fill_f64(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        }
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with the given **m**ean and **s**tandard **d**eviation, in
    /// whatever unit the caller works in (unit-agnostic; renamed from
    /// `normal_ms`, whose suffix read like "milliseconds" at call sites
    /// that pass seconds — e.g. the simulator's RTT noise).
    pub fn normal_mean_sd(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from a categorical distribution given logits (softmax sample).
    pub fn categorical_logits(&mut self, logits: &[f32]) -> usize {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits.iter().map(|&l| ((l - max) as f64).exp()).collect();
        self.weighted(&weights)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `n` distinct indices in [0, len) (len >= n), in random order.
    pub fn sample_indices(&mut self, len: usize, n: usize) -> Vec<usize> {
        assert!(n <= len);
        let mut idx: Vec<usize> = (0..len).collect();
        self.shuffle(&mut idx);
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    /// RNG draw-order stability under batching: one `fill_f64` over a
    /// buffer is the identical variate sequence as that many sequential
    /// `f64()` calls, bit for bit, and leaves the generator in the same
    /// state. This is the contract the batched simulator loss phase
    /// depends on for golden-replay byte-identity.
    #[test]
    fn fill_f64_matches_sequential_draws() {
        let mut scalar = Rng::new(99);
        let mut batched = Rng::new(99);
        let mut buf = [0.0f64; 257]; // odd length: no chunk-boundary luck
        batched.fill_f64(&mut buf);
        for (i, &x) in buf.iter().enumerate() {
            assert_eq!(x.to_bits(), scalar.f64().to_bits(), "draw {i} diverged");
        }
        // Post-batch generator state is identical too.
        assert_eq!(scalar.next_u64(), batched.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(19);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn categorical_logits_argmax_dominates() {
        let mut r = Rng::new(23);
        let logits = [0.0f32, 8.0, 0.0, 0.0, 0.0];
        let hits = (0..1000).filter(|_| r.categorical_logits(&logits) == 1).count();
        assert!(hits > 950);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(31);
        let idx = r.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }
}
