//! Self-contained utility layer.
//!
//! The build environment is fully offline, so the usual ecosystem crates
//! (rand, serde, clap, criterion) are unavailable; the pieces SPARTA needs
//! from them are implemented here and tested like any other module.

pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
