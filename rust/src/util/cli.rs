//! Tiny argument parser for the `sparta` CLI and the bench binaries.
//!
//! Grammar: `sparta <subcommand> [--flag] [--key value]...`. Unknown keys are
//! reported as errors so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` / `--flag` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: expected number, got '{s}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: expected integer, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name}: expected integer, got '{s}'")),
        }
    }

    /// Validate that every provided option/flag is in the allowed set.
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(format!("unknown option --{k} (allowed: {})", allowed.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("transfer --testbed chameleon --files 50 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("transfer"));
        assert_eq!(a.get("testbed"), Some("chameleon"));
        assert_eq!(a.get_usize("files", 0).unwrap(), 50);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn key_equals_value() {
        let a = parse("x --k=v");
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn positional_args() {
        let a = parse("run a b --k v c");
        assert_eq!(a.positional, vec!["a", "b", "c"]);
    }

    #[test]
    fn numeric_defaults() {
        let a = parse("x");
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert!(parse("x --n abc").get_usize("n", 0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse("x --good 1 --bad 2");
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }
}
