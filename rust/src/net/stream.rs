//! Fluid-model TCP CUBIC stream.
//!
//! Each application-layer stream (one of a file-task's `p` parallel sockets)
//! carries a CUBIC congestion window evolved at tick granularity:
//! slow start → cubic concave/convex growth around `w_max`, multiplicative
//! decrease (β = 0.7) on loss events, at most one decrease per RTT, and
//! growth freezing while application-limited (sender has nothing to push).
//!
//! The fluid approximation follows Ha/Rhee/Xu's CUBIC window function
//! W(t) = C·(t−K)³ + W_max with C = 0.4, K = ∛(W_max·β_dec/C).

use super::MSS_BITS;

/// CUBIC constant C (MSS/s³).
const CUBIC_C: f64 = 0.4;
/// Multiplicative decrease factor: cwnd ← cwnd · BETA on loss.
const CUBIC_BETA: f64 = 0.7;

/// One TCP CUBIC stream (fluid model).
#[derive(Debug, Clone)]
pub struct CubicStream {
    /// Congestion window in MSS.
    pub cwnd: f64,
    /// Window size before the last decrease, in MSS.
    w_max: f64,
    /// Slow-start threshold in MSS.
    ssthresh: f64,
    /// Seconds since the last loss epoch began.
    epoch_t: f64,
    /// Seconds since the last multiplicative decrease (rate-limits cuts).
    since_cut: f64,
    /// True until the first loss event.
    pub in_slow_start: bool,
    /// Whether the stream is admitted (paused streams keep state but send 0).
    pub active: bool,
}

impl Default for CubicStream {
    fn default() -> Self {
        CubicStream::new()
    }
}

impl CubicStream {
    pub fn new() -> CubicStream {
        CubicStream {
            cwnd: 10.0, // RFC 6928 initial window
            w_max: 0.0,
            ssthresh: f64::MAX,
            epoch_t: 0.0,
            since_cut: f64::MAX / 2.0,
            in_slow_start: true,
            active: true,
        }
    }

    /// Offered rate in Gbps given the current RTT, before caps.
    pub fn cwnd_rate_gbps(&self, rtt_s: f64) -> f64 {
        if !self.active {
            return 0.0;
        }
        self.cwnd * MSS_BITS / rtt_s / 1e9
    }

    /// Advance the window by `dt` seconds.
    ///
    /// * `rtt_s` — current path RTT.
    /// * `app_limited` — the application could not fill the current window
    ///   this tick (I/O cap or receive-window cap binding); growth freezes.
    pub fn grow(&mut self, dt: f64, rtt_s: f64, app_limited: bool) {
        if !self.active {
            return;
        }
        self.since_cut += dt;
        if app_limited {
            // Don't build an unusable window (mirrors Linux cwnd validation).
            return;
        }
        self.epoch_t += dt;
        if self.in_slow_start {
            // Double per RTT: dW/dt = W/RTT * ln 2 ~ W/RTT.
            self.cwnd += self.cwnd * dt / rtt_s;
            if self.cwnd >= self.ssthresh {
                self.in_slow_start = false;
                self.w_max = self.cwnd;
                self.epoch_t = 0.0;
            }
            return;
        }
        // CUBIC window function.
        let k = (self.w_max * (1.0 - CUBIC_BETA) / CUBIC_C).cbrt();
        let target = CUBIC_C * (self.epoch_t - k).powi(3) + self.w_max;
        // TCP-friendly AIMD floor: at least 1 MSS per RTT of growth headroom.
        let aimd_floor = self.cwnd + dt / rtt_s;
        if target > self.cwnd {
            // Fluid pacing toward the cubic target over roughly one RTT.
            self.cwnd += ((target - self.cwnd) * dt / rtt_s).max(0.0);
        }
        self.cwnd = self.cwnd.max(aimd_floor.min(target.max(aimd_floor)));
    }

    /// Register a loss event. Returns true if a multiplicative decrease was
    /// applied (at most one per RTT).
    pub fn on_loss(&mut self, rtt_s: f64) -> bool {
        if !self.active || self.since_cut < rtt_s {
            return false;
        }
        self.w_max = self.cwnd;
        self.cwnd = (self.cwnd * CUBIC_BETA).max(2.0);
        self.ssthresh = self.cwnd;
        self.in_slow_start = false;
        self.epoch_t = 0.0;
        self.since_cut = 0.0;
        true
    }

    /// Pause the stream (keeps window state; sends nothing while paused).
    pub fn pause(&mut self) {
        self.active = false;
    }

    /// Resume a paused stream. The window restarts conservatively from
    /// slow-start with a reduced threshold, like a TCP connection coming back
    /// from idle (RFC 5681 restart).
    pub fn resume(&mut self) {
        if !self.active {
            self.active = true;
            self.ssthresh = self.cwnd.max(10.0);
            self.cwnd = 10.0;
            self.in_slow_start = true;
            self.epoch_t = 0.0;
        }
    }
}

/// Struct-of-arrays CUBIC state for the arena simulator.
///
/// Same fluid model as [`CubicStream`], laid out as parallel `f64`/flag
/// slices indexed by arena slot so the simulator tick streams through
/// contiguous memory instead of chasing `Vec<CubicStream>` pointers. Every
/// formula is copied verbatim from [`CubicStream`] — the
/// `arena_matches_cubic_stream_bit_for_bit` test locks the two
/// implementations together, and `tests/golden_replay.rs` locks the whole
/// simulator against the pre-arena loop.
///
/// Callers (the simulator tick) only invoke [`StreamArena::cwnd_rate_gbps`],
/// [`StreamArena::grow`] and [`StreamArena::on_loss`] on **active** slots;
/// unlike [`CubicStream`], the per-op `active` short-circuits are hoisted
/// into the caller's loop bounds (§Perf).
///
/// The batched row methods ([`StreamArena::rates_into`],
/// [`StreamArena::grow_row`]) process one task row's contiguous active
/// prefix as slice passes instead of per-slot calls: bounds checks are
/// paid once per row, the unconditional `since_cut` bump becomes a
/// straight-line vectorizable loop, and per-slot arithmetic keeps the
/// exact op order of the scalar methods — the
/// `batched_row_ops_match_per_slot_ops_bit_for_bit` test locks the two
/// forms together.
#[derive(Debug, Clone, Default)]
pub struct StreamArena {
    cwnd: Vec<f64>,
    w_max: Vec<f64>,
    ssthresh: Vec<f64>,
    epoch_t: Vec<f64>,
    since_cut: Vec<f64>,
    in_slow_start: Vec<bool>,
    active: Vec<bool>,
}

/// A captured [`StreamArena`] — one parallel column per arena field, in
/// slot order. Part of the serve snapshot ([`crate::net::SimState`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArenaState {
    pub cwnd: Vec<f64>,
    pub w_max: Vec<f64>,
    pub ssthresh: Vec<f64>,
    pub epoch_t: Vec<f64>,
    pub since_cut: Vec<f64>,
    pub in_slow_start: Vec<bool>,
    pub active: Vec<bool>,
}

impl StreamArena {
    pub fn new() -> StreamArena {
        StreamArena::default()
    }

    /// Total slots (created or reserved).
    pub fn len(&self) -> usize {
        self.cwnd.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cwnd.is_empty()
    }

    /// Reserve capacity for `n` additional slots across every parallel
    /// column — a pure capacity hint (§Perf: large fleet admits), never
    /// affecting slot contents.
    pub fn reserve(&mut self, n: usize) {
        self.cwnd.reserve(n);
        self.w_max.reserve(n);
        self.ssthresh.reserve(n);
        self.epoch_t.reserve(n);
        self.since_cut.reserve(n);
        self.in_slow_start.reserve(n);
        self.active.reserve(n);
    }

    /// Append `n` fresh slots (RFC 6928 initial window, slow start,
    /// active) and return the index of the first. Fresh-slot state is
    /// exactly [`CubicStream::new`].
    pub fn push_fresh(&mut self, n: usize) -> usize {
        let base = self.cwnd.len();
        self.cwnd.resize(base + n, 10.0);
        self.w_max.resize(base + n, 0.0);
        self.ssthresh.resize(base + n, f64::MAX);
        self.epoch_t.resize(base + n, 0.0);
        self.since_cut.resize(base + n, f64::MAX / 2.0);
        self.in_slow_start.resize(base + n, true);
        self.active.resize(base + n, true);
        base
    }

    pub fn is_active(&self, i: usize) -> bool {
        self.active[i]
    }

    /// Current window of slot `i`, MSS (telemetry/tests).
    pub fn cwnd(&self, i: usize) -> f64 {
        self.cwnd[i]
    }

    /// Pause slot `i` (keeps window state; sends nothing while paused).
    pub fn pause(&mut self, i: usize) {
        self.active[i] = false;
    }

    /// Resume a paused slot: conservative slow-start restart with a
    /// reduced threshold, exactly [`CubicStream::resume`]. No-op on an
    /// active slot.
    pub fn resume(&mut self, i: usize) {
        if !self.active[i] {
            self.active[i] = true;
            self.ssthresh[i] = self.cwnd[i].max(10.0);
            self.cwnd[i] = 10.0;
            self.in_slow_start[i] = true;
            self.epoch_t[i] = 0.0;
        }
    }

    /// Offered rate of an **active** slot in Gbps, before caps.
    #[inline]
    pub fn cwnd_rate_gbps(&self, i: usize, rtt_s: f64) -> f64 {
        self.cwnd[i] * MSS_BITS / rtt_s / 1e9
    }

    /// Advance an **active** slot's window by `dt` seconds
    /// ([`CubicStream::grow`], verbatim).
    #[inline]
    pub fn grow(&mut self, i: usize, dt: f64, rtt_s: f64, app_limited: bool) {
        self.since_cut[i] += dt;
        if app_limited {
            return;
        }
        self.epoch_t[i] += dt;
        if self.in_slow_start[i] {
            self.cwnd[i] += self.cwnd[i] * dt / rtt_s;
            if self.cwnd[i] >= self.ssthresh[i] {
                self.in_slow_start[i] = false;
                self.w_max[i] = self.cwnd[i];
                self.epoch_t[i] = 0.0;
            }
            return;
        }
        let k = (self.w_max[i] * (1.0 - CUBIC_BETA) / CUBIC_C).cbrt();
        let target = CUBIC_C * (self.epoch_t[i] - k).powi(3) + self.w_max[i];
        let aimd_floor = self.cwnd[i] + dt / rtt_s;
        if target > self.cwnd[i] {
            self.cwnd[i] += ((target - self.cwnd[i]) * dt / rtt_s).max(0.0);
        }
        self.cwnd[i] = self.cwnd[i].max(aimd_floor.min(target.max(aimd_floor)));
    }

    /// Register a loss event on an **active** slot
    /// ([`CubicStream::on_loss`], verbatim). Returns true if a
    /// multiplicative decrease was applied.
    #[inline]
    pub fn on_loss(&mut self, i: usize, rtt_s: f64) -> bool {
        if self.since_cut[i] < rtt_s {
            return false;
        }
        self.w_max[i] = self.cwnd[i];
        self.cwnd[i] = (self.cwnd[i] * CUBIC_BETA).max(2.0);
        self.ssthresh[i] = self.cwnd[i];
        self.in_slow_start[i] = false;
        self.epoch_t[i] = 0.0;
        self.since_cut[i] = 0.0;
        true
    }

    /// Capture the full arena — every column of every slot — for
    /// checkpointing. Slot order is the arena layout itself, so a restored
    /// arena is indistinguishable from the original.
    pub fn export_state(&self) -> ArenaState {
        ArenaState {
            cwnd: self.cwnd.clone(),
            w_max: self.w_max.clone(),
            ssthresh: self.ssthresh.clone(),
            epoch_t: self.epoch_t.clone(),
            since_cut: self.since_cut.clone(),
            in_slow_start: self.in_slow_start.clone(),
            active: self.active.clone(),
        }
    }

    /// Overwrite the arena wholesale from a captured [`ArenaState`]
    /// (checkpoint restore; replaces any slots the rebuild created).
    pub fn import_state(&mut self, s: &ArenaState) {
        self.cwnd = s.cwnd.clone();
        self.w_max = s.w_max.clone();
        self.ssthresh = s.ssthresh.clone();
        self.epoch_t = s.epoch_t.clone();
        self.since_cut = s.since_cut.clone();
        self.in_slow_start = s.in_slow_start.clone();
        self.active = s.active.clone();
    }

    /// Batched rate pass over one task row's active prefix: writes the
    /// capped offered rate of slots `base..base + out.len()` into `out`.
    /// Per slot this is exactly
    /// `cwnd_rate_gbps(slot, rtt_s).min(stream_cap_gbps).min(io_share_gbps)`
    /// — same op order, bit-identical — but as one contiguous slice pass
    /// (mul + two divs + two mins per element, no per-call bounds checks)
    /// that LLVM can auto-vectorize.
    #[inline]
    pub fn rates_into(
        &self,
        base: usize,
        rtt_s: f64,
        stream_cap_gbps: f64,
        io_share_gbps: f64,
        out: &mut [f64],
    ) {
        let cwnd = &self.cwnd[base..base + out.len()];
        for (r, &w) in out.iter_mut().zip(cwnd) {
            *r = (w * MSS_BITS / rtt_s / 1e9).min(stream_cap_gbps).min(io_share_gbps);
        }
    }

    /// Batched growth pass over one task row's active prefix (slots
    /// `base..base + rates.len()`, where `rates` are the post-rescale
    /// offered rates from the tick's phase-1 scratch).
    ///
    /// Two sub-passes, preserving [`StreamArena::grow`]'s per-slot
    /// arithmetic bit-for-bit:
    ///
    /// 1. the unconditional `since_cut += dt` cut-timer bump, hoisted out
    ///    of the app-limited branch into a straight-line vectorizable
    ///    loop over the row;
    /// 2. window growth where the window (not a cap) was binding — the
    ///    app-limited test `rate + 1e-12 < cwnd_rate || cwnd_rate >= caps`
    ///    is computed here from the row's cwnd slice, exactly as the
    ///    scalar tick derived it per slot after the loss cut.
    ///
    /// Must be called **after** this tick's loss cuts for the row (growth
    /// reads post-cut state, matching the scalar per-slot order).
    #[inline]
    pub fn grow_row(&mut self, base: usize, rates: &[f64], dt: f64, rtt_s: f64, caps_gbps: f64) {
        let end = base + rates.len();
        for t in &mut self.since_cut[base..end] {
            *t += dt;
        }
        let cwnd = &mut self.cwnd[base..end];
        let w_max = &mut self.w_max[base..end];
        let ssthresh = &mut self.ssthresh[base..end];
        let epoch_t = &mut self.epoch_t[base..end];
        let slow = &mut self.in_slow_start[base..end];
        for (j, &rate) in rates.iter().enumerate() {
            let cwnd_rate = cwnd[j] * MSS_BITS / rtt_s / 1e9;
            let app_limited = rate + 1e-12 < cwnd_rate || cwnd_rate >= caps_gbps;
            if app_limited {
                continue;
            }
            epoch_t[j] += dt;
            if slow[j] {
                cwnd[j] += cwnd[j] * dt / rtt_s;
                if cwnd[j] >= ssthresh[j] {
                    slow[j] = false;
                    w_max[j] = cwnd[j];
                    epoch_t[j] = 0.0;
                }
                continue;
            }
            let k = (w_max[j] * (1.0 - CUBIC_BETA) / CUBIC_C).cbrt();
            let target = CUBIC_C * (epoch_t[j] - k).powi(3) + w_max[j];
            let aimd_floor = cwnd[j] + dt / rtt_s;
            if target > cwnd[j] {
                cwnd[j] += ((target - cwnd[j]) * dt / rtt_s).max(0.0);
            }
            cwnd[j] = cwnd[j].max(aimd_floor.min(target.max(aimd_floor)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RTT: f64 = 0.032;
    const DT: f64 = 0.05;

    #[test]
    fn slow_start_doubles_quickly() {
        let mut s = CubicStream::new();
        let w0 = s.cwnd;
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        // 2 seconds of slow start at 32 ms RTT: enormous growth.
        assert!(s.cwnd > w0 * 100.0, "cwnd={}", s.cwnd);
    }

    #[test]
    fn loss_cuts_window_by_beta() {
        let mut s = CubicStream::new();
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        let before = s.cwnd;
        assert!(s.on_loss(RTT));
        assert!((s.cwnd - before * CUBIC_BETA).abs() < 1e-9);
        assert!(!s.in_slow_start);
    }

    #[test]
    fn at_most_one_cut_per_rtt() {
        let mut s = CubicStream::new();
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        assert!(s.on_loss(RTT));
        assert!(!s.on_loss(RTT)); // within the same RTT
        s.grow(RTT * 1.1, RTT, false);
        assert!(s.on_loss(RTT));
    }

    #[test]
    fn cubic_regrows_toward_wmax() {
        let mut s = CubicStream::new();
        // Modest slow-start phase (unbounded slow start would explode the
        // window; real streams are rwnd/app capped by the simulator).
        for _ in 0..10 {
            s.grow(DT, RTT, false);
        }
        s.on_loss(RTT);
        let after_cut = s.cwnd;
        let w_max = s.w_max;
        // Regrow for 30 simulated seconds.
        for _ in 0..600 {
            s.grow(DT, RTT, false);
        }
        assert!(s.cwnd > after_cut);
        assert!(s.cwnd >= w_max * 0.9, "cwnd={} w_max={}", s.cwnd, w_max);
    }

    #[test]
    fn app_limited_freezes_growth() {
        let mut s = CubicStream::new();
        for _ in 0..20 {
            s.grow(DT, RTT, false);
        }
        let w = s.cwnd;
        for _ in 0..100 {
            s.grow(DT, RTT, true);
        }
        assert_eq!(s.cwnd, w);
    }

    #[test]
    fn paused_stream_sends_nothing_and_resumes_in_slow_start() {
        let mut s = CubicStream::new();
        for _ in 0..100 {
            s.grow(DT, RTT, false);
        }
        s.pause();
        assert_eq!(s.cwnd_rate_gbps(RTT), 0.0);
        s.grow(DT, RTT, false);
        s.resume();
        assert!(s.active && s.in_slow_start);
        assert!(s.cwnd <= 10.0 + 1e-9);
    }

    #[test]
    fn rate_matches_window_over_rtt() {
        let s = CubicStream::new();
        let expect = 10.0 * MSS_BITS / RTT / 1e9;
        assert!((s.cwnd_rate_gbps(RTT) - expect).abs() < 1e-12);
    }

    /// The SoA arena and the AoS stream evolve bit-for-bit identically
    /// through a long randomized op sequence (grow with mixed app-limited
    /// flags, rate-limited loss events, pause/resume cycles).
    #[test]
    fn arena_matches_cubic_stream_bit_for_bit() {
        let mut aos = CubicStream::new();
        let mut soa = StreamArena::new();
        let i = soa.push_fresh(3) + 1; // middle slot: neighbors must not alias
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for step in 0..5_000 {
            match next() % 10 {
                0 => {
                    aos.pause();
                    soa.pause(i);
                }
                1 => {
                    aos.resume();
                    soa.resume(i);
                }
                2 if aos.active => {
                    let a = aos.on_loss(RTT);
                    let b = soa.on_loss(i, RTT);
                    assert_eq!(a, b, "loss outcome diverged at step {step}");
                }
                _ if aos.active => {
                    let app_limited = next() % 3 == 0;
                    aos.grow(DT, RTT, app_limited);
                    soa.grow(i, DT, RTT, app_limited);
                }
                _ => {}
            }
            assert_eq!(aos.active, soa.is_active(i), "active flag diverged at step {step}");
            assert_eq!(
                aos.cwnd.to_bits(),
                soa.cwnd(i).to_bits(),
                "cwnd diverged at step {step}: {} vs {}",
                aos.cwnd,
                soa.cwnd(i)
            );
            if aos.active {
                assert_eq!(
                    aos.cwnd_rate_gbps(RTT).to_bits(),
                    soa.cwnd_rate_gbps(i, RTT).to_bits(),
                    "rate diverged at step {step}"
                );
            }
        }
    }

    /// The batched row passes (`rates_into`, `grow_row`) and the scalar
    /// per-slot path (`cwnd_rate_gbps` + caps, `on_loss`, `grow` with the
    /// tick's app-limited derivation) evolve a seeded row bit-for-bit
    /// identically through randomized rescales, loss masks and RTT drift —
    /// the associative-safe half of the batching contract (§Perf in
    /// `net/sim.rs`).
    #[test]
    fn batched_row_ops_match_per_slot_ops_bit_for_bit() {
        const N: usize = 7; // odd width: no lane-multiple luck
        let mut scalar = StreamArena::new();
        let mut batched = StreamArena::new();
        assert_eq!(scalar.push_fresh(N), 0);
        assert_eq!(batched.push_fresh(N), 0);
        let (cap_stream, cap_io) = (1.0, 0.6);
        let caps = f64::min(cap_stream, cap_io);
        let mut state = 0x243F6A8885A308D3u64;
        let mut next = || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut rates_s = [0.0f64; N];
        let mut rates_b = [0.0f64; N];
        for step in 0..3_000 {
            let rtt = RTT * (1.0 + (next() % 64) as f64 / 256.0);
            for (j, r) in rates_s.iter_mut().enumerate() {
                *r = scalar.cwnd_rate_gbps(j, rtt).min(cap_stream).min(cap_io);
            }
            batched.rates_into(0, rtt, cap_stream, cap_io, &mut rates_b);
            for j in 0..N {
                assert_eq!(
                    rates_s[j].to_bits(),
                    rates_b[j].to_bits(),
                    "rate diverged at step {step} slot {j}"
                );
            }
            // Occasionally rescale (the demand-cap path) so growth sees
            // app-limited slots.
            if next() % 3 == 0 {
                let scale = (next() % 1000) as f64 / 1000.0;
                for j in 0..N {
                    rates_s[j] *= scale;
                    rates_b[j] *= scale;
                }
            }
            // Random pre-gathered loss mask, applied per slot on both.
            for j in 0..N {
                if next() % 11 == 0 {
                    assert_eq!(
                        scalar.on_loss(j, rtt),
                        batched.on_loss(j, rtt),
                        "loss outcome diverged at step {step} slot {j}"
                    );
                }
            }
            // Scalar growth exactly as the pre-batch tick derived it.
            for j in 0..N {
                let cwnd_rate = scalar.cwnd_rate_gbps(j, rtt);
                let app_limited = rates_s[j] + 1e-12 < cwnd_rate || cwnd_rate >= caps;
                scalar.grow(j, DT, rtt, app_limited);
            }
            batched.grow_row(0, &rates_b, DT, rtt, caps);
            for j in 0..N {
                assert_eq!(
                    scalar.cwnd(j).to_bits(),
                    batched.cwnd(j).to_bits(),
                    "cwnd diverged at step {step} slot {j}: {} vs {}",
                    scalar.cwnd(j),
                    batched.cwnd(j)
                );
            }
        }
    }

    #[test]
    fn window_never_below_two_mss() {
        let mut s = CubicStream::new();
        for i in 0..200 {
            s.grow(DT, RTT, false);
            if i % 3 == 0 {
                s.grow(RTT * 1.01, RTT, false);
                s.on_loss(RTT);
            }
            assert!(s.cwnd >= 2.0);
        }
    }
}
