//! Fluid-model TCP CUBIC stream.
//!
//! Each application-layer stream (one of a file-task's `p` parallel sockets)
//! carries a CUBIC congestion window evolved at tick granularity:
//! slow start → cubic concave/convex growth around `w_max`, multiplicative
//! decrease (β = 0.7) on loss events, at most one decrease per RTT, and
//! growth freezing while application-limited (sender has nothing to push).
//!
//! The fluid approximation follows Ha/Rhee/Xu's CUBIC window function
//! W(t) = C·(t−K)³ + W_max with C = 0.4, K = ∛(W_max·β_dec/C).

use super::MSS_BITS;

/// CUBIC constant C (MSS/s³).
const CUBIC_C: f64 = 0.4;
/// Multiplicative decrease factor: cwnd ← cwnd · BETA on loss.
const CUBIC_BETA: f64 = 0.7;

/// One TCP CUBIC stream (fluid model).
#[derive(Debug, Clone)]
pub struct CubicStream {
    /// Congestion window in MSS.
    pub cwnd: f64,
    /// Window size before the last decrease, in MSS.
    w_max: f64,
    /// Slow-start threshold in MSS.
    ssthresh: f64,
    /// Seconds since the last loss epoch began.
    epoch_t: f64,
    /// Seconds since the last multiplicative decrease (rate-limits cuts).
    since_cut: f64,
    /// True until the first loss event.
    pub in_slow_start: bool,
    /// Whether the stream is admitted (paused streams keep state but send 0).
    pub active: bool,
}

impl Default for CubicStream {
    fn default() -> Self {
        CubicStream::new()
    }
}

impl CubicStream {
    pub fn new() -> CubicStream {
        CubicStream {
            cwnd: 10.0, // RFC 6928 initial window
            w_max: 0.0,
            ssthresh: f64::MAX,
            epoch_t: 0.0,
            since_cut: f64::MAX / 2.0,
            in_slow_start: true,
            active: true,
        }
    }

    /// Offered rate in Gbps given the current RTT, before caps.
    pub fn cwnd_rate_gbps(&self, rtt_s: f64) -> f64 {
        if !self.active {
            return 0.0;
        }
        self.cwnd * MSS_BITS / rtt_s / 1e9
    }

    /// Advance the window by `dt` seconds.
    ///
    /// * `rtt_s` — current path RTT.
    /// * `app_limited` — the application could not fill the current window
    ///   this tick (I/O cap or receive-window cap binding); growth freezes.
    pub fn grow(&mut self, dt: f64, rtt_s: f64, app_limited: bool) {
        if !self.active {
            return;
        }
        self.since_cut += dt;
        if app_limited {
            // Don't build an unusable window (mirrors Linux cwnd validation).
            return;
        }
        self.epoch_t += dt;
        if self.in_slow_start {
            // Double per RTT: dW/dt = W/RTT * ln 2 ~ W/RTT.
            self.cwnd += self.cwnd * dt / rtt_s;
            if self.cwnd >= self.ssthresh {
                self.in_slow_start = false;
                self.w_max = self.cwnd;
                self.epoch_t = 0.0;
            }
            return;
        }
        // CUBIC window function.
        let k = (self.w_max * (1.0 - CUBIC_BETA) / CUBIC_C).cbrt();
        let target = CUBIC_C * (self.epoch_t - k).powi(3) + self.w_max;
        // TCP-friendly AIMD floor: at least 1 MSS per RTT of growth headroom.
        let aimd_floor = self.cwnd + dt / rtt_s;
        if target > self.cwnd {
            // Fluid pacing toward the cubic target over roughly one RTT.
            self.cwnd += ((target - self.cwnd) * dt / rtt_s).max(0.0);
        }
        self.cwnd = self.cwnd.max(aimd_floor.min(target.max(aimd_floor)));
    }

    /// Register a loss event. Returns true if a multiplicative decrease was
    /// applied (at most one per RTT).
    pub fn on_loss(&mut self, rtt_s: f64) -> bool {
        if !self.active || self.since_cut < rtt_s {
            return false;
        }
        self.w_max = self.cwnd;
        self.cwnd = (self.cwnd * CUBIC_BETA).max(2.0);
        self.ssthresh = self.cwnd;
        self.in_slow_start = false;
        self.epoch_t = 0.0;
        self.since_cut = 0.0;
        true
    }

    /// Pause the stream (keeps window state; sends nothing while paused).
    pub fn pause(&mut self) {
        self.active = false;
    }

    /// Resume a paused stream. The window restarts conservatively from
    /// slow-start with a reduced threshold, like a TCP connection coming back
    /// from idle (RFC 5681 restart).
    pub fn resume(&mut self) {
        if !self.active {
            self.active = true;
            self.ssthresh = self.cwnd.max(10.0);
            self.cwnd = 10.0;
            self.in_slow_start = true;
            self.epoch_t = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RTT: f64 = 0.032;
    const DT: f64 = 0.05;

    #[test]
    fn slow_start_doubles_quickly() {
        let mut s = CubicStream::new();
        let w0 = s.cwnd;
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        // 2 seconds of slow start at 32 ms RTT: enormous growth.
        assert!(s.cwnd > w0 * 100.0, "cwnd={}", s.cwnd);
    }

    #[test]
    fn loss_cuts_window_by_beta() {
        let mut s = CubicStream::new();
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        let before = s.cwnd;
        assert!(s.on_loss(RTT));
        assert!((s.cwnd - before * CUBIC_BETA).abs() < 1e-9);
        assert!(!s.in_slow_start);
    }

    #[test]
    fn at_most_one_cut_per_rtt() {
        let mut s = CubicStream::new();
        for _ in 0..40 {
            s.grow(DT, RTT, false);
        }
        assert!(s.on_loss(RTT));
        assert!(!s.on_loss(RTT)); // within the same RTT
        s.grow(RTT * 1.1, RTT, false);
        assert!(s.on_loss(RTT));
    }

    #[test]
    fn cubic_regrows_toward_wmax() {
        let mut s = CubicStream::new();
        // Modest slow-start phase (unbounded slow start would explode the
        // window; real streams are rwnd/app capped by the simulator).
        for _ in 0..10 {
            s.grow(DT, RTT, false);
        }
        s.on_loss(RTT);
        let after_cut = s.cwnd;
        let w_max = s.w_max;
        // Regrow for 30 simulated seconds.
        for _ in 0..600 {
            s.grow(DT, RTT, false);
        }
        assert!(s.cwnd > after_cut);
        assert!(s.cwnd >= w_max * 0.9, "cwnd={} w_max={}", s.cwnd, w_max);
    }

    #[test]
    fn app_limited_freezes_growth() {
        let mut s = CubicStream::new();
        for _ in 0..20 {
            s.grow(DT, RTT, false);
        }
        let w = s.cwnd;
        for _ in 0..100 {
            s.grow(DT, RTT, true);
        }
        assert_eq!(s.cwnd, w);
    }

    #[test]
    fn paused_stream_sends_nothing_and_resumes_in_slow_start() {
        let mut s = CubicStream::new();
        for _ in 0..100 {
            s.grow(DT, RTT, false);
        }
        s.pause();
        assert_eq!(s.cwnd_rate_gbps(RTT), 0.0);
        s.grow(DT, RTT, false);
        s.resume();
        assert!(s.active && s.in_slow_start);
        assert!(s.cwnd <= 10.0 + 1e-9);
    }

    #[test]
    fn rate_matches_window_over_rtt() {
        let s = CubicStream::new();
        let expect = 10.0 * MSS_BITS / RTT / 1e9;
        assert!((s.cwnd_rate_gbps(RTT) - expect).abs() < 1e-12);
    }

    #[test]
    fn window_never_below_two_mss() {
        let mut s = CubicStream::new();
        for i in 0..200 {
            s.grow(DT, RTT, false);
            if i % 3 == 0 {
                s.grow(RTT * 1.01, RTT, false);
                s.on_loss(RTT);
            }
            assert!(s.cwnd >= 2.0);
        }
    }
}
