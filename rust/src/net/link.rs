//! Shared bottleneck link with a droptail queue.

/// A bottleneck link: fixed capacity, droptail buffer, propagation delay.
#[derive(Debug, Clone)]
pub struct Link {
    /// Capacity in Gbps.
    pub capacity_gbps: f64,
    /// One-way-equivalent base RTT in seconds (propagation, no queueing).
    pub base_rtt_s: f64,
    /// Buffer size in bits (droptail).
    pub buffer_bits: f64,
    /// Current queue occupancy in bits.
    queue_bits: f64,
}

/// Outcome of offering one tick of traffic to the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TickOutcome {
    /// Fraction of offered bits that were dropped (0..1).
    pub drop_frac: f64,
    /// Fraction of offered bits delivered (serviced or queued).
    pub accept_frac: f64,
    /// Queueing delay experienced this tick, seconds.
    pub queue_delay_s: f64,
}

impl Link {
    /// Create a link; `buffer_bdp` sizes the droptail buffer as a multiple of
    /// the bandwidth-delay product (1.0 = classic BDP rule).
    pub fn new(capacity_gbps: f64, base_rtt_s: f64, buffer_bdp: f64) -> Link {
        assert!(capacity_gbps > 0.0 && base_rtt_s > 0.0 && buffer_bdp > 0.0);
        let bdp_bits = capacity_gbps * 1e9 * base_rtt_s;
        Link {
            capacity_gbps,
            base_rtt_s,
            buffer_bits: buffer_bdp * bdp_bits,
            queue_bits: 0.0,
        }
    }

    /// Offer `offered_gbps` of aggregate traffic for `dt` seconds.
    ///
    /// The queue drains at link capacity; arrivals beyond capacity fill the
    /// queue; arrivals beyond the remaining buffer are dropped (droptail).
    pub fn tick(&mut self, offered_gbps: f64, dt: f64) -> TickOutcome {
        let capacity_bits = self.capacity_gbps * 1e9 * dt;
        let offered_bits = offered_gbps.max(0.0) * 1e9 * dt;

        // Serve the queue first, then arrivals.
        let served_from_queue = self.queue_bits.min(capacity_bits);
        self.queue_bits -= served_from_queue;
        let remaining_capacity = capacity_bits - served_from_queue;

        let direct = offered_bits.min(remaining_capacity);
        let to_queue_want = offered_bits - direct;
        let space = self.buffer_bits - self.queue_bits;
        let queued = to_queue_want.min(space);
        self.queue_bits += queued;
        let dropped = to_queue_want - queued;

        let drop_frac = if offered_bits > 0.0 { dropped / offered_bits } else { 0.0 };
        TickOutcome {
            drop_frac,
            accept_frac: 1.0 - drop_frac,
            queue_delay_s: self.queue_delay_s(),
        }
    }

    /// Current queueing delay (queue occupancy / capacity).
    pub fn queue_delay_s(&self) -> f64 {
        self.queue_bits / (self.capacity_gbps * 1e9)
    }

    /// Current RTT including queueing delay.
    pub fn rtt_s(&self) -> f64 {
        self.base_rtt_s + self.queue_delay_s()
    }

    /// Queue occupancy as a fraction of the buffer (0..1).
    pub fn queue_fill(&self) -> f64 {
        self.queue_bits / self.buffer_bits
    }

    /// Raw queue occupancy in bits (checkpointing).
    pub fn queue_bits(&self) -> f64 {
        self.queue_bits
    }

    /// Restore a captured queue occupancy (checkpointing).
    pub fn set_queue_bits(&mut self, bits: f64) {
        self.queue_bits = bits;
    }

    /// Reset queue state (new experiment).
    pub fn reset(&mut self) {
        self.queue_bits = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link::new(10.0, 0.032, 1.0)
    }

    #[test]
    fn under_capacity_no_drops_no_queue() {
        let mut l = link();
        for _ in 0..100 {
            let o = l.tick(5.0, 0.05);
            assert_eq!(o.drop_frac, 0.0);
        }
        assert!(l.queue_delay_s() < 1e-9);
    }

    #[test]
    fn over_capacity_builds_queue_then_drops() {
        let mut l = link();
        let mut saw_queue = false;
        let mut saw_drop = false;
        for _ in 0..200 {
            let o = l.tick(20.0, 0.05);
            if o.queue_delay_s > 0.0 {
                saw_queue = true;
            }
            if o.drop_frac > 0.0 {
                saw_drop = true;
            }
        }
        assert!(saw_queue && saw_drop);
        // At steady state with 2x overload, half the offered bits are dropped.
        let o = l.tick(20.0, 0.05);
        assert!((o.drop_frac - 0.5).abs() < 0.05, "drop={}", o.drop_frac);
    }

    #[test]
    fn queue_drains_when_idle() {
        let mut l = link();
        for _ in 0..100 {
            l.tick(30.0, 0.05);
        }
        assert!(l.queue_delay_s() > 0.0);
        for _ in 0..100 {
            l.tick(0.0, 0.05);
        }
        assert!(l.queue_delay_s() < 1e-9);
    }

    #[test]
    fn rtt_inflates_with_queue() {
        let mut l = link();
        let base = l.rtt_s();
        for _ in 0..100 {
            l.tick(30.0, 0.05);
        }
        assert!(l.rtt_s() > base);
        // Max inflation = buffer/capacity = base_rtt * buffer_bdp.
        assert!(l.rtt_s() <= base + 0.032 + 1e-9);
    }

    #[test]
    fn drop_frac_bounded() {
        let mut l = link();
        for mult in [0.5, 1.0, 3.0, 10.0] {
            let o = l.tick(10.0 * mult, 0.05);
            assert!((0.0..=1.0).contains(&o.drop_frac));
        }
    }
}
