//! The **pre-arena** simulator hot loop, frozen as a baseline.
//!
//! This is the `Flow → Task → Vec<CubicStream>` pointer-chasing loop the
//! struct-of-arrays arena in [`super::sim`] replaced, kept verbatim for two
//! jobs:
//!
//! * **bit-identity oracle** — `tests/golden_replay.rs` drives
//!   [`BaselineSim`] and the arena [`super::NetworkSim`] through identical
//!   command scripts (and whole churn sessions, via
//!   `SessionBuilder::substrate`) and asserts every metric and event is
//!   byte-for-byte equal: the arena is a layout/performance change, never a
//!   results change;
//! * **recorded perf trajectory** — `sparta bench` times the same fleet
//!   `churn-heavy` scale curve on both loops in the same process, so the
//!   speedups in `BENCH_5.json` are honest same-machine ratios rather than
//!   stale constants.
//!
//! **Do not optimize this module.** Its slowness is the measurement. Any
//! behavioral fix must land in both loops (the golden suite will catch a
//! one-sided change).

use super::background::{Background, BackgroundState};
use super::link::Link;
use super::sim::{FlowId, MiMetrics, SimConfig};
use super::stream::CubicStream;
use super::substrate::Substrate;
use super::testbed::Testbed;
use super::topology::Topology;
use super::MSS_BITS;
use crate::util::Rng;

/// One file-task: a group of `p` parallel streams.
#[derive(Debug, Clone)]
struct Task {
    streams: Vec<CubicStream>,
    /// Number of currently-active streams (prefix of `streams`).
    p_active: usize,
    /// Whether the task itself is admitted (prefix `cc` of tasks are).
    active: bool,
}

/// One transfer application's traffic.
#[derive(Debug, Clone)]
struct Flow {
    tasks: Vec<Task>,
    cc_active: usize,
    /// Per-task application I/O rate cap (engine property), Gbps.
    task_io_gbps: f64,
    /// Per-stream receiver-window rate cap, Gbps.
    stream_cap_gbps: f64,
    /// Optional cap on total demand (e.g. job nearly complete), Gbps.
    demand_cap_gbps: f64,
    // Per-MI accumulators.
    acc_delivered_bits: f64,
    acc_sent_bits: f64,
    acc_lost_bits: f64,
    acc_rtt_sum: f64,
    acc_rtt_n: u64,
}

impl Flow {
    fn new(cc: u32, p: u32, task_io_gbps: f64, stream_cap_gbps: f64, cfg: &SimConfig) -> Flow {
        let mut f = Flow {
            tasks: Vec::new(),
            cc_active: 0,
            task_io_gbps,
            stream_cap_gbps,
            demand_cap_gbps: f64::MAX,
            acc_delivered_bits: 0.0,
            acc_sent_bits: 0.0,
            acc_lost_bits: 0.0,
            acc_rtt_sum: 0.0,
            acc_rtt_n: 0,
        };
        f.set_cc_p(cc, p, cfg);
        f
    }

    /// Apply a (cc, p) setting: tasks/streams beyond the new limits are
    /// *paused* (keeping TCP state), previously paused ones are *resumed*.
    fn set_cc_p(&mut self, cc: u32, p: u32, cfg: &SimConfig) {
        let cc = cc.clamp(1, cfg.max_cc) as usize;
        let p = p.clamp(1, cfg.max_p) as usize;
        while self.tasks.len() < cc {
            self.tasks.push(Task { streams: Vec::new(), p_active: 0, active: false });
        }
        for (i, task) in self.tasks.iter_mut().enumerate() {
            let task_active = i < cc;
            while task.streams.len() < p {
                task.streams.push(CubicStream::new());
            }
            for (j, s) in task.streams.iter_mut().enumerate() {
                if task_active && j < p {
                    s.resume();
                } else {
                    s.pause();
                }
            }
            task.active = task_active;
            task.p_active = if task_active { p } else { 0 };
        }
        self.cc_active = cc;
    }

    fn active_stream_count(&self) -> usize {
        self.tasks.iter().map(|t| t.p_active).sum()
    }
}

/// One path stage at runtime: its droptail link plus optional cross traffic.
struct Segment {
    link: Link,
    background: Option<BackgroundState>,
}

/// The pre-arena shared-path simulator (see the module docs).
pub struct BaselineSim {
    pub cfg: SimConfig,
    segments: Vec<Segment>,
    wan_idx: usize,
    flows: Vec<Flow>,
    time_s: f64,
    rng: Rng,
    testbed: Testbed,
    /// Reusable per-tick scratch of per-stream desired rates.
    scratch: Vec<f64>,
}

impl BaselineSim {
    /// Build a single-bottleneck simulator for a testbed preset with its
    /// default background.
    pub fn new(testbed: Testbed, seed: u64) -> BaselineSim {
        let topology = Topology::single(&testbed);
        BaselineSim::from_topology(testbed, &topology, seed)
    }

    /// Build a simulator over an explicit multi-segment topology.
    pub fn from_topology(testbed: Testbed, topology: &Topology, seed: u64) -> BaselineSim {
        let wan_idx = topology.wan_index();
        let segments: Vec<Segment> = topology
            .segments
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let bg = spec
                    .background
                    .clone()
                    .or_else(|| (i == wan_idx).then(|| testbed.default_background.clone()));
                Segment { link: spec.link(), background: bg.map(Background::into_state) }
            })
            .collect();
        BaselineSim {
            cfg: SimConfig::default(),
            segments,
            wan_idx,
            flows: Vec::new(),
            time_s: 0.0,
            rng: Rng::new(seed),
            testbed,
            scratch: Vec::new(),
        }
    }

    /// Replace the WAN stage's cross-traffic process.
    pub fn with_background(mut self, bg: Background) -> BaselineSim {
        self.segments[self.wan_idx].background = Some(bg.into_state());
        self
    }

    /// Advance one tick of the fluid model (the pre-arena loop, verbatim:
    /// recounts `total_streams`, walks every created stream, clones
    /// nothing per tick but touches inactive state).
    fn tick(&mut self) {
        let dt = self.cfg.tick_s;
        let rtt = self.segments.iter().map(|s| s.link.rtt_s()).sum::<f64>();

        let mut offered_total = 0.0;
        let total_streams: usize =
            self.flows.iter().map(|f| f.tasks.iter().map(|t| t.streams.len()).sum::<usize>()).sum();
        self.scratch.clear();
        self.scratch.resize(total_streams, 0.0);
        let mut idx = 0usize;
        for flow in &self.flows {
            let flow_start = idx;
            let mut per_flow = 0.0;
            for task in &flow.tasks {
                if !task.active || task.p_active == 0 {
                    idx += task.streams.len();
                    continue;
                }
                let io_share = flow.task_io_gbps / task.p_active as f64;
                for s in &task.streams {
                    let r = if s.active {
                        s.cwnd_rate_gbps(rtt).min(flow.stream_cap_gbps).min(io_share)
                    } else {
                        0.0
                    };
                    self.scratch[idx] = r;
                    idx += 1;
                    per_flow += r;
                }
            }
            if per_flow > flow.demand_cap_gbps {
                let scale = flow.demand_cap_gbps / per_flow;
                for r in &mut self.scratch[flow_start..idx] {
                    *r *= scale;
                }
                per_flow = flow.demand_cap_gbps;
            }
            offered_total += per_flow;
        }

        let time_s = self.time_s;
        let mut fg_in = offered_total;
        let mut fg_drop = 0.0;
        for seg in &mut self.segments {
            let bg_rate = match seg.background.as_mut() {
                Some(bg) => bg.rate_gbps(time_s, dt, &mut self.rng),
                None => 0.0,
            };
            let outcome = seg.link.tick(fg_in + bg_rate, dt);
            if let Some(bg) = seg.background.as_mut() {
                bg.observe_loss(outcome.drop_frac, dt);
            }
            fg_in *= outcome.accept_frac;
            fg_drop += (1.0 - fg_drop) * outcome.drop_frac;
        }
        let drop_frac = fg_drop.clamp(0.0, 1.0);
        let rtt_after = self.segments.iter().map(|s| s.link.rtt_s()).sum::<f64>();

        let mut idx = 0usize;
        for flow in self.flows.iter_mut() {
            let mut delivered = 0.0;
            let mut sent = 0.0;
            let mut lost = 0.0;
            for task in flow.tasks.iter_mut() {
                if !task.active {
                    idx += task.streams.len();
                    continue;
                }
                let io_share = flow.task_io_gbps / task.p_active.max(1) as f64;
                for s in task.streams.iter_mut() {
                    let rate = self.scratch[idx];
                    idx += 1;
                    if !s.active {
                        continue;
                    }
                    let sent_bits = rate * 1e9 * dt;
                    let lost_bits = sent_bits * drop_frac;
                    delivered += sent_bits - lost_bits;
                    sent += sent_bits;
                    lost += lost_bits;

                    if drop_frac > 0.0 {
                        let pkts = sent_bits / MSS_BITS;
                        let p_event = 1.0 - (1.0 - drop_frac).powf(pkts.max(0.0));
                        if self.rng.chance(p_event) {
                            s.on_loss(rtt_after);
                        }
                    }
                    let cwnd_rate = s.cwnd_rate_gbps(rtt_after);
                    let app_limited = rate + 1e-12 < cwnd_rate
                        || cwnd_rate >= flow.stream_cap_gbps.min(io_share);
                    s.grow(dt, rtt_after, app_limited);
                }
            }
            flow.acc_delivered_bits += delivered;
            flow.acc_sent_bits += sent;
            flow.acc_lost_bits += lost;
            flow.acc_rtt_sum += rtt_after;
            flow.acc_rtt_n += 1;
        }
        self.time_s += dt;
    }
}

impl Substrate for BaselineSim {
    fn add_flow(&mut self, cc: u32, p: u32, task_io_gbps: Option<f64>) -> FlowId {
        let io = task_io_gbps.unwrap_or(self.testbed.task_io_gbps);
        let f = Flow::new(cc, p, io, self.testbed.per_stream_cap_gbps, &self.cfg);
        self.flows.push(f);
        FlowId(self.flows.len() - 1)
    }

    fn set_cc_p(&mut self, id: FlowId, cc: u32, p: u32) {
        // The pre-arena loop cloned the whole SimConfig per call — kept,
        // like everything here, as the recorded baseline.
        let cfg = self.cfg.clone();
        self.flows[id.0].set_cc_p(cc, p, &cfg);
    }

    fn set_demand_cap(&mut self, id: FlowId, gbps: f64) {
        self.flows[id.0].demand_cap_gbps = gbps;
    }

    fn active_streams(&self, id: FlowId) -> usize {
        self.flows[id.0].active_stream_count()
    }

    fn run_mi_into(&mut self, dur_s: f64, out: &mut Vec<MiMetrics>) {
        for f in &mut self.flows {
            f.acc_delivered_bits = 0.0;
            f.acc_sent_bits = 0.0;
            f.acc_lost_bits = 0.0;
            f.acc_rtt_sum = 0.0;
            f.acc_rtt_n = 0;
        }
        let ticks = (dur_s / self.cfg.tick_s).round().max(1.0) as usize;
        for _ in 0..ticks {
            self.tick();
        }
        let actual_dur = ticks as f64 * self.cfg.tick_s;
        let noise = self.cfg.rtt_noise_s;
        let fallback_rtt = self.link_rtt_s();
        out.clear();
        // Borrow dance: collect metrics first, then add noise with rng.
        let metrics: Vec<(f64, f64, f64, f64, usize)> = self
            .flows
            .iter()
            .map(|f| {
                let thr = f.acc_delivered_bits / actual_dur / 1e9;
                let plr =
                    if f.acc_sent_bits > 0.0 { f.acc_lost_bits / f.acc_sent_bits } else { 0.0 };
                let rtt =
                    if f.acc_rtt_n > 0 { f.acc_rtt_sum / f.acc_rtt_n as f64 } else { fallback_rtt };
                (thr, plr, rtt, f.acc_delivered_bits / 8.0, f.active_stream_count())
            })
            .collect();
        for (thr, plr, rtt, bytes, streams) in metrics {
            let rtt_noisy = (rtt + self.rng.normal_mean_sd(0.0, noise)).max(1e-4);
            out.push(MiMetrics {
                throughput_gbps: thr,
                plr,
                rtt_s: rtt_noisy,
                bytes_delivered: bytes,
                active_streams: streams,
                duration_s: actual_dur,
            });
        }
    }

    fn time_s(&self) -> f64 {
        self.time_s
    }

    fn link_rtt_s(&self) -> f64 {
        self.segments.iter().map(|s| s.link.rtt_s()).sum()
    }

    fn testbed(&self) -> &Testbed {
        &self.testbed
    }
}
