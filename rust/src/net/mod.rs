//! Fluid-model wide-area network simulator.
//!
//! This substrate replaces the paper's physical testbeds (Chameleon, CloudLab,
//! FABRIC — see DESIGN.md §1). It simulates, at a 50 ms tick granularity:
//!
//! * per-TCP-stream CUBIC congestion windows (slow start, cubic growth,
//!   multiplicative decrease on loss events),
//! * a shared droptail bottleneck queue (RTT inflation = queueing delay,
//!   packet drops on overflow),
//! * per-stream receiver-window caps and per-file-task application I/O caps
//!   (the reason parallelism `p` and concurrency `cc` help at all),
//! * time-varying background traffic (the reason the optimum moves).
//!
//! The coordinator only ever sees what a real end host would see: per
//! monitoring-interval goodput, packet-loss rate, and (noisy) RTT samples.

pub mod background;
pub mod baseline;
pub mod link;
pub mod sim;
pub mod stream;
pub mod substrate;
pub mod testbed;
pub mod topology;

pub use background::Background;
pub use link::Link;
pub use sim::{FlowId, MiMetrics, NetworkSim, SimConfig, SimState};
pub use stream::CubicStream;
pub use substrate::Substrate;
pub use testbed::Testbed;
pub use topology::{SegmentSpec, Topology};

/// Bits per packet (1500-byte MSS).
pub const MSS_BITS: f64 = 1500.0 * 8.0;
