//! Multi-segment transfer paths: sender NIC → shared WAN → receiver I/O.
//!
//! The paper evaluates on single-bottleneck testbeds, but real transfers can
//! bottleneck at any stage of the path: the sender's NIC / host egress, the
//! shared wide-area segment, or the receiver's storage/ingest stage. A
//! [`Topology`] describes the path as an ordered list of [`SegmentSpec`]s,
//! each an independent droptail [`Link`] with its own capacity, propagation
//! delay, buffering and (optional) cross traffic. [`super::NetworkSim`]
//! carries flows through every segment in order: a segment's drops remove
//! traffic before the next segment sees it, and the observable RTT is the sum
//! of all segments' base delays and queueing delays.
//!
//! A [`Topology::single`] path (one WAN segment) reproduces the seed
//! simulator's behavior exactly, so every testbed preset remains available
//! unchanged; scenarios compose richer paths on top.

use super::background::Background;
use super::link::Link;
use super::testbed::Testbed;

/// One path segment: an independent bottleneck stage.
#[derive(Debug, Clone)]
pub struct SegmentSpec {
    /// Short stage name ("nic", "wan", "rx", ...), used in telemetry.
    pub name: &'static str,
    /// Stage capacity in Gbps.
    pub capacity_gbps: f64,
    /// Propagation-delay contribution of this stage, seconds (> 0).
    pub delay_s: f64,
    /// Buffer depth in seconds at stage capacity (> 0). Droptail.
    pub buffer_s: f64,
    /// Cross traffic that shares *only* this stage (None = idle stage).
    pub background: Option<Background>,
    /// Marks the shared WAN bottleneck — the stage whose background is
    /// replaced by [`super::NetworkSim::with_background`] and by testbed
    /// defaults.
    pub wan: bool,
}

impl SegmentSpec {
    /// The shared WAN stage of a testbed, sized exactly like the seed
    /// simulator's single link (buffer = `buffer_bdp` × BDP).
    pub fn wan_of(tb: &Testbed) -> SegmentSpec {
        SegmentSpec {
            name: "wan",
            capacity_gbps: tb.capacity_gbps,
            delay_s: tb.base_rtt_s,
            buffer_s: tb.buffer_bdp * tb.base_rtt_s,
            background: None,
            wan: true,
        }
    }

    /// An end-system edge stage (sender NIC or receiver I/O): negligible
    /// propagation delay, a few milliseconds of buffering.
    pub fn edge(name: &'static str, capacity_gbps: f64) -> SegmentSpec {
        SegmentSpec {
            name,
            capacity_gbps,
            delay_s: 0.0005,
            buffer_s: 0.004,
            background: None,
            wan: false,
        }
    }

    /// Attach cross traffic to this stage.
    pub fn with_background(mut self, bg: Background) -> SegmentSpec {
        self.background = Some(bg);
        self
    }

    /// This stage as one host's static fair share of a segment shared by
    /// `n` sender hosts: capacity divides by `n`, and because `buffer_s`
    /// is a *duration* at stage capacity, the bit-buffer scales down
    /// proportionally too (each host owns 1/n of the queue). Delay and
    /// cross traffic semantics are unchanged — per-host cross traffic is
    /// the caller's share. A static slice keeps host simulations fully
    /// independent, which is what makes cluster runs bit-identical at any
    /// shard count.
    pub fn shared_slice(mut self, n: usize) -> SegmentSpec {
        let n = n.max(1) as f64;
        self.capacity_gbps /= n;
        if let Some(bg) = self.background.take() {
            self.background = Some(bg.scaled(1.0 / n));
        }
        self
    }

    /// Build the droptail link for this stage.
    pub fn link(&self) -> Link {
        // Link sizes its buffer as a multiple of capacity × delay, so a
        // buffer of `buffer_s` seconds is the ratio of the two durations.
        Link::new(self.capacity_gbps, self.delay_s, self.buffer_s / self.delay_s)
    }
}

/// An ordered multi-segment path.
#[derive(Debug, Clone)]
pub struct Topology {
    pub segments: Vec<SegmentSpec>,
}

impl Topology {
    /// The seed simulator's shape: one shared WAN bottleneck.
    pub fn single(tb: &Testbed) -> Topology {
        Topology { segments: vec![SegmentSpec::wan_of(tb)] }
    }

    /// Three-stage path: sender NIC → shared WAN → receiver I/O. The WAN
    /// stage keeps the testbed's RTT and buffering; the edges bottleneck
    /// independently at `nic_gbps` / `rx_gbps`.
    pub fn three_stage(tb: &Testbed, nic_gbps: f64, rx_gbps: f64) -> Topology {
        Topology {
            segments: vec![
                SegmentSpec::edge("nic", nic_gbps),
                SegmentSpec::wan_of(tb),
                SegmentSpec::edge("rx", rx_gbps),
            ],
        }
    }

    /// One sender host's path in an N-senders → one-receiver **incast**
    /// fleet: a private full-rate NIC edge, then the testbed WAN and a
    /// receiver-ingest edge both sliced to this host's static fair share
    /// (capacity and queue each divided by `hosts`; per-host share of the
    /// WAN cross traffic rides along). The receiver edge is provisioned at
    /// `rx_over_wan` × WAN capacity *before* slicing — below `hosts` ×
    /// that, the receiver, not the WAN, is the incast bottleneck.
    ///
    /// Hosts simulate independently over their slices (no cross-host
    /// coupling), which is what keeps cluster fleets bit-identical at any
    /// shard count ([`crate::coordinator::Cluster`]).
    pub fn incast_host(tb: &Testbed, hosts: usize, rx_over_wan: f64) -> Topology {
        // Attach the testbed's default cross traffic *before* slicing so
        // the per-host WAN slice carries its 1/hosts share of it (a bare
        // WAN segment would inherit the full-capacity background from
        // `NetworkSim::from_topology`).
        let wan = SegmentSpec::wan_of(tb)
            .with_background(tb.default_background.clone())
            .shared_slice(hosts);
        let rx =
            SegmentSpec::edge("rx", tb.capacity_gbps * rx_over_wan).shared_slice(hosts);
        Topology {
            segments: vec![SegmentSpec::edge("nic", tb.capacity_gbps), wan, rx],
        }
    }

    /// Index of the shared WAN stage (first `wan` segment; stage 0 when the
    /// topology marks none).
    pub fn wan_index(&self) -> usize {
        self.segments.iter().position(|s| s.wan).unwrap_or(0)
    }

    /// Replace the WAN stage's cross traffic.
    pub fn with_wan_background(mut self, bg: Background) -> Topology {
        let i = self.wan_index();
        self.segments[i].background = Some(bg);
        self
    }

    /// Total propagation delay of the path, seconds.
    pub fn base_rtt_s(&self) -> f64 {
        self.segments.iter().map(|s| s.delay_s).sum()
    }

    /// Capacity of the tightest stage, Gbps.
    pub fn min_capacity_gbps(&self) -> f64 {
        self.segments.iter().map(|s| s.capacity_gbps).fold(f64::MAX, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_matches_testbed_link() {
        let tb = Testbed::chameleon();
        let topo = Topology::single(&tb);
        assert_eq!(topo.segments.len(), 1);
        assert_eq!(topo.wan_index(), 0);
        let link = topo.segments[0].link();
        let seed_link = tb.link();
        assert_eq!(link.capacity_gbps, seed_link.capacity_gbps);
        assert_eq!(link.base_rtt_s, seed_link.base_rtt_s);
        assert!((link.buffer_bits - seed_link.buffer_bits).abs() < 1.0);
        assert!((topo.base_rtt_s() - tb.base_rtt_s).abs() < 1e-12);
    }

    #[test]
    fn three_stage_orders_and_finds_wan() {
        let tb = Testbed::cloudlab();
        let topo = Topology::three_stage(&tb, 40.0, 8.0);
        let names: Vec<&str> = topo.segments.iter().map(|s| s.name).collect();
        assert_eq!(names, ["nic", "wan", "rx"]);
        assert_eq!(topo.wan_index(), 1);
        assert_eq!(topo.min_capacity_gbps(), 8.0);
        // Edge delays are negligible next to the WAN RTT.
        assert!(topo.base_rtt_s() < tb.base_rtt_s * 1.1);
    }

    #[test]
    fn wan_background_lands_on_wan_stage() {
        let tb = Testbed::chameleon();
        let topo = Topology::three_stage(&tb, 10.0, 10.0)
            .with_wan_background(Background::Constant { gbps: 2.0 });
        assert!(topo.segments[0].background.is_none());
        assert!(topo.segments[1].background.is_some());
        assert!(topo.segments[2].background.is_none());
    }

    #[test]
    fn incast_host_slices_shared_stages() {
        let tb = Testbed::chameleon();
        let solo = Topology::incast_host(&tb, 1, 0.8);
        let topo = Topology::incast_host(&tb, 4, 0.8);
        let names: Vec<&str> = topo.segments.iter().map(|s| s.name).collect();
        assert_eq!(names, ["nic", "wan", "rx"]);
        assert_eq!(topo.wan_index(), 1);
        // NIC stays private/full-rate; WAN and RX divide by host count.
        assert_eq!(topo.segments[0].capacity_gbps, tb.capacity_gbps);
        assert!((topo.segments[1].capacity_gbps - tb.capacity_gbps / 4.0).abs() < 1e-12);
        assert!((topo.segments[2].capacity_gbps - 0.8 * tb.capacity_gbps / 4.0).abs() < 1e-12);
        // The bit-buffer scales with the slice (buffer_s is a duration).
        let full = solo.segments[1].link().buffer_bits;
        let slice = topo.segments[1].link().buffer_bits;
        assert!((slice - full / 4.0).abs() < 1.0, "{slice} vs {full}/4");
        // Receiver ingest, not the WAN, is the incast bottleneck.
        assert_eq!(topo.min_capacity_gbps(), topo.segments[2].capacity_gbps);
    }

    #[test]
    fn edge_links_have_positive_buffers() {
        let e = SegmentSpec::edge("nic", 10.0);
        let l = e.link();
        assert!(l.buffer_bits > 0.0);
        assert!(l.base_rtt_s > 0.0);
    }
}
