//! Background (competing) traffic generators.
//!
//! The paper's testbeds share WAN paths with uncontrolled traffic, which is
//! what moves the optimal (cc, p) around over time (Fig. 1). These generators
//! reproduce the three regimes the paper samples — light, moderate and heavy —
//! plus diurnal and bursty patterns for training diversity. Background traffic
//! is modeled as partially loss-responsive: a fraction behaves like open-loop
//! (UDP/video) load and the rest backs off when the link drops packets, like
//! the aggregate of many small TCP flows.

use crate::util::Rng;

/// A background-traffic process. Call [`Background::rate_gbps`] once per tick.
#[derive(Debug, Clone)]
pub enum Background {
    /// No competing traffic.
    Idle,
    /// Constant offered load.
    Constant { gbps: f64 },
    /// Sinusoidal "time of day" pattern plus Gaussian jitter.
    Diurnal { mean_gbps: f64, amplitude_gbps: f64, period_s: f64, jitter_gbps: f64 },
    /// Two-state Markov burst process (low/high).
    Bursty { low_gbps: f64, high_gbps: f64, switch_prob: f64 },
    /// Piecewise-constant schedule of (start_time_s, gbps), sorted by time.
    Steps { schedule: Vec<(f64, f64)> },
}

/// Runtime state for a background process.
#[derive(Debug, Clone)]
pub struct BackgroundState {
    spec: Background,
    bursty_high: bool,
    /// Loss-responsiveness: multiplier in (0, 1] applied to the nominal rate,
    /// reduced when the link reports drops and recovering otherwise.
    responsive_scale: f64,
    /// Fraction of the background that reacts to loss (0 = pure UDP).
    responsive_frac: f64,
}

impl Background {
    /// The paper's three Fig.-1 regimes as fractions of link capacity.
    pub fn regime(name: &str, capacity_gbps: f64) -> Background {
        match name {
            "low" => Background::Constant { gbps: 0.05 * capacity_gbps },
            "medium" => Background::Diurnal {
                mean_gbps: 0.25 * capacity_gbps,
                amplitude_gbps: 0.10 * capacity_gbps,
                period_s: 600.0,
                jitter_gbps: 0.02 * capacity_gbps,
            },
            "high" => Background::Diurnal {
                mean_gbps: 0.45 * capacity_gbps,
                amplitude_gbps: 0.15 * capacity_gbps,
                period_s: 400.0,
                jitter_gbps: 0.04 * capacity_gbps,
            },
            other => panic!("unknown background regime '{other}' (low|medium|high)"),
        }
    }

    /// Scale every nominal rate by `f`, keeping timing/switching behavior
    /// unchanged — one host's fair share of cross traffic on a stage
    /// shared by `1/f` hosts (see
    /// [`super::topology::SegmentSpec::shared_slice`]).
    pub fn scaled(self, f: f64) -> Background {
        match self {
            Background::Idle => Background::Idle,
            Background::Constant { gbps } => Background::Constant { gbps: gbps * f },
            Background::Diurnal { mean_gbps, amplitude_gbps, period_s, jitter_gbps } => {
                Background::Diurnal {
                    mean_gbps: mean_gbps * f,
                    amplitude_gbps: amplitude_gbps * f,
                    period_s,
                    jitter_gbps: jitter_gbps * f,
                }
            }
            Background::Bursty { low_gbps, high_gbps, switch_prob } => {
                Background::Bursty { low_gbps: low_gbps * f, high_gbps: high_gbps * f, switch_prob }
            }
            Background::Steps { schedule } => Background::Steps {
                schedule: schedule.into_iter().map(|(t, g)| (t, g * f)).collect(),
            },
        }
    }

    pub fn into_state(self) -> BackgroundState {
        BackgroundState {
            spec: self,
            bursty_high: false,
            responsive_scale: 1.0,
            responsive_frac: 0.6,
        }
    }
}

impl BackgroundState {
    /// Offered background rate at simulation time `t` (seconds).
    pub fn rate_gbps(&mut self, t: f64, dt: f64, rng: &mut Rng) -> f64 {
        let nominal = match &self.spec {
            Background::Idle => 0.0,
            Background::Constant { gbps } => *gbps,
            Background::Diurnal { mean_gbps, amplitude_gbps, period_s, jitter_gbps } => {
                let phase = 2.0 * std::f64::consts::PI * t / period_s;
                (mean_gbps + amplitude_gbps * phase.sin() + rng.normal_mean_sd(0.0, *jitter_gbps))
                    .max(0.0)
            }
            Background::Bursty { low_gbps, high_gbps, switch_prob } => {
                // Scale switching probability with dt so behaviour is
                // tick-size independent (prob per second = switch_prob).
                if rng.chance(switch_prob * dt) {
                    self.bursty_high = !self.bursty_high;
                }
                if self.bursty_high { *high_gbps } else { *low_gbps }
            }
            Background::Steps { schedule } => {
                let mut rate = 0.0;
                for &(start, gbps) in schedule {
                    if t >= start {
                        rate = gbps;
                    }
                }
                rate
            }
        };
        let responsive = nominal * self.responsive_frac * self.responsive_scale;
        let open_loop = nominal * (1.0 - self.responsive_frac);
        responsive + open_loop
    }

    /// The mutable runtime state — `(bursty_high, responsive_scale)` — for
    /// checkpointing. The spec and `responsive_frac` are rebuild-time
    /// constants, so they are not part of the captured state.
    pub fn runtime_state(&self) -> (bool, f64) {
        (self.bursty_high, self.responsive_scale)
    }

    /// Restore a captured [`BackgroundState::runtime_state`].
    pub fn set_runtime_state(&mut self, bursty_high: bool, responsive_scale: f64) {
        self.bursty_high = bursty_high;
        self.responsive_scale = responsive_scale;
    }

    /// Feed back the link's drop fraction; responsive share backs off on loss
    /// and additively recovers when the path is clean.
    pub fn observe_loss(&mut self, drop_frac: f64, dt: f64) {
        if drop_frac > 1e-6 {
            self.responsive_scale = (self.responsive_scale * 0.92).max(0.2);
        } else {
            self.responsive_scale = (self.responsive_scale + 0.05 * dt).min(1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_is_zero() {
        let mut b = Background::Idle.into_state();
        let mut rng = Rng::new(1);
        assert_eq!(b.rate_gbps(0.0, 0.05, &mut rng), 0.0);
    }

    #[test]
    fn constant_holds() {
        let mut b = Background::Constant { gbps: 3.0 }.into_state();
        let mut rng = Rng::new(1);
        assert!((b.rate_gbps(10.0, 0.05, &mut rng) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn diurnal_oscillates_nonnegative() {
        let mut b = Background::Diurnal {
            mean_gbps: 2.0,
            amplitude_gbps: 1.5,
            period_s: 100.0,
            jitter_gbps: 0.1,
        }
        .into_state();
        let mut rng = Rng::new(2);
        let rates: Vec<f64> = (0..2000).map(|i| b.rate_gbps(i as f64 * 0.05, 0.05, &mut rng)).collect();
        assert!(rates.iter().all(|&r| r >= 0.0));
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min > 1.0, "should oscillate, spread={}", max - min);
    }

    #[test]
    fn bursty_switches_states() {
        let mut b = Background::Bursty { low_gbps: 0.5, high_gbps: 5.0, switch_prob: 0.5 }.into_state();
        let mut rng = Rng::new(3);
        let mut saw_low = false;
        let mut saw_high = false;
        for i in 0..4000 {
            let r = b.rate_gbps(i as f64 * 0.05, 0.05, &mut rng);
            if r < 1.0 { saw_low = true } else { saw_high = true }
        }
        assert!(saw_low && saw_high);
    }

    #[test]
    fn steps_follow_schedule() {
        let mut b = Background::Steps { schedule: vec![(0.0, 1.0), (10.0, 4.0)] }.into_state();
        let mut rng = Rng::new(4);
        assert!((b.rate_gbps(5.0, 0.05, &mut rng) - 1.0).abs() < 1e-9);
        assert!((b.rate_gbps(15.0, 0.05, &mut rng) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn backs_off_under_loss() {
        let mut b = Background::Constant { gbps: 4.0 }.into_state();
        let mut rng = Rng::new(5);
        let before = b.rate_gbps(0.0, 0.05, &mut rng);
        for _ in 0..50 {
            b.observe_loss(0.1, 0.05);
        }
        let after = b.rate_gbps(1.0, 0.05, &mut rng);
        assert!(after < before, "{after} !< {before}");
        for _ in 0..2000 {
            b.observe_loss(0.0, 0.05);
        }
        let recovered = b.rate_gbps(2.0, 0.05, &mut rng);
        assert!((recovered - before).abs() < 1e-6);
    }

    #[test]
    fn regimes_scale_with_capacity() {
        let mut lo = Background::regime("low", 10.0).into_state();
        let mut hi = Background::regime("high", 10.0).into_state();
        let mut rng = Rng::new(6);
        let l = lo.rate_gbps(0.0, 0.05, &mut rng);
        let h = hi.rate_gbps(0.0, 0.05, &mut rng);
        assert!(h > l);
    }
}
