//! Testbed presets mirroring the paper's three experimental environments.

use super::background::Background;
use super::link::Link;
use crate::energy::{CpuRail, EnergyConfig, FixedRail, HostSpec, NicRail};

/// Hardware class of an end node, carrying its component-rail calibration.
///
/// The paper's testbeds do not share silicon: Chameleon's gpu_p100 nodes
/// are Haswell-era Xeon E5-2670 v3 machines with 10 GbE NICs, while
/// CloudLab pairs an EPYC 7302P sender (c6525-100g, ConnectX-5 100 GbE)
/// with a dual-EPYC d7525 receiver. Each class resolves to a [`HostSpec`]
/// with its own CPU/NIC/fixed rail coefficients; only [`NodeClass::Efficient`]
/// re-sums to the lumped [`crate::energy::PowerModel::efficient`] curve
/// (the compat anchor used by FABRIC, whose virtualized hosts are never
/// billed anyway).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeClass {
    /// The lumped-compat calibration ([`HostSpec::efficient`]).
    Efficient,
    /// Xeon E5-2670 v3 (Haswell, 10 GbE): pricier per stream and per bit,
    /// higher resident draw, shallow NIC LPI.
    Xeon2670,
    /// c6525-100g (EPYC 7302P, ConnectX-5 100 GbE): modern cores and a NIC
    /// that moves more bits per joule.
    C6525,
    /// d7525 (dual EPYC 7302, GPU chassis): like c6525 but with a higher
    /// base draw from the bigger chassis.
    D7525,
}

impl NodeClass {
    /// Resolve this class to a named host spec.
    pub fn host(&self, name: impl Into<String>) -> HostSpec {
        match self {
            NodeClass::Efficient => HostSpec::efficient(name),
            NodeClass::Xeon2670 => HostSpec {
                name: name.into(),
                cpu: CpuRail { c_stream_w: 1.1, stream_exp: 0.9, c_gbps_w: 3.2 },
                nic: NicRail { c_gbps_w: 4.1, lpi_idle_w: 1.4 },
                fixed: FixedRail { active_w: 24.0, lane_idle_w: 3.0 },
                noise_w: 0.8,
            },
            NodeClass::C6525 => HostSpec {
                name: name.into(),
                cpu: CpuRail { c_stream_w: 0.7, stream_exp: 0.9, c_gbps_w: 2.1 },
                nic: NicRail { c_gbps_w: 3.0, lpi_idle_w: 0.8 },
                fixed: FixedRail { active_w: 16.0, lane_idle_w: 2.2 },
                noise_w: 0.8,
            },
            NodeClass::D7525 => HostSpec {
                name: name.into(),
                cpu: CpuRail { c_stream_w: 0.75, stream_exp: 0.9, c_gbps_w: 2.3 },
                nic: NicRail { c_gbps_w: 3.2, lpi_idle_w: 0.9 },
                fixed: FixedRail { active_w: 17.0, lane_idle_w: 2.4 },
                noise_w: 0.8,
            },
        }
    }
}

/// A named testbed configuration (link + node characteristics).
#[derive(Debug, Clone)]
pub struct Testbed {
    pub name: &'static str,
    /// Bottleneck capacity in Gbps (effective, not nominal).
    pub capacity_gbps: f64,
    /// Base RTT in seconds.
    pub base_rtt_s: f64,
    /// Droptail buffer as a multiple of BDP.
    pub buffer_bdp: f64,
    /// Per-stream receiver-window rate cap in Gbps (OS socket buffers / RTT).
    pub per_stream_cap_gbps: f64,
    /// Per-file-task application I/O rate for an efficient engine, Gbps.
    pub task_io_gbps: f64,
    /// Whether RAPL-like energy counters exist (FABRIC: no — VMs).
    pub has_energy_counters: bool,
    /// Default background regime for evaluation runs.
    pub default_background: Background,
    /// Hardware class of the sending node (rail calibration).
    pub sender_node: NodeClass,
    /// Hardware class of the receiving node (rail calibration).
    pub receiver_node: NodeClass,
}

impl Testbed {
    /// Chameleon Cloud, TACC ↔ UC: shared 10 Gbps WAN, ~32 ms RTT,
    /// gpu_p100 nodes (Xeon E5-2670 v3), 10 GbE NICs.
    pub fn chameleon() -> Testbed {
        Testbed {
            name: "chameleon",
            capacity_gbps: 10.0,
            base_rtt_s: 0.032,
            buffer_bdp: 1.0,
            per_stream_cap_gbps: 1.0,  // 4 MB socket buffers at 32 ms
            task_io_gbps: 3.0,
            has_energy_counters: true,
            default_background: Background::regime("medium", 10.0),
            sender_node: NodeClass::Xeon2670,
            receiver_node: NodeClass::Xeon2670,
        }
    }

    /// CloudLab, Utah (c6525-100g) ↔ Wisconsin (d7525): WAN capped at
    /// 25 Gbps, ~36 ms RTT, NVMe-class local storage.
    pub fn cloudlab() -> Testbed {
        Testbed {
            name: "cloudlab",
            capacity_gbps: 25.0,
            base_rtt_s: 0.036,
            buffer_bdp: 1.0,
            per_stream_cap_gbps: 1.8,  // 8 MB socket buffers at 36 ms
            task_io_gbps: 10.0,
            has_energy_counters: true,
            default_background: Background::regime("medium", 25.0),
            sender_node: NodeClass::C6525,
            receiver_node: NodeClass::D7525,
        }
    }

    /// FABRIC, Princeton ↔ Utah VMs: ConnectX-6 100 GbE NICs but ~30 Gbps
    /// effective WAN (shared NIC among VMs), 56 ms RTT, no hardware energy
    /// counters (virtualized).
    pub fn fabric() -> Testbed {
        Testbed {
            name: "fabric",
            capacity_gbps: 30.0,
            base_rtt_s: 0.056,
            buffer_bdp: 0.8,
            per_stream_cap_gbps: 1.2,  // 8 MB socket buffers at 56 ms
            task_io_gbps: 8.0,
            has_energy_counters: false,
            default_background: Background::regime("medium", 30.0),
            sender_node: NodeClass::Efficient,
            receiver_node: NodeClass::Efficient,
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Testbed> {
        match name {
            "chameleon" => Some(Testbed::chameleon()),
            "cloudlab" => Some(Testbed::cloudlab()),
            "fabric" => Some(Testbed::fabric()),
            _ => None,
        }
    }

    /// All presets.
    pub fn all() -> Vec<Testbed> {
        vec![Testbed::chameleon(), Testbed::cloudlab(), Testbed::fabric()]
    }

    /// Build the bottleneck link for this testbed.
    pub fn link(&self) -> Link {
        Link::new(self.capacity_gbps, self.base_rtt_s, self.buffer_bdp)
    }

    /// The sender end host's component-rail definition, resolved from
    /// [`Testbed::sender_node`] and named per preset — e.g. `chameleon-tx`.
    /// On FABRIC the spec exists but is never billed
    /// (`has_energy_counters` is false).
    pub fn sender_host(&self) -> HostSpec {
        self.sender_node.host(format!("{}-tx", self.name))
    }

    /// The receiver end host's component-rail definition (`<name>-rx`),
    /// resolved from [`Testbed::receiver_node`].
    pub fn receiver_host(&self) -> HostSpec {
        self.receiver_node.host(format!("{}-rx", self.name))
    }

    /// Host-resolved energy accounting over this testbed's sender and
    /// receiver hosts — what `sparta fleet` passes to
    /// [`crate::coordinator::SessionBuilder::energy`] so colocated lanes
    /// share one ledger per host instead of multiply-counting fixed power.
    pub fn energy_hosts(&self) -> EnergyConfig {
        EnergyConfig::Hosts { sender: self.sender_host(), receiver: self.receiver_host() }
    }

    /// Host-resolved accounting for sender host `h` of an incast fleet of
    /// `hosts` senders: a private sender host (`<name>-tx<h>`) plus a
    /// `1/hosts` share of the single physical receiver
    /// ([`HostSpec::share`]), so summing attribution over every host
    /// session pays the receiver's residency exactly once — the cluster
    /// conservation invariant.
    pub fn energy_hosts_of(&self, h: usize, hosts: usize) -> EnergyConfig {
        EnergyConfig::Hosts {
            sender: self.sender_node.host(format!("{}-tx{h}", self.name)),
            receiver: self.receiver_host().share(hosts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        for name in ["chameleon", "cloudlab", "fabric"] {
            let tb = Testbed::by_name(name).unwrap();
            assert_eq!(tb.name, name);
            assert!(tb.capacity_gbps > 0.0);
        }
        assert!(Testbed::by_name("nope").is_none());
    }

    #[test]
    fn fabric_has_no_energy_counters() {
        assert!(!Testbed::fabric().has_energy_counters);
        assert!(Testbed::chameleon().has_energy_counters);
    }

    #[test]
    fn single_stream_cannot_fill_any_link() {
        for tb in Testbed::all() {
            assert!(tb.per_stream_cap_gbps < tb.capacity_gbps / 5.0);
        }
    }

    /// Every preset defines named sender/receiver hosts whose rail
    /// decomposition re-sums to that host's own power curve; only the
    /// Efficient node class (FABRIC) also matches the lumped compat curve.
    #[test]
    fn hosts_defined_per_preset_and_rails_self_consistent() {
        for tb in Testbed::all() {
            let tx = tb.sender_host();
            let rx = tb.receiver_host();
            assert_eq!(tx.name, format!("{}-tx", tb.name));
            assert_eq!(rx.name, format!("{}-rx", tb.name));
            for (streams, gbps) in [(1usize, 1.0), (16, 5.0), (256, 8.0)] {
                let (cpu, nic, fixed) = tx.rails_w(streams, gbps);
                let got = tx.power_w(streams, gbps);
                assert!(
                    (cpu + nic + fixed - got).abs() <= 1e-9 * got,
                    "{}: rails don't re-sum at ({streams}, {gbps})",
                    tb.name
                );
            }
            assert!(matches!(tb.energy_hosts(), EnergyConfig::Hosts { .. }));
        }
    }

    /// Per-node-class calibrations: FABRIC keeps the lumped-compat
    /// efficient class; Chameleon's Haswell Xeons burn more per bit than
    /// either CloudLab EPYC class; CloudLab's sender and receiver differ.
    #[test]
    fn node_classes_are_heterogeneous_and_fabric_stays_lumped_compat() {
        let lumped = crate::energy::PowerModel::efficient();
        let fab = Testbed::fabric().sender_host();
        for (streams, gbps) in [(1usize, 1.0), (16, 5.0), (256, 8.0)] {
            let want = lumped.power_w(streams, gbps);
            let got = fab.power_w(streams, gbps);
            assert!((got - want).abs() <= 1e-9 * want, "fabric: {got} vs lumped {want}");
        }

        let cham = Testbed::chameleon();
        assert_eq!(cham.sender_node, NodeClass::Xeon2670);
        let xeon = cham.sender_host();
        let cl = Testbed::cloudlab();
        assert_eq!((cl.sender_node, cl.receiver_node), (NodeClass::C6525, NodeClass::D7525));
        let c6525 = cl.sender_host();
        let d7525 = cl.receiver_host();

        // Haswell is the hungriest class at every operating point probed.
        for (streams, gbps) in [(1usize, 1.0), (16, 5.0), (64, 8.0)] {
            let x = xeon.power_w(streams, gbps);
            assert!(x > c6525.power_w(streams, gbps), "xeon vs c6525 at ({streams}, {gbps})");
            assert!(x > d7525.power_w(streams, gbps), "xeon vs d7525 at ({streams}, {gbps})");
        }
        // The CloudLab pair is asymmetric: the GPU-chassis receiver idles
        // higher than the sender.
        assert!(d7525.fixed.active_w > c6525.fixed.active_w);
        assert_ne!(c6525.power_w(16, 5.0), d7525.power_w(16, 5.0));
    }
}
