//! Testbed presets mirroring the paper's three experimental environments.

use super::background::Background;
use super::link::Link;
use crate::energy::{EnergyConfig, HostSpec};

/// A named testbed configuration (link + node characteristics).
#[derive(Debug, Clone)]
pub struct Testbed {
    pub name: &'static str,
    /// Bottleneck capacity in Gbps (effective, not nominal).
    pub capacity_gbps: f64,
    /// Base RTT in seconds.
    pub base_rtt_s: f64,
    /// Droptail buffer as a multiple of BDP.
    pub buffer_bdp: f64,
    /// Per-stream receiver-window rate cap in Gbps (OS socket buffers / RTT).
    pub per_stream_cap_gbps: f64,
    /// Per-file-task application I/O rate for an efficient engine, Gbps.
    pub task_io_gbps: f64,
    /// Whether RAPL-like energy counters exist (FABRIC: no — VMs).
    pub has_energy_counters: bool,
    /// Default background regime for evaluation runs.
    pub default_background: Background,
}

impl Testbed {
    /// Chameleon Cloud, TACC ↔ UC: shared 10 Gbps WAN, ~32 ms RTT,
    /// gpu_p100 nodes (Xeon E5-2670 v3), 10 GbE NICs.
    pub fn chameleon() -> Testbed {
        Testbed {
            name: "chameleon",
            capacity_gbps: 10.0,
            base_rtt_s: 0.032,
            buffer_bdp: 1.0,
            per_stream_cap_gbps: 1.0,  // 4 MB socket buffers at 32 ms
            task_io_gbps: 3.0,
            has_energy_counters: true,
            default_background: Background::regime("medium", 10.0),
        }
    }

    /// CloudLab, Utah (c6525-100g) ↔ Wisconsin (d7525): WAN capped at
    /// 25 Gbps, ~36 ms RTT, NVMe-class local storage.
    pub fn cloudlab() -> Testbed {
        Testbed {
            name: "cloudlab",
            capacity_gbps: 25.0,
            base_rtt_s: 0.036,
            buffer_bdp: 1.0,
            per_stream_cap_gbps: 1.8,  // 8 MB socket buffers at 36 ms
            task_io_gbps: 10.0,
            has_energy_counters: true,
            default_background: Background::regime("medium", 25.0),
        }
    }

    /// FABRIC, Princeton ↔ Utah VMs: ConnectX-6 100 GbE NICs but ~30 Gbps
    /// effective WAN (shared NIC among VMs), 56 ms RTT, no hardware energy
    /// counters (virtualized).
    pub fn fabric() -> Testbed {
        Testbed {
            name: "fabric",
            capacity_gbps: 30.0,
            base_rtt_s: 0.056,
            buffer_bdp: 0.8,
            per_stream_cap_gbps: 1.2,  // 8 MB socket buffers at 56 ms
            task_io_gbps: 8.0,
            has_energy_counters: false,
            default_background: Background::regime("medium", 30.0),
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Testbed> {
        match name {
            "chameleon" => Some(Testbed::chameleon()),
            "cloudlab" => Some(Testbed::cloudlab()),
            "fabric" => Some(Testbed::fabric()),
            _ => None,
        }
    }

    /// All presets.
    pub fn all() -> Vec<Testbed> {
        vec![Testbed::chameleon(), Testbed::cloudlab(), Testbed::fabric()]
    }

    /// Build the bottleneck link for this testbed.
    pub fn link(&self) -> Link {
        Link::new(self.capacity_gbps, self.base_rtt_s, self.buffer_bdp)
    }

    /// The sender end host's component-rail definition (the efficient
    /// calibration, named per preset — e.g. `chameleon-tx`). On FABRIC the
    /// spec exists but is never billed (`has_energy_counters` is false).
    pub fn sender_host(&self) -> HostSpec {
        HostSpec::efficient(format!("{}-tx", self.name))
    }

    /// The receiver end host's component-rail definition (`<name>-rx`).
    pub fn receiver_host(&self) -> HostSpec {
        HostSpec::efficient(format!("{}-rx", self.name))
    }

    /// Host-resolved energy accounting over this testbed's sender and
    /// receiver hosts — what `sparta fleet` passes to
    /// [`crate::coordinator::SessionBuilder::energy`] so colocated lanes
    /// share one ledger per host instead of multiply-counting fixed power.
    pub fn energy_hosts(&self) -> EnergyConfig {
        EnergyConfig::Hosts { sender: self.sender_host(), receiver: self.receiver_host() }
    }

    /// Host-resolved accounting for sender host `h` of an incast fleet of
    /// `hosts` senders: a private sender host (`<name>-tx<h>`) plus a
    /// `1/hosts` share of the single physical receiver
    /// ([`HostSpec::share`]), so summing attribution over every host
    /// session pays the receiver's residency exactly once — the cluster
    /// conservation invariant.
    pub fn energy_hosts_of(&self, h: usize, hosts: usize) -> EnergyConfig {
        EnergyConfig::Hosts {
            sender: HostSpec::efficient(format!("{}-tx{h}", self.name)),
            receiver: self.receiver_host().share(hosts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_by_name() {
        for name in ["chameleon", "cloudlab", "fabric"] {
            let tb = Testbed::by_name(name).unwrap();
            assert_eq!(tb.name, name);
            assert!(tb.capacity_gbps > 0.0);
        }
        assert!(Testbed::by_name("nope").is_none());
    }

    #[test]
    fn fabric_has_no_energy_counters() {
        assert!(!Testbed::fabric().has_energy_counters);
        assert!(Testbed::chameleon().has_energy_counters);
    }

    #[test]
    fn single_stream_cannot_fill_any_link() {
        for tb in Testbed::all() {
            assert!(tb.per_stream_cap_gbps < tb.capacity_gbps / 5.0);
        }
    }

    /// Every preset defines sender/receiver hosts whose single-lane rail
    /// power re-sums to the lumped efficient curve (the compat guarantee).
    #[test]
    fn hosts_defined_per_preset_and_match_lumped_curve() {
        let lumped = crate::energy::PowerModel::efficient();
        for tb in Testbed::all() {
            let tx = tb.sender_host();
            let rx = tb.receiver_host();
            assert_eq!(tx.name, format!("{}-tx", tb.name));
            assert_eq!(rx.name, format!("{}-rx", tb.name));
            for (streams, gbps) in [(1usize, 1.0), (16, 5.0), (256, 8.0)] {
                let want = lumped.power_w(streams, gbps);
                let got = tx.power_w(streams, gbps);
                assert!(
                    (got - want).abs() <= 1e-9 * want,
                    "{}: rails {got} vs lumped {want}",
                    tb.name
                );
            }
            assert!(matches!(tb.energy_hosts(), EnergyConfig::Hosts { .. }));
        }
    }
}
