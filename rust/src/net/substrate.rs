//! The network-substrate abstraction the control plane runs against.
//!
//! [`Substrate`] is the exact surface the coordinator, the live training
//! environment and the experiments need from a network: admit flows, apply
//! (cc, p) pause/resume updates, advance monitoring intervals, and read
//! end-host-observable metrics. The fluid-model [`NetworkSim`] (single- or
//! multi-segment) is the in-tree implementation; an emulator- or
//! kernel-backed substrate can slot in behind the same trait without
//! touching the control loop.

use super::sim::{FlowId, MiMetrics, NetworkSim, SimState};
use super::testbed::Testbed;

/// A network substrate: the `add_flow` / `set_cc_p` / `run_mi_into` surface
/// of [`NetworkSim`], object-safe so controllers can hold `Box<dyn Substrate>`.
pub trait Substrate: Send {
    /// Add a flow with an engine-specific per-task I/O cap; returns its id.
    /// `task_io_gbps = None` uses the testbed's efficient-engine default.
    fn add_flow(&mut self, cc: u32, p: u32, task_io_gbps: Option<f64>) -> FlowId;

    /// Apply a (cc, p) update to a flow (pause/resume semantics).
    fn set_cc_p(&mut self, id: FlowId, cc: u32, p: u32);

    /// Cap a flow's total demand (Gbps) — used when a job is nearly done.
    fn set_demand_cap(&mut self, id: FlowId, gbps: f64);

    /// Number of currently active streams of a flow.
    fn active_streams(&self, id: FlowId) -> usize;

    /// Capacity hint for `n` additional flows (e.g. a fleet schedule's
    /// expected lane count). Purely advisory — implementations may
    /// preallocate flow tables and stream arenas; the default does
    /// nothing. Must never affect simulation results.
    fn reserve_flows(&mut self, _n: usize) {}

    /// Advance one monitoring interval of `dur_s` seconds, writing per-flow
    /// metrics in flow-id order into a caller-reused buffer (cleared first).
    ///
    /// This is the trait's single source of truth for MI stepping — the
    /// allocation-free path the session's step loop and the cluster drive
    /// (§Perf). Implementations must leave `out` holding exactly one
    /// [`MiMetrics`] per flow, regardless of the buffer's prior contents.
    fn run_mi_into(&mut self, dur_s: f64, out: &mut Vec<MiMetrics>);

    /// Allocating convenience wrapper over [`Substrate::run_mi_into`] for
    /// tests and one-shot probes. External drivers on the hot path should
    /// hold a buffer and call `run_mi_into` instead.
    fn run_mi(&mut self, dur_s: f64) -> Vec<MiMetrics> {
        let mut out = Vec::new();
        self.run_mi_into(dur_s, &mut out);
        out
    }

    /// Simulated time elapsed, seconds.
    fn time_s(&self) -> f64;

    /// Ground-truth path RTT including queueing (tests/telemetry).
    fn link_rtt_s(&self) -> f64;

    /// The testbed preset this substrate models.
    fn testbed(&self) -> &Testbed;

    /// Capture the substrate's complete mutable state at an MI boundary for
    /// checkpointing (the serve snapshot). Substrates that cannot express
    /// their state as a [`SimState`] return `None` — such substrates cannot
    /// back a checkpointable service.
    fn save_state(&self) -> Option<SimState> {
        None
    }

    /// Restore a state captured by [`Substrate::save_state`] into a
    /// substrate rebuilt with the same topology and flow sequence. Returns
    /// `false` when the substrate does not support restore or the capture
    /// does not match its shape.
    fn load_state(&mut self, _state: &SimState) -> bool {
        false
    }

    /// Fault-injection hook ([`crate::faults`]): scale the named topology
    /// segment's capacity against its *nominal* value (`1.0` heals it,
    /// `0.0` is clamped to a numerically-safe floor). Called only at MI
    /// boundaries by a session applying a seeded fault plan; draws no
    /// randomness and must be a no-op on unknown segment names. Returns
    /// `false` when the substrate does not model named segments (e.g. the
    /// frozen golden-replay baseline), in which case link faults are
    /// reported as unsupported rather than silently ignored.
    fn fault_segment(&mut self, _segment: &str, _scale: f64) -> bool {
        false
    }
}

impl Substrate for NetworkSim {
    fn add_flow(&mut self, cc: u32, p: u32, task_io_gbps: Option<f64>) -> FlowId {
        NetworkSim::add_flow(self, cc, p, task_io_gbps)
    }

    fn set_cc_p(&mut self, id: FlowId, cc: u32, p: u32) {
        NetworkSim::set_cc_p(self, id, cc, p)
    }

    fn set_demand_cap(&mut self, id: FlowId, gbps: f64) {
        NetworkSim::set_demand_cap(self, id, gbps)
    }

    fn active_streams(&self, id: FlowId) -> usize {
        NetworkSim::active_streams(self, id)
    }

    fn reserve_flows(&mut self, n: usize) {
        NetworkSim::reserve_flows(self, n)
    }

    fn run_mi_into(&mut self, dur_s: f64, out: &mut Vec<MiMetrics>) {
        NetworkSim::run_mi_into(self, dur_s, out)
    }

    fn time_s(&self) -> f64 {
        NetworkSim::time_s(self)
    }

    fn link_rtt_s(&self) -> f64 {
        NetworkSim::link_rtt_s(self)
    }

    fn testbed(&self) -> &Testbed {
        NetworkSim::testbed(self)
    }

    fn save_state(&self) -> Option<SimState> {
        Some(NetworkSim::save_state(self))
    }

    fn load_state(&mut self, state: &SimState) -> bool {
        NetworkSim::load_state(self, state)
    }

    fn fault_segment(&mut self, segment: &str, scale: f64) -> bool {
        NetworkSim::fault_segment(self, segment, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The trait surface drives a simulation end to end through `dyn`.
    #[test]
    fn network_sim_is_usable_as_dyn_substrate() {
        let mut sub: Box<dyn Substrate> =
            Box::new(NetworkSim::new(Testbed::chameleon(), 7));
        let id = sub.add_flow(4, 4, None);
        assert_eq!(sub.active_streams(id), 16);
        sub.set_cc_p(id, 2, 2);
        assert_eq!(sub.active_streams(id), 4);
        let m = sub.run_mi(1.0);
        assert_eq!(m.len(), 1);
        assert!(m[0].rtt_s > 0.0);
        // The allocating wrapper and the buffer path share one source of
        // truth: a dirty, over-capacity buffer comes back identical.
        let mut buf = vec![m[0]; 7];
        sub.run_mi_into(1.0, &mut buf);
        assert_eq!(buf.len(), 1);
        assert!(sub.time_s() > 0.0);
        assert_eq!(sub.testbed().name, "chameleon");
    }
}
