//! Multi-flow network simulator: streams × tasks × flows over a shared link.
//!
//! A *flow* is one transfer application (one SPARTA agent or baseline tool)
//! holding `cc` file-tasks with `p` TCP streams each. All flows plus the
//! background process share one bottleneck [`Link`]. Each call to
//! [`NetworkSim::run_mi`] advances one monitoring interval and returns the
//! end-host-observable metrics per flow — exactly the signal set the paper's
//! agents consume.

use super::background::BackgroundState;
use super::link::Link;
use super::stream::CubicStream;
use super::testbed::Testbed;
use super::MSS_BITS;
use crate::util::Rng;

/// Identifies a flow within a [`NetworkSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

/// Simulator tick configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Fluid-model tick, seconds.
    pub tick_s: f64,
    /// Std-dev of RTT measurement noise, seconds.
    pub rtt_noise_s: f64,
    /// Maximum concurrent tasks / streams-per-task a flow may use.
    pub max_cc: u32,
    pub max_p: u32,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { tick_s: 0.05, rtt_noise_s: 0.0004, max_cc: 32, max_p: 32 }
    }
}

/// One file-task: a group of `p` parallel streams.
#[derive(Debug, Clone)]
struct Task {
    streams: Vec<CubicStream>,
    /// Number of currently-active streams (prefix of `streams`).
    p_active: usize,
    /// Whether the task itself is admitted (prefix `cc` of tasks are).
    active: bool,
}

/// One transfer application's traffic.
#[derive(Debug, Clone)]
struct Flow {
    tasks: Vec<Task>,
    cc_active: usize,
    /// Per-task application I/O rate cap (engine property), Gbps.
    task_io_gbps: f64,
    /// Per-stream receiver-window rate cap, Gbps.
    stream_cap_gbps: f64,
    /// Optional cap on total demand (e.g. job nearly complete), Gbps.
    demand_cap_gbps: f64,
    // Per-MI accumulators.
    acc_delivered_bits: f64,
    acc_sent_bits: f64,
    acc_lost_bits: f64,
    acc_rtt_sum: f64,
    acc_rtt_n: u64,
}

impl Flow {
    fn new(cc: u32, p: u32, task_io_gbps: f64, stream_cap_gbps: f64, cfg: &SimConfig) -> Flow {
        let mut f = Flow {
            tasks: Vec::new(),
            cc_active: 0,
            task_io_gbps,
            stream_cap_gbps,
            demand_cap_gbps: f64::MAX,
            acc_delivered_bits: 0.0,
            acc_sent_bits: 0.0,
            acc_lost_bits: 0.0,
            acc_rtt_sum: 0.0,
            acc_rtt_n: 0,
        };
        f.set_cc_p(cc, p, cfg);
        f
    }

    /// Apply a (cc, p) setting: tasks/streams beyond the new limits are
    /// *paused* (keeping TCP state), previously paused ones are *resumed* —
    /// the paper's pause/resume thread semantics.
    fn set_cc_p(&mut self, cc: u32, p: u32, cfg: &SimConfig) {
        let cc = cc.clamp(1, cfg.max_cc) as usize;
        let p = p.clamp(1, cfg.max_p) as usize;
        while self.tasks.len() < cc {
            self.tasks.push(Task { streams: Vec::new(), p_active: 0, active: false });
        }
        for (i, task) in self.tasks.iter_mut().enumerate() {
            let task_active = i < cc;
            while task.streams.len() < p {
                task.streams.push(CubicStream::new());
            }
            for (j, s) in task.streams.iter_mut().enumerate() {
                if task_active && j < p {
                    s.resume();
                } else {
                    s.pause();
                }
            }
            task.active = task_active;
            task.p_active = if task_active { p } else { 0 };
        }
        self.cc_active = cc;
    }

    fn active_stream_count(&self) -> usize {
        self.tasks.iter().map(|t| t.p_active).sum()
    }
}

/// End-host-observable metrics for one flow over one monitoring interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiMetrics {
    /// Goodput over the MI, Gbps.
    pub throughput_gbps: f64,
    /// Packet loss rate over the MI (lost / sent).
    pub plr: f64,
    /// Mean measured RTT over the MI, seconds (with measurement noise).
    pub rtt_s: f64,
    /// Bytes delivered during the MI.
    pub bytes_delivered: f64,
    /// Number of active streams during the MI (cc × p, post-clamp).
    pub active_streams: usize,
    /// MI duration, seconds.
    pub duration_s: f64,
}

/// The shared-bottleneck simulator.
pub struct NetworkSim {
    pub cfg: SimConfig,
    link: Link,
    background: BackgroundState,
    flows: Vec<Flow>,
    time_s: f64,
    rng: Rng,
    testbed: Testbed,
    /// Reusable per-tick scratch of per-stream desired rates (flat, in
    /// flow-major/task-major/stream-major order) — §Perf: the tick loop is
    /// allocation-free at steady state.
    scratch: Vec<f64>,
}

impl NetworkSim {
    /// Build a simulator for a testbed preset with its default background.
    pub fn new(testbed: Testbed, seed: u64) -> NetworkSim {
        let background = testbed.default_background.clone().into_state();
        NetworkSim {
            cfg: SimConfig::default(),
            link: testbed.link(),
            background,
            flows: Vec::new(),
            time_s: 0.0,
            rng: Rng::new(seed),
            testbed,
            scratch: Vec::new(),
        }
    }

    /// Replace the background process.
    pub fn with_background(mut self, bg: super::background::Background) -> NetworkSim {
        self.background = bg.into_state();
        self
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn time_s(&self) -> f64 {
        self.time_s
    }

    /// Add a flow with an engine-specific per-task I/O cap; returns its id.
    /// `task_io_gbps = None` uses the testbed's efficient-engine default.
    pub fn add_flow(&mut self, cc: u32, p: u32, task_io_gbps: Option<f64>) -> FlowId {
        let io = task_io_gbps.unwrap_or(self.testbed.task_io_gbps);
        let f = Flow::new(cc, p, io, self.testbed.per_stream_cap_gbps, &self.cfg);
        self.flows.push(f);
        FlowId(self.flows.len() - 1)
    }

    /// Apply a (cc, p) update to a flow (pause/resume semantics).
    pub fn set_cc_p(&mut self, id: FlowId, cc: u32, p: u32) {
        let cfg = self.cfg.clone();
        self.flows[id.0].set_cc_p(cc, p, &cfg);
    }

    /// Cap a flow's total demand (Gbps) — used when a job is nearly done.
    pub fn set_demand_cap(&mut self, id: FlowId, gbps: f64) {
        self.flows[id.0].demand_cap_gbps = gbps;
    }

    /// Number of currently active streams of a flow.
    pub fn active_streams(&self, id: FlowId) -> usize {
        self.flows[id.0].active_stream_count()
    }

    /// Current link RTT (ground truth, for tests/telemetry).
    pub fn link_rtt_s(&self) -> f64 {
        self.link.rtt_s()
    }

    /// Advance one tick of the fluid model.
    fn tick(&mut self) {
        let dt = self.cfg.tick_s;
        let rtt = self.link.rtt_s();

        // Phase 1: compute each active stream's desired rate into the
        // reusable flat scratch (flow-major, task-major, stream-major) —
        // no allocation at steady state (§Perf).
        let mut offered_total = 0.0;
        let total_streams: usize =
            self.flows.iter().map(|f| f.tasks.iter().map(|t| t.streams.len()).sum::<usize>()).sum();
        self.scratch.clear();
        self.scratch.resize(total_streams, 0.0);
        let mut idx = 0usize;
        for flow in &self.flows {
            let flow_start = idx;
            let mut per_flow = 0.0;
            for task in &flow.tasks {
                if !task.active || task.p_active == 0 {
                    idx += task.streams.len();
                    continue;
                }
                let io_share = flow.task_io_gbps / task.p_active as f64;
                for s in &task.streams {
                    let r = if s.active {
                        s.cwnd_rate_gbps(rtt)
                            .min(flow.stream_cap_gbps)
                            .min(io_share)
                    } else {
                        0.0
                    };
                    self.scratch[idx] = r;
                    idx += 1;
                    per_flow += r;
                }
            }
            // Demand cap: scale all stream rates down proportionally.
            if per_flow > flow.demand_cap_gbps {
                let scale = flow.demand_cap_gbps / per_flow;
                for r in &mut self.scratch[flow_start..idx] {
                    *r *= scale;
                }
                per_flow = flow.demand_cap_gbps;
            }
            offered_total += per_flow;
        }
        let bg_rate = self.background.rate_gbps(self.time_s, dt, &mut self.rng);
        offered_total += bg_rate;

        // Phase 2: offer to the link.
        let outcome = self.link.tick(offered_total, dt);
        self.background.observe_loss(outcome.drop_frac, dt);
        let rtt_after = self.link.rtt_s();

        // Phase 3: deliver, account, and evolve windows (same scratch walk
        // order as phase 1).
        let mut idx = 0usize;
        for flow in self.flows.iter_mut() {
            let mut delivered = 0.0;
            let mut sent = 0.0;
            let mut lost = 0.0;
            for task in flow.tasks.iter_mut() {
                if !task.active {
                    idx += task.streams.len();
                    continue;
                }
                let io_share = flow.task_io_gbps / task.p_active.max(1) as f64;
                for s in task.streams.iter_mut() {
                    let rate = self.scratch[idx];
                    idx += 1;
                    if !s.active {
                        continue;
                    }
                    let sent_bits = rate * 1e9 * dt;
                    let lost_bits = sent_bits * outcome.drop_frac;
                    delivered += sent_bits - lost_bits;
                    sent += sent_bits;
                    lost += lost_bits;

                    // Loss events: probability that at least one of this
                    // stream's packets this tick was dropped.
                    if outcome.drop_frac > 0.0 {
                        let pkts = sent_bits / MSS_BITS;
                        let p_event = 1.0 - (1.0 - outcome.drop_frac).powf(pkts.max(0.0));
                        if self.rng.chance(p_event) {
                            s.on_loss(rtt_after);
                        }
                    }
                    // Growth: app-limited if a cap (not cwnd) was binding.
                    let cwnd_rate = s.cwnd_rate_gbps(rtt_after);
                    let app_limited = rate + 1e-12 < cwnd_rate
                        || cwnd_rate >= flow.stream_cap_gbps.min(io_share);
                    s.grow(dt, rtt_after, app_limited);
                }
            }
            flow.acc_delivered_bits += delivered;
            flow.acc_sent_bits += sent;
            flow.acc_lost_bits += lost;
            flow.acc_rtt_sum += rtt_after;
            flow.acc_rtt_n += 1;
        }
        self.time_s += dt;
    }

    /// Run one monitoring interval of `dur_s` seconds; returns per-flow
    /// metrics in flow-id order.
    pub fn run_mi(&mut self, dur_s: f64) -> Vec<MiMetrics> {
        for f in &mut self.flows {
            f.acc_delivered_bits = 0.0;
            f.acc_sent_bits = 0.0;
            f.acc_lost_bits = 0.0;
            f.acc_rtt_sum = 0.0;
            f.acc_rtt_n = 0;
        }
        let ticks = (dur_s / self.cfg.tick_s).round().max(1.0) as usize;
        for _ in 0..ticks {
            self.tick();
        }
        let actual_dur = ticks as f64 * self.cfg.tick_s;
        let noise = self.cfg.rtt_noise_s;
        let mut out = Vec::with_capacity(self.flows.len());
        // Borrow dance: collect metrics first, then add noise with rng.
        let metrics: Vec<(f64, f64, f64, f64, usize)> = self
            .flows
            .iter()
            .map(|f| {
                let thr = f.acc_delivered_bits / actual_dur / 1e9;
                let plr = if f.acc_sent_bits > 0.0 { f.acc_lost_bits / f.acc_sent_bits } else { 0.0 };
                let rtt = if f.acc_rtt_n > 0 { f.acc_rtt_sum / f.acc_rtt_n as f64 } else { self.link.rtt_s() };
                (thr, plr, rtt, f.acc_delivered_bits / 8.0, f.active_stream_count())
            })
            .collect();
        for (thr, plr, rtt, bytes, streams) in metrics {
            let rtt_noisy = (rtt + self.rng.normal_ms(0.0, noise)).max(1e-4);
            out.push(MiMetrics {
                throughput_gbps: thr,
                plr,
                rtt_s: rtt_noisy,
                bytes_delivered: bytes,
                active_streams: streams,
                duration_s: actual_dur,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::background::Background;

    fn sim(bg: Background) -> NetworkSim {
        NetworkSim::new(Testbed::chameleon(), 42).with_background(bg)
    }

    /// Warm up (slow start + convergence), then measure average throughput.
    fn steady_throughput(cc: u32, p: u32, bg: Background, mis: usize) -> (f64, f64) {
        let mut s = sim(bg);
        let id = s.add_flow(cc, p, None);
        for _ in 0..15 {
            s.run_mi(1.0);
        }
        let mut thr = 0.0;
        let mut plr = 0.0;
        for _ in 0..mis {
            let m = s.run_mi(1.0);
            thr += m[id.0].throughput_gbps;
            plr += m[id.0].plr;
        }
        (thr / mis as f64, plr / mis as f64)
    }

    #[test]
    fn single_stream_is_rwnd_capped() {
        let (thr, _) = steady_throughput(1, 1, Background::Idle, 10);
        // cap = 1 Gbps per stream on chameleon
        assert!(thr > 0.7 && thr < 1.1, "thr={thr}");
    }

    #[test]
    fn parallelism_scales_until_io_cap() {
        let (t1, _) = steady_throughput(1, 1, Background::Idle, 10);
        let (t2, _) = steady_throughput(1, 2, Background::Idle, 10);
        let (t8, _) = steady_throughput(1, 8, Background::Idle, 10);
        assert!(t2 > t1 * 1.5, "t1={t1} t2={t2}");
        // One task's I/O cap is 3 Gbps on chameleon.
        assert!(t8 < 3.3, "t8={t8}");
        assert!(t8 > 2.4, "t8={t8}");
    }

    #[test]
    fn concurrency_and_parallelism_approach_capacity() {
        let (thr, _) = steady_throughput(4, 4, Background::Idle, 10);
        assert!(thr > 7.5, "thr={thr}");
        assert!(thr <= 10.0 + 1e-6);
    }

    #[test]
    fn oversubscription_raises_loss() {
        let (_, plr_small) = steady_throughput(2, 2, Background::Idle, 10);
        let (_, plr_big) = steady_throughput(16, 16, Background::Idle, 10);
        // 256 CUBIC streams on a 10G link sit at a small but clearly nonzero
        // equilibrium loss rate (Mathis: L ∝ (MSS/(RTT·T_stream))²).
        assert!(plr_big > plr_small, "small={plr_small} big={plr_big}");
        assert!(plr_big > 1e-5, "plr_big={plr_big}");
    }

    #[test]
    fn background_reduces_foreground_share() {
        let (free, _) = steady_throughput(4, 4, Background::Idle, 10);
        let (busy, _) = steady_throughput(4, 4, Background::Constant { gbps: 4.5 }, 10);
        assert!(busy < free - 0.7, "free={free} busy={busy}");
    }

    #[test]
    fn two_equal_flows_share_roughly_equally() {
        let mut s = sim(Background::Idle);
        let a = s.add_flow(4, 4, None);
        let b = s.add_flow(4, 4, None);
        for _ in 0..20 {
            s.run_mi(1.0);
        }
        let mut ta = 0.0;
        let mut tb = 0.0;
        for _ in 0..10 {
            let m = s.run_mi(1.0);
            ta += m[a.0].throughput_gbps;
            tb += m[b.0].throughput_gbps;
        }
        let ratio = ta / tb;
        assert!(ratio > 0.7 && ratio < 1.4, "ratio={ratio}");
    }

    #[test]
    fn more_streams_grab_bigger_share() {
        let mut s = sim(Background::Idle);
        let big = s.add_flow(6, 6, None);
        let small = s.add_flow(1, 2, None);
        for _ in 0..20 {
            s.run_mi(1.0);
        }
        let m = s.run_mi(1.0);
        assert!(m[big.0].throughput_gbps > 2.0 * m[small.0].throughput_gbps);
    }

    #[test]
    fn set_cc_p_changes_active_streams() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(4, 4, None);
        assert_eq!(s.active_streams(id), 16);
        s.set_cc_p(id, 2, 3);
        assert_eq!(s.active_streams(id), 6);
        s.set_cc_p(id, 6, 6);
        assert_eq!(s.active_streams(id), 36);
    }

    #[test]
    fn cc_p_clamped_to_config() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(100, 100, None);
        let max = (s.cfg.max_cc * s.cfg.max_p) as usize;
        assert_eq!(s.active_streams(id), max);
    }

    #[test]
    fn rtt_metric_tracks_congestion() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(1, 1, None);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let calm = s.run_mi(1.0)[id.0].rtt_s;
        s.set_cc_p(id, 16, 16);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let busy = s.run_mi(1.0)[id.0].rtt_s;
        assert!(busy > calm, "calm={calm} busy={busy}");
    }

    #[test]
    fn demand_cap_limits_throughput() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(4, 4, None);
        s.set_demand_cap(id, 1.5);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let m = s.run_mi(1.0);
        assert!(m[id.0].throughput_gbps <= 1.6);
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let run = || {
            let mut s = NetworkSim::new(Testbed::chameleon(), 7)
                .with_background(Background::Constant { gbps: 2.0 });
            let id = s.add_flow(3, 3, None);
            let mut total = 0.0;
            for _ in 0..20 {
                total += s.run_mi(1.0)[id.0].throughput_gbps;
            }
            total
        };
        assert_eq!(run(), run());
    }
}
