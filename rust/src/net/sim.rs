//! Multi-flow network simulator: streams × tasks × flows over a shared path.
//!
//! A *flow* is one transfer application (one SPARTA agent or baseline tool)
//! holding `cc` file-tasks with `p` TCP streams each. All flows traverse the
//! same multi-segment [`Topology`] (sender NIC → shared WAN → receiver I/O in
//! the general case; a single WAN bottleneck for the testbed presets). Each
//! segment is an independent droptail [`super::Link`] with its own optional
//! cross traffic, so flows can bottleneck at different stages. Each call to
//! [`NetworkSim::run_mi`] advances one monitoring interval and returns the
//! end-host-observable metrics per flow — exactly the signal set the paper's
//! agents consume.
//!
//! The control plane consumes this simulator through the
//! [`super::Substrate`] trait rather than the concrete type.
//!
//! ## Arena layout
//!
//! Stream state lives in one flat struct-of-arrays [`StreamArena`]
//! (parallel `cwnd`/`w_max`/`ssthresh`/`epoch_t`/`since_cut` `f64` slices
//! plus `in_slow_start`/`active` flags) instead of the former
//! `Flow → Task → Vec<CubicStream>` nest. Each task owns a contiguous
//! **row** of `cfg.max_p` reserved slots ([`TaskRange`]); rows are
//! allocated once, when `set_cc_p` first grows a flow to that task, and
//! never move. Within a row, slots `0..created` have been materialized by
//! some past `(cc, p)` setting (matching the old loop's lazy
//! `Vec::push(CubicStream::new())` semantics exactly — a slot reserved but
//! never inside a `p` range is untouched fresh state), and the currently
//! *active* streams of a flow are exactly slots `0..p_active` of its first
//! `cc_active` task rows.
//!
//! ## §Perf invariants
//!
//! * `tick()` touches **only active slots**: the phase-1 rate pass and the
//!   phase-3 deliver/grow pass iterate `cc_active × p_active` per flow and
//!   never walk created-but-paused streams (the old loop walked every
//!   created stream and branched per slot).
//! * Per-flow active-stream counts and the arena-wide total are maintained
//!   **incrementally** by `add_flow`/`set_cc_p`; nothing on the tick path
//!   recounts streams or task rows.
//! * The tick path is **allocation-free** at steady state: the per-stream
//!   rate scratch is reused across ticks (capacity = total active
//!   streams), and [`NetworkSim::run_mi_into`] writes metrics into a
//!   caller-owned buffer ([`NetworkSim::run_mi`] is the allocating compat
//!   wrapper).
//! * Results are **bit-identical** to the pre-arena loop, which is kept
//!   in-tree as [`super::baseline::BaselineSim`]: same float-op order (the
//!   skipped inactive slots only ever contributed exact `+ 0.0` terms),
//!   same RNG draw sequence (backgrounds, per-active-stream loss events,
//!   per-flow RTT noise). `tests/golden_replay.rs` enforces this
//!   byte-for-byte on whole sessions; do not reorder arithmetic here
//!   without updating the baseline contract.
//! * The tick is **batched**: per-active-slot work runs as contiguous
//!   slice passes over the arena's parallel state vectors rather than
//!   per-slot method calls. Phase 1 is a per-row vectorizable rate pass
//!   ([`StreamArena::rates_into`]); phase 3 splits the old interleaved
//!   per-slot loop into (a) one batched RNG draw
//!   ([`crate::util::Rng::fill_f64`]) plus a loss-probability pass that
//!   pre-gathers the slots to cut, (b) a loss-cut pass over that mask
//!   only, and (c) a per-row growth pass ([`StreamArena::grow_row`]).
//!   This reordering is bit-exact because slots are independent, growth
//!   consumes no randomness, per-slot cut-before-grow order is kept, and
//!   the tick-constant `drop_frac > 0` branch hoists without changing
//!   which streams draw: the batched fill consumes the generator exactly
//!   as the per-stream `chance()` calls did.
//! * **Adding a new tick phase**: keep reductions in flow-major scratch
//!   order with left-to-right accumulation, draw any randomness as one
//!   `fill_f64` over the active total (stream order), and mutate arena
//!   state only through row passes that preserve the scalar op order —
//!   then extend `arena_matches_baseline_sim_bit_for_bit` (and the
//!   baseline, if the physics changed) before trusting golden replay.
//! * The only sanctioned departure from bit-identity is
//!   [`SimConfig::reassociate_sums`] (default **off**): the per-flow
//!   offered/delivered reductions switch to a chunked four-accumulator
//!   sum and the sent/lost totals are factored through the flow rate sum,
//!   letting LLVM vectorize the reductions at the cost of float
//!   re-association. That path is excluded from golden replay and is
//!   instead tolerance-bounded by `reassociated_sums_stay_within_tolerance`.

use super::background::{Background, BackgroundState};
use super::link::Link;
use super::stream::{ArenaState, StreamArena};
use super::testbed::Testbed;
use super::topology::Topology;
use super::MSS_BITS;
use crate::util::Rng;

/// A captured [`NetworkSim`] at a monitoring-interval boundary — everything
/// the tick loop mutates (flows incl. their arena row tables, the arena
/// itself, per-segment queues and background runtime state, the RNG, and
/// the clock). The per-MI `acc_*` accumulators are reset at the start of
/// every [`NetworkSim::run_mi_into`], so a boundary capture omits them;
/// per-tick scratch buffers are likewise rebuilt on demand. Restoring into
/// a sim rebuilt with the same topology and admit sequence resumes the
/// exact tick/RNG trajectory (the serve snapshot contract).
#[derive(Debug, Clone, PartialEq)]
pub struct SimState {
    pub time_s: f64,
    pub rng: [u64; 4],
    pub active_total: usize,
    pub flows: Vec<FlowState>,
    pub segments: Vec<SegmentState>,
    pub arena: ArenaState,
}

/// One flow's captured state: its arena row table `(base, created, cap)`
/// per task plus the active counts and rate caps. Row indices refer to the
/// captured [`SimState::arena`] layout, which is imported wholesale.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowState {
    pub tasks: Vec<(usize, usize, usize)>,
    pub cc_active: usize,
    pub p_active: usize,
    pub active_streams: usize,
    pub task_io_gbps: f64,
    pub stream_cap_gbps: f64,
    pub demand_cap_gbps: f64,
}

/// One path stage's captured runtime state: droptail queue occupancy plus
/// the cross-traffic process state when the stage has one.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentState {
    pub queue_bits: f64,
    /// `(bursty_high, responsive_scale)` of the stage's background, if any.
    pub background: Option<(bool, f64)>,
}

/// Identifies a flow within a [`NetworkSim`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(pub usize);

/// Simulator tick configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Fluid-model tick, seconds.
    pub tick_s: f64,
    /// Std-dev of RTT measurement noise, in **seconds** (the default models
    /// ~0.4 ms of kernel timestamping jitter; the
    /// `rtt_noise_magnitude_is_sub_millisecond` regression test pins the
    /// unit).
    pub rtt_noise_s: f64,
    /// Maximum concurrent tasks / streams-per-task a flow may use. Also
    /// the arena row capacity reserved per task at creation, so raising
    /// `max_p` after flows were added does not widen their existing rows.
    pub max_cc: u32,
    pub max_p: u32,
    /// Opt out of the §Perf bit-identity contract for the tick's
    /// reduction sums (default **off**). When set, per-flow rate
    /// reductions use a chunked four-accumulator sum and the per-tick
    /// sent/lost/delivered totals are factored through the flow rate sum
    /// — re-associated float arithmetic LLVM can vectorize. Results then
    /// differ from [`super::baseline::BaselineSim`] only by reduction
    /// rounding (bounded by `reassociated_sums_stay_within_tolerance`);
    /// everything outside these sums keeps the exact scalar op order.
    pub reassociate_sums: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            tick_s: 0.05,
            rtt_noise_s: 0.0004,
            max_cc: 32,
            max_p: 32,
            reassociate_sums: false,
        }
    }
}

/// Left-to-right reduction — the §Perf default, matching the baseline
/// loop's accumulation order bit-for-bit.
#[inline]
fn sum_ordered(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Chunked four-accumulator reduction: re-associates the adds so the
/// loop vectorizes. Reachable only behind [`SimConfig::reassociate_sums`].
#[inline]
fn sum_reassociated(xs: &[f64]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut chunks = xs.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += c[0];
        acc[1] += c[1];
        acc[2] += c[2];
        acc[3] += c[3];
    }
    let mut tail = 0.0;
    for &x in chunks.remainder() {
        tail += x;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// One file-task's contiguous slot row in the stream arena.
#[derive(Debug, Clone, Copy)]
struct TaskRange {
    /// Arena index of the row's first slot.
    base: usize,
    /// Slots materialized so far (prefix; grows monotonically with the
    /// largest `p` this task has seen).
    created: usize,
    /// Reserved row width (`cfg.max_p` at creation time).
    cap: usize,
}

/// One transfer application's traffic (stream state lives in the arena).
#[derive(Debug, Clone)]
struct Flow {
    tasks: Vec<TaskRange>,
    /// Admitted tasks (prefix of `tasks`).
    cc_active: usize,
    /// Active streams per admitted task (uniform across admitted tasks).
    p_active: usize,
    /// Cached `cc_active * p_active` — the tick path and per-MI metrics
    /// never recount.
    active_streams: usize,
    /// Per-task application I/O rate cap (engine property), Gbps.
    task_io_gbps: f64,
    /// Per-stream receiver-window rate cap, Gbps.
    stream_cap_gbps: f64,
    /// Optional cap on total demand (e.g. job nearly complete), Gbps.
    demand_cap_gbps: f64,
    // Per-MI accumulators.
    acc_delivered_bits: f64,
    acc_sent_bits: f64,
    acc_lost_bits: f64,
    acc_rtt_sum: f64,
    acc_rtt_n: u64,
}

/// Apply a (cc, p) setting to `flow`: tasks/streams beyond the new limits
/// are *paused* (keeping TCP state in the arena), previously paused ones
/// are *resumed* — the paper's pause/resume thread semantics. New task
/// rows are reserved on first use; slots first covered by a `p` range are
/// materialized fresh, exactly as the old loop lazily pushed
/// `CubicStream::new()`.
fn apply_cc_p(arena: &mut StreamArena, flow: &mut Flow, cc: u32, p: u32, max_cc: u32, max_p: u32) {
    let cc = cc.clamp(1, max_cc) as usize;
    let p = p.clamp(1, max_p) as usize;
    while flow.tasks.len() < cc {
        // Reserve the full row up front; reserved-but-unmaterialized slots
        // hold untouched fresh state, so later materialization is a count
        // bump, not an initialization pass.
        let cap = max_p as usize;
        let base = arena.push_fresh(cap);
        flow.tasks.push(TaskRange { base, created: 0, cap });
    }
    // Rows are `cfg.max_p` wide at creation and `p` is clamped to that
    // same config, so normally every active row can hold `p` slots. If
    // `cfg.max_p` was raised after rows were reserved (unsupported for
    // determinism), the active width is clamped to the narrowest active
    // row so the tick can never walk past a row into its neighbor.
    let p = flow.tasks[..cc].iter().map(|t| t.cap).fold(p, usize::min);
    for (i, task) in flow.tasks.iter_mut().enumerate() {
        let task_active = i < cc;
        let p_row = p.min(task.cap);
        if task.created < p_row {
            task.created = p_row;
        }
        for j in 0..task.created {
            let slot = task.base + j;
            if task_active && j < p {
                arena.resume(slot);
            } else {
                arena.pause(slot);
            }
        }
    }
    flow.cc_active = cc;
    flow.p_active = p;
    flow.active_streams = cc * p;
}

/// End-host-observable metrics for one flow over one monitoring interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiMetrics {
    /// Goodput over the MI, Gbps.
    pub throughput_gbps: f64,
    /// Packet loss rate over the MI (lost / sent).
    pub plr: f64,
    /// Mean measured RTT over the MI, seconds (with measurement noise).
    pub rtt_s: f64,
    /// Bytes delivered during the MI.
    pub bytes_delivered: f64,
    /// Number of active streams during the MI (cc × p, post-clamp).
    pub active_streams: usize,
    /// MI duration, seconds.
    pub duration_s: f64,
}

/// One path stage at runtime: its droptail link plus optional cross traffic.
struct Segment {
    name: &'static str,
    link: Link,
    /// Healthy capacity, Gbps — the reference point fault injection
    /// ([`NetworkSim::fault_segment`]) scales against, so repeated
    /// degrade/heal cycles cannot drift.
    nominal_gbps: f64,
    background: Option<BackgroundState>,
}

/// The shared-path simulator (arena-backed; see the module docs).
pub struct NetworkSim {
    pub cfg: SimConfig,
    segments: Vec<Segment>,
    /// Index of the shared WAN stage ([`NetworkSim::with_background`] target).
    wan_idx: usize,
    flows: Vec<Flow>,
    /// Flat SoA stream state; task rows index into it (§Perf).
    arena: StreamArena,
    /// Σ over flows of `active_streams`, maintained incrementally — sizes
    /// the rate scratch without recounting.
    active_total: usize,
    time_s: f64,
    rng: Rng,
    testbed: Testbed,
    /// Reusable per-tick scratch of per-**active**-stream desired rates
    /// (flow-major, task-major, stream-major) — §Perf: the tick loop is
    /// allocation-free at steady state.
    scratch: Vec<f64>,
    /// Reusable per-tick batched loss draws, aligned with `scratch`
    /// (one uniform per active stream whenever the path dropped).
    loss_u: Vec<f64>,
    /// Reusable pre-gathered loss mask: arena slots whose loss-event draw
    /// fired this tick, cut in a separate batched phase.
    cut_slots: Vec<usize>,
}

impl NetworkSim {
    /// Build a single-bottleneck simulator for a testbed preset with its
    /// default background (the seed simulator's shape).
    pub fn new(testbed: Testbed, seed: u64) -> NetworkSim {
        let topology = Topology::single(&testbed);
        NetworkSim::from_topology(testbed, &topology, seed)
    }

    /// Build a simulator over an explicit multi-segment topology. A WAN
    /// segment without its own cross traffic inherits the testbed's default
    /// background; other segments default to idle.
    pub fn from_topology(testbed: Testbed, topology: &Topology, seed: u64) -> NetworkSim {
        let wan_idx = topology.wan_index();
        let segments: Vec<Segment> = topology
            .segments
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let bg = spec
                    .background
                    .clone()
                    .or_else(|| (i == wan_idx).then(|| testbed.default_background.clone()));
                let link = spec.link();
                Segment {
                    name: spec.name,
                    nominal_gbps: link.capacity_gbps,
                    link,
                    background: bg.map(Background::into_state),
                }
            })
            .collect();
        NetworkSim {
            cfg: SimConfig::default(),
            segments,
            wan_idx,
            flows: Vec::new(),
            arena: StreamArena::new(),
            active_total: 0,
            time_s: 0.0,
            rng: Rng::new(seed),
            testbed,
            scratch: Vec::new(),
            loss_u: Vec::new(),
            cut_slots: Vec::new(),
        }
    }

    /// Replace the WAN stage's cross-traffic process.
    pub fn with_background(mut self, bg: Background) -> NetworkSim {
        self.segments[self.wan_idx].background = Some(bg.into_state());
        self
    }

    pub fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    pub fn time_s(&self) -> f64 {
        self.time_s
    }

    /// Capacity hint for `n` additional flows: reserves the flow table and
    /// enough arena slots for each flow's worst-case stream rows
    /// (`max_p` per flow). A pure capacity hint — never affects results.
    pub fn reserve_flows(&mut self, n: usize) {
        self.flows.reserve(n);
        self.arena.reserve(n * self.cfg.max_p as usize);
    }

    /// Add a flow with an engine-specific per-task I/O cap; returns its id.
    /// `task_io_gbps = None` uses the testbed's efficient-engine default.
    pub fn add_flow(&mut self, cc: u32, p: u32, task_io_gbps: Option<f64>) -> FlowId {
        let io = task_io_gbps.unwrap_or(self.testbed.task_io_gbps);
        let mut f = Flow {
            tasks: Vec::new(),
            cc_active: 0,
            p_active: 0,
            active_streams: 0,
            task_io_gbps: io,
            stream_cap_gbps: self.testbed.per_stream_cap_gbps,
            demand_cap_gbps: f64::MAX,
            acc_delivered_bits: 0.0,
            acc_sent_bits: 0.0,
            acc_lost_bits: 0.0,
            acc_rtt_sum: 0.0,
            acc_rtt_n: 0,
        };
        apply_cc_p(&mut self.arena, &mut f, cc, p, self.cfg.max_cc, self.cfg.max_p);
        self.active_total += f.active_streams;
        self.flows.push(f);
        FlowId(self.flows.len() - 1)
    }

    /// Apply a (cc, p) update to a flow (pause/resume semantics). Borrows
    /// the clamp bounds out of `cfg` up front instead of cloning the whole
    /// config per call, and keeps the incremental active-stream totals.
    pub fn set_cc_p(&mut self, id: FlowId, cc: u32, p: u32) {
        let (max_cc, max_p) = (self.cfg.max_cc, self.cfg.max_p);
        let flow = &mut self.flows[id.0];
        self.active_total -= flow.active_streams;
        apply_cc_p(&mut self.arena, flow, cc, p, max_cc, max_p);
        self.active_total += flow.active_streams;
    }

    /// Cap a flow's total demand (Gbps) — used when a job is nearly done.
    pub fn set_demand_cap(&mut self, id: FlowId, gbps: f64) {
        self.flows[id.0].demand_cap_gbps = gbps;
    }

    /// Number of currently active streams of a flow (cached; never
    /// recounted).
    pub fn active_streams(&self, id: FlowId) -> usize {
        self.flows[id.0].active_streams
    }

    /// Current ground-truth path RTT: the sum of every segment's propagation
    /// and queueing delay (for tests/telemetry).
    pub fn link_rtt_s(&self) -> f64 {
        self.segments.iter().map(|s| s.link.rtt_s()).sum()
    }

    /// Per-segment (name, queue-fill) snapshots in path order, borrowed —
    /// no allocation per call (collect if a snapshot is needed).
    pub fn segment_queue_fills(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.segments.iter().map(|s| (s.name, s.link.queue_fill()))
    }

    /// Fault injection ([`crate::faults`]): rescale every segment named
    /// `segment` to `scale` × its nominal (healthy) capacity. `1.0` heals;
    /// `0.0` is clamped to [`crate::faults::MIN_SEGMENT_SCALE`] so the
    /// droptail queue-delay math stays finite on a fully cut link. Draws
    /// no randomness and touches nothing when the name does not match, so
    /// installing a fault plan cannot perturb the golden replay. Returns
    /// whether any segment matched.
    pub fn fault_segment(&mut self, segment: &str, scale: f64) -> bool {
        let mut hit = false;
        for s in self.segments.iter_mut().filter(|s| s.name == segment) {
            s.link.capacity_gbps = s.nominal_gbps * scale.max(crate::faults::MIN_SEGMENT_SCALE);
            hit = true;
        }
        hit
    }

    /// Capture the complete mutable simulator state at an MI boundary (see
    /// [`SimState`] for what is and is not included).
    pub fn save_state(&self) -> SimState {
        SimState {
            time_s: self.time_s,
            rng: self.rng.state(),
            active_total: self.active_total,
            flows: self
                .flows
                .iter()
                .map(|f| FlowState {
                    tasks: f.tasks.iter().map(|t| (t.base, t.created, t.cap)).collect(),
                    cc_active: f.cc_active,
                    p_active: f.p_active,
                    active_streams: f.active_streams,
                    task_io_gbps: f.task_io_gbps,
                    stream_cap_gbps: f.stream_cap_gbps,
                    demand_cap_gbps: f.demand_cap_gbps,
                })
                .collect(),
            segments: self
                .segments
                .iter()
                .map(|s| SegmentState {
                    queue_bits: s.link.queue_bits(),
                    background: s.background.as_ref().map(BackgroundState::runtime_state),
                })
                .collect(),
            arena: self.arena.export_state(),
        }
    }

    /// Restore a [`SimState`] captured from a sim built with the same
    /// topology and `add_flow` sequence. Flow row tables, the arena, link
    /// queues, background runtime state, the RNG and the clock are all
    /// overwritten wholesale; per-MI accumulators are left to their
    /// start-of-MI reset. Returns `false` (sim untouched) when the flow or
    /// segment counts disagree with the capture.
    pub fn load_state(&mut self, state: &SimState) -> bool {
        if self.flows.len() != state.flows.len() || self.segments.len() != state.segments.len() {
            return false;
        }
        for (flow, fs) in self.flows.iter_mut().zip(&state.flows) {
            flow.tasks = fs
                .tasks
                .iter()
                .map(|&(base, created, cap)| TaskRange { base, created, cap })
                .collect();
            flow.cc_active = fs.cc_active;
            flow.p_active = fs.p_active;
            flow.active_streams = fs.active_streams;
            flow.task_io_gbps = fs.task_io_gbps;
            flow.stream_cap_gbps = fs.stream_cap_gbps;
            flow.demand_cap_gbps = fs.demand_cap_gbps;
        }
        for (seg, ss) in self.segments.iter_mut().zip(&state.segments) {
            seg.link.set_queue_bits(ss.queue_bits);
            if let (Some(bg), Some((high, scale))) = (seg.background.as_mut(), ss.background) {
                bg.set_runtime_state(high, scale);
            }
        }
        self.arena.import_state(&state.arena);
        self.active_total = state.active_total;
        self.time_s = state.time_s;
        self.rng = Rng::from_state(state.rng);
        true
    }

    /// Advance one tick of the fluid model. §Perf: walks active slots
    /// only, as batched slice passes (see the module docs); bit-identical
    /// to [`super::baseline::BaselineSim`]'s tick unless
    /// [`SimConfig::reassociate_sums`] is set.
    fn tick(&mut self) {
        let NetworkSim {
            cfg, segments, flows, arena, active_total, time_s, rng, scratch, loss_u, cut_slots, ..
        } = self;
        let dt = cfg.tick_s;
        let reassoc = cfg.reassociate_sums;
        let rtt: f64 = segments.iter().map(|s| s.link.rtt_s()).sum();

        // Phase 1: batched per-row rate passes into the reusable flat
        // scratch (flow-major, task-major, stream-major), then one
        // reduction per flow. Inactive slots contributed exact `+ 0.0`
        // terms in the old loop, so skipping them entirely preserves
        // every sum bit-for-bit; the ordered reduction repeats the old
        // interleaved accumulation order exactly.
        scratch.clear();
        scratch.resize(*active_total, 0.0);
        let mut offered_total = 0.0;
        let mut idx = 0usize;
        for flow in flows.iter() {
            let flow_start = idx;
            let io_share = flow.task_io_gbps / flow.p_active as f64;
            for task in &flow.tasks[..flow.cc_active] {
                arena.rates_into(
                    task.base,
                    rtt,
                    flow.stream_cap_gbps,
                    io_share,
                    &mut scratch[idx..idx + flow.p_active],
                );
                idx += flow.p_active;
            }
            let flow_rates = &mut scratch[flow_start..idx];
            let mut per_flow =
                if reassoc { sum_reassociated(flow_rates) } else { sum_ordered(flow_rates) };
            // Demand cap: scale all stream rates down proportionally.
            if per_flow > flow.demand_cap_gbps {
                let scale = flow.demand_cap_gbps / per_flow;
                for r in flow_rates.iter_mut() {
                    *r *= scale;
                }
                per_flow = flow.demand_cap_gbps;
            }
            offered_total += per_flow;
        }
        debug_assert_eq!(idx, *active_total);

        // Phase 2: carry the aggregate through every path stage in order.
        // Each stage's drops thin the foreground before the next stage sees
        // it; a stage's cross traffic joins (and exits) at that stage only.
        let now = *time_s;
        let mut fg_in = offered_total;
        // Cumulative foreground drop fraction across the path, accumulated as
        // d ← d + (1 − d)·dᵢ so a single-segment path yields the segment's
        // own drop_frac bit-for-bit (the seed simulator's value).
        let mut fg_drop = 0.0;
        for seg in segments.iter_mut() {
            let bg_rate = match seg.background.as_mut() {
                Some(bg) => bg.rate_gbps(now, dt, rng),
                None => 0.0,
            };
            let outcome = seg.link.tick(fg_in + bg_rate, dt);
            if let Some(bg) = seg.background.as_mut() {
                bg.observe_loss(outcome.drop_frac, dt);
            }
            fg_in *= outcome.accept_frac;
            fg_drop += (1.0 - fg_drop) * outcome.drop_frac;
        }
        let drop_frac = fg_drop.clamp(0.0, 1.0);
        let rtt_after: f64 = segments.iter().map(|s| s.link.rtt_s()).sum();

        // Phase 3a: loss events, batched. `drop_frac` is tick-constant,
        // so the old per-stream `if drop_frac > 0.0` branch hoists; one
        // `fill_f64` call pre-draws the per-active-stream uniforms in
        // the exact sequence the old per-stream `chance()` calls
        // consumed (the loss-event probability is that at least one of
        // the stream's packets this tick was dropped). The slots whose
        // draw fired become the pre-gathered loss mask.
        cut_slots.clear();
        if drop_frac > 0.0 && *active_total > 0 {
            loss_u.clear();
            loss_u.resize(*active_total, 0.0);
            rng.fill_f64(loss_u);
            let mut idx = 0usize;
            for flow in flows.iter() {
                for task in &flow.tasks[..flow.cc_active] {
                    for j in 0..flow.p_active {
                        let sent_bits = scratch[idx] * 1e9 * dt;
                        let pkts = sent_bits / MSS_BITS;
                        let p_event = 1.0 - (1.0 - drop_frac).powf(pkts.max(0.0));
                        if loss_u[idx] < p_event {
                            cut_slots.push(task.base + j);
                        }
                        idx += 1;
                    }
                }
            }
        }
        // Phase 3b: cut exactly the masked slots (rare at steady state;
        // everything else never touches the cut fields this tick).
        for &slot in cut_slots.iter() {
            arena.on_loss(slot, rtt_after);
        }
        // Phase 3c: batched per-row growth over post-cut state, then one
        // accounting reduction per flow (same scratch walk order as
        // phase 1). Slots are independent and growth draws no
        // randomness, so running all cuts before all growth preserves
        // the old per-slot cut-then-grow order bit-for-bit.
        let mut idx = 0usize;
        for flow in flows.iter_mut() {
            let io_share = flow.task_io_gbps / flow.p_active as f64;
            let caps = flow.stream_cap_gbps.min(io_share);
            let flow_start = idx;
            for task in &flow.tasks[..flow.cc_active] {
                arena.grow_row(
                    task.base,
                    &scratch[idx..idx + flow.p_active],
                    dt,
                    rtt_after,
                    caps,
                );
                idx += flow.p_active;
            }
            let flow_rates = &scratch[flow_start..idx];
            if reassoc {
                // Factored through the flow rate sum (re-associated and
                // distributed): Σ rate·1e9·dt ≡ (Σ rate)·1e9·dt up to
                // rounding.
                let sent = sum_reassociated(flow_rates) * 1e9 * dt;
                let lost = sent * drop_frac;
                flow.acc_delivered_bits += sent - lost;
                flow.acc_sent_bits += sent;
                flow.acc_lost_bits += lost;
            } else {
                let mut delivered = 0.0;
                let mut sent = 0.0;
                let mut lost = 0.0;
                for &rate in flow_rates {
                    let sent_bits = rate * 1e9 * dt;
                    let lost_bits = sent_bits * drop_frac;
                    delivered += sent_bits - lost_bits;
                    sent += sent_bits;
                    lost += lost_bits;
                }
                flow.acc_delivered_bits += delivered;
                flow.acc_sent_bits += sent;
                flow.acc_lost_bits += lost;
            }
            flow.acc_rtt_sum += rtt_after;
            flow.acc_rtt_n += 1;
        }
        *time_s += dt;
    }

    /// Run one monitoring interval of `dur_s` seconds, writing per-flow
    /// metrics (flow-id order) into the caller-reused `out` buffer — the
    /// allocation-free primitive behind [`NetworkSim::run_mi`].
    pub fn run_mi_into(&mut self, dur_s: f64, out: &mut Vec<MiMetrics>) {
        out.clear();
        for f in &mut self.flows {
            f.acc_delivered_bits = 0.0;
            f.acc_sent_bits = 0.0;
            f.acc_lost_bits = 0.0;
            f.acc_rtt_sum = 0.0;
            f.acc_rtt_n = 0;
        }
        let ticks = (dur_s / self.cfg.tick_s).round().max(1.0) as usize;
        for _ in 0..ticks {
            self.tick();
        }
        let actual_dur = ticks as f64 * self.cfg.tick_s;
        let noise = self.cfg.rtt_noise_s;
        let fallback_rtt = self.link_rtt_s();
        out.reserve(self.flows.len());
        let NetworkSim { flows, rng, .. } = self;
        for f in flows.iter() {
            let thr = f.acc_delivered_bits / actual_dur / 1e9;
            let plr = if f.acc_sent_bits > 0.0 { f.acc_lost_bits / f.acc_sent_bits } else { 0.0 };
            let rtt =
                if f.acc_rtt_n > 0 { f.acc_rtt_sum / f.acc_rtt_n as f64 } else { fallback_rtt };
            let rtt_noisy = (rtt + rng.normal_mean_sd(0.0, noise)).max(1e-4);
            out.push(MiMetrics {
                throughput_gbps: thr,
                plr,
                rtt_s: rtt_noisy,
                bytes_delivered: f.acc_delivered_bits / 8.0,
                active_streams: f.active_streams,
                duration_s: actual_dur,
            });
        }
    }

    /// Run one monitoring interval of `dur_s` seconds; returns per-flow
    /// metrics in flow-id order (allocating compat wrapper over
    /// [`NetworkSim::run_mi_into`]).
    pub fn run_mi(&mut self, dur_s: f64) -> Vec<MiMetrics> {
        let mut out = Vec::with_capacity(self.flows.len());
        self.run_mi_into(dur_s, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::background::Background;
    use crate::net::baseline::BaselineSim;
    use crate::net::Substrate;

    fn sim(bg: Background) -> NetworkSim {
        NetworkSim::new(Testbed::chameleon(), 42).with_background(bg)
    }

    /// Warm up (slow start + convergence), then measure average throughput.
    fn steady_throughput(cc: u32, p: u32, bg: Background, mis: usize) -> (f64, f64) {
        let mut s = sim(bg);
        let id = s.add_flow(cc, p, None);
        for _ in 0..15 {
            s.run_mi(1.0);
        }
        let mut thr = 0.0;
        let mut plr = 0.0;
        for _ in 0..mis {
            let m = s.run_mi(1.0);
            thr += m[id.0].throughput_gbps;
            plr += m[id.0].plr;
        }
        (thr / mis as f64, plr / mis as f64)
    }

    #[test]
    fn single_stream_is_rwnd_capped() {
        let (thr, _) = steady_throughput(1, 1, Background::Idle, 10);
        // cap = 1 Gbps per stream on chameleon
        assert!(thr > 0.7 && thr < 1.1, "thr={thr}");
    }

    #[test]
    fn parallelism_scales_until_io_cap() {
        let (t1, _) = steady_throughput(1, 1, Background::Idle, 10);
        let (t2, _) = steady_throughput(1, 2, Background::Idle, 10);
        let (t8, _) = steady_throughput(1, 8, Background::Idle, 10);
        assert!(t2 > t1 * 1.5, "t1={t1} t2={t2}");
        // One task's I/O cap is 3 Gbps on chameleon.
        assert!(t8 < 3.3, "t8={t8}");
        assert!(t8 > 2.4, "t8={t8}");
    }

    #[test]
    fn concurrency_and_parallelism_approach_capacity() {
        let (thr, _) = steady_throughput(4, 4, Background::Idle, 10);
        assert!(thr > 7.5, "thr={thr}");
        assert!(thr <= 10.0 + 1e-6);
    }

    #[test]
    fn oversubscription_raises_loss() {
        let (_, plr_small) = steady_throughput(2, 2, Background::Idle, 10);
        let (_, plr_big) = steady_throughput(16, 16, Background::Idle, 10);
        // 256 CUBIC streams on a 10G link sit at a small but clearly nonzero
        // equilibrium loss rate (Mathis: L ∝ (MSS/(RTT·T_stream))²).
        assert!(plr_big > plr_small, "small={plr_small} big={plr_big}");
        assert!(plr_big > 1e-5, "plr_big={plr_big}");
    }

    #[test]
    fn background_reduces_foreground_share() {
        let (free, _) = steady_throughput(4, 4, Background::Idle, 10);
        let (busy, _) = steady_throughput(4, 4, Background::Constant { gbps: 4.5 }, 10);
        assert!(busy < free - 0.7, "free={free} busy={busy}");
    }

    #[test]
    fn two_equal_flows_share_roughly_equally() {
        let mut s = sim(Background::Idle);
        let a = s.add_flow(4, 4, None);
        let b = s.add_flow(4, 4, None);
        for _ in 0..20 {
            s.run_mi(1.0);
        }
        let mut ta = 0.0;
        let mut tb = 0.0;
        for _ in 0..10 {
            let m = s.run_mi(1.0);
            ta += m[a.0].throughput_gbps;
            tb += m[b.0].throughput_gbps;
        }
        let ratio = ta / tb;
        assert!(ratio > 0.7 && ratio < 1.4, "ratio={ratio}");
    }

    #[test]
    fn more_streams_grab_bigger_share() {
        let mut s = sim(Background::Idle);
        let big = s.add_flow(6, 6, None);
        let small = s.add_flow(1, 2, None);
        for _ in 0..20 {
            s.run_mi(1.0);
        }
        let m = s.run_mi(1.0);
        assert!(m[big.0].throughput_gbps > 2.0 * m[small.0].throughput_gbps);
    }

    #[test]
    fn set_cc_p_changes_active_streams() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(4, 4, None);
        assert_eq!(s.active_streams(id), 16);
        s.set_cc_p(id, 2, 3);
        assert_eq!(s.active_streams(id), 6);
        s.set_cc_p(id, 6, 6);
        assert_eq!(s.active_streams(id), 36);
    }

    #[test]
    fn cc_p_clamped_to_config() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(100, 100, None);
        let max = (s.cfg.max_cc * s.cfg.max_p) as usize;
        assert_eq!(s.active_streams(id), max);
    }

    #[test]
    fn rtt_metric_tracks_congestion() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(1, 1, None);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let calm = s.run_mi(1.0)[id.0].rtt_s;
        s.set_cc_p(id, 16, 16);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let busy = s.run_mi(1.0)[id.0].rtt_s;
        assert!(busy > calm, "calm={calm} busy={busy}");
    }

    #[test]
    fn demand_cap_limits_throughput() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(4, 4, None);
        s.set_demand_cap(id, 1.5);
        for _ in 0..10 {
            s.run_mi(1.0);
        }
        let m = s.run_mi(1.0);
        assert!(m[id.0].throughput_gbps <= 1.6);
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let run = || {
            let mut s = NetworkSim::new(Testbed::chameleon(), 7)
                .with_background(Background::Constant { gbps: 2.0 });
            let id = s.add_flow(3, 3, None);
            let mut total = 0.0;
            for _ in 0..20 {
                total += s.run_mi(1.0)[id.0].throughput_gbps;
            }
            total
        };
        assert_eq!(run(), run());
    }

    /// `run_mi_into` over a reused (dirty, over-capacity) buffer returns
    /// exactly what fresh allocation returns — the zero-alloc path is pure
    /// plumbing.
    #[test]
    fn run_mi_into_reuse_matches_fresh_allocation() {
        let build = || {
            let mut s = NetworkSim::new(Testbed::chameleon(), 11)
                .with_background(Background::Constant { gbps: 1.5 });
            s.add_flow(4, 4, None);
            s.add_flow(2, 8, None);
            s
        };
        let mut fresh = build();
        let mut reused = build();
        let mut buf: Vec<MiMetrics> = Vec::new();
        // Pre-dirty the buffer so clear/overwrite bugs would surface.
        buf.resize(
            7,
            MiMetrics {
                throughput_gbps: -1.0,
                plr: -1.0,
                rtt_s: -1.0,
                bytes_delivered: -1.0,
                active_streams: 999,
                duration_s: -1.0,
            },
        );
        for _ in 0..12 {
            let a = fresh.run_mi(1.0);
            reused.run_mi_into(1.0, &mut buf);
            assert_eq!(a.len(), buf.len());
            for (x, y) in a.iter().zip(buf.iter()) {
                assert_eq!(x.throughput_gbps.to_bits(), y.throughput_gbps.to_bits());
                assert_eq!(x.plr.to_bits(), y.plr.to_bits());
                assert_eq!(x.rtt_s.to_bits(), y.rtt_s.to_bits());
                assert_eq!(x.bytes_delivered.to_bits(), y.bytes_delivered.to_bits());
                assert_eq!(x.active_streams, y.active_streams);
                assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
            }
        }
    }

    /// The arena loop reproduces the frozen pre-arena baseline loop
    /// bit-for-bit through a churning (cc, p)/demand-cap script (the
    /// whole-session equivalent lives in `tests/golden_replay.rs`).
    #[test]
    fn arena_matches_baseline_sim_bit_for_bit() {
        let tb = Testbed::chameleon();
        let topo = crate::net::Topology::three_stage(&tb, 8.0, 6.0);
        let bursty =
            || Background::Bursty { low_gbps: 0.5, high_gbps: 5.0, switch_prob: 0.2 };
        let mut arena =
            NetworkSim::from_topology(tb.clone(), &topo, 23).with_background(bursty());
        let mut base: BaselineSim =
            BaselineSim::from_topology(tb, &topo, 23).with_background(bursty());
        let a0 = arena.add_flow(4, 4, None);
        let b0 = Substrate::add_flow(&mut base, 4, 4, None);
        assert_eq!(a0, b0);
        let a1 = arena.add_flow(2, 8, Some(2.0));
        Substrate::add_flow(&mut base, 2, 8, Some(2.0));
        // A churn script that exercises grow/shrink, re-resume of kept
        // state, demand caps (incl. zero) and lazy row creation.
        let script: &[(u32, u32)] = &[(8, 8), (2, 2), (16, 4), (1, 16), (6, 6), (16, 16), (3, 3)];
        for (step, &(cc, p)) in script.iter().enumerate() {
            let ma = arena.run_mi(1.0);
            let mb = Substrate::run_mi(&mut base, 1.0);
            assert_eq!(ma.len(), mb.len());
            for (x, y) in ma.iter().zip(mb.iter()) {
                assert_eq!(
                    x.throughput_gbps.to_bits(),
                    y.throughput_gbps.to_bits(),
                    "step {step}: throughput diverged ({} vs {})",
                    x.throughput_gbps,
                    y.throughput_gbps
                );
                assert_eq!(x.plr.to_bits(), y.plr.to_bits(), "step {step}: plr diverged");
                assert_eq!(x.rtt_s.to_bits(), y.rtt_s.to_bits(), "step {step}: rtt diverged");
                assert_eq!(
                    x.bytes_delivered.to_bits(),
                    y.bytes_delivered.to_bits(),
                    "step {step}: bytes diverged"
                );
                assert_eq!(x.active_streams, y.active_streams, "step {step}: streams diverged");
            }
            arena.set_cc_p(a0, cc, p);
            Substrate::set_cc_p(&mut base, b0, cc, p);
            let cap = if step % 3 == 0 { 0.0 } else { 1.5 + step as f64 };
            arena.set_demand_cap(a1, cap);
            Substrate::set_demand_cap(&mut base, a1, cap);
            assert_eq!(
                arena.active_streams(a0),
                Substrate::active_streams(&base, b0),
                "step {step}: cached active count diverged"
            );
        }
    }

    /// The sanctioned bit-identity opt-out: with
    /// `cfg.reassociate_sums = true` the tick's reductions re-associate
    /// (chunked sums, factored sent/lost totals), so metrics may differ
    /// from the default path — but only by reduction rounding. Documented
    /// tolerance bound: ≤ 1e-9 relative on every per-MI metric across a
    /// churning multi-flow script (observed ~1e-12; the bound leaves
    /// headroom for feedback through the cwnd evolution). Exact-integer
    /// fields stay exact. RNG draw counts are unchanged, so the two paths
    /// stay in generator lockstep.
    #[test]
    fn reassociated_sums_stay_within_tolerance() {
        let tb = Testbed::chameleon();
        let topo = crate::net::Topology::three_stage(&tb, 8.0, 6.0);
        let bursty = || Background::Bursty { low_gbps: 0.5, high_gbps: 5.0, switch_prob: 0.2 };
        let build = |reassoc: bool| {
            let mut s =
                NetworkSim::from_topology(tb.clone(), &topo, 23).with_background(bursty());
            s.cfg.reassociate_sums = reassoc;
            s.add_flow(4, 4, None);
            s.add_flow(2, 8, Some(2.0));
            s
        };
        let mut exact = build(false);
        let mut reassoc = build(true);
        let a0 = FlowId(0);
        let a1 = FlowId(1);
        let script: &[(u32, u32)] = &[(8, 8), (2, 2), (16, 4), (1, 16), (6, 6), (16, 16), (3, 3)];
        let close = |a: f64, b: f64, what: &str, step: usize| {
            let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
            assert!(
                (a - b).abs() <= tol,
                "step {step}: {what} diverged beyond tolerance: {a} vs {b}"
            );
        };
        for (step, &(cc, p)) in script.iter().enumerate() {
            let ma = exact.run_mi(1.0);
            let mb = reassoc.run_mi(1.0);
            assert_eq!(ma.len(), mb.len());
            for (x, y) in ma.iter().zip(mb.iter()) {
                close(x.throughput_gbps, y.throughput_gbps, "throughput", step);
                close(x.plr, y.plr, "plr", step);
                close(x.rtt_s, y.rtt_s, "rtt", step);
                close(x.bytes_delivered, y.bytes_delivered, "bytes", step);
                assert_eq!(x.active_streams, y.active_streams, "step {step}: streams diverged");
                assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
            }
            exact.set_cc_p(a0, cc, p);
            reassoc.set_cc_p(a0, cc, p);
            let cap = if step % 3 == 0 { 0.0 } else { 1.5 + step as f64 };
            exact.set_demand_cap(a1, cap);
            reassoc.set_demand_cap(a1, cap);
        }
    }

    /// Regression (units audit): `rtt_noise_s` is *seconds*. The default
    /// 0.0004 s must show up as ~0.4 ms of measurement jitter — three orders
    /// of magnitude below a seconds-vs-milliseconds mixup.
    #[test]
    fn rtt_noise_magnitude_is_sub_millisecond() {
        let mut s = sim(Background::Idle);
        let id = s.add_flow(1, 1, None);
        for _ in 0..5 {
            s.run_mi(1.0);
        }
        // One-tick MIs: the measured RTT is a single ground-truth sample
        // plus measurement noise, so (measured − ground truth) isolates the
        // noise term (a 1×1 flow never builds a queue on a 10G link).
        let mut devs = Vec::new();
        for _ in 0..300 {
            let m = s.run_mi(0.05);
            devs.push(m[id.0].rtt_s - s.link_rtt_s());
        }
        let n = devs.len() as f64;
        let mean = devs.iter().sum::<f64>() / n;
        let var = devs.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / n;
        let sd = var.sqrt();
        let want = SimConfig::default().rtt_noise_s;
        assert!(mean.abs() < want, "noise should be zero-mean: mean={mean}");
        assert!(sd > 0.5 * want && sd < 2.0 * want, "sd={sd} want~{want}");
        // A seconds-vs-ms mixup would put sd near 0.4 s.
        assert!(sd < 0.002, "sd={sd} is not sub-millisecond");
    }

    #[test]
    fn receiver_limited_path_bottlenecks_at_rx() {
        let tb = Testbed::cloudlab();
        let topo = Topology::three_stage(&tb, tb.capacity_gbps, 5.0);
        let mut s = NetworkSim::from_topology(tb, &topo, 11).with_background(Background::Idle);
        let id = s.add_flow(8, 8, None);
        for _ in 0..15 {
            s.run_mi(1.0);
        }
        let mut thr = 0.0;
        for _ in 0..10 {
            thr += s.run_mi(1.0)[id.0].throughput_gbps;
        }
        thr /= 10.0;
        // Goodput pins to the 5 Gbps receiver stage, far below the 25G WAN.
        assert!(thr <= 5.0 + 1e-6, "thr={thr}");
        assert!(thr > 2.0, "thr={thr}");
        // And the WAN itself stays uncongested: the receiver stage, not the
        // WAN, carries whatever standing queue exists.
        let wan = s.segment_queue_fills().find(|(n, _)| *n == "wan").unwrap().1;
        let rx = s.segment_queue_fills().find(|(n, _)| *n == "rx").unwrap().1;
        assert!(rx >= wan, "rx={rx} wan={wan}");
        assert!(wan < 0.1, "wan queue should be empty: {wan}");
    }

    #[test]
    fn nic_limited_path_bottlenecks_at_sender() {
        let tb = Testbed::chameleon();
        let topo = Topology::three_stage(&tb, 3.0, tb.capacity_gbps);
        let mut s = NetworkSim::from_topology(tb, &topo, 13).with_background(Background::Idle);
        let id = s.add_flow(8, 8, None);
        for _ in 0..15 {
            s.run_mi(1.0);
        }
        let mut thr = 0.0;
        for _ in 0..10 {
            thr += s.run_mi(1.0)[id.0].throughput_gbps;
        }
        thr /= 10.0;
        assert!(thr <= 3.0 + 1e-6, "thr={thr}");
        assert!(thr > 1.2, "thr={thr}");
    }

    #[test]
    fn three_stage_rtt_sums_segments() {
        let tb = Testbed::chameleon();
        let topo = Topology::three_stage(&tb, 10.0, 10.0);
        let expected = topo.base_rtt_s();
        let s = NetworkSim::from_topology(tb, &topo, 1);
        assert!((s.link_rtt_s() - expected).abs() < 1e-12);
        assert!(s.link_rtt_s() > Testbed::chameleon().base_rtt_s);
    }

    #[test]
    fn multi_segment_determinism() {
        let run = || {
            let tb = Testbed::chameleon();
            let topo = Topology::three_stage(&tb, 6.0, 8.0).with_wan_background(
                Background::Bursty { low_gbps: 0.5, high_gbps: 5.0, switch_prob: 0.2 },
            );
            let mut s = NetworkSim::from_topology(tb, &topo, 23);
            let id = s.add_flow(4, 4, None);
            let mut total = 0.0;
            for _ in 0..20 {
                total += s.run_mi(1.0)[id.0].throughput_gbps;
            }
            total
        };
        assert_eq!(run(), run());
    }
}
