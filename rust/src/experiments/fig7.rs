//! Fig. 7: fairness of concurrent transfers (JFI timelines) in three
//! scenarios on the Chameleon preset.

use super::common::{make_optimizer, Scale, SpartaCtx};
use super::runner;
use crate::config::Paths;
use crate::coordinator::{LaneSpec, Session, DEFAULT_MAX_MIS};
use crate::net::Testbed;
use crate::runtime::WeightSnapshot;
use crate::telemetry::{ReportSink, Table};
use crate::transfer::TransferJob;
use crate::util::stats;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// One concurrent-transfer scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub methods: Vec<String>,
    /// Per-MI Jain's fairness index.
    pub jfi: Vec<f64>,
    /// Per-lane mean throughput.
    pub lane_throughput: Vec<(String, f64)>,
}

impl Scenario {
    pub fn avg_jfi(&self) -> f64 {
        stats::mean(&self.jfi)
    }

    /// Mean JFI after the convergence phase (second half of the run).
    pub fn converged_jfi(&self) -> f64 {
        let half = self.jfi.len() / 2;
        stats::mean(&self.jfi[half..])
    }

    /// Std-dev of JFI after convergence (SPARTA-T fluctuates more).
    pub fn jfi_std(&self) -> f64 {
        let half = self.jfi.len() / 2;
        stats::Summary::of(&self.jfi[half..]).std
    }
}

/// The paper's three scenarios: (a) 3 × SPARTA-T, (b) 3 × SPARTA-FE,
/// (c) SPARTA-FE + Falcon_MP + rclone.
pub fn scenarios() -> Vec<(&'static str, Vec<&'static str>)> {
    vec![
        ("3x sparta-t", vec!["sparta-t", "sparta-t", "sparta-t"]),
        ("3x sparta-fe", vec!["sparta-fe", "sparta-fe", "sparta-fe"]),
        ("mixed", vec!["sparta-fe", "falcon_mp", "rclone"]),
    ]
}

/// Run one concurrent scenario.
pub fn run_scenario(
    ctx: &SpartaCtx,
    name: &str,
    methods: &[&str],
    scale: Scale,
    seed: u64,
) -> Result<Scenario> {
    let (files, bytes) = scale.workload();
    let mut session = Session::builder(Testbed::chameleon()).seed(seed).build();
    for (i, method) in methods.iter().enumerate() {
        let (opt, engine, reward) = make_optimizer(ctx, method, seed ^ (i as u64 + 1))?;
        session.admit(
            LaneSpec::new(opt, TransferJob::files(files, bytes)).engine(engine).reward(reward),
        );
    }
    let mut sink = ReportSink::new();
    session.run_to_completion(DEFAULT_MAX_MIS, &mut sink);
    let report = sink.finish(session.time_s());
    Ok(Scenario {
        name: name.to_string(),
        methods: methods.iter().map(|s| s.to_string()).collect(),
        jfi: report.jfi_series.clone(),
        lane_throughput: report
            .lanes
            .iter()
            .map(|l| (l.name.clone(), l.avg_throughput_gbps()))
            .collect(),
    })
}

/// Run all three scenarios, sharded over `jobs` workers (each concurrent
/// scenario is an independent simulation). Takes [`Paths`] rather than a
/// loaded context: the PJRT runtime is thread-local, so every worker builds
/// its own — over one shared, read-only weight snapshot taken by the parent.
pub fn run(paths: &Paths, scale: Scale, seed: u64, jobs: usize) -> Result<Vec<Scenario>> {
    let specs = scenarios();
    // Snapshot only — the parent does not need a runtime of its own.
    let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
    let paths = paths.clone();
    runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(paths.clone(), snapshot.clone()),
        |worker_ctx, _i, (name, methods)| {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            run_scenario(ctx, name, methods, scale, seed)
        },
    )
    .into_iter()
    .collect()
}

pub fn print(scenarios: &[Scenario]) {
    println!("\nFig 7 — fairness of concurrent transfers (Chameleon, shared 10G):");
    let mut table = Table::new(&["scenario", "avg JFI", "converged JFI", "JFI std", "per-lane Gbps"]);
    for s in scenarios {
        let lanes = s
            .lane_throughput
            .iter()
            .map(|(n, t)| format!("{n}={t:.1}"))
            .collect::<Vec<_>>()
            .join(" ");
        table.row(vec![
            s.name.clone(),
            format!("{:.3}", s.avg_jfi()),
            format!("{:.3}", s.converged_jfi()),
            format!("{:.3}", s.jfi_std()),
            lanes,
        ]);
    }
    table.print();
}
