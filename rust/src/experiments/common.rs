//! Shared experiment plumbing: context, scales, optimizer factory and the
//! offline training pipeline.

use crate::agents::{make_agent, DrlOptimizer};
use crate::baselines::{FalconMp, StaticTool, TwoPhase};
use crate::config::Paths;
use crate::coordinator::{Optimizer, ParamBounds, RewardKind};
use crate::emulator::{ClusterEnv, Transition, TransitionStore};
use crate::net::Testbed;
use crate::runtime::{Runtime, WeightSnapshot, WeightStore};
use crate::scenarios::Scenario;
use crate::trainer::{
    collect_transitions, collect_transitions_scenario, train_offline, LiveEnv, TrainConfig,
    TrainStats,
};
use crate::transfer::EngineProfile;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Experiment size: `Quick` for tests/benches/CI, `Paper` for full runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Paper,
}

impl Scale {
    pub fn by_name(s: &str) -> Scale {
        if s == "paper" { Scale::Paper } else { Scale::Quick }
    }

    /// Evaluation workload: (files, bytes-per-file). The paper moves
    /// 1000 × 1 GB; Quick moves 48 × 256 MB — long enough for the online
    /// optimizers to converge and differentiate, ~80× faster than Paper.
    pub fn workload(&self) -> (usize, u64) {
        match self {
            Scale::Quick => (48, 256 << 20),
            Scale::Paper => (1000, 1 << 30),
        }
    }

    pub fn trials(&self) -> usize {
        match self {
            Scale::Quick => 2,
            Scale::Paper => 5,
        }
    }

    /// Exploration phase: (runs, MIs per run).
    pub fn explore(&self) -> (usize, usize) {
        match self {
            Scale::Quick => (3, 150),
            Scale::Paper => (9, 400),
        }
    }

    /// Offline training budget (env steps).
    pub fn train_steps(&self) -> usize {
        match self {
            Scale::Quick => 12_000,
            Scale::Paper => 60_000,
        }
    }

    /// Live validation/re-training budget after emulated training (the
    /// paper's Fig.-2 offline-online feedback loop).
    pub fn finetune_steps(&self) -> usize {
        match self {
            Scale::Quick => 4_000,
            Scale::Paper => 15_000,
        }
    }

    /// k-means cluster count for the emulator.
    pub fn clusters(&self) -> usize {
        match self {
            Scale::Quick => 48,
            Scale::Paper => 96,
        }
    }
}

/// Everything the experiments need: artifact runtime, data directories and
/// a read-only snapshot of the trained weights.
///
/// The snapshot is taken once at load time and shared behind an [`Arc`]:
/// parallel experiment workers each build their own `SpartaCtx` (the PJRT
/// runtime is thread-local) via [`SpartaCtx::with_snapshot`], but all read
/// trained parameters from the same in-memory snapshot, so evaluation never
/// touches the weights directory concurrently.
pub struct SpartaCtx {
    pub runtime: Runtime,
    pub paths: Paths,
    pub snapshot: Arc<WeightSnapshot>,
}

impl SpartaCtx {
    pub fn load(paths: Paths) -> Result<SpartaCtx> {
        let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
        SpartaCtx::with_snapshot(paths, snapshot)
    }

    /// Build a context around an existing (shared) weight snapshot — the
    /// per-worker constructor used by the parallel experiment runners.
    pub fn with_snapshot(paths: Paths, snapshot: Arc<WeightSnapshot>) -> Result<SpartaCtx> {
        let runtime = Runtime::load(&paths.artifacts)?;
        Ok(SpartaCtx { runtime, paths, snapshot })
    }

    /// Re-read the weights directory into a fresh snapshot (after a
    /// training phase wrote new files).
    pub fn refresh_snapshot(&mut self) -> Result<()> {
        self.snapshot = Arc::new(WeightSnapshot::load_dir(self.paths.weights())?);
        Ok(())
    }

    /// The *write* path for trained weights (training only; evaluation
    /// reads through [`SpartaCtx::snapshot`]).
    pub fn weight_store(&self) -> WeightStore {
        WeightStore::new(self.paths.weights())
    }

    /// Weight file name for an agent trained on a bare testbed.
    pub fn weight_name(algo: &str, reward: RewardKind) -> String {
        format!("{algo}_{}", reward.short().to_lowercase())
    }
}

/// Weight file name for an agent trained under a registered scenario —
/// scoped so scenario training never clobbers the bare-testbed defaults.
pub fn scoped_weight_name(algo: &str, reward: RewardKind, scenario: &str) -> String {
    format!("{}@{}", SpartaCtx::weight_name(algo, reward), scenario)
}

/// Expected flat-parameter length for `algo`: manifest-driven for the HLO
/// algorithms, 0 (= any length) for the self-sizing `linq` fallback core.
/// When the manifest has no entry for an HLO algorithm (no artifacts, or
/// the algorithm was removed), the check is also skipped — agent
/// construction fails right after with a clear missing-graph error, so no
/// wrong-length vector ever reaches an executing agent.
pub fn expected_params(ctx: &SpartaCtx, algo: &str) -> usize {
    if algo == crate::agents::FALLBACK_ALGO {
        return 0;
    }
    ctx.runtime.manifest.algo(algo).map(|a| a.n_params).unwrap_or(0)
}

/// Where the training pipeline explores, fine-tunes and (for the scenario
/// variant) scopes its weight names: a bare testbed — the seed behavior —
/// or a registered scenario's topology and cross traffic.
#[derive(Clone, Copy)]
pub enum TrainSource<'a> {
    Testbed(&'a Testbed),
    Scenario(&'a Scenario),
}

impl TrainSource<'_> {
    pub fn name(&self) -> &str {
        match self {
            TrainSource::Testbed(t) => t.name,
            TrainSource::Scenario(s) => s.name,
        }
    }

    /// Name the trained weights are saved under (see [`scoped_weight_name`]).
    pub fn weight_name(&self, algo: &str, reward: RewardKind) -> String {
        match self {
            TrainSource::Testbed(_) => SpartaCtx::weight_name(algo, reward),
            TrainSource::Scenario(s) => scoped_weight_name(algo, reward, s.name),
        }
    }

    fn transitions(&self, ctx: &SpartaCtx, scale: Scale, seed: u64) -> Result<Vec<Transition>> {
        match self {
            TrainSource::Testbed(t) => transitions_for(ctx, t, scale, seed),
            TrainSource::Scenario(s) => transitions_for_scenario(ctx, s, scale, seed),
        }
    }

    fn live_env(
        &self,
        reward: RewardKind,
        bounds: ParamBounds,
        history: usize,
        episode_len: usize,
        seed: u64,
    ) -> LiveEnv {
        match self {
            TrainSource::Testbed(t) => {
                LiveEnv::new((*t).clone(), reward, bounds, history, episode_len, seed)
            }
            TrainSource::Scenario(s) => {
                LiveEnv::for_scenario(s, reward, bounds, history, episode_len, seed)
            }
        }
    }
}

/// The six evaluated methods of Fig. 6.
pub const METHODS: [&str; 6] =
    ["rclone", "escp", "falcon_mp", "2-phase", "sparta-t", "sparta-fe"];

/// Build an optimizer + engine for a method name. SPARTA variants load
/// trained R_PPO weights (`sparta-t` = T/E reward, `sparta-fe` = F&E); DRL
/// algorithm names ("dqn", ..., with a `:fe`/`:te` suffix) load that
/// algorithm's trained weights for Fig. 4. Trained weights are read from
/// the context's in-memory [`WeightSnapshot`] — never from disk — so any
/// number of concurrent workers can build optimizers over one shared
/// snapshot.
pub fn make_optimizer(
    ctx: &SpartaCtx,
    method: &str,
    seed: u64,
) -> Result<(Box<dyn Optimizer>, EngineProfile, RewardKind)> {
    // `display` becomes the lane's reported name: SPARTA variants label
    // themselves "sparta-t"/"sparta-fe" rather than the underlying
    // "rppo-te"/"rppo-fe" core.
    let load = |algo: &str, kind: RewardKind, display: String| -> Result<Box<dyn Optimizer>> {
        let name = SpartaCtx::weight_name(algo, kind);
        let weights = ctx
            .snapshot
            .params(&name, expected_params(ctx, algo))
            .map_err(|e| anyhow!("{e} — train first: `sparta train --algo {algo} --reward {}`", kind.short()))?;
        let agent = make_agent(&ctx.runtime, algo, seed, Some(weights))?;
        // Deployment: frozen greedy policy plus the coordinator's
        // resume-guardrail (see DrlOptimizer::decide). Online tuning is
        // exercised separately by Fig. 5 / `sparta tune`.
        Ok(Box::new(DrlOptimizer::new(agent, display)))
    };

    Ok(match method {
        "rclone" => (
            Box::new(StaticTool::rclone()),
            EngineProfile::rclone(),
            RewardKind::ThroughputEnergy,
        ),
        "escp" => (
            Box::new(StaticTool::escp()),
            EngineProfile::escp(),
            RewardKind::ThroughputEnergy,
        ),
        "falcon_mp" => (
            Box::new(FalconMp::new()),
            EngineProfile::efficient(),
            RewardKind::FairnessEfficiency,
        ),
        "2-phase" => (
            Box::new(TwoPhase::new()),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        ),
        "sparta-t" => (
            load("rppo", RewardKind::ThroughputEnergy, "sparta-t".into())?,
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        ),
        "sparta-fe" => (
            load("rppo", RewardKind::FairnessEfficiency, "sparta-fe".into())?,
            EngineProfile::efficient(),
            RewardKind::FairnessEfficiency,
        ),
        other => {
            // "algo" or "algo:te"/"algo:fe" — a trained DRL agent.
            let (algo, kind) = match other.split_once(':') {
                Some((a, "fe")) => (a, RewardKind::FairnessEfficiency),
                Some((a, _)) => (a, RewardKind::ThroughputEnergy),
                None => (other, RewardKind::ThroughputEnergy),
            };
            let display = format!("{algo}-{}", kind.short().to_lowercase());
            (load(algo, kind, display)?, EngineProfile::efficient(), kind)
        }
    })
}

/// Load cached exploration transitions for a testbed, collecting and saving
/// them on first use.
pub fn transitions_for(ctx: &SpartaCtx, testbed: &Testbed, scale: Scale, seed: u64) -> Result<Vec<Transition>> {
    let path = ctx
        .paths
        .transitions()
        .join(format!("{}_{:?}", testbed.name, scale).to_lowercase());
    if let Ok(ts) = TransitionStore::load(&path) {
        if !ts.is_empty() {
            return Ok(ts);
        }
    }
    let (runs, mis) = scale.explore();
    crate::log_info!("collecting {} exploration runs x {} MIs on {}", runs, mis, testbed.name);
    let ts = collect_transitions(testbed, runs, mis, seed);
    TransitionStore::save(&path, &ts)?;
    // Round-trip through the store: saving quantizes f64 outcome fields to
    // f32, so returning the freshly-collected vector would differ (in the
    // last bits) from every later cache hit — reload so first use and cache
    // hits are bit-identical.
    TransitionStore::load(&path)
}

/// Like [`transitions_for`], but explored under a registered scenario's
/// topology and cross traffic (cached per scenario name).
pub fn transitions_for_scenario(
    ctx: &SpartaCtx,
    scenario: &Scenario,
    scale: Scale,
    seed: u64,
) -> Result<Vec<Transition>> {
    let path = ctx
        .paths
        .transitions()
        .join(format!("sc_{}_{:?}", scenario.name, scale).to_lowercase());
    if let Ok(ts) = TransitionStore::load(&path) {
        if !ts.is_empty() {
            return Ok(ts);
        }
    }
    let (runs, mis) = scale.explore();
    crate::log_info!(
        "collecting {} exploration runs x {} MIs under scenario {}",
        runs,
        mis,
        scenario.name
    );
    let ts = collect_transitions_scenario(scenario, runs, mis, seed);
    TransitionStore::save(&path, &ts)?;
    // Same round-trip as [`transitions_for`]: the store's f32 quantization
    // makes the cache canonical.
    TransitionStore::load(&path)
}

/// Full offline pipeline: transitions → cluster emulator → train → persist.
/// Returns the training stats (Table 1 rows are built from these).
///
/// The `source` picks where exploration and live fine-tuning happen: a bare
/// testbed (the seed behavior, weights saved as `algo_te`) or a registered
/// scenario's topology and cross traffic (weights saved scoped, e.g.
/// `rppo_te@lossy-wan` — see [`scoped_weight_name`]). Fully deterministic
/// for a given `(algo, reward, source, scale, seed)` tuple, which is what
/// lets `sparta generalize` shard training rows across workers.
pub fn train_pipeline(
    ctx: &SpartaCtx,
    algo: &str,
    reward: RewardKind,
    source: TrainSource<'_>,
    scale: Scale,
    seed: u64,
) -> Result<TrainStats> {
    let transitions = source.transitions(ctx, scale, seed ^ 0x7E57)?;
    let mut env = ClusterEnv::new(
        transitions,
        scale.clusters(),
        ParamBounds::default(),
        reward,
        8,
        64,
        seed,
    );
    let mut agent = make_agent(&ctx.runtime, algo, seed, None)?;
    let cfg = TrainConfig { max_env_steps: scale.train_steps(), ..TrainConfig::default() };
    let mut stats = train_offline(&mut agent, &mut env, &cfg);

    // Offline-online feedback loop (paper Fig. 2): after emulated training,
    // validate and re-train against the live substrate so the deployed
    // policy has seen real steady-state dynamics (the emulator's sampled
    // transitions under-represent perfectly calm links).
    let mut live = source.live_env(reward, ParamBounds::default(), 8, 48, seed ^ 0xF1E1D);
    let fine_cfg = TrainConfig { max_env_steps: scale.finetune_steps(), ..TrainConfig::default() };
    let fine = train_offline(&mut agent, &mut live, &fine_cfg);
    stats.wall_s += fine.wall_s;
    stats.env_steps += fine.env_steps;
    stats.train_calls = agent.train_steps();
    stats.energy_kj += fine.energy_kj;

    let store = ctx.weight_store();
    store.save(&source.weight_name(algo, reward), agent.params())?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing_and_workloads() {
        assert_eq!(Scale::by_name("paper"), Scale::Paper);
        assert_eq!(Scale::by_name("anything-else"), Scale::Quick);
        let (files, bytes) = Scale::Paper.workload();
        assert_eq!(files, 1000);
        assert_eq!(bytes, 1 << 30);
    }

    #[test]
    fn weight_names_distinguish_rewards() {
        assert_eq!(SpartaCtx::weight_name("rppo", RewardKind::ThroughputEnergy), "rppo_te");
        assert_eq!(SpartaCtx::weight_name("rppo", RewardKind::FairnessEfficiency), "rppo_fe");
    }

    /// Scenario-trained weights are scoped (`algo_reward@scenario`) so they
    /// never clobber the bare-testbed defaults.
    #[test]
    fn scenario_weight_names_are_scoped() {
        assert_eq!(
            scoped_weight_name("rppo", RewardKind::ThroughputEnergy, "lossy-wan"),
            "rppo_te@lossy-wan"
        );
        let sc = crate::scenarios::Scenario::by_name("calm").unwrap();
        let src = TrainSource::Scenario(&sc);
        assert_eq!(src.name(), "calm");
        assert_eq!(src.weight_name("linq", RewardKind::FairnessEfficiency), "linq_fe@calm");
        let tb = Testbed::chameleon();
        let src = TrainSource::Testbed(&tb);
        assert_eq!(src.name(), "chameleon");
        assert_eq!(src.weight_name("rppo", RewardKind::ThroughputEnergy), "rppo_te");
    }

    /// Regression: SPARTA lanes must report their method names ("sparta-t",
    /// "sparta-fe"), not the underlying "rppo-te"/"rppo-fe" core labels —
    /// the display name is baked in at construction.
    #[test]
    fn sparta_variants_report_display_names() {
        struct NullAgent {
            params: Vec<f32>,
        }
        impl crate::agents::DrlAgent for NullAgent {
            fn name(&self) -> &str {
                "null"
            }
            fn act(&mut self, _state: &[f32], _explore: bool) -> usize {
                0
            }
            fn observe(
                &mut self,
                _state: &[f32],
                _action: usize,
                _reward: f64,
                _next_state: &[f32],
                _done: bool,
            ) {
            }
            fn params(&self) -> &[f32] {
                &self.params
            }
            fn set_params(&mut self, params: Vec<f32>) {
                self.params = params;
            }
            fn train_steps(&self) -> u64 {
                0
            }
            fn xla_seconds(&self) -> f64 {
                0.0
            }
        }
        let opt = DrlOptimizer::new(Box::new(NullAgent { params: Vec::new() }), "sparta-t");
        assert_eq!(opt.name(), "sparta-t");
    }
}
