//! Table 1: per-algorithm training and inference cost comparison.

use super::common::{train_pipeline, Scale, SpartaCtx};
use crate::agents::make_agent;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::Env;
use crate::energy::PowerModel;
use crate::net::Testbed;
use crate::telemetry::Table;
use crate::trainer::{LiveEnv, ResourceMeter};
use anyhow::Result;

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Row {
    pub algo: String,
    pub offline_train_min: f64,
    pub steps_to_converge: usize,
    pub cpu_pct: f64,
    /// XLA-executable share of wall time — the "GPU%" analogue (DESIGN.md §1).
    pub xla_pct: f64,
    pub mem_pct: f64,
    pub train_energy_kj: f64,
    pub inference_ms: f64,
    pub inference_energy_j: f64,
    pub online_tuning_kj: f64,
}

/// Train each algorithm offline (T/E reward, Chameleon transitions), then
/// microbench inference and measure a short online-tuning phase.
pub fn run(ctx: &SpartaCtx, algos: &[&str], scale: Scale, seed: u64) -> Result<Vec<Row>> {
    let tb = Testbed::chameleon();
    let mut rows = Vec::new();
    for algo in algos {
        let stats = train_pipeline(ctx, algo, RewardKind::ThroughputEnergy, &tb, scale, seed)?;

        // Inference microbench: steady-state per-decision latency.
        let mut agent = make_agent(&ctx.runtime, algo, seed, None)?;
        let state_len = ctx
            .runtime
            .compile(&format!("{algo}_forward"))?
            .spec
            .arg_len(1);
        let state = vec![0.1f32; state_len];
        for _ in 0..20 {
            agent.act(&state, false); // warm-up
        }
        let reps = 200;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            agent.act(&state, false);
        }
        let inference_ms = t0.elapsed().as_secs_f64() * 1000.0 / reps as f64;
        // Per-inference energy: latency × a one-core active-power figure
        // (the paper measures ~0.09 J at sub-ms latencies on server CPUs).
        let inference_energy_j = inference_ms / 1000.0 * 130.0;

        // Online tuning energy: a short adaptation phase on CloudLab.
        let meter = ResourceMeter::start();
        let mut env = LiveEnv::new(
            Testbed::cloudlab(),
            RewardKind::ThroughputEnergy,
            ParamBounds::default(),
            8,
            30,
            seed ^ 0x0711,
        );
        let tune_episodes = match scale {
            Scale::Quick => 4,
            Scale::Paper => 20,
        };
        for _ in 0..tune_episodes {
            let mut state = env.reset();
            loop {
                let a = agent.act(&state, true);
                let out = env.step(a);
                agent.observe(&state, a, out.reward, &out.state, out.done);
                state = out.state;
                if out.done {
                    break;
                }
            }
        }
        let tune = meter.stop();
        // Add the end-system transfer energy the tuning phase burned
        // (suboptimal exploration transfers): approximate with the
        // efficient-engine power at the tuning workload.
        let transfer_kj = tune.wall_s * PowerModel::efficient().power_w(36, 5.0) / 1000.0;

        rows.push(Row {
            algo: algo.to_string(),
            offline_train_min: stats.wall_s / 60.0,
            steps_to_converge: stats.steps_to_converge,
            cpu_pct: stats.cpu_pct,
            xla_pct: stats.xla_pct,
            mem_pct: stats.mem_pct,
            train_energy_kj: stats.energy_kj,
            inference_ms,
            inference_energy_j,
            online_tuning_kj: tune.energy_kj + transfer_kj,
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Row]) {
    println!("\nTable 1 — training/inference cost per algorithm:");
    let mut table = Table::new(&[
        "method",
        "offline min",
        "steps conv",
        "CPU%",
        "XLA% (GPU)",
        "mem%",
        "train kJ",
        "infer ms",
        "infer J",
        "tuning kJ",
    ]);
    for r in rows {
        table.row(vec![
            r.algo.clone(),
            format!("{:.1}", r.offline_train_min),
            format!("{}", r.steps_to_converge),
            format!("{:.1}", r.cpu_pct),
            format!("{:.1}", r.xla_pct),
            format!("{:.1}", r.mem_pct),
            format!("{:.1}", r.train_energy_kj),
            format!("{:.3}", r.inference_ms),
            format!("{:.4}", r.inference_energy_j),
            format!("{:.2}", r.online_tuning_kj),
        ]);
    }
    table.print();
}
