//! Table 1: per-algorithm training and inference cost comparison.
//!
//! Each algorithm's (train → microbench → tune) pipeline is an independent
//! cell sharded over `--jobs` workers. The parent pre-warms the shared
//! exploration-transition cache so workers never race on first-use
//! collection; weights are written under per-algorithm names, so the write
//! paths never collide. Simulation-derived columns (steps to converge,
//! train calls) are identity-seeded and bit-identical at any thread count;
//! wall-clock columns (minutes, CPU%, inference ms) are measurements and
//! vary run to run by nature.

use super::common::{train_pipeline, Scale, SpartaCtx, TrainSource};
use super::runner;
use crate::agents::make_agent;
use crate::config::Paths;
use crate::coordinator::{FeatureWindow, ParamBounds, RewardKind};
use crate::emulator::Env;
use crate::net::Testbed;
use crate::telemetry::Table;
use crate::trainer::{LiveEnv, ResourceMeter};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One Table-1 row. Simulation-derived columns (`steps_to_converge`,
/// `train_calls`, `env_steps`) are identity-seeded and bit-identical at any
/// thread count; the rest are wall-clock measurements that vary run to run
/// (see [`to_json_deterministic`]).
#[derive(Debug, Clone)]
pub struct Row {
    pub algo: String,
    pub offline_train_min: f64,
    pub steps_to_converge: usize,
    /// Training-step executions of the full pipeline (deterministic).
    pub train_calls: u64,
    /// Environment steps of the full pipeline (deterministic).
    pub env_steps: usize,
    pub cpu_pct: f64,
    /// XLA-executable share of wall time — the "GPU%" analogue (DESIGN.md §1).
    pub xla_pct: f64,
    pub mem_pct: f64,
    pub train_energy_kj: f64,
    pub inference_ms: f64,
    pub inference_energy_j: f64,
    pub online_tuning_kj: f64,
}

/// Train each algorithm offline (T/E reward, Chameleon transitions), then
/// microbench inference and measure a short online-tuning phase. Cells
/// shard over `jobs` workers.
pub fn run(
    paths: &Paths,
    algos: &[&str],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<Vec<Row>> {
    let tb = Testbed::chameleon();
    let ctx = SpartaCtx::load(paths.clone())?;
    // Pre-warm the shared transition cache (keyed by testbed + scale) so
    // parallel workers hit it read-only instead of racing to collect.
    super::common::transitions_for(&ctx, &tb, scale, seed ^ 0x7E57)?;

    let snapshot = ctx.snapshot.clone();
    let worker_paths = paths.clone();
    let specs: Vec<String> = algos.iter().map(|a| a.to_string()).collect();
    let outs: Vec<Result<Row>> = runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, algo| -> Result<Row> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            let cs = runner::cell_seed(seed, &format!("table1/{algo}"), 0);
            let stats = train_pipeline(
                ctx,
                algo,
                RewardKind::ThroughputEnergy,
                TrainSource::Testbed(&tb),
                scale,
                cs,
            )?;

            // Inference microbench: steady-state per-decision latency.
            let mut agent = make_agent(&ctx.runtime, algo, cs, None)?;
            // HLO algos take their state length from the compiled forward
            // graph; runtime-free cores (linq) size themselves from the
            // coordinator's feature window.
            let state_len = match ctx.runtime.compile(&format!("{algo}_forward")) {
                Ok(exe) => exe.spec.arg_len(1),
                Err(_) => {
                    let b = ParamBounds::default();
                    FeatureWindow::new(8, b.cc_max, b.p_max).state_len()
                }
            };
            let state = vec![0.1f32; state_len];
            for _ in 0..20 {
                agent.act(&state, false); // warm-up
            }
            let reps = 200;
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                agent.act(&state, false);
            }
            let inference_ms = t0.elapsed().as_secs_f64() * 1000.0 / reps as f64;
            // Per-inference energy: latency × a one-core active-power figure
            // (the paper measures ~0.09 J at sub-ms latencies on server CPUs).
            let inference_energy_j = inference_ms / 1000.0 * 130.0;

            // Online tuning energy: a short adaptation phase on CloudLab.
            let meter = ResourceMeter::start();
            let mut env = LiveEnv::new(
                Testbed::cloudlab(),
                RewardKind::ThroughputEnergy,
                ParamBounds::default(),
                8,
                30,
                cs ^ 0x0711,
            );
            let tune_episodes = match scale {
                Scale::Quick => 4,
                Scale::Paper => 20,
            };
            for _ in 0..tune_episodes {
                let mut state = env.reset();
                loop {
                    let a = agent.act(&state, true);
                    let out = env.step(a);
                    agent.observe(&state, a, out.reward, &out.state, out.done);
                    state = out.state;
                    if out.done {
                        break;
                    }
                }
            }
            let tune = meter.stop();
            // Add the end-system transfer energy the tuning phase burned
            // (suboptimal exploration transfers): host-truth power of the
            // CloudLab sender at the tuning workload, sourced from the
            // c6525-100g node-class calibration like the other energy
            // columns.
            let transfer_kj =
                Testbed::cloudlab().sender_host().power_w(36, 5.0) * tune.wall_s / 1000.0;

            Ok(Row {
                algo: algo.clone(),
                offline_train_min: stats.wall_s / 60.0,
                steps_to_converge: stats.steps_to_converge,
                train_calls: stats.train_calls,
                env_steps: stats.env_steps,
                cpu_pct: stats.cpu_pct,
                xla_pct: stats.xla_pct,
                mem_pct: stats.mem_pct,
                train_energy_kj: stats.energy_kj,
                inference_ms,
                inference_energy_j,
                online_tuning_kj: tune.energy_kj + transfer_kj,
            })
        },
    );

    outs.into_iter().collect()
}

/// Print the table, split into the simulation-derived (deterministic)
/// columns and the measured wall-clock columns; `deterministic` drops the
/// measured half entirely (the CI byte-identity mode).
pub fn print(rows: &[Row], deterministic: bool) {
    println!("\nTable 1 — training/inference cost per algorithm:");
    println!("simulation-derived (deterministic at any --jobs count):");
    let mut sim = Table::new(&["method", "steps conv", "train calls", "env steps"]);
    for r in rows {
        sim.row(vec![
            r.algo.clone(),
            format!("{}", r.steps_to_converge),
            format!("{}", r.train_calls),
            format!("{}", r.env_steps),
        ]);
    }
    sim.print();
    if deterministic {
        return;
    }
    println!("\nmeasured wall-clock (varies run to run by nature):");
    let mut measured = Table::new(&[
        "method",
        "offline min",
        "CPU%",
        "XLA% (GPU)",
        "mem%",
        "train kJ",
        "infer ms",
        "infer J",
        "tuning kJ",
    ]);
    for r in rows {
        measured.row(vec![
            r.algo.clone(),
            format!("{:.1}", r.offline_train_min),
            format!("{:.1}", r.cpu_pct),
            format!("{:.1}", r.xla_pct),
            format!("{:.1}", r.mem_pct),
            format!("{:.1}", r.train_energy_kj),
            format!("{:.3}", r.inference_ms),
            format!("{:.4}", r.inference_energy_j),
            format!("{:.2}", r.online_tuning_kj),
        ]);
    }
    measured.print();
}

/// Machine-readable report (wall-clock columns included; note they are
/// measurements, not simulation outputs, and vary run to run — use
/// [`to_json_deterministic`] for byte-identity checks).
pub fn to_json(rows: &[Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("algo", Json::from(r.algo.clone())),
                    ("offline_train_min", Json::from(r.offline_train_min)),
                    ("steps_to_converge", Json::from(r.steps_to_converge)),
                    ("train_calls", Json::from(r.train_calls as usize)),
                    ("env_steps", Json::from(r.env_steps)),
                    ("cpu_pct", Json::from(r.cpu_pct)),
                    ("xla_pct", Json::from(r.xla_pct)),
                    ("mem_pct", Json::from(r.mem_pct)),
                    ("train_energy_kj", Json::from(r.train_energy_kj)),
                    ("inference_ms", Json::from(r.inference_ms)),
                    ("inference_energy_j", Json::from(r.inference_energy_j)),
                    ("online_tuning_kj", Json::from(r.online_tuning_kj)),
                ])
            })
            .collect(),
    )
}

/// Only the simulation-derived columns — byte-identical for a fixed
/// seed at any `--jobs` count, so table1 joins the CI determinism job.
pub fn to_json_deterministic(rows: &[Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("algo", Json::from(r.algo.clone())),
                    ("steps_to_converge", Json::from(r.steps_to_converge)),
                    ("train_calls", Json::from(r.train_calls as usize)),
                    ("env_steps", Json::from(r.env_steps)),
                ])
            })
            .collect(),
    )
}
