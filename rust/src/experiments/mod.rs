//! Experiment harness: one module per paper table/figure.
//!
//! The CLI subcommands and the `cargo bench` binaries are thin wrappers over
//! these functions, so every reported number is regenerable both ways. Each
//! experiment takes a [`Scale`] so tests/benches can run a reduced (but
//! structurally identical) version of the paper's full workload.

pub mod common;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table1;

pub use common::{make_optimizer, train_pipeline, Scale, SpartaCtx};
