//! Experiment harness: one module per paper table/figure.
//!
//! The CLI subcommands and the `cargo bench` binaries are thin wrappers over
//! these functions, so every reported number is regenerable both ways. Each
//! experiment takes a [`Scale`] so tests/benches can run a reduced (but
//! structurally identical) version of the paper's full workload. Every
//! grid-shaped experiment (Fig. 1/4/5/6/7, Table 1 and the [`generalize`]
//! matrix) shards its independent cells across worker threads via
//! [`runner`]; trained weights are read from a shared, read-only
//! [`crate::runtime::WeightSnapshot`] and per-cell seeding is
//! identity-derived, so reports are bit-identical at any `--jobs` count.
//!
//! Beyond the paper's figures, [`fleet`] runs dynamic-admission workloads
//! (transfers arriving/departing on a shared bottleneck) through the
//! step-driven [`crate::coordinator::Session`] API.

pub mod bench;
pub mod common;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fleet;
pub mod generalize;
pub mod runner;
pub mod table1;

pub use common::{
    make_optimizer, scoped_weight_name, train_pipeline, transitions_for_scenario, Scale,
    SpartaCtx, TrainSource,
};
pub use runner::{default_jobs, parallel_map, parallel_map_with};
