//! Experiment harness: one module per paper table/figure.
//!
//! The CLI subcommands and the `cargo bench` binaries are thin wrappers over
//! these functions, so every reported number is regenerable both ways. Each
//! experiment takes a [`Scale`] so tests/benches can run a reduced (but
//! structurally identical) version of the paper's full workload. Grid-shaped
//! experiments (Fig. 1, Fig. 6, Fig. 7) shard their independent cells across
//! worker threads via [`runner`]; per-cell seeding is identity-derived, so
//! reports are bit-identical at any `--jobs` count.

pub mod common;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod runner;
pub mod table1;

pub use common::{
    make_optimizer, train_pipeline, transitions_for_scenario, Scale, SpartaCtx,
};
pub use runner::{default_jobs, parallel_map, parallel_map_with};
