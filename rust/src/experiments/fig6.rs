//! Fig. 6: six methods × evaluation scenarios — transfer throughput and
//! energy (the headline evaluation).
//!
//! The paper's matrix is methods × three testbeds; this generalizes the
//! column axis to any set of registered [`Scenario`]s (the testbed presets
//! are scenarios themselves) and shards the (scenario × method × trial)
//! cells across worker threads. Per-cell seeding depends only on the cell's
//! identity, so reports are bit-identical at any `jobs` count.

use super::common::{make_optimizer, Scale, SpartaCtx};
use super::runner;
use crate::config::Paths;
use crate::runtime::WeightSnapshot;
use crate::scenarios::Scenario;
use crate::telemetry::Table;
use crate::transfer::TransferJob;
use crate::util::json::Json;
use crate::util::{stats, Summary};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Results for one (method, scenario) cell over all trials.
#[derive(Debug, Clone)]
pub struct Cell {
    pub method: String,
    pub scenario: String,
    pub throughput_gbps: Vec<f64>,
    /// Total transfer energy per trial, kJ (empty where the testbed has no
    /// energy counters, e.g. FABRIC).
    pub energy_kj: Vec<f64>,
    pub duration_s: Vec<f64>,
}

/// One (scenario, method, trial) unit of work.
struct TrialSpec {
    scenario: Scenario,
    method: String,
    seed: u64,
}

/// One trial's extracted results.
struct TrialOut {
    throughput_gbps: f64,
    energy_kj: Option<f64>,
    duration_s: f64,
}

/// Run the methods × scenarios matrix, sharding trials over `jobs` workers.
/// Takes [`Paths`] rather than a loaded context: workers cannot share a
/// `SpartaCtx` (the PJRT runtime is thread-local), so each builds its own —
/// but all of them read trained weights from one shared, read-only
/// [`crate::runtime::WeightSnapshot`] taken by the parent, so evaluation
/// never touches the weights directory concurrently.
pub fn run(
    paths: &Paths,
    scenarios: &[Scenario],
    methods: &[String],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<Vec<Cell>> {
    let (files, bytes) = scale.workload();
    let mut specs = Vec::new();
    for sc in scenarios {
        for method in methods {
            for trial in 0..scale.trials() {
                specs.push(TrialSpec {
                    scenario: sc.clone(),
                    method: method.clone(),
                    // Identity-derived seeding: the seed depends only on
                    // this cell's (scenario, method, trial), so reports are
                    // bit-identical at any thread count.
                    seed: runner::cell_seed(
                        seed,
                        &format!("{}/{}", sc.name, method),
                        trial as u64,
                    ),
                });
            }
        }
    }

    // Snapshot only — the parent does not need a runtime of its own.
    let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
    let paths = paths.clone();
    let outs: Vec<Result<TrialOut>> = runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(paths.clone(), snapshot.clone()),
        |worker_ctx, _i, spec| -> Result<TrialOut> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            let (opt, engine, reward) = make_optimizer(ctx, &spec.method, spec.seed)?;
            let mut ctl = spec
                .scenario
                .controller()
                .job(TransferJob::files(files, bytes))
                .engine(engine)
                .reward(reward)
                .seed(spec.seed)
                .build();
            let report = ctl.run(opt, spec.seed);
            let lane = report.lane();
            crate::log_info!(
                "fig6 {}/{}: {:.2} Gbps, {:.1} kJ ({:.0} s)",
                spec.scenario.name,
                spec.method,
                lane.avg_throughput_gbps(),
                lane.total_energy_j / 1000.0,
                lane.duration_s
            );
            Ok(TrialOut {
                throughput_gbps: lane.avg_throughput_gbps(),
                energy_kj: spec
                    .scenario
                    .testbed
                    .has_energy_counters
                    .then_some(lane.total_energy_j / 1000.0),
                duration_s: lane.duration_s,
            })
        },
    );

    // Fold trial results (spec order == result order) into cells.
    let mut cells: Vec<Cell> = Vec::new();
    for (spec, out) in specs.iter().zip(outs) {
        let out = out?;
        let matches = cells
            .last()
            .is_some_and(|c| c.method == spec.method && c.scenario == spec.scenario.name);
        if !matches {
            cells.push(Cell {
                method: spec.method.clone(),
                scenario: spec.scenario.name.to_string(),
                throughput_gbps: Vec::new(),
                energy_kj: Vec::new(),
                duration_s: Vec::new(),
            });
        }
        let cell = cells.last_mut().unwrap();
        cell.throughput_gbps.push(out.throughput_gbps);
        cell.duration_s.push(out.duration_s);
        if let Some(e) = out.energy_kj {
            cell.energy_kj.push(e);
        }
    }
    Ok(cells)
}

/// Paper-style table of the matrix.
pub fn print(cells: &[Cell]) {
    println!("\nFig 6 — transfer throughput (Gbps) and energy (kJ), mean over trials:");
    let mut table = Table::new(&["scenario", "method", "thr mean", "thr p50", "thr std", "energy kJ", "duration s"]);
    for c in cells {
        let t = Summary::of(&c.throughput_gbps);
        let e = stats::mean(&c.energy_kj);
        table.row(vec![
            c.scenario.clone(),
            c.method.clone(),
            format!("{:.2}", t.mean),
            format!("{:.2}", t.median),
            format!("{:.2}", t.std),
            if c.energy_kj.is_empty() { "n/a".into() } else { format!("{e:.1}") },
            format!("{:.0}", stats::mean(&c.duration_s)),
        ]);
    }
    table.print();
}

/// Machine-readable report (for `--out` and the CI determinism check).
pub fn to_json(cells: &[Cell]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("scenario", Json::from(c.scenario.clone())),
                    ("method", Json::from(c.method.clone())),
                    ("throughput_gbps", Json::arr_f64(&c.throughput_gbps)),
                    ("energy_kj", Json::arr_f64(&c.energy_kj)),
                    ("duration_s", Json::arr_f64(&c.duration_s)),
                ])
            })
            .collect(),
    )
}

/// Headline deltas vs the static baselines (the abstract's claims).
pub fn headline(cells: &[Cell]) -> (f64, f64) {
    let mean_of = |method: &str, f: &dyn Fn(&Cell) -> f64| -> f64 {
        let xs: Vec<f64> = cells.iter().filter(|c| c.method == method).map(f).collect();
        stats::mean(&xs)
    };
    let thr = |c: &Cell| stats::mean(&c.throughput_gbps);
    let en = |c: &Cell| stats::mean(&c.energy_kj);
    let static_thr = (mean_of("rclone", &thr) + mean_of("escp", &thr)) / 2.0;
    let sparta_thr = mean_of("sparta-t", &thr).max(mean_of("sparta-fe", &thr));
    let static_en = (mean_of("rclone", &en) + mean_of("escp", &en)) / 2.0;
    let sparta_en = mean_of("sparta-fe", &en).min(mean_of("sparta-t", &en));
    let thr_gain = (sparta_thr - static_thr) / static_thr * 100.0;
    let energy_cut = (static_en - sparta_en) / static_en * 100.0;
    (thr_gain, energy_cut)
}
