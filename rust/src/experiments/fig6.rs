//! Fig. 6: six methods × three testbeds — transfer throughput and energy
//! (the headline evaluation).

use super::common::{make_optimizer, Scale, SpartaCtx, METHODS};
use crate::coordinator::Controller;
use crate::net::Testbed;
use crate::telemetry::Table;
use crate::transfer::TransferJob;
use crate::util::{stats, Summary};
use anyhow::Result;

/// Results for one (method, testbed) cell over all trials.
#[derive(Debug, Clone)]
pub struct Cell {
    pub method: String,
    pub testbed: String,
    pub throughput_gbps: Vec<f64>,
    /// Total transfer energy per trial, kJ (empty on FABRIC).
    pub energy_kj: Vec<f64>,
    pub duration_s: Vec<f64>,
}

/// Run the full methods × testbeds matrix.
pub fn run(ctx: &SpartaCtx, testbeds: &[Testbed], scale: Scale, seed: u64) -> Result<Vec<Cell>> {
    let (files, bytes) = scale.workload();
    let mut cells = Vec::new();
    for tb in testbeds {
        for method in METHODS {
            let mut cell = Cell {
                method: method.to_string(),
                testbed: tb.name.to_string(),
                throughput_gbps: Vec::new(),
                energy_kj: Vec::new(),
                duration_s: Vec::new(),
            };
            for trial in 0..scale.trials() {
                let trial_seed = seed ^ (trial as u64 * 0x9E3779B9);
                let (opt, engine, reward) = make_optimizer(ctx, method, trial_seed)?;
                let mut ctl = Controller::builder(tb.clone())
                    .job(TransferJob::files(files, bytes))
                    .engine(engine)
                    .reward(reward)
                    .seed(trial_seed)
                    .build();
                let report = ctl.run(opt, trial_seed);
                let lane = report.lane();
                cell.throughput_gbps.push(lane.avg_throughput_gbps());
                cell.duration_s.push(lane.duration_s);
                if tb.has_energy_counters {
                    cell.energy_kj.push(lane.total_energy_j / 1000.0);
                }
            }
            crate::log_info!(
                "fig6 {}/{}: {:.2} Gbps, {:.1} kJ",
                tb.name,
                method,
                stats::mean(&cell.throughput_gbps),
                stats::mean(&cell.energy_kj)
            );
            cells.push(cell);
        }
    }
    Ok(cells)
}

/// Paper-style table of the matrix.
pub fn print(cells: &[Cell]) {
    println!("\nFig 6 — transfer throughput (Gbps) and energy (kJ), mean over trials:");
    let mut table = Table::new(&["testbed", "method", "thr mean", "thr p50", "thr std", "energy kJ", "duration s"]);
    for c in cells {
        let t = Summary::of(&c.throughput_gbps);
        let e = stats::mean(&c.energy_kj);
        table.row(vec![
            c.testbed.clone(),
            c.method.clone(),
            format!("{:.2}", t.mean),
            format!("{:.2}", t.median),
            format!("{:.2}", t.std),
            if c.energy_kj.is_empty() { "n/a".into() } else { format!("{e:.1}") },
            format!("{:.0}", stats::mean(&c.duration_s)),
        ]);
    }
    table.print();
}

/// Headline deltas vs the static baselines (the abstract's claims).
pub fn headline(cells: &[Cell]) -> (f64, f64) {
    let mean_of = |method: &str, f: &dyn Fn(&Cell) -> f64| -> f64 {
        let xs: Vec<f64> = cells.iter().filter(|c| c.method == method).map(f).collect();
        stats::mean(&xs)
    };
    let thr = |c: &Cell| stats::mean(&c.throughput_gbps);
    let en = |c: &Cell| stats::mean(&c.energy_kj);
    let static_thr = (mean_of("rclone", &thr) + mean_of("escp", &thr)) / 2.0;
    let sparta_thr = mean_of("sparta-t", &thr).max(mean_of("sparta-fe", &thr));
    let static_en = (mean_of("rclone", &en) + mean_of("escp", &en)) / 2.0;
    let sparta_en = mean_of("sparta-fe", &en).min(mean_of("sparta-t", &en));
    let thr_gain = (sparta_thr - static_thr) / static_thr * 100.0;
    let energy_cut = (static_en - sparta_en) / static_en * 100.0;
    (thr_gain, energy_cut)
}
