//! Fig. 5: online tuning — agents trained on Chameleon (T/E reward) are
//! deployed on CloudLab and keep learning; cumulative reward per episode.

use super::common::{Scale, SpartaCtx};
use crate::agents::make_agent;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::Env;
use crate::net::Testbed;
use crate::runtime::WeightStore;
use crate::telemetry::Table;
use crate::trainer::LiveEnv;
use crate::util::stats;
use anyhow::Result;

/// Tuning trajectory of one algorithm.
#[derive(Debug, Clone)]
pub struct TuneCurve {
    pub algo: String,
    /// Episode rewards in deployment order.
    pub episode_rewards: Vec<f64>,
}

impl TuneCurve {
    /// Mean reward over an episode range (for table summaries).
    pub fn window_mean(&self, from: usize, to: usize) -> f64 {
        let hi = to.min(self.episode_rewards.len());
        if from >= hi {
            return 0.0;
        }
        stats::mean(&self.episode_rewards[from..hi])
    }
}

/// Fine-tune each Chameleon-trained (T/E) agent on the CloudLab preset.
pub fn run(ctx: &SpartaCtx, algos: &[&str], scale: Scale, seed: u64) -> Result<Vec<TuneCurve>> {
    let episodes = match scale {
        Scale::Quick => 60,
        Scale::Paper => 500,
    };
    let episode_len = 30;
    let store = WeightStore::new(ctx.paths.weights());
    let mut out = Vec::new();
    for algo in algos {
        let n = ctx.runtime.manifest.algo(algo)?.n_params;
        let weights = store.load(&SpartaCtx::weight_name(algo, RewardKind::ThroughputEnergy), n)?;
        let mut agent = make_agent(&ctx.runtime, algo, seed, Some(weights))?;
        let mut env = LiveEnv::new(
            Testbed::cloudlab(),
            RewardKind::ThroughputEnergy,
            ParamBounds::default(),
            8,
            episode_len,
            seed ^ 0xC10D,
        );
        let mut rewards = Vec::with_capacity(episodes);
        for _ in 0..episodes {
            let mut state = env.reset();
            let mut ep = 0.0;
            loop {
                let action = agent.act(&state, true);
                let step = env.step(action);
                agent.observe(&state, action, step.reward, &step.state, step.done);
                ep += step.reward;
                state = step.state;
                if step.done {
                    break;
                }
            }
            rewards.push(ep);
        }
        crate::log_info!("fig5 {}: first10={:.2} last10={:.2}", algo,
            stats::mean(&rewards[..10.min(rewards.len())]),
            stats::mean(&rewards[rewards.len().saturating_sub(10)..]));
        out.push(TuneCurve { algo: algo.to_string(), episode_rewards: rewards });
    }
    Ok(out)
}

pub fn print(curves: &[TuneCurve]) {
    println!("\nFig 5 — online tuning on CloudLab (T/E reward), episode-reward progression:");
    let n = curves.iter().map(|c| c.episode_rewards.len()).max().unwrap_or(0);
    let q = (n / 4).max(1);
    let mut table = Table::new(&["algo", "ep 0-q1", "q1-q2", "q2-q3", "q3-end", "improvement"]);
    for c in curves {
        let a = c.window_mean(0, q);
        let b = c.window_mean(q, 2 * q);
        let d = c.window_mean(2 * q, 3 * q);
        let e = c.window_mean(3 * q, n);
        table.row(vec![
            c.algo.clone(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{d:.2}"),
            format!("{e:.2}"),
            format!("{:+.2}", e - a),
        ]);
    }
    table.print();
}
