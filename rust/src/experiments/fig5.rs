//! Fig. 5: online tuning — agents trained on Chameleon (T/E reward) are
//! deployed on CloudLab and keep learning; cumulative reward per episode.
//!
//! Each algorithm's tuning run is an independent cell: the starting weights
//! come from the shared read-only [`crate::runtime::WeightSnapshot`] (one
//! disk read at startup, total), and per-cell seeding is identity-derived,
//! so the curves are bit-identical at any `--jobs` count.

use super::common::{expected_params, Scale, SpartaCtx};
use super::runner;
use crate::agents::make_agent;
use crate::config::Paths;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::Env;
use crate::net::Testbed;
use crate::runtime::WeightSnapshot;
use crate::telemetry::Table;
use crate::trainer::LiveEnv;
use crate::util::json::Json;
use crate::util::stats;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Tuning trajectory of one algorithm.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneCurve {
    pub algo: String,
    /// Episode rewards in deployment order.
    pub episode_rewards: Vec<f64>,
}

impl TuneCurve {
    /// Mean reward over an episode range (for table summaries).
    pub fn window_mean(&self, from: usize, to: usize) -> f64 {
        let hi = to.min(self.episode_rewards.len());
        if from >= hi {
            return 0.0;
        }
        stats::mean(&self.episode_rewards[from..hi])
    }
}

/// Fine-tune each Chameleon-trained (T/E) agent on the CloudLab preset,
/// sharding the per-algorithm cells over `jobs` workers.
pub fn run(
    paths: &Paths,
    algos: &[&str],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<Vec<TuneCurve>> {
    let episodes = match scale {
        Scale::Quick => 60,
        Scale::Paper => 500,
    };
    let episode_len = 30;
    // Snapshot only — the parent does not need a runtime of its own.
    let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
    let worker_paths = paths.clone();

    let specs: Vec<String> = algos.iter().map(|a| a.to_string()).collect();
    let outs: Vec<Result<Vec<f64>>> = runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, algo| -> Result<Vec<f64>> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            let cs = runner::cell_seed(seed, &format!("fig5/{algo}"), 0);
            let weights = ctx.snapshot.params(
                &SpartaCtx::weight_name(algo, RewardKind::ThroughputEnergy),
                expected_params(ctx, algo),
            )?;
            let mut agent = make_agent(&ctx.runtime, algo, cs, Some(weights))?;
            let mut env = LiveEnv::new(
                Testbed::cloudlab(),
                RewardKind::ThroughputEnergy,
                ParamBounds::default(),
                8,
                episode_len,
                cs ^ 0xC10D,
            );
            let mut rewards = Vec::with_capacity(episodes);
            for _ in 0..episodes {
                let mut state = env.reset();
                let mut ep = 0.0;
                loop {
                    let action = agent.act(&state, true);
                    let step = env.step(action);
                    agent.observe(&state, action, step.reward, &step.state, step.done);
                    ep += step.reward;
                    state = step.state;
                    if step.done {
                        break;
                    }
                }
                rewards.push(ep);
            }
            crate::log_info!(
                "fig5 {}: first10={:.2} last10={:.2}",
                algo,
                stats::mean(&rewards[..10.min(rewards.len())]),
                stats::mean(&rewards[rewards.len().saturating_sub(10)..])
            );
            Ok(rewards)
        },
    );

    let mut out = Vec::new();
    for (algo, rewards) in specs.iter().zip(outs) {
        out.push(TuneCurve { algo: algo.clone(), episode_rewards: rewards? });
    }
    Ok(out)
}

pub fn print(curves: &[TuneCurve]) {
    println!("\nFig 5 — online tuning on CloudLab (T/E reward), episode-reward progression:");
    let n = curves.iter().map(|c| c.episode_rewards.len()).max().unwrap_or(0);
    let q = (n / 4).max(1);
    let mut table = Table::new(&["algo", "ep 0-q1", "q1-q2", "q2-q3", "q3-end", "improvement"]);
    for c in curves {
        let a = c.window_mean(0, q);
        let b = c.window_mean(q, 2 * q);
        let d = c.window_mean(2 * q, 3 * q);
        let e = c.window_mean(3 * q, n);
        table.row(vec![
            c.algo.clone(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{d:.2}"),
            format!("{e:.2}"),
            format!("{:+.2}", e - a),
        ]);
    }
    table.print();
}

/// Machine-readable report (for `--out` and the CI determinism check).
pub fn to_json(curves: &[TuneCurve]) -> Json {
    Json::Arr(
        curves
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("algo", Json::from(c.algo.clone())),
                    ("episode_rewards", Json::arr_f64(&c.episode_rewards)),
                ])
            })
            .collect(),
    )
}
