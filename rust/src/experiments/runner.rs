//! Deterministic parallel trial runner.
//!
//! Experiments fan a grid of independent cells — (method × trial × scenario)
//! for Fig. 6, (regime × cc × p) for Fig. 1 — across worker threads with
//! [`std::thread::scope`]. Determinism contract: every cell derives its RNG
//! seeding purely from its own identity (never from a shared RNG drawn in
//! execution order) and results are written back by cell index, so the same
//! inputs produce **bit-identical** outputs at any thread count, including
//! `jobs = 1`.
//!
//! Workers that need per-thread state that is neither `Send` nor cheap (the
//! PJRT runtime behind [`super::SpartaCtx`]) build it once per worker via
//! [`parallel_map_with`]'s `init` hook.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use when the CLI doesn't pin `--jobs`.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` with up to `jobs` worker threads; returns outputs in
/// item order. `f(i, &items[i])` must derive any randomness from the item
/// itself for the bit-identical-at-any-thread-count guarantee to hold.
pub fn parallel_map<I, O, F>(items: &[I], jobs: usize, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    parallel_map_with(items, jobs, || (), move |_, i, item| f(i, item))
}

/// [`parallel_map`] with per-worker state: each worker thread calls `init`
/// once and passes the state to every `f` call it executes (used to build
/// one [`super::SpartaCtx`] per worker instead of per cell).
pub fn parallel_map_with<I, O, S, FS, F>(items: &[I], jobs: usize, init: FS, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    FS: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &I) -> O + Sync,
{
    let jobs = jobs.max(1).min(items.len().max(1));
    if jobs <= 1 {
        let mut state = init();
        return items.iter().enumerate().map(|(i, item)| f(&mut state, i, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<O>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let out = f(&mut state, i, &items[i]);
                    *slots[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker left a cell unfilled"))
        .collect()
}

/// Stable 64-bit mix of a base seed and a cell label — the per-cell seeding
/// helper (see [`crate::util::rng::mix_seed`] for the shared mix).
pub fn cell_seed(base: u64, label: &str, index: u64) -> u64 {
    crate::util::rng::mix_seed(base, label, index)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_item_order() {
        let items: Vec<usize> = (0..37).collect();
        let out = parallel_map(&items, 4, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..37).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn identical_results_at_any_thread_count() {
        let items: Vec<u64> = (0..64).collect();
        let work = |_: usize, &x: &u64| {
            // Deterministic per-item pseudo-work seeded by the item alone.
            let mut rng = crate::util::Rng::new(cell_seed(99, "t", x));
            (0..100).map(|_| rng.f64()).sum::<f64>().to_bits()
        };
        let serial = parallel_map(&items, 1, work);
        for jobs in [2, 4, 8] {
            assert_eq!(serial, parallel_map(&items, jobs, work), "jobs={jobs}");
        }
    }

    #[test]
    fn empty_and_single_item_grids() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, x| *x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |_, x| *x), vec![7]);
    }

    #[test]
    fn worker_state_is_reused_within_a_worker() {
        let items: Vec<usize> = (0..16).collect();
        let inits = AtomicUsize::new(0);
        let out = parallel_map_with(
            &items,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |calls, _, &x| {
                *calls += 1;
                x
            },
        );
        assert_eq!(out, items);
        // At most one init per worker (and at least one overall).
        let n = inits.load(Ordering::Relaxed);
        assert!(n >= 1 && n <= 4, "inits={n}");
    }

    #[test]
    fn cell_seed_is_stable_and_label_sensitive() {
        assert_eq!(cell_seed(1, "rclone", 0), cell_seed(1, "rclone", 0));
        assert_ne!(cell_seed(1, "rclone", 0), cell_seed(1, "escp", 0));
        assert_ne!(cell_seed(1, "rclone", 0), cell_seed(1, "rclone", 1));
        assert_ne!(cell_seed(1, "rclone", 0), cell_seed(2, "rclone", 0));
    }

    #[test]
    fn errors_propagate_as_values() {
        let items: Vec<u32> = (0..8).collect();
        let out: Vec<Result<u32, String>> = parallel_map(&items, 3, |_, &x| {
            if x % 2 == 0 { Ok(x) } else { Err(format!("odd {x}")) }
        });
        assert_eq!(out.iter().filter(|r| r.is_err()).count(), 4);
    }
}
