//! Fig. 4: DRL algorithms × two rewards, evaluated in simulation (the
//! cluster emulator) and in "real-world" transfers (the live fluid
//! simulator), on the Chameleon preset.
//!
//! The (algo × world) cells are independent, so they shard across worker
//! threads like Fig. 1/6/7: exploration transitions are collected (or
//! cache-loaded) once by the parent and shared, trained weights come from
//! the parent's read-only [`crate::runtime::WeightSnapshot`], and every
//! cell derives its seeding purely from its own identity — reports are
//! bit-identical at any `--jobs` count.

use super::common::{expected_params, transitions_for, Scale, SpartaCtx};
use super::runner;
use crate::agents::make_agent;
use crate::config::Paths;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::{ClusterEnv, Env};
use crate::net::Testbed;
use crate::telemetry::Table;
use crate::trainer::LiveEnv;
use crate::util::json::Json;
use crate::util::{stats, Summary};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Distribution of per-episode outcomes for one (algo, reward, world) cell.
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoCell {
    pub algo: String,
    pub reward: RewardKind,
    /// "sim" (emulator) or "real" (live simulator).
    pub world: &'static str,
    pub throughput_gbps: Vec<f64>,
    pub energy_j_per_mi: Vec<f64>,
}

/// Evaluate one trained agent in an environment for `episodes`, reading the
/// trained weights from the shared in-memory snapshot (never from disk).
fn eval_in_env(
    ctx: &SpartaCtx,
    algo: &str,
    reward: RewardKind,
    env: &mut dyn Env,
    episodes: usize,
    seed: u64,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let weights = ctx
        .snapshot
        .params(&SpartaCtx::weight_name(algo, reward), expected_params(ctx, algo))?;
    let mut agent = make_agent(&ctx.runtime, algo, seed, Some(weights))?;
    let mut thr = Vec::new();
    let mut energy = Vec::new();
    for _ in 0..episodes {
        let mut state = env.reset();
        let mut ep_thr = 0.0;
        let mut ep_energy = 0.0;
        let mut steps = 0;
        loop {
            // Sample the policy (the paper's agents act stochastically in
            // deployment); no learning here — Fig. 4 isolates offline
            // generalization.
            let action = agent.act(&state, true);
            let out = env.step(action);
            ep_thr += out.throughput_gbps;
            if out.energy_j.is_finite() {
                ep_energy += out.energy_j;
            }
            steps += 1;
            state = out.state;
            if out.done {
                break;
            }
        }
        thr.push(ep_thr / steps as f64);
        energy.push(ep_energy / steps as f64);
    }
    Ok((thr, energy))
}

/// One (algo, world) unit of work.
struct CellSpec {
    algo: String,
    world: &'static str,
}

/// Run the full algorithm comparison for one reward kind, sharding the
/// (algo × world) cells over `jobs` workers.
pub fn run(
    paths: &Paths,
    reward: RewardKind,
    algos: &[&str],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<Vec<AlgoCell>> {
    let ctx = SpartaCtx::load(paths.clone())?;
    let tb = Testbed::chameleon();
    let episodes = match scale {
        Scale::Quick => 6,
        Scale::Paper => 20,
    };
    // Collected (or cache-loaded) once, shared read-only by every sim cell.
    let transitions = Arc::new(transitions_for(&ctx, &tb, scale, seed ^ 0x7E57)?);

    let mut specs = Vec::new();
    for algo in algos {
        for world in ["sim", "real"] {
            specs.push(CellSpec { algo: algo.to_string(), world });
        }
    }

    let snapshot = ctx.snapshot.clone();
    let worker_paths = paths.clone();
    let outs: Vec<Result<(Vec<f64>, Vec<f64>)>> = runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, spec| -> Result<(Vec<f64>, Vec<f64>)> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            // Identity-derived seeding: depends only on this cell's
            // (algo, reward, world), so reports are bit-identical at any
            // thread count.
            let cs = runner::cell_seed(
                seed,
                &format!("fig4/{}/{}/{}", spec.algo, reward.short(), spec.world),
                0,
            );
            let out = match spec.world {
                "sim" => {
                    let mut env = ClusterEnv::new(
                        transitions.as_ref().clone(),
                        scale.clusters(),
                        ParamBounds::default(),
                        reward,
                        8,
                        64,
                        cs ^ 0x51,
                    );
                    eval_in_env(ctx, &spec.algo, reward, &mut env, episodes, cs)?
                }
                _ => {
                    let mut env = LiveEnv::new(
                        tb.clone(),
                        reward,
                        ParamBounds::default(),
                        8,
                        40,
                        cs ^ 0x1F,
                    );
                    eval_in_env(ctx, &spec.algo, reward, &mut env, episodes, cs)?
                }
            };
            crate::log_info!("fig4 {}/{} ({}): done", spec.algo, spec.world, reward.short());
            Ok(out)
        },
    );

    let mut cells = Vec::new();
    for (spec, out) in specs.iter().zip(outs) {
        let (thr, en) = out?;
        cells.push(AlgoCell {
            algo: spec.algo.clone(),
            reward,
            world: spec.world,
            throughput_gbps: thr,
            energy_j_per_mi: en,
        });
    }
    Ok(cells)
}

pub fn print(cells: &[AlgoCell]) {
    println!("\nFig 4 — DRL algorithms, throughput and per-MI energy distributions:");
    let mut table = Table::new(&[
        "algo", "reward", "world", "thr mean", "thr p25", "thr p75", "energy/MI mean",
    ]);
    for c in cells {
        let t = Summary::of(&c.throughput_gbps);
        table.row(vec![
            c.algo.clone(),
            c.reward.short().to_string(),
            c.world.to_string(),
            format!("{:.2}", t.mean),
            format!("{:.2}", t.p25),
            format!("{:.2}", t.p75),
            format!("{:.1}", stats::mean(&c.energy_j_per_mi)),
        ]);
    }
    table.print();
}

/// Machine-readable report (for `--out` and the CI determinism check).
pub fn to_json(cells: &[AlgoCell]) -> Json {
    Json::Arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("algo", Json::from(c.algo.clone())),
                    ("reward", Json::from(c.reward.short())),
                    ("world", Json::from(c.world)),
                    ("throughput_gbps", Json::arr_f64(&c.throughput_gbps)),
                    ("energy_j_per_mi", Json::arr_f64(&c.energy_j_per_mi)),
                ])
            })
            .collect(),
    )
}
