//! Fig. 4: the five DRL algorithms × two rewards, evaluated in simulation
//! (the cluster emulator) and in "real-world" transfers (the live fluid
//! simulator), on the Chameleon preset.

use super::common::{transitions_for, Scale, SpartaCtx};
use crate::agents::make_agent;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::{ClusterEnv, Env};
use crate::net::Testbed;
use crate::runtime::WeightStore;
use crate::telemetry::Table;
use crate::trainer::LiveEnv;
use crate::util::{stats, Summary};
use anyhow::Result;

/// Distribution of per-episode outcomes for one (algo, reward, world) cell.
#[derive(Debug, Clone)]
pub struct AlgoCell {
    pub algo: String,
    pub reward: RewardKind,
    /// "sim" (emulator) or "real" (live simulator).
    pub world: &'static str,
    pub throughput_gbps: Vec<f64>,
    pub energy_j_per_mi: Vec<f64>,
}

/// Evaluate one trained agent greedily in an environment for `episodes`.
fn eval_in_env(
    ctx: &SpartaCtx,
    algo: &str,
    reward: RewardKind,
    env: &mut dyn Env,
    episodes: usize,
    seed: u64,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let store = WeightStore::new(ctx.paths.weights());
    let n = ctx.runtime.manifest.algo(algo)?.n_params;
    let weights = store.load(&SpartaCtx::weight_name(algo, reward), n)?;
    let mut agent = make_agent(&ctx.runtime, algo, seed, Some(weights))?;
    let mut thr = Vec::new();
    let mut energy = Vec::new();
    for _ in 0..episodes {
        let mut state = env.reset();
        let mut ep_thr = 0.0;
        let mut ep_energy = 0.0;
        let mut steps = 0;
        loop {
            // Sample the policy (the paper's agents act stochastically in
            // deployment); no learning here — Fig. 4 isolates offline
            // generalization.
            let action = agent.act(&state, true);
            let out = env.step(action);
            ep_thr += out.throughput_gbps;
            if out.energy_j.is_finite() {
                ep_energy += out.energy_j;
            }
            steps += 1;
            state = out.state;
            if out.done {
                break;
            }
        }
        thr.push(ep_thr / steps as f64);
        energy.push(ep_energy / steps as f64);
    }
    Ok((thr, energy))
}

/// Run the full algorithm comparison for one reward kind.
pub fn run(
    ctx: &SpartaCtx,
    reward: RewardKind,
    algos: &[&str],
    scale: Scale,
    seed: u64,
) -> Result<Vec<AlgoCell>> {
    let tb = Testbed::chameleon();
    let episodes = match scale {
        Scale::Quick => 6,
        Scale::Paper => 20,
    };
    let mut out = Vec::new();
    for algo in algos {
        // Simulation world: the cluster emulator.
        let transitions = transitions_for(ctx, &tb, scale, seed ^ 0x7E57)?;
        let mut sim_env = ClusterEnv::new(
            transitions,
            scale.clusters(),
            ParamBounds::default(),
            reward,
            8,
            64,
            seed ^ 0x51,
        );
        let (thr, en) = eval_in_env(ctx, algo, reward, &mut sim_env, episodes, seed)?;
        out.push(AlgoCell {
            algo: algo.to_string(),
            reward,
            world: "sim",
            throughput_gbps: thr,
            energy_j_per_mi: en,
        });

        // Real world: the live fluid simulator.
        let mut live = LiveEnv::new(tb.clone(), reward, ParamBounds::default(), 8, 40, seed ^ 0x1F);
        let (thr, en) = eval_in_env(ctx, algo, reward, &mut live, episodes, seed)?;
        out.push(AlgoCell {
            algo: algo.to_string(),
            reward,
            world: "real",
            throughput_gbps: thr,
            energy_j_per_mi: en,
        });
        crate::log_info!("fig4 {} ({}): done", algo, reward.short());
    }
    Ok(out)
}

pub fn print(cells: &[AlgoCell]) {
    println!("\nFig 4 — DRL algorithms, throughput and per-MI energy distributions:");
    let mut table = Table::new(&[
        "algo", "reward", "world", "thr mean", "thr p25", "thr p75", "energy/MI mean",
    ]);
    for c in cells {
        let t = Summary::of(&c.throughput_gbps);
        table.row(vec![
            c.algo.clone(),
            c.reward.short().to_string(),
            c.world.to_string(),
            format!("{:.2}", t.mean),
            format!("{:.2}", t.p25),
            format!("{:.2}", t.p75),
            format!("{:.1}", stats::mean(&c.energy_j_per_mi)),
        ]);
    }
    table.print();
}
