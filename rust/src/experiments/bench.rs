//! `sparta bench` — the repo's recorded performance trajectory.
//!
//! Runs a **scale curve** — fleet `churn-heavy` at 16/64/256 lanes on one
//! host (via [`ArrivalSchedule::churn_heavy_scaled`]), at **cluster
//! scale**: 1024 lanes sharded across 8 incast sender hosts (and 4096
//! across 16 in full mode) through [`crate::coordinator::Cluster`], and at
//! **giant scale** ([`BENCH_GIANT`]): 16384 lanes × 32 hosts in quick mode
//! and 65536 × 64 in full — on both simulator hot loops — the
//! struct-of-arrays arena ([`crate::net::NetworkSim`]) and the frozen
//! pre-arena loop ([`crate::net::baseline::BaselineSim`]) — plus the
//! hot-path microbenches, and emits a machine-readable `BENCH_*.json`.
//! The headline is **host-MIs/s at cluster scale**: cluster MIs × hosts
//! per wall second. Multi-host points are additionally timed with the
//! cluster's intra-step worker pool (§Perf in
//! [`crate::coordinator::cluster`]): a `threaded_wall_s_per_trial` column
//! at `min(hosts, cores)` step threads, whose report bytes the bench
//! requires to be identical to the serial run's — the threaded-vs-serial
//! wall comparison is a speedup claim only because the streams match.
//! Because the baseline is timed **in the same process on the same
//! machine**, the reported speedups are honest ratios, not stale
//! constants; and because both loops must produce byte-identical fleet
//! reports, every bench run doubles as a results-drift gate (the full gate
//! lives in `tests/golden_replay.rs`). The giant points skip the baseline
//! loop (a frozen O(N²)-ish reference at 65k lanes would dominate the
//! run for no information) — their ratchet quantity is the
//! threaded/serial ratio instead. CI runs `sparta bench --quick` and
//! uploads the `BENCH_*.json` artifact; the perf-trend job additionally
//! passes `--against <anchor>` so every PR pays its perf bill visibly
//! (see [`trend_gate`]).
//!
//! ## `BENCH_*.json` schema (version 4)
//!
//! Version 4 (PR 9) adds per-point `step_threads` plus the threaded
//! timing columns (`threaded_wall_s_per_trial`, `thread_speedup_x`), the
//! giant cluster points, and makes the baseline columns
//! (`baseline_wall_s_per_trial`, `speedup_x`) optional — absent on points
//! that skip the pre-arena loop. Version 3 (PR 7) added per-point `hosts`
//! and the cluster points ([`BENCH_CLUSTER`]); on multi-host points
//! `mis_per_s` counts **host-MIs** (cluster MIs × hosts). Version 2
//! (PR 6) added stable-comparison metadata (`meta`, `iters`), per-trial
//! MI counts (`trial_mis`), and the MIs/s headline over version 1 (PR 5).
//! Old anchors remain readable — the gate only needs
//! `scale_curve[*].{lanes, wall_s_per_trial}` plus whichever ratio
//! columns a point has, and `measured`; points without `hosts` /
//! `step_threads` are treated as single-host / serial.
//!
//! ```json
//! {
//!   "bench": "sparta-bench",          // harness identifier
//!   "schema_version": 4,
//!   "pr": 9,                          // PR that introduced the schema
//!   "mode": "quick" | "full",         // --quick: 120-MI horizon; full: 360
//!   "baseline": "net::baseline::BaselineSim (pre-arena loop, d6d9964),
//!                timed in-process",
//!   "measured": true,                 // false only in committed repo-root
//!                                     // schema/seed anchors, which also
//!                                     // carry a free-text "note"; the
//!                                     // trend gate treats those as
//!                                     // seed-only (record, don't compare)
//!   "iters": 3,                       // timing repetitions; walls below
//!                                     // are the per-iteration minimum
//!   "meta": {                         // where the numbers were taken
//!     "host": "runner-abc",           // /proc hostname (or $HOSTNAME)
//!     "os": "linux", "arch": "x86_64",
//!     "cpus": 8,                      // available parallelism
//!     "rustc": "rustc 1.79.0"         // compiler that built the binary
//!   },
//!   "scale_curve": [                  // one point per (lanes, hosts)
//!     { "lanes": 256,                 // requested fleet size
//!       "hosts": 1,                   // incast sender hosts the lanes are
//!                                     // sharded across (1 = single-host;
//!                                     // the trend gate matches points by
//!                                     // (lanes, hosts, step_threads))
//!       "step_threads": 1,            // intra-step cluster workers of the
//!                                     // threaded column (1 = no threaded
//!                                     // timing: single host or one core)
//!       "trials": 2,                  // seeded trials timed (jobs = 1)
//!       "horizon_mis": 120,           // MI cap per trial
//!       "mis_run": 240,               // MIs actually stepped, all trials
//!       "trial_mis": [120, 120],      // per-trial MI counts (from the
//!                                     // fleet report's serialized
//!                                     // `mis_run`), so MIs/s per trial
//!                                     // needs no re-derivation
//!       "wall_s_per_trial": 0.6,      // arena loop, serial stepping,
//!                                     // wall s per trial
//!       "mis_per_s": 400.0,           // host-MIs (MIs × hosts) per wall
//!                                     // second — the headline number
//!                                     // (serial wall)
//!       "ticks_per_s": 8000.0,        // fluid-model ticks per wall second
//!       "baseline_wall_s_per_trial": 2.1,  // pre-arena loop, same workload
//!                                     // (absent on giant points)
//!       "speedup_x": 3.5,             // baseline / arena wall per trial
//!                                     // (absent on giant points)
//!       "threaded_wall_s_per_trial": 0.2,  // arena loop at step_threads
//!                                     // workers (absent when
//!                                     // step_threads == 1)
//!       "thread_speedup_x": 3.0 }     // serial / threaded wall per trial
//!   ],
//!   "micro": [                        // hot-path microbenches
//!     { "name": "net sim MI (256 streams)", "per_op_s": ..., "ops_per_s": ... }
//!   ]
//! }
//! ```
//!
//! ## The perf-trend gate
//!
//! Wall seconds are machine-dependent, so the gate never compares them
//! across runs. Instead it compares **same-process wall ratios**: on
//! points with a baseline column, the arena/baseline ratio
//! (`1 / speedup_x`); on giant points (no baseline), the threaded/serial
//! ratio (`1 / thread_speedup_x`). Both sides of either ratio run the
//! identical seeded workload in the same process, so machine speed
//! cancels and the ratio isolates a real code regression. Points are
//! matched by `(lanes, hosts, step_threads)` — anchor points without the
//! newer fields default to single-host/serial — and the two runs must
//! agree on which ratio a point carries (a point that changed metric is
//! skipped, never silently compared). A point regresses when its ratio
//! worsens by more than [`TREND_MAX_REGRESS_FRAC`] relative to the
//! anchor's. Anchors with `"measured": false` (or an empty curve) are
//! **seed-only**: the gate records the fresh numbers and passes, so the
//! first measured run after a schema anchor establishes the ratchet
//! instead of tripping it — the CI `perf-trend` job caches its own
//! measured runs per runner class precisely so this gate compares
//! measured-vs-measured in practice. `--inject-slowdown <frac>` sleeps
//! that fraction of each arena timing (test flag) — CI uses it to prove
//! the gate fails a synthetic 15%+ slowdown.

use super::common::Scale;
use super::fleet::{self, FleetOpts};
use crate::config::Paths;
use crate::coordinator::{LaneSpec, Session};
use crate::net::baseline::BaselineSim;
use crate::net::{background::Background, NetworkSim, SimConfig, Substrate, Testbed};
use crate::scenarios::ArrivalSchedule;
use crate::telemetry::Table;
use crate::transfer::TransferJob;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// The single-host fleet sizes of the scale curve.
pub const BENCH_LANES: [usize; 3] = [16, 64, 256];

/// The cluster-scale points of the curve, `(lanes, sender hosts)`: lanes
/// sharded round-robin across an incast [`crate::coordinator::Cluster`].
/// The first point runs in `--quick` mode too (it feeds the CI perf-trend
/// ratchet); the rest are full-mode only.
pub const BENCH_CLUSTER: [(usize, usize); 2] = [(1024, 8), (4096, 16)];

/// The giant cluster points, `(lanes, sender hosts)` — the 16k–65k end of
/// the curve the intra-step worker pool exists for. The first runs in
/// `--quick` mode, the second full-mode only. These skip the pre-arena
/// baseline loop (its wall at this scale adds nothing but hours); their
/// ratchet quantity is the threaded/serial wall ratio instead.
pub const BENCH_GIANT: [(usize, usize); 2] = [(16384, 32), (65536, 64)];

/// Maximum tolerated worsening of the arena/baseline wall ratio vs the
/// anchor before the trend gate fails (15%).
pub const TREND_MAX_REGRESS_FRAC: f64 = 0.15;

/// Run knobs.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// 120-MI horizon instead of the full 360 (the CI lane).
    pub quick: bool,
    /// Timing repetitions per scale point; the reported wall is the
    /// per-iteration **minimum** (the least-noise estimator — external
    /// interference only ever adds time).
    pub iters: usize,
    /// Test flag: sleep this fraction of every arena timing, so CI can
    /// demonstrate the trend gate failing a synthetic slowdown. 0 in
    /// normal runs; the sleep is real and billed to the arena wall.
    pub inject_slowdown: f64,
    /// Restrict the curve to these fleet sizes (None = full
    /// [`BENCH_LANES`] curve).
    pub lanes: Option<Vec<usize>>,
    /// Intra-step cluster workers for the threaded timing column on
    /// multi-host points: `0` (the default) resolves to
    /// `min(hosts, cores)` per point; an explicit value is used as given.
    /// When the resolved count is 1 (single core, or single-host points)
    /// the threaded column is skipped.
    pub step_threads: usize,
    /// Optional fault preset: time the curve with a seeded
    /// [`crate::faults::FaultPlan`] installed per trial (recovery-path
    /// overhead). The pre-arena baseline loop has no fault plane, so its
    /// comparison column is skipped; the threaded byte-identity gate
    /// still runs — chaos must not break determinism.
    pub faults: Option<&'static crate::faults::FaultSchedule>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            quick: false,
            iters: 1,
            inject_slowdown: 0.0,
            lanes: None,
            step_threads: 0,
            faults: None,
        }
    }
}

/// One point of the scale curve: the same seeded workload timed on both
/// loops.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub lanes: usize,
    /// Incast sender hosts the lanes are sharded across (1 = single-host
    /// point; above 1 the workload runs a [`crate::coordinator::Cluster`]
    /// and `mis_per_s` / `ticks_per_s` count host-MIs / host-ticks).
    pub hosts: usize,
    /// Intra-step cluster workers of the threaded timing column (1 = no
    /// threaded column; the trend gate keys points by
    /// `(lanes, hosts, step_threads)`).
    pub step_threads: usize,
    pub trials: usize,
    pub horizon_mis: usize,
    /// MIs actually stepped, summed over trials (identical across loops —
    /// the reports are byte-identical).
    pub mis_run: usize,
    /// Per-trial MI counts, in trial order (the fleet report's serialized
    /// `mis_run` values).
    pub trial_mis: Vec<usize>,
    /// Arena loop, serial stepping, wall seconds per trial.
    pub wall_s_per_trial: f64,
    pub mis_per_s: f64,
    pub ticks_per_s: f64,
    /// Frozen pre-arena loop, wall seconds per trial, same workload.
    /// `None` on giant points, which skip the baseline loop.
    pub baseline_wall_s_per_trial: Option<f64>,
    /// `baseline / arena` wall per trial (`None` with no baseline timing).
    pub speedup_x: Option<f64>,
    /// Arena loop at `step_threads` intra-step workers, wall seconds per
    /// trial. `None` when `step_threads == 1`.
    pub threaded_wall_s_per_trial: Option<f64>,
    /// `serial / threaded` wall per trial (`None` with no threaded
    /// timing).
    pub thread_speedup_x: Option<f64>,
}

/// One hot-path microbench row.
#[derive(Debug, Clone)]
pub struct MicroBench {
    pub name: &'static str,
    pub per_op_s: f64,
    pub ops_per_s: f64,
}

/// Where the numbers were taken: enough context to tell a code regression
/// from a machine or toolchain change when reading an anchor later.
#[derive(Debug, Clone)]
pub struct BenchMeta {
    pub host: String,
    pub os: &'static str,
    pub arch: &'static str,
    pub cpus: usize,
    pub rustc: &'static str,
}

impl BenchMeta {
    pub fn collect() -> Self {
        let host = std::fs::read_to_string("/proc/sys/kernel/hostname")
            .map(|s| s.trim().to_string())
            .ok()
            .filter(|s| !s.is_empty())
            .or_else(|| std::env::var("HOSTNAME").ok())
            .unwrap_or_else(|| "unknown".to_string());
        BenchMeta {
            host,
            os: std::env::consts::OS,
            arch: std::env::consts::ARCH,
            cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            rustc: option_env!("SPARTA_RUSTC_VERSION").unwrap_or("unknown"),
        }
    }
}

/// The full bench report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub quick: bool,
    pub iters: usize,
    pub meta: BenchMeta,
    pub points: Vec<ScalePoint>,
    pub micro: Vec<MicroBench>,
}

/// Time `reps` iterations of `f`; returns mean seconds per call. Shared
/// with `benches/micro.rs` so the standalone bench binary and `sparta
/// bench` report the same quantities.
pub fn bench_loop<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Seconds per simulator MI for one 16×16-stream flow under medium cross
/// traffic — the `net sim MI (256 streams)` microbench. `baseline`
/// selects the frozen pre-arena loop.
pub fn sim_mi_micro(reps: usize, baseline: bool) -> f64 {
    let tb = Testbed::chameleon();
    let bg = Background::regime("medium", 10.0);
    let mut sim: Box<dyn Substrate> = if baseline {
        Box::new(BaselineSim::new(tb, 1).with_background(bg))
    } else {
        Box::new(NetworkSim::new(tb, 1).with_background(bg))
    };
    sim.add_flow(16, 16, None);
    let mut out = Vec::new();
    for _ in 0..10 {
        sim.run_mi_into(1.0, &mut out);
    }
    bench_loop(reps, || {
        sim.run_mi_into(1.0, &mut out);
    })
}

/// Seconds per `Session::step` with `lanes` static transfer lanes in
/// flight (jobs sized so no lane completes during the measurement).
pub fn session_step_micro(lanes: usize, reps: usize) -> f64 {
    let mut session = Session::builder(Testbed::chameleon())
        .background(Background::Idle)
        .seed(7)
        .build();
    for _ in 0..lanes {
        session.admit(LaneSpec::new(
            Box::new(crate::baselines::StaticTool::efficient_static(4, 4)),
            TransferJob::files(100_000, 1 << 30),
        ));
    }
    let mut events = Vec::new();
    for _ in 0..5 {
        session.step_into(&mut events);
    }
    bench_loop(reps, || {
        session.step_into(&mut events);
    })
}

/// Time one side of a scale point: `trials × churn-heavy(lanes)` at
/// `--jobs 1` (so wall per trial is not muddied by worker scheduling).
/// `hosts` above 1 runs each trial as an incast cluster; `step_threads`
/// above 1 steps its hosts with the intra-step worker pool.
fn timed_fleet(
    paths: &Paths,
    sched: &ArrivalSchedule,
    methods: &[String],
    baseline_loop: bool,
    hosts: usize,
    step_threads: usize,
    faults: Option<&'static crate::faults::FaultSchedule>,
) -> Result<(fleet::FleetReport, f64)> {
    let opts = FleetOpts { baseline_loop, hosts, step_threads, faults, ..FleetOpts::default() };
    let t0 = Instant::now();
    let report = fleet::run(paths, sched, methods, Scale::Quick, 42, 1, opts)?;
    Ok((report, t0.elapsed().as_secs_f64()))
}

/// Run the scale curve (both loops) plus microbenches.
pub fn run(paths: &Paths, opts: BenchOpts) -> Result<BenchReport> {
    let horizon = if opts.quick { 120 } else { 360 };
    let iters = opts.iters.max(1);
    let methods: Vec<String> =
        ["falcon_mp", "2-phase", "rclone"].iter().map(|m| m.to_string()).collect();
    // Discarded warmup on both loops, so one-time process costs (lazy
    // statics, allocator growth, page-cache warmup) are not billed to
    // whichever side happens to be timed first.
    let warmup = ArrivalSchedule::churn_heavy_scaled(8, 30);
    timed_fleet(paths, &warmup, &methods, false, 1, 1, opts.faults)?;
    timed_fleet(paths, &warmup, &methods, true, 1, 1, None)?;
    // The curve as (lanes, hosts, with_baseline) points: the single-host
    // sizes, the incast cluster points, then the giant points (which skip
    // the frozen baseline loop — module docs). The first cluster and giant
    // points also run in quick mode. An explicit --lanes subset keeps the
    // curve single-host.
    let curve: Vec<(usize, usize, bool)> = match &opts.lanes {
        Some(subset) => subset.iter().map(|&l| (l, 1, true)).collect(),
        None => {
            let mut c: Vec<(usize, usize, bool)> =
                BENCH_LANES.iter().map(|&l| (l, 1, true)).collect();
            let take = if opts.quick { 1 } else { 2 };
            c.extend(BENCH_CLUSTER[..take].iter().map(|&(l, h)| (l, h, true)));
            c.extend(BENCH_GIANT[..take].iter().map(|&(l, h)| (l, h, false)));
            c
        }
    };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut points = Vec::new();
    for &(lanes, hosts, with_baseline) in &curve {
        let sched = ArrivalSchedule::churn_heavy_scaled(lanes, horizon);
        // The threaded column's worker count: explicit --step-threads, or
        // min(hosts, cores). 1 (single host, or one core) skips the column.
        let step_threads = match opts.step_threads {
            0 => hosts.min(cores),
            n => n.min(hosts),
        };
        // Stable-comparison mode: repeat the timing and keep the minimum
        // wall per side — interference only ever adds time, so the min is
        // the low-noise estimator the trend gate compares.
        let mut wall = f64::INFINITY;
        let mut base_wall = f64::INFINITY;
        let mut threaded_wall = f64::INFINITY;
        let mut report = None;
        // A fault plan disables the baseline comparison column (the
        // frozen loop has no fault plane to replay it on).
        let with_baseline = with_baseline && opts.faults.is_none();
        for _ in 0..iters {
            let (rep, mut w) = timed_fleet(paths, &sched, &methods, false, hosts, 1, opts.faults)?;
            if opts.inject_slowdown > 0.0 {
                // Real sleep, billed to the arena wall: the synthetic
                // regression the CI perf-trend job proves it can catch.
                let pause = w * opts.inject_slowdown;
                std::thread::sleep(std::time::Duration::from_secs_f64(pause));
                w += pause;
            }
            if with_baseline {
                let (base_rep, base_w) =
                    timed_fleet(paths, &sched, &methods, true, hosts, 1, None)?;
                // The bench doubles as a drift gate: both loops must
                // produce the same report bytes (full suite:
                // tests/golden_replay.rs).
                if fleet::to_json(&rep).to_string() != fleet::to_json(&base_rep).to_string() {
                    return Err(anyhow!(
                        "bench: arena and baseline loops diverged at {lanes} lanes — \
                         results drift, not a perf difference"
                    ));
                }
                base_wall = base_wall.min(base_w);
            }
            if step_threads > 1 {
                let (thr_rep, thr_w) =
                    timed_fleet(paths, &sched, &methods, false, hosts, step_threads, opts.faults)?;
                // Byte-identity is what makes the threaded column a
                // speedup rather than a different computation.
                if fleet::to_json(&rep).to_string() != fleet::to_json(&thr_rep).to_string() {
                    return Err(anyhow!(
                        "bench: threaded cluster stepping diverged from serial at \
                         {lanes} lanes x {hosts} hosts x {step_threads} threads"
                    ));
                }
                threaded_wall = threaded_wall.min(thr_w);
            }
            wall = wall.min(w);
            report = Some(rep);
        }
        let report = report.expect("iters >= 1");
        let trials = report.trials.len().max(1);
        let trial_mis: Vec<usize> = report.trials.iter().map(|t| t.mis_run).collect();
        let mis_run: usize = trial_mis.iter().sum();
        // Fluid ticks per MI at the bench scenario's defaults (1.0-s MI,
        // 0.05-s tick).
        let ticks_per_mi = (1.0 / SimConfig::default().tick_s).round();
        // Cluster points report host-MIs: every cluster MI steps all hosts.
        let host_mis = (mis_run * hosts) as f64;
        let threaded = step_threads > 1;
        let point = ScalePoint {
            lanes,
            hosts,
            step_threads: if threaded { step_threads } else { 1 },
            trials,
            horizon_mis: horizon,
            mis_run,
            trial_mis,
            wall_s_per_trial: wall / trials as f64,
            mis_per_s: host_mis / wall,
            ticks_per_s: host_mis * ticks_per_mi / wall,
            baseline_wall_s_per_trial: with_baseline.then(|| base_wall / trials as f64),
            speedup_x: with_baseline.then(|| base_wall / wall),
            threaded_wall_s_per_trial: threaded.then(|| threaded_wall / trials as f64),
            thread_speedup_x: threaded.then(|| wall / threaded_wall),
        };
        let base_col = point
            .speedup_x
            .map(|s| format!("baseline {:.2}x", s))
            .unwrap_or_else(|| "no baseline".to_string());
        let thr_col = point
            .thread_speedup_x
            .map(|s| format!(", {} threads {:.2}x", point.step_threads, s))
            .unwrap_or_default();
        crate::log_info!(
            "bench: {} lanes x {} host(s), {} trials, arena {:.2} s/trial ({base_col}{thr_col})",
            lanes,
            hosts,
            trials,
            point.wall_s_per_trial,
        );
        points.push(point);
    }
    let micro_reps = if opts.quick { 60 } else { 200 };
    let sim_s = sim_mi_micro(micro_reps, false);
    let sim_base_s = sim_mi_micro(micro_reps, true);
    let step1_s = session_step_micro(1, micro_reps);
    let step8_s = session_step_micro(8, micro_reps);
    let micro = vec![
        MicroBench { name: "net sim MI (256 streams)", per_op_s: sim_s, ops_per_s: 1.0 / sim_s },
        MicroBench {
            name: "net sim MI (256 streams, pre-arena baseline)",
            per_op_s: sim_base_s,
            ops_per_s: 1.0 / sim_base_s,
        },
        MicroBench { name: "session step (1 lane)", per_op_s: step1_s, ops_per_s: 1.0 / step1_s },
        MicroBench { name: "session step (8 lanes)", per_op_s: step8_s, ops_per_s: 1.0 / step8_s },
    ];
    Ok(BenchReport { quick: opts.quick, iters, meta: BenchMeta::collect(), points, micro })
}

/// Human summary: the scale curve and microbenches.
pub fn print(report: &BenchReport) {
    println!(
        "\nBench — fleet churn-heavy scale curve, arena vs pre-arena baseline \
         ({} mode, jobs 1, min of {} iter{}):",
        if report.quick { "quick" } else { "full" },
        report.iters,
        if report.iters == 1 { "" } else { "s" }
    );
    println!(
        "  on {} ({}/{}, {} cpus, {})",
        report.meta.host, report.meta.os, report.meta.arch, report.meta.cpus, report.meta.rustc
    );
    if let Some(peak) = report.points.iter().map(|p| p.mis_per_s).fold(None, |m: Option<f64>, x| {
        Some(m.map_or(x, |m| m.max(x)))
    }) {
        println!("  headline: {peak:.0} host-MIs/s peak across the curve (cluster scale)");
    }
    let mut t = Table::new(&[
        "lanes",
        "hosts",
        "trials",
        "MIs run",
        "s/trial",
        "baseline s/trial",
        "MIs/s",
        "speedup",
        "threads",
        "threaded s/trial",
        "thread speedup",
    ]);
    let opt3 = |v: Option<f64>| v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into());
    let optx = |v: Option<f64>| v.map(|x| format!("{x:.2}x")).unwrap_or_else(|| "-".into());
    for p in &report.points {
        t.row(vec![
            p.lanes.to_string(),
            p.hosts.to_string(),
            p.trials.to_string(),
            p.mis_run.to_string(),
            format!("{:.3}", p.wall_s_per_trial),
            opt3(p.baseline_wall_s_per_trial),
            format!("{:.0}", p.mis_per_s),
            optx(p.speedup_x),
            p.step_threads.to_string(),
            opt3(p.threaded_wall_s_per_trial),
            optx(p.thread_speedup_x),
        ]);
    }
    t.print();
    let mut t = Table::new(&["microbench", "per-op", "ops/s"]);
    for m in &report.micro {
        let fmt = if m.per_op_s < 1e-3 {
            format!("{:.1} us", m.per_op_s * 1e6)
        } else {
            format!("{:.2} ms", m.per_op_s * 1e3)
        };
        t.row(vec![m.name.into(), fmt, format!("{:.0}", m.ops_per_s)]);
    }
    t.print();
}

/// The `BENCH_*.json` payload (schema documented in the module docs).
pub fn to_json(report: &BenchReport) -> Json {
    Json::obj(vec![
        ("bench", Json::from("sparta-bench")),
        ("schema_version", Json::from(4usize)),
        ("pr", Json::from(9usize)),
        ("mode", Json::from(if report.quick { "quick" } else { "full" })),
        (
            "baseline",
            Json::from("net::baseline::BaselineSim (pre-arena loop, d6d9964), timed in-process"),
        ),
        ("measured", Json::from(true)),
        ("iters", Json::from(report.iters)),
        (
            "meta",
            Json::obj(vec![
                ("host", Json::from(report.meta.host.clone())),
                ("os", Json::from(report.meta.os)),
                ("arch", Json::from(report.meta.arch)),
                ("cpus", Json::from(report.meta.cpus)),
                ("rustc", Json::from(report.meta.rustc)),
            ]),
        ),
        (
            "scale_curve",
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        let mut fields = vec![
                            ("lanes", Json::from(p.lanes)),
                            ("hosts", Json::from(p.hosts)),
                            ("step_threads", Json::from(p.step_threads)),
                            ("trials", Json::from(p.trials)),
                            ("horizon_mis", Json::from(p.horizon_mis)),
                            ("mis_run", Json::from(p.mis_run)),
                            (
                                "trial_mis",
                                Json::Arr(p.trial_mis.iter().map(|&m| Json::from(m)).collect()),
                            ),
                            ("wall_s_per_trial", Json::from(p.wall_s_per_trial)),
                            ("mis_per_s", Json::from(p.mis_per_s)),
                            ("ticks_per_s", Json::from(p.ticks_per_s)),
                        ];
                        // Optional columns are absent, not null, so old
                        // readers (and the gate) need no null handling.
                        if let Some(b) = p.baseline_wall_s_per_trial {
                            fields.push(("baseline_wall_s_per_trial", Json::from(b)));
                        }
                        if let Some(s) = p.speedup_x {
                            fields.push(("speedup_x", Json::from(s)));
                        }
                        if let Some(t) = p.threaded_wall_s_per_trial {
                            fields.push(("threaded_wall_s_per_trial", Json::from(t)));
                        }
                        if let Some(s) = p.thread_speedup_x {
                            fields.push(("thread_speedup_x", Json::from(s)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "micro",
            Json::Arr(
                report
                    .micro
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("name", Json::from(m.name)),
                            ("per_op_s", Json::from(m.per_op_s)),
                            ("ops_per_s", Json::from(m.ops_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

// ---------------------------------------------------------------------------
// Perf-trend gate (`sparta bench --against <anchor>`)
// ---------------------------------------------------------------------------

/// One lane point compared against the anchor.
#[derive(Debug, Clone)]
pub struct TrendRow {
    pub lanes: usize,
    /// Incast hosts of the point (points are matched by `(lanes, hosts,
    /// step_threads)`; anchor points without the newer fields are
    /// single-host/serial).
    pub hosts: usize,
    /// Intra-step workers of the point's threaded column (1 = serial).
    pub step_threads: usize,
    /// Which same-process wall ratio this row ratchets:
    /// `"arena/baseline"` on points with a baseline column,
    /// `"threaded/serial"` on giant points without one. Both runs must
    /// carry the same metric for a point to compare.
    pub metric: &'static str,
    /// Anchor's ratio for `metric` — the machine-normalized quantity the
    /// ratchet tracks.
    pub anchor_ratio: f64,
    /// This run's ratio for `metric`.
    pub current_ratio: f64,
    /// `current_ratio / anchor_ratio - 1`: positive means this run got
    /// slower relative to its in-process reference.
    pub delta_frac: f64,
    pub regressed: bool,
}

/// Outcome of [`trend_gate`].
#[derive(Debug, Clone)]
pub struct TrendReport {
    /// The anchor was unmeasured (`"measured": false` or empty curve):
    /// this run records the first real numbers instead of comparing.
    pub seed_only: bool,
    pub rows: Vec<TrendRow>,
    /// Fleet sizes in this run with no counterpart in the anchor curve.
    pub skipped: Vec<usize>,
    pub max_regress_frac: f64,
}

impl TrendReport {
    pub fn failed(&self) -> bool {
        self.rows.iter().any(|r| r.regressed)
    }
}

/// Compare a fresh run against a committed `BENCH_*.json` anchor.
///
/// Never compares raw wall seconds (machine-dependent); see the module
/// docs for the ratio normalization. Unmeasured anchors — the committed
/// schema/seed files with `"measured": false` — are seed-only: the gate
/// passes and the fresh artifact becomes the next anchor. Reads only
/// fields present since schema v1, so old anchors stay comparable.
pub fn trend_gate(
    current: &BenchReport,
    anchor: &Json,
    max_regress_frac: f64,
) -> Result<TrendReport> {
    if anchor.as_obj().is_none() {
        return Err(anyhow!("trend gate: anchor is not a JSON object"));
    }
    let measured = anchor.get("measured").and_then(Json::as_bool).unwrap_or(false);
    let empty: [Json; 0] = [];
    let curve = anchor.get("scale_curve").and_then(Json::as_arr).unwrap_or(&empty);
    // The ratchet quantity of one curve point: the arena/baseline wall
    // ratio when the point carries a baseline column, else the
    // threaded/serial ratio (giant points). The label rides along so the
    // gate never compares a point whose metric changed between runs.
    fn ratio_of(
        wall: f64,
        base: Option<f64>,
        threaded: Option<f64>,
    ) -> Option<(&'static str, f64)> {
        if wall <= 0.0 {
            return None;
        }
        if let Some(b) = base.filter(|&b| b > 0.0) {
            return Some(("arena/baseline", wall / b));
        }
        threaded.filter(|&t| t > 0.0).map(|t| ("threaded/serial", t / wall))
    }
    // Anchor points with usable timings, keyed by (lanes, hosts,
    // step_threads) — points without the newer fields (schema < 3 / < 4)
    // are single-host / serial.
    let mut anchor_ratios: Vec<(usize, usize, usize, &'static str, f64)> = Vec::new();
    for p in curve {
        let lanes = p.get("lanes").and_then(Json::as_usize);
        let hosts = p.get("hosts").and_then(Json::as_usize).unwrap_or(1);
        let threads = p.get("step_threads").and_then(Json::as_usize).unwrap_or(1);
        let wall = p.get("wall_s_per_trial").and_then(Json::as_f64);
        let base = p.get("baseline_wall_s_per_trial").and_then(Json::as_f64);
        let thr = p.get("threaded_wall_s_per_trial").and_then(Json::as_f64);
        if let (Some(l), Some(w)) = (lanes, wall) {
            if let Some((metric, r)) = ratio_of(w, base, thr) {
                anchor_ratios.push((l, hosts, threads, metric, r));
            }
        }
    }
    if !measured || anchor_ratios.is_empty() {
        return Ok(TrendReport {
            seed_only: true,
            rows: Vec::new(),
            skipped: current.points.iter().map(|p| p.lanes).collect(),
            max_regress_frac,
        });
    }
    let mut rows = Vec::new();
    let mut skipped = Vec::new();
    for p in &current.points {
        let anchor_hit = anchor_ratios
            .iter()
            .find(|(l, h, t, _, _)| *l == p.lanes && *h == p.hosts && *t == p.step_threads);
        let current_ratio =
            ratio_of(p.wall_s_per_trial, p.baseline_wall_s_per_trial, p.threaded_wall_s_per_trial);
        match (anchor_hit, current_ratio) {
            (Some(&(_, _, _, am, a)), Some((cm, c))) if am == cm => {
                let delta_frac = c / a - 1.0;
                rows.push(TrendRow {
                    lanes: p.lanes,
                    hosts: p.hosts,
                    step_threads: p.step_threads,
                    metric: cm,
                    anchor_ratio: a,
                    current_ratio: c,
                    delta_frac,
                    regressed: delta_frac > max_regress_frac,
                });
            }
            _ => skipped.push(p.lanes),
        }
    }
    Ok(TrendReport { seed_only: false, rows, skipped, max_regress_frac })
}

/// Human summary of the trend comparison (stdout).
pub fn trend_print(trend: &TrendReport) {
    if trend.seed_only {
        println!(
            "\nPerf trend: anchor is seed-only (unmeasured) — recording this run, not comparing."
        );
        return;
    }
    println!(
        "\nPerf trend vs anchor (same-process wall ratios; fail above +{:.0}%):",
        trend.max_regress_frac * 100.0
    );
    let mut t = Table::new(&[
        "lanes",
        "hosts",
        "threads",
        "metric",
        "anchor ratio",
        "current ratio",
        "delta",
        "verdict",
    ]);
    for r in &trend.rows {
        t.row(vec![
            r.lanes.to_string(),
            r.hosts.to_string(),
            r.step_threads.to_string(),
            r.metric.to_string(),
            format!("{:.4}", r.anchor_ratio),
            format!("{:.4}", r.current_ratio),
            format!("{:+.1}%", r.delta_frac * 100.0),
            if r.regressed { "REGRESSED".into() } else { "ok".into() },
        ]);
    }
    t.print();
    if !trend.skipped.is_empty() {
        let s: Vec<String> = trend.skipped.iter().map(|l| l.to_string()).collect();
        println!("  (no anchor counterpart for {} lanes — skipped)", s.join(", "));
    }
}

/// Markdown rendering of the per-lane delta table, for the CI job summary
/// (`$GITHUB_STEP_SUMMARY`).
pub fn trend_markdown(trend: &TrendReport) -> String {
    let mut md = String::from("### Perf trend vs committed anchor\n\n");
    if trend.seed_only {
        md.push_str("Anchor is seed-only (unmeasured): recorded this run, nothing to compare.\n");
        return md;
    }
    md.push_str(&format!(
        "Same-process wall ratio per curve point; gate fails above +{:.0}%.\n\n",
        trend.max_regress_frac * 100.0
    ));
    md.push_str(
        "| lanes | hosts | threads | metric | anchor ratio | current ratio | delta | verdict |\n",
    );
    md.push_str("|---:|---:|---:|---|---:|---:|---:|---|\n");
    for r in &trend.rows {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {:.4} | {:.4} | {:+.1}% | {} |\n",
            r.lanes,
            r.hosts,
            r.step_threads,
            r.metric,
            r.anchor_ratio,
            r.current_ratio,
            r.delta_frac * 100.0,
            if r.regressed { "**REGRESSED**" } else { "ok" },
        ));
    }
    if !trend.skipped.is_empty() {
        let s: Vec<String> = trend.skipped.iter().map(|l| l.to_string()).collect();
        md.push_str(&format!("\nNo anchor counterpart for {} lanes (skipped).\n", s.join(", ")));
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(lanes: usize, wall: f64, base: f64) -> ScalePoint {
        ScalePoint {
            lanes,
            hosts: 1,
            step_threads: 1,
            trials: 2,
            horizon_mis: 120,
            mis_run: 240,
            trial_mis: vec![120, 120],
            wall_s_per_trial: wall,
            mis_per_s: 240.0 / wall,
            ticks_per_s: 4800.0 / wall,
            baseline_wall_s_per_trial: Some(base),
            speedup_x: Some(base / wall),
            threaded_wall_s_per_trial: None,
            thread_speedup_x: None,
        }
    }

    /// A giant-style point: no baseline columns, a threaded column at
    /// `threads` workers.
    fn giant_point(lanes: usize, hosts: usize, threads: usize, wall: f64, thr: f64) -> ScalePoint {
        ScalePoint {
            hosts,
            step_threads: threads,
            baseline_wall_s_per_trial: None,
            speedup_x: None,
            threaded_wall_s_per_trial: Some(thr),
            thread_speedup_x: Some(wall / thr),
            ..point(lanes, wall, 0.0)
        }
    }

    fn rep(points: Vec<ScalePoint>) -> BenchReport {
        BenchReport {
            quick: true,
            iters: 1,
            meta: BenchMeta::collect(),
            points,
            micro: Vec::new(),
        }
    }

    /// Round-trips the anchor through the real serializer + parser, so the
    /// gate is tested against the bytes CI actually reads back.
    fn anchor_of(points: Vec<ScalePoint>) -> Json {
        Json::parse(&to_json(&rep(points)).to_string()).unwrap()
    }

    #[test]
    fn trend_gate_passes_at_parity_and_below_threshold() {
        let anchor = anchor_of(vec![point(16, 1.0, 3.0), point(64, 2.0, 7.0)]);
        // Identical ratios, then a 10% worsening at 64 lanes: both within
        // the 15% ratchet.
        let current = rep(vec![point(16, 1.0, 3.0), point(64, 2.2, 7.0)]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert!(!t.seed_only);
        assert_eq!(t.rows.len(), 2);
        assert!(!t.failed(), "rows: {:?}", t.rows);
        assert!(t.rows[0].delta_frac.abs() < 1e-12);
        assert!((t.rows[1].delta_frac - 0.10).abs() < 1e-9);
    }

    #[test]
    fn trend_gate_fails_past_threshold() {
        let anchor = anchor_of(vec![point(16, 1.0, 3.0)]);
        // 25% worse arena/baseline ratio: the synthetic slowdown CI injects.
        let current = rep(vec![point(16, 1.25, 3.0)]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert!(t.failed());
        assert!(t.rows[0].regressed);
        assert!((t.rows[0].delta_frac - 0.25).abs() < 1e-9);
        assert!(trend_markdown(&t).contains("**REGRESSED**"));
    }

    #[test]
    fn trend_gate_normalizes_out_machine_speed() {
        let anchor = anchor_of(vec![point(16, 1.0, 3.0)]);
        // A machine 4x slower across the board: ratios unchanged, no fail.
        let current = rep(vec![point(16, 4.0, 12.0)]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert!(!t.failed());
        assert!(t.rows[0].delta_frac.abs() < 1e-12);
    }

    #[test]
    fn trend_gate_treats_unmeasured_anchor_as_seed_only() {
        // The shape of the committed schema/seed anchors: measured false,
        // empty arrays, free-text note.
        let anchor = Json::parse(
            r#"{"bench":"sparta-bench","schema_version":2,"measured":false,
                "note":"seed anchor","scale_curve":[],"micro":[]}"#,
        )
        .unwrap();
        let current = rep(vec![point(16, 1.0, 3.0)]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert!(t.seed_only);
        assert!(!t.failed());
        assert!(t.rows.is_empty());
        assert!(trend_markdown(&t).contains("seed-only"));
        // A measured flag with an empty curve is equally seed-only: there
        // is nothing to compare against.
        let hollow =
            Json::parse(r#"{"measured":true,"scale_curve":[]}"#).unwrap();
        assert!(trend_gate(&current, &hollow, TREND_MAX_REGRESS_FRAC).unwrap().seed_only);
    }

    #[test]
    fn trend_gate_matches_points_by_lanes_and_hosts() {
        // A cluster point only compares against an anchor point with the
        // same (lanes, hosts) pair.
        let cluster = ScalePoint { hosts: 8, ..point(1024, 2.0, 7.0) };
        let anchor = anchor_of(vec![cluster.clone()]);
        let t = trend_gate(&rep(vec![cluster]), &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0].hosts, 8);
        assert_eq!(t.rows[0].metric, "arena/baseline");
        assert!(!t.failed());
        // The same lane count on one host has no counterpart: skipped, so
        // re-sharding a point can never trip the ratchet silently.
        let t = trend_gate(&rep(vec![point(1024, 2.0, 7.0)]), &anchor, TREND_MAX_REGRESS_FRAC)
            .unwrap();
        assert!(t.rows.is_empty());
        assert_eq!(t.skipped, vec![1024]);
    }

    #[test]
    fn trend_gate_ratchets_threaded_ratio_on_giant_points() {
        // Giant points have no baseline column: the ratchet quantity is
        // the threaded/serial ratio, matched by (lanes, hosts,
        // step_threads).
        let anchor = anchor_of(vec![giant_point(16384, 32, 8, 10.0, 2.5)]);
        // Same ratio, 2x slower machine: passes.
        let t = trend_gate(
            &rep(vec![giant_point(16384, 32, 8, 20.0, 5.0)]),
            &anchor,
            TREND_MAX_REGRESS_FRAC,
        )
        .unwrap();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0].metric, "threaded/serial");
        assert_eq!(t.rows[0].step_threads, 8);
        assert!(!t.failed(), "rows: {:?}", t.rows);
        assert!(t.rows[0].delta_frac.abs() < 1e-9);
        // Threaded wall worsening 30% relative to serial: regressed.
        let t = trend_gate(
            &rep(vec![giant_point(16384, 32, 8, 10.0, 3.25)]),
            &anchor,
            TREND_MAX_REGRESS_FRAC,
        )
        .unwrap();
        assert!(t.failed());
        // A different thread count (another runner class) never compares:
        // the point is skipped, not misjudged.
        let t = trend_gate(
            &rep(vec![giant_point(16384, 32, 4, 10.0, 3.0)]),
            &anchor,
            TREND_MAX_REGRESS_FRAC,
        )
        .unwrap();
        assert!(t.rows.is_empty());
        assert_eq!(t.skipped, vec![16384]);
    }

    #[test]
    fn trend_gate_skips_points_whose_metric_changed() {
        // Anchor measured arena/baseline; the current run has only a
        // threaded column for that shape. Comparing the two ratios would
        // be meaningless — the point must be skipped.
        let anchor = anchor_of(vec![point(256, 1.0, 3.0)]);
        let current = rep(vec![ScalePoint {
            baseline_wall_s_per_trial: None,
            speedup_x: None,
            threaded_wall_s_per_trial: Some(0.5),
            thread_speedup_x: Some(2.0),
            ..point(256, 1.0, 0.0)
        }]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert!(t.rows.is_empty());
        assert_eq!(t.skipped, vec![256]);
    }

    #[test]
    fn scale_point_json_omits_absent_optional_columns() {
        let j = Json::parse(&to_json(&rep(vec![giant_point(16384, 32, 8, 10.0, 2.5)])).to_string())
            .unwrap();
        let p = &j.get("scale_curve").and_then(Json::as_arr).unwrap()[0];
        assert!(p.get("baseline_wall_s_per_trial").is_none());
        assert!(p.get("speedup_x").is_none());
        assert_eq!(p.get("step_threads").and_then(Json::as_usize), Some(8));
        assert!((p.get("thread_speedup_x").and_then(Json::as_f64).unwrap() - 4.0).abs() < 1e-9);
        let j = Json::parse(&to_json(&rep(vec![point(16, 1.0, 3.0)])).to_string()).unwrap();
        let p = &j.get("scale_curve").and_then(Json::as_arr).unwrap()[0];
        assert!(p.get("threaded_wall_s_per_trial").is_none());
        assert!((p.get("speedup_x").and_then(Json::as_f64).unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn trend_gate_skips_lanes_missing_from_anchor() {
        let anchor = anchor_of(vec![point(16, 1.0, 3.0)]);
        let current = rep(vec![point(16, 1.0, 3.0), point(64, 2.0, 7.0)]);
        let t = trend_gate(&current, &anchor, TREND_MAX_REGRESS_FRAC).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.skipped, vec![64]);
        assert!(!t.failed());
    }

    #[test]
    fn trend_gate_rejects_non_object_anchor() {
        let current = rep(vec![point(16, 1.0, 3.0)]);
        assert!(trend_gate(&current, &Json::Arr(vec![]), TREND_MAX_REGRESS_FRAC).is_err());
    }
}
