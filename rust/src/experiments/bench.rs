//! `sparta bench` — the repo's recorded performance trajectory.
//!
//! Runs a **scale curve** (fleet `churn-heavy` at 16/64/256 lanes via
//! [`ArrivalSchedule::churn_heavy_scaled`]) on both simulator hot loops —
//! the struct-of-arrays arena ([`crate::net::NetworkSim`]) and the frozen
//! pre-arena loop ([`crate::net::baseline::BaselineSim`]) — plus the
//! hot-path microbenches, and emits a machine-readable `BENCH_5.json`.
//! Because the baseline is timed **in the same process on the same
//! machine**, the reported speedups are honest ratios, not stale
//! constants; and because both loops must produce byte-identical fleet
//! reports, every bench run doubles as a results-drift gate (the full gate
//! lives in `tests/golden_replay.rs`). CI runs `sparta bench --quick` and
//! uploads `BENCH_5.json` as an artifact.
//!
//! ## `BENCH_*.json` schema (version 1)
//!
//! ```json
//! {
//!   "bench": "sparta-bench",          // harness identifier
//!   "schema_version": 1,
//!   "pr": 5,                          // PR that introduced the file
//!   "mode": "quick" | "full",         // --quick: 120-MI horizon; full: 360
//!   "baseline": "net::baseline::BaselineSim (pre-arena loop, d6d9964),
//!                timed in-process",
//!   "measured": true,                 // false only in the committed
//!                                     // repo-root schema anchor, which
//!                                     // also carries a free-text "note"
//!                                     // and empty curve/micro arrays
//!   "scale_curve": [                  // one point per fleet size
//!     { "lanes": 256,                 // requested fleet size
//!       "trials": 2,                  // seeded trials timed (jobs = 1)
//!       "horizon_mis": 120,           // MI cap per trial
//!       "mis_run": 240,               // MIs actually stepped, all trials
//!       "wall_s_per_trial": 0.6,      // arena loop, wall s per trial
//!       "mis_per_s": 400.0,           // simulated MIs per wall second
//!       "ticks_per_s": 8000.0,        // fluid-model ticks per wall second
//!       "baseline_wall_s_per_trial": 2.1,  // pre-arena loop, same workload
//!       "speedup_x": 3.5 }            // baseline / arena wall per trial
//!   ],
//!   "micro": [                        // hot-path microbenches
//!     { "name": "net sim MI (256 streams)", "per_op_s": ..., "ops_per_s": ... }
//!   ]
//! }
//! ```

use super::common::Scale;
use super::fleet::{self, FleetOpts};
use crate::config::Paths;
use crate::coordinator::{LaneSpec, Session};
use crate::net::baseline::BaselineSim;
use crate::net::{background::Background, NetworkSim, SimConfig, Substrate, Testbed};
use crate::scenarios::ArrivalSchedule;
use crate::telemetry::Table;
use crate::transfer::TransferJob;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::time::Instant;

/// The fleet sizes of the scale curve.
pub const BENCH_LANES: [usize; 3] = [16, 64, 256];

/// Run knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct BenchOpts {
    /// 120-MI horizon instead of the full 360 (the CI lane).
    pub quick: bool,
}

/// One point of the scale curve: the same seeded workload timed on both
/// loops.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub lanes: usize,
    pub trials: usize,
    pub horizon_mis: usize,
    /// MIs actually stepped, summed over trials (identical across loops —
    /// the reports are byte-identical).
    pub mis_run: usize,
    /// Arena loop, wall seconds per trial.
    pub wall_s_per_trial: f64,
    pub mis_per_s: f64,
    pub ticks_per_s: f64,
    /// Frozen pre-arena loop, wall seconds per trial, same workload.
    pub baseline_wall_s_per_trial: f64,
    /// `baseline / arena` wall per trial.
    pub speedup_x: f64,
}

/// One hot-path microbench row.
#[derive(Debug, Clone)]
pub struct MicroBench {
    pub name: &'static str,
    pub per_op_s: f64,
    pub ops_per_s: f64,
}

/// The full bench report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub quick: bool,
    pub points: Vec<ScalePoint>,
    pub micro: Vec<MicroBench>,
}

/// Time `reps` iterations of `f`; returns mean seconds per call. Shared
/// with `benches/micro.rs` so the standalone bench binary and `sparta
/// bench` report the same quantities.
pub fn bench_loop<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Seconds per simulator MI for one 16×16-stream flow under medium cross
/// traffic — the `net sim MI (256 streams)` microbench. `baseline`
/// selects the frozen pre-arena loop.
pub fn sim_mi_micro(reps: usize, baseline: bool) -> f64 {
    let tb = Testbed::chameleon();
    let bg = Background::regime("medium", 10.0);
    let mut sim: Box<dyn Substrate> = if baseline {
        Box::new(BaselineSim::new(tb, 1).with_background(bg))
    } else {
        Box::new(NetworkSim::new(tb, 1).with_background(bg))
    };
    sim.add_flow(16, 16, None);
    let mut out = Vec::new();
    for _ in 0..10 {
        sim.run_mi_into(1.0, &mut out);
    }
    bench_loop(reps, || {
        sim.run_mi_into(1.0, &mut out);
    })
}

/// Seconds per `Session::step` with `lanes` static transfer lanes in
/// flight (jobs sized so no lane completes during the measurement).
pub fn session_step_micro(lanes: usize, reps: usize) -> f64 {
    let mut session = Session::builder(Testbed::chameleon())
        .background(Background::Idle)
        .seed(7)
        .build();
    for _ in 0..lanes {
        session.admit(LaneSpec::new(
            Box::new(crate::baselines::StaticTool::efficient_static(4, 4)),
            TransferJob::files(100_000, 1 << 30),
        ));
    }
    let mut events = Vec::new();
    for _ in 0..5 {
        session.step_into(&mut events);
    }
    bench_loop(reps, || {
        session.step_into(&mut events);
    })
}

/// Time one side of a scale point: `trials × churn-heavy(lanes)` at
/// `--jobs 1` (so wall per trial is not muddied by worker scheduling).
fn timed_fleet(
    paths: &Paths,
    sched: &ArrivalSchedule,
    methods: &[String],
    baseline_loop: bool,
) -> Result<(fleet::FleetReport, f64)> {
    let opts = FleetOpts { baseline_loop, ..FleetOpts::default() };
    let t0 = Instant::now();
    let report = fleet::run(paths, sched, methods, Scale::Quick, 42, 1, opts)?;
    Ok((report, t0.elapsed().as_secs_f64()))
}

/// Run the scale curve (both loops) plus microbenches.
pub fn run(paths: &Paths, opts: BenchOpts) -> Result<BenchReport> {
    let horizon = if opts.quick { 120 } else { 360 };
    let methods: Vec<String> =
        ["falcon_mp", "2-phase", "rclone"].iter().map(|m| m.to_string()).collect();
    // Discarded warmup on both loops, so one-time process costs (lazy
    // statics, allocator growth, page-cache warmup) are not billed to
    // whichever side happens to be timed first.
    let warmup = ArrivalSchedule::churn_heavy_scaled(8, 30);
    timed_fleet(paths, &warmup, &methods, false)?;
    timed_fleet(paths, &warmup, &methods, true)?;
    let mut points = Vec::new();
    for &lanes in &BENCH_LANES {
        let sched = ArrivalSchedule::churn_heavy_scaled(lanes, horizon);
        let (report, wall) = timed_fleet(paths, &sched, &methods, false)?;
        let (base_report, base_wall) = timed_fleet(paths, &sched, &methods, true)?;
        // The bench doubles as a drift gate: both loops must produce the
        // same report bytes (the full suite is tests/golden_replay.rs).
        if fleet::to_json(&report).to_string() != fleet::to_json(&base_report).to_string() {
            return Err(anyhow!(
                "bench: arena and baseline loops diverged at {lanes} lanes — \
                 results drift, not a perf difference"
            ));
        }
        let trials = report.trials.len().max(1);
        let mis_run: usize = report.trials.iter().map(|t| t.mis_run).sum();
        // Fluid ticks per MI at the bench scenario's defaults (1.0-s MI,
        // 0.05-s tick).
        let ticks_per_mi = (1.0 / SimConfig::default().tick_s).round();
        let point = ScalePoint {
            lanes,
            trials,
            horizon_mis: horizon,
            mis_run,
            wall_s_per_trial: wall / trials as f64,
            mis_per_s: mis_run as f64 / wall,
            ticks_per_s: mis_run as f64 * ticks_per_mi / wall,
            baseline_wall_s_per_trial: base_wall / trials as f64,
            speedup_x: base_wall / wall,
        };
        crate::log_info!(
            "bench: {} lanes, {} trials, arena {:.2} s/trial vs baseline {:.2} s/trial ({:.2}x)",
            lanes,
            trials,
            point.wall_s_per_trial,
            point.baseline_wall_s_per_trial,
            point.speedup_x
        );
        points.push(point);
    }
    let micro_reps = if opts.quick { 60 } else { 200 };
    let sim_s = sim_mi_micro(micro_reps, false);
    let sim_base_s = sim_mi_micro(micro_reps, true);
    let step1_s = session_step_micro(1, micro_reps);
    let step8_s = session_step_micro(8, micro_reps);
    let micro = vec![
        MicroBench { name: "net sim MI (256 streams)", per_op_s: sim_s, ops_per_s: 1.0 / sim_s },
        MicroBench {
            name: "net sim MI (256 streams, pre-arena baseline)",
            per_op_s: sim_base_s,
            ops_per_s: 1.0 / sim_base_s,
        },
        MicroBench { name: "session step (1 lane)", per_op_s: step1_s, ops_per_s: 1.0 / step1_s },
        MicroBench { name: "session step (8 lanes)", per_op_s: step8_s, ops_per_s: 1.0 / step8_s },
    ];
    Ok(BenchReport { quick: opts.quick, points, micro })
}

/// Human summary: the scale curve and microbenches.
pub fn print(report: &BenchReport) {
    println!(
        "\nBench — fleet churn-heavy scale curve, arena vs pre-arena baseline ({} mode, jobs 1):",
        if report.quick { "quick" } else { "full" }
    );
    let mut t = Table::new(&[
        "lanes",
        "trials",
        "MIs run",
        "s/trial",
        "baseline s/trial",
        "MIs/s",
        "speedup",
    ]);
    for p in &report.points {
        t.row(vec![
            p.lanes.to_string(),
            p.trials.to_string(),
            p.mis_run.to_string(),
            format!("{:.3}", p.wall_s_per_trial),
            format!("{:.3}", p.baseline_wall_s_per_trial),
            format!("{:.0}", p.mis_per_s),
            format!("{:.2}x", p.speedup_x),
        ]);
    }
    t.print();
    let mut t = Table::new(&["microbench", "per-op", "ops/s"]);
    for m in &report.micro {
        let fmt = if m.per_op_s < 1e-3 {
            format!("{:.1} us", m.per_op_s * 1e6)
        } else {
            format!("{:.2} ms", m.per_op_s * 1e3)
        };
        t.row(vec![m.name.into(), fmt, format!("{:.0}", m.ops_per_s)]);
    }
    t.print();
}

/// The `BENCH_*.json` payload (schema documented in the module docs).
pub fn to_json(report: &BenchReport) -> Json {
    Json::obj(vec![
        ("bench", Json::from("sparta-bench")),
        ("schema_version", Json::from(1usize)),
        ("pr", Json::from(5usize)),
        ("mode", Json::from(if report.quick { "quick" } else { "full" })),
        (
            "baseline",
            Json::from("net::baseline::BaselineSim (pre-arena loop, d6d9964), timed in-process"),
        ),
        ("measured", Json::from(true)),
        (
            "scale_curve",
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("lanes", Json::from(p.lanes)),
                            ("trials", Json::from(p.trials)),
                            ("horizon_mis", Json::from(p.horizon_mis)),
                            ("mis_run", Json::from(p.mis_run)),
                            ("wall_s_per_trial", Json::from(p.wall_s_per_trial)),
                            ("mis_per_s", Json::from(p.mis_per_s)),
                            ("ticks_per_s", Json::from(p.ticks_per_s)),
                            (
                                "baseline_wall_s_per_trial",
                                Json::from(p.baseline_wall_s_per_trial),
                            ),
                            ("speedup_x", Json::from(p.speedup_x)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "micro",
            Json::Arr(
                report
                    .micro
                    .iter()
                    .map(|m| {
                        Json::obj(vec![
                            ("name", Json::from(m.name)),
                            ("per_op_s", Json::from(m.per_op_s)),
                            ("ops_per_s", Json::from(m.ops_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
