//! Cross-scenario generalization: train one agent per training scenario,
//! then deploy each trained policy greedily on every evaluation scenario.
//!
//! This is the experiment the scenario registry exists for: the paper's
//! claim rests on agents trained once generalizing across conditions
//! (cf. Swargo et al. 2025 on elastic cross-condition transfer tuning).
//! Phase 1 shards the independent training rows over `--jobs` workers
//! (each writes its own scoped weight file, e.g. `linq_te@lossy-wan`);
//! phase 2 takes one fresh read-only [`crate::runtime::WeightSnapshot`]
//! and shards the (train × eval) matrix cells over the same workers, all
//! reading from that shared snapshot. Per-cell seeding is identity-derived
//! throughout, so the emitted matrix is bit-identical at any `--jobs`
//! count.

use super::common::{
    expected_params, scoped_weight_name, train_pipeline, Scale, SpartaCtx, TrainSource,
};
use super::runner;
use crate::agents::make_agent;
use crate::config::Paths;
use crate::coordinator::{ParamBounds, RewardKind};
use crate::emulator::Env;
use crate::scenarios::Scenario;
use crate::telemetry::Table;
use crate::trainer::LiveEnv;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One (train scenario, eval scenario) matrix cell: greedy deployment of
/// the train-scenario policy under the eval scenario's conditions.
#[derive(Debug, Clone, PartialEq)]
pub struct GenCell {
    pub train_scenario: String,
    pub eval_scenario: String,
    pub mean_reward: f64,
    pub mean_throughput_gbps: f64,
    pub mean_energy_j_per_mi: f64,
}

/// The full generalization matrix (cells in row-major train × eval order).
#[derive(Debug, Clone, PartialEq)]
pub struct GenReport {
    pub algo: String,
    pub reward: RewardKind,
    pub train_scenarios: Vec<String>,
    pub eval_scenarios: Vec<String>,
    pub cells: Vec<GenCell>,
}

/// One (train, eval) unit of phase-2 work.
struct EvalSpec {
    train: String,
    eval: Scenario,
}

#[allow(clippy::too_many_arguments)]
pub fn run(
    paths: &Paths,
    algo: &str,
    reward: RewardKind,
    train_on: &[Scenario],
    eval_on: &[Scenario],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<GenReport> {
    // Phase 1 — train one policy per training scenario. Rows are
    // independent: each explores/fine-tunes under its own scenario and
    // writes its own scoped weight file, so they shard cleanly.
    let mut ctx = SpartaCtx::load(paths.clone())?;
    let phase1_snapshot = ctx.snapshot.clone();
    let phase1_paths = paths.clone();
    let train_outs: Vec<Result<()>> = runner::parallel_map_with(
        train_on,
        jobs,
        move || SpartaCtx::with_snapshot(phase1_paths.clone(), phase1_snapshot.clone()),
        |worker_ctx, _i, sc| -> Result<()> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            let cs = runner::cell_seed(seed, &format!("gen-train/{}", sc.name), 0);
            let stats = train_pipeline(ctx, algo, reward, TrainSource::Scenario(sc), scale, cs)?;
            crate::log_info!(
                "generalize: trained {} on {} ({} env steps, converged@{})",
                algo,
                sc.name,
                stats.env_steps,
                stats.steps_to_converge
            );
            Ok(())
        },
    );
    for r in train_outs {
        r?;
    }

    // Phase 2 — one fresh snapshot of everything phase 1 wrote; all matrix
    // cells evaluate over it concurrently, read-only, never touching disk.
    ctx.refresh_snapshot()?;
    let snapshot = ctx.snapshot.clone();
    let worker_paths = paths.clone();

    let (episodes, episode_len) = match scale {
        Scale::Quick => (4, 24),
        Scale::Paper => (12, 60),
    };
    let mut specs = Vec::new();
    for t in train_on {
        for e in eval_on {
            specs.push(EvalSpec { train: t.name.to_string(), eval: e.clone() });
        }
    }

    let outs: Vec<Result<GenCell>> = runner::parallel_map_with(
        &specs,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, spec| -> Result<GenCell> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            let cs = runner::cell_seed(
                seed,
                &format!("gen-eval/{}/{}", spec.train, spec.eval.name),
                0,
            );
            let weights = ctx.snapshot.params(
                &scoped_weight_name(algo, reward, &spec.train),
                expected_params(ctx, algo),
            )?;
            let mut agent = make_agent(&ctx.runtime, algo, cs, Some(weights))?;
            let mut env = LiveEnv::for_scenario(
                &spec.eval,
                reward,
                ParamBounds::default(),
                8,
                episode_len,
                cs ^ 0xE7A1,
            );
            let mut reward_sum = 0.0;
            let mut thr_sum = 0.0;
            let mut energy_sum = 0.0;
            let mut steps = 0usize;
            for _ in 0..episodes {
                let mut state = env.reset();
                loop {
                    // Greedy deployment: no exploration, no learning — the
                    // matrix isolates cross-condition generalization.
                    let action = agent.act(&state, false);
                    let out = env.step(action);
                    reward_sum += out.reward;
                    thr_sum += out.throughput_gbps;
                    if out.energy_j.is_finite() {
                        energy_sum += out.energy_j;
                    }
                    steps += 1;
                    state = out.state;
                    if out.done {
                        break;
                    }
                }
            }
            let n = steps.max(1) as f64;
            Ok(GenCell {
                train_scenario: spec.train.clone(),
                eval_scenario: spec.eval.name.to_string(),
                mean_reward: reward_sum / episodes.max(1) as f64,
                mean_throughput_gbps: thr_sum / n,
                mean_energy_j_per_mi: energy_sum / n,
            })
        },
    );

    let mut cells = Vec::new();
    for out in outs {
        cells.push(out?);
    }
    Ok(GenReport {
        algo: algo.to_string(),
        reward,
        train_scenarios: train_on.iter().map(|s| s.name.to_string()).collect(),
        eval_scenarios: eval_on.iter().map(|s| s.name.to_string()).collect(),
        cells,
    })
}

/// Per-train-row digest of the matrix: how well the policy does at home,
/// how much it loses in transfer, and where it is worst.
#[derive(Debug, Clone, PartialEq)]
pub struct SummaryRow {
    pub train_scenario: String,
    /// Mean episode reward when deployed on the training scenario itself
    /// (NaN when the train scenario is not among the eval columns).
    pub self_reward: f64,
    /// Mean reward over every *other* eval scenario.
    pub transfer_reward: f64,
    /// `self_reward - transfer_reward` (positive = policy degrades when it
    /// leaves home).
    pub gap: f64,
    /// Worst eval column for this policy.
    pub worst_eval: String,
    pub worst_reward: f64,
}

/// Summarize the matrix per training scenario (self vs transfer gap and
/// worst-case column — the footer that makes the matrix readable without
/// post-processing).
pub fn summarize(report: &GenReport) -> Vec<SummaryRow> {
    report
        .train_scenarios
        .iter()
        .map(|t| {
            let row: Vec<&GenCell> =
                report.cells.iter().filter(|c| &c.train_scenario == t).collect();
            let self_reward = row
                .iter()
                .find(|c| c.eval_scenario == *t)
                .map(|c| c.mean_reward)
                .unwrap_or(f64::NAN);
            let transfer: Vec<f64> = row
                .iter()
                .filter(|c| c.eval_scenario != *t)
                .map(|c| c.mean_reward)
                .collect();
            let transfer_reward = crate::util::stats::mean(&transfer);
            let worst = row.iter().min_by(|a, b| a.mean_reward.total_cmp(&b.mean_reward));
            SummaryRow {
                train_scenario: t.clone(),
                self_reward,
                transfer_reward,
                gap: self_reward - transfer_reward,
                worst_eval: worst.map(|c| c.eval_scenario.clone()).unwrap_or_default(),
                worst_reward: worst.map(|c| c.mean_reward).unwrap_or(f64::NAN),
            }
        })
        .collect()
}

/// Print the train-scenario × eval-scenario matrices (mean episode reward,
/// then mean throughput).
pub fn print(report: &GenReport) {
    let cell = |t: &str, e: &str| -> Option<&GenCell> {
        report
            .cells
            .iter()
            .find(|c| c.train_scenario == t && c.eval_scenario == e)
    };
    let matrix = |title: &str, f: &dyn Fn(&GenCell) -> f64| {
        println!("\n{title}");
        let mut header: Vec<&str> = vec!["train \\ eval"];
        header.extend(report.eval_scenarios.iter().map(|s| s.as_str()));
        let mut table = Table::new(&header);
        for t in &report.train_scenarios {
            let mut row = vec![t.clone()];
            for e in &report.eval_scenarios {
                row.push(match cell(t, e) {
                    Some(c) => format!("{:.2}", f(c)),
                    None => "-".into(),
                });
            }
            table.row(row);
        }
        table.print();
    };
    println!(
        "\nGeneralization — {} ({}), trained per row scenario, deployed greedily per column:",
        report.algo,
        report.reward.short()
    );
    matrix("mean episode reward:", &|c| c.mean_reward);
    matrix("mean throughput (Gbps):", &|c| c.mean_throughput_gbps);

    // Footer: per-row self vs transfer digest.
    println!("\nself-scenario vs transfer (mean episode reward):");
    let mut table =
        Table::new(&["train", "self", "transfer", "gap", "worst eval", "worst"]);
    for s in summarize(report) {
        table.row(vec![
            s.train_scenario,
            format!("{:.2}", s.self_reward),
            format!("{:.2}", s.transfer_reward),
            format!("{:+.2}", s.gap),
            s.worst_eval,
            format!("{:.2}", s.worst_reward),
        ]);
    }
    table.print();
}

/// Machine-readable report (for `--out` and the CI determinism check).
pub fn to_json(report: &GenReport) -> Json {
    fn names(xs: &[String]) -> Json {
        Json::arr_str(&xs.iter().map(|s| s.as_str()).collect::<Vec<_>>())
    }
    Json::obj(vec![
        ("algo", Json::from(report.algo.clone())),
        ("reward", Json::from(report.reward.short())),
        ("train_scenarios", names(&report.train_scenarios)),
        ("eval_scenarios", names(&report.eval_scenarios)),
        (
            "cells",
            Json::Arr(
                report
                    .cells
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("train_scenario", Json::from(c.train_scenario.clone())),
                            ("eval_scenario", Json::from(c.eval_scenario.clone())),
                            ("mean_reward", Json::from(c.mean_reward)),
                            ("mean_throughput_gbps", Json::from(c.mean_throughput_gbps)),
                            ("mean_energy_j_per_mi", Json::from(c.mean_energy_j_per_mi)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "summary",
            Json::Arr(
                summarize(report)
                    .into_iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("train_scenario", Json::from(s.train_scenario)),
                            ("self_reward", Json::from(s.self_reward)),
                            ("transfer_reward", Json::from(s.transfer_reward)),
                            ("gap", Json::from(s.gap)),
                            ("worst_eval", Json::from(s.worst_eval)),
                            ("worst_reward", Json::from(s.worst_reward)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
