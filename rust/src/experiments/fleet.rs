//! `sparta fleet` — N transfer applications joining and leaving a shared
//! bottleneck under a seeded [`ArrivalSchedule`].
//!
//! This is the experiment the step-driven [`crate::coordinator::Session`]
//! API exists for: lanes
//! are admitted mid-run as the arrival process fires, force-departed when
//! their lifetime expires, and the report is computed from the event stream
//! (per-epoch Jain's fairness over concurrently active lanes, energy per
//! delivered gigabyte, completion-time distribution). Trials shard over the
//! parallel runner with identity-derived seeds, so reports are
//! bit-identical at any `--jobs` count.

use super::common::{make_optimizer, Scale, SpartaCtx};
use super::runner;
use crate::config::Paths;
use crate::coordinator::{Event, LaneId, LaneSpec};
use crate::runtime::WeightSnapshot;
use crate::scenarios::ArrivalSchedule;
use crate::telemetry::Table;
use crate::transfer::TransferJob;
use crate::util::json::Json;
use crate::util::stats;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Fairness is reported per epoch of this many MIs.
pub const EPOCH_MIS: usize = 20;

/// Final accounting for one admitted lane.
#[derive(Debug, Clone)]
pub struct LaneOutcome {
    pub name: String,
    pub admitted_mi: usize,
    pub completed: bool,
    /// True when the schedule force-departed the lane before completion.
    pub departed_early: bool,
    /// Admission-to-end time, seconds (end = completion, departure, or the
    /// horizon for lanes still running).
    pub duration_s: f64,
    pub bytes_gb: f64,
    pub energy_kj: f64,
}

/// One trial: a full session over the arrival schedule.
#[derive(Debug, Clone)]
pub struct FleetTrial {
    pub trial: usize,
    pub lanes: Vec<LaneOutcome>,
    /// Jain's fairness per epoch over lanes active in that epoch (mean
    /// per-lane throughput within the epoch).
    pub epoch_jfi: Vec<f64>,
    /// Total metered energy / total delivered GB, J/GB.
    pub energy_per_gb_j: f64,
    /// Completion times of lanes that finished, seconds, ascending.
    pub completion_s: Vec<f64>,
}

/// The full fleet report.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub schedule: String,
    pub scenario: String,
    pub methods: Vec<String>,
    pub horizon_mis: usize,
    pub trials: Vec<FleetTrial>,
}

/// Run `scale.trials()` independent fleet trials of `schedule`, cycling
/// lane optimizers through `methods` in arrival order, sharded over `jobs`
/// workers. Takes [`Paths`] (not a loaded context): workers each build
/// their own [`SpartaCtx`] over one shared read-only weight snapshot.
pub fn run(
    paths: &Paths,
    schedule: &ArrivalSchedule,
    methods: &[String],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<FleetReport> {
    if methods.is_empty() {
        return Err(anyhow!("fleet needs at least one method"));
    }
    let trials: Vec<usize> = (0..scale.trials()).collect();
    let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
    let worker_paths = paths.clone();
    let outs: Vec<Result<FleetTrial>> = runner::parallel_map_with(
        &trials,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, &trial| -> Result<FleetTrial> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            // Identity-derived: the trial seed depends only on
            // (base seed, schedule, trial index).
            let trial_seed =
                runner::cell_seed(seed, &format!("fleet/{}", schedule.name), trial as u64);
            run_trial(ctx, schedule, methods, trial, trial_seed)
        },
    );
    let mut out_trials = Vec::new();
    for out in outs {
        out_trials.push(out?);
    }
    Ok(FleetReport {
        schedule: schedule.name.to_string(),
        scenario: schedule.scenario.name.to_string(),
        methods: methods.to_vec(),
        horizon_mis: schedule.horizon_mis,
        trials: out_trials,
    })
}

/// One seeded session over the schedule's arrival process.
fn run_trial(
    ctx: &SpartaCtx,
    schedule: &ArrivalSchedule,
    methods: &[String],
    trial: usize,
    trial_seed: u64,
) -> Result<FleetTrial> {
    let arrivals = schedule.arrivals(trial_seed);
    let mut session = schedule.scenario.session().seed(trial_seed).build();

    // Per-lane trackers, indexed by LaneId (admission order).
    let mut admitted_mi: Vec<usize> = Vec::new();
    let mut admitted_s: Vec<f64> = Vec::new();
    let mut deadline: Vec<Option<usize>> = Vec::new();
    let mut names: Vec<String> = Vec::new();
    let mut ended: Vec<Option<(bool, f64, f64, f64)>> = Vec::new(); // (completed, end_s, bytes, energy_j)
    let mut running_bytes: Vec<f64> = Vec::new();
    let mut running_energy: Vec<f64> = Vec::new();
    // epoch_thr[epoch][lane] = (throughput sum, samples).
    let mut epoch_thr: Vec<Vec<(f64, usize)>> = Vec::new();

    let mut next_arrival = 0usize;
    for mi in 0..schedule.horizon_mis {
        while next_arrival < arrivals.len() && arrivals[next_arrival].at_mi <= mi {
            let a = &arrivals[next_arrival];
            let k = next_arrival;
            let method = &methods[k % methods.len()];
            // Lane seeding depends only on (trial seed, method, arrival index).
            let lane_seed = runner::cell_seed(trial_seed, method, k as u64);
            let (opt, engine, reward) = make_optimizer(ctx, method, lane_seed)?;
            let name = format!("{method}#{k}");
            session.admit(
                LaneSpec::new(opt, TransferJob::files(a.files, a.file_bytes))
                    .engine(engine)
                    .reward(reward)
                    .named(name.clone()),
            );
            admitted_mi.push(mi);
            admitted_s.push(session.time_s());
            deadline.push(a.max_lifetime_mis.map(|l| mi + l));
            names.push(name);
            ended.push(None);
            running_bytes.push(0.0);
            running_energy.push(0.0);
            next_arrival += 1;
        }
        for (li, d) in deadline.iter_mut().enumerate() {
            if d.is_some_and(|dl| mi >= dl) {
                // Cancel returns false (and emits nothing) if the lane
                // already completed; either way the deadline is spent.
                session.cancel(LaneId(li));
                *d = None;
            }
        }
        for ev in session.step() {
            match &ev {
                Event::MiCompleted { lane, record } => {
                    running_bytes[lane.0] = record.bytes_total;
                    running_energy[lane.0] = record.energy_total_j;
                    let e = record.mi / EPOCH_MIS;
                    while epoch_thr.len() <= e {
                        epoch_thr.push(Vec::new());
                    }
                    let row = &mut epoch_thr[e];
                    while row.len() <= lane.0 {
                        row.push((0.0, 0));
                    }
                    row[lane.0].0 += record.throughput_gbps;
                    row[lane.0].1 += 1;
                }
                Event::Completed { lane, time_s, bytes_delivered, total_energy_j, .. } => {
                    ended[lane.0] = Some((true, *time_s, *bytes_delivered, *total_energy_j));
                }
                Event::Departed { lane, time_s, bytes_delivered, total_energy_j, .. } => {
                    ended[lane.0] = Some((false, *time_s, *bytes_delivered, *total_energy_j));
                }
                _ => {}
            }
        }
        if next_arrival >= arrivals.len() && session.is_idle() {
            break;
        }
    }

    let final_s = session.time_s();
    let mut lanes = Vec::new();
    let mut total_bytes = 0.0;
    let mut total_energy_j = 0.0;
    let mut completion_s = Vec::new();
    for li in 0..names.len() {
        let (completed, end_s, bytes, energy_j) = match ended[li] {
            Some(e) => e,
            // Still running at the horizon.
            None => (false, final_s, running_bytes[li], running_energy[li]),
        };
        let duration_s = end_s - admitted_s[li];
        if completed {
            completion_s.push(duration_s);
        }
        total_bytes += bytes;
        total_energy_j += energy_j;
        lanes.push(LaneOutcome {
            name: names[li].clone(),
            admitted_mi: admitted_mi[li],
            completed,
            departed_early: !completed && ended[li].is_some(),
            duration_s,
            bytes_gb: bytes / 1e9,
            energy_kj: energy_j / 1000.0,
        });
    }
    completion_s.sort_by(f64::total_cmp);
    // Epochs where no lane was active are skipped rather than scored as
    // vacuously perfect fairness (same rule as `ReportSink::finish`).
    let epoch_jfi: Vec<f64> = epoch_thr
        .iter()
        .filter_map(|row| {
            let means: Vec<f64> = row
                .iter()
                .filter(|(_, n)| *n > 0)
                .map(|(s, n)| s / *n as f64)
                .collect();
            if means.is_empty() {
                None
            } else {
                Some(stats::jain_fairness(&means))
            }
        })
        .collect();
    let energy_per_gb_j = if total_bytes > 0.0 {
        total_energy_j / (total_bytes / 1e9)
    } else {
        0.0
    };
    crate::log_info!(
        "fleet {} trial {}: {} lanes, {} completed, jfi {:.3}, {:.0} J/GB",
        schedule.name,
        trial,
        lanes.len(),
        completion_s.len(),
        stats::mean(&epoch_jfi),
        energy_per_gb_j
    );
    Ok(FleetTrial { trial, lanes, epoch_jfi, energy_per_gb_j, completion_s })
}

/// Paper-style summary: one row per trial plus per-lane detail at verbose.
pub fn print(report: &FleetReport) {
    println!(
        "\nFleet — {} arrivals on '{}' ({} MI horizon, methods: {}):",
        report.schedule,
        report.scenario,
        report.horizon_mis,
        report.methods.join(",")
    );
    let mut table = Table::new(&[
        "trial",
        "lanes",
        "completed",
        "departed",
        "mean JFI",
        "J/GB",
        "p50 done s",
        "p90 done s",
    ]);
    let pct = |xs: &[f64], q: f64| {
        if xs.is_empty() {
            "-".to_string()
        } else {
            format!("{:.0}", stats::percentile_sorted(xs, q))
        }
    };
    for t in &report.trials {
        let departed = t.lanes.iter().filter(|l| l.departed_early).count();
        table.row(vec![
            t.trial.to_string(),
            t.lanes.len().to_string(),
            t.completion_s.len().to_string(),
            departed.to_string(),
            format!("{:.3}", stats::mean(&t.epoch_jfi)),
            format!("{:.0}", t.energy_per_gb_j),
            pct(&t.completion_s, 0.50),
            pct(&t.completion_s, 0.90),
        ]);
    }
    table.print();
}

/// Machine-readable report (for `--out` and the CI determinism check).
pub fn to_json(report: &FleetReport) -> Json {
    Json::obj(vec![
        ("schedule", Json::from(report.schedule.clone())),
        ("scenario", Json::from(report.scenario.clone())),
        (
            "methods",
            Json::arr_str(&report.methods.iter().map(|m| m.as_str()).collect::<Vec<_>>()),
        ),
        ("horizon_mis", Json::from(report.horizon_mis)),
        ("epoch_mis", Json::from(EPOCH_MIS)),
        (
            "trials",
            Json::Arr(
                report
                    .trials
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("trial", Json::from(t.trial)),
                            ("epoch_jfi", Json::arr_f64(&t.epoch_jfi)),
                            ("energy_per_gb_j", Json::from(t.energy_per_gb_j)),
                            ("completion_s", Json::arr_f64(&t.completion_s)),
                            (
                                "lanes",
                                Json::Arr(
                                    t.lanes
                                        .iter()
                                        .map(|l| {
                                            Json::obj(vec![
                                                ("name", Json::from(l.name.clone())),
                                                ("admitted_mi", Json::from(l.admitted_mi)),
                                                ("completed", Json::from(l.completed)),
                                                ("departed_early", Json::from(l.departed_early)),
                                                ("duration_s", Json::from(l.duration_s)),
                                                ("bytes_gb", Json::from(l.bytes_gb)),
                                                ("energy_kj", Json::from(l.energy_kj)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
