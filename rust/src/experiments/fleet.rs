//! `sparta fleet` — N transfer applications joining and leaving a shared
//! bottleneck under a seeded [`ArrivalSchedule`].
//!
//! This is the experiment the step-driven [`crate::coordinator::Session`]
//! API exists for: lanes are admitted mid-run as the arrival process fires,
//! force-departed when their lifetime expires, and the report is computed
//! from the event stream (per-epoch Jain's fairness via
//! [`crate::telemetry::FairnessSink`], energy per delivered gigabyte,
//! completion-time distributions). Trials shard over the parallel runner
//! with identity-derived seeds, so reports are bit-identical at any
//! `--jobs` count.
//!
//! Energy is **host-resolved**: all lanes colocated on the scenario's
//! sender/receiver hosts share one [`crate::energy::HostLedger`] per host,
//! so fixed power is paid once per host (the seed-era per-lane meters
//! counted it once per lane) and J/GB comes from host truth. Per-trial
//! conservation — attributed lane energy sums to the host total — is
//! asserted on every run.
//!
//! The optional contention-**yield controller** pauses the youngest lanes
//! when too many compete for the bottleneck. Each lane consents to yield
//! only while it believes pausing is energetically free: lanes running
//! blind (no `observe_paused`) never see their idle bills and always
//! consent — the seed-era assumption that pausing costs nothing — while
//! lanes observing paused MIs learn the idle-rail price and refuse, i.e.
//! pause less eagerly. `sparta fleet --compare-observe` runs both sides.

use super::common::{make_optimizer, Scale, SpartaCtx};
use super::runner;
use crate::config::Paths;
use crate::coordinator::{
    Cluster, Event, LaneId, LaneSpec, LaneStatus, Session, Stepping, INCAST_RX_OVER_WAN,
};
use crate::energy::RailEnergy;
use crate::faults::FaultSchedule;
use crate::net::Topology;
use crate::runtime::WeightSnapshot;
use crate::scenarios::ArrivalSchedule;
use crate::telemetry::{FairnessSink, Table, TelemetrySink};
use crate::transfer::TransferJob;
use crate::util::json::Json;
use crate::util::stats;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Fairness is reported per epoch of this many MIs.
pub const EPOCH_MIS: usize = 20;

/// Yield controller: pause the youngest active lanes while more than this
/// many compete for the bottleneck.
pub const YIELD_ACTIVE_TARGET: usize = 4;

/// Yield controller: a policy-paused lane is resumed after this many MIs.
pub const YIELD_GAP_MIS: usize = 10;

/// Yield controller: a lane consents to pause only while its observed
/// pause cost estimate is at most this many joules per MI ("basically
/// free"). Lanes that never observe paused MIs estimate zero.
pub const YIELD_COST_BUDGET_J: f64 = 1.0;

/// Fleet run knobs (see the module docs).
#[derive(Debug, Clone, Copy)]
pub struct FleetOpts {
    /// Paused lanes emit zero-throughput records carrying idle energy, so
    /// their optimizers (and the yield controller) see preemption costs.
    pub observe_paused: bool,
    /// Enable the contention-yield controller.
    pub yield_policy: bool,
    /// Run every trial over the frozen pre-arena
    /// [`crate::net::baseline::BaselineSim`] instead of the arena loop —
    /// the measured "before" side of `sparta bench` and the golden-replay
    /// byte-identity suite. Reports must be byte-identical either way.
    pub baseline_loop: bool,
    /// Sender hosts. 1 (the default) keeps the single-session path with
    /// byte-identical reports; above 1 each trial runs a [`Cluster`] of
    /// per-host sessions over the incast topology
    /// ([`Topology::incast_host`]) with lanes placed round-robin, and the
    /// report carries per-host ledger rows.
    pub hosts: usize,
    /// Intra-step cluster worker threads (§Perf in
    /// [`crate::coordinator::cluster`]): `1` steps hosts serially, `N > 1`
    /// steps up to N hosts concurrently per MI with a byte-identical
    /// merged stream, `0` resolves automatically — serial when the run is
    /// already sharded across trial workers (`jobs > 1`), else
    /// `min(hosts, cores)`. See [`resolve_step_threads`]; pure wall-clock
    /// knob, never serialized into reports.
    pub step_threads: usize,
    /// Optional seeded fault preset (`--faults NAME`): every trial runs
    /// with a [`crate::faults::FaultPlan`] resolved from its trial seed,
    /// so the same failure history replays at any `--jobs` and
    /// `--step-threads` count. Incompatible with `baseline_loop` — the
    /// frozen pre-arena simulator has no fault plane.
    pub faults: Option<&'static FaultSchedule>,
}

impl Default for FleetOpts {
    fn default() -> FleetOpts {
        FleetOpts {
            observe_paused: false,
            yield_policy: false,
            baseline_loop: false,
            hosts: 1,
            step_threads: 1,
            faults: None,
        }
    }
}

/// Resolve the `--step-threads` knob against the outer `--jobs` trial
/// sharding. `0` (auto) picks serial stepping when trials are already
/// sharded (`jobs > 1` would oversubscribe: every worker would spawn its
/// own host pool), else `min(hosts, available cores)`. An explicit
/// request is honored as given, but `jobs * threads > cores` warns once
/// with the effective thread budget instead of silently oversubscribing.
pub fn resolve_step_threads(step_threads: usize, hosts: usize, jobs: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let resolved = match step_threads {
        0 if jobs > 1 => 1,
        0 => hosts.max(1).min(cores),
        n => n,
    };
    static OVERSUBSCRIBE_WARN: std::sync::Once = std::sync::Once::new();
    if resolved > 1 && jobs.max(1) * resolved > cores {
        OVERSUBSCRIBE_WARN.call_once(|| {
            crate::log_warn!(
                "--jobs {} x --step-threads {} = {} threads oversubscribes {} cores; \
                 results are unaffected (byte-identical at any thread count) but \
                 wall clock may regress — consider --step-threads {}",
                jobs.max(1),
                resolved,
                jobs.max(1) * resolved,
                cores,
                (cores / jobs.max(1)).max(1)
            );
        });
    }
    resolved
}

/// One sender host's ledger truth inside a cluster trial (sender rails
/// plus its `1/N` receiver share — see
/// [`crate::energy::HostSpec::share`]).
#[derive(Debug, Clone)]
pub struct HostEnergyRow {
    pub name: String,
    pub energy_j: f64,
    pub rails: Option<RailEnergy>,
}

/// Final accounting for one admitted lane.
#[derive(Debug, Clone)]
pub struct LaneOutcome {
    pub name: String,
    pub admitted_mi: usize,
    pub completed: bool,
    /// True when the schedule force-departed the lane before completion.
    pub departed_early: bool,
    /// Admission-to-end time, seconds (end = completion, departure, or the
    /// horizon for lanes still running).
    pub duration_s: f64,
    pub bytes_gb: f64,
    /// Host-ledger energy attributed to this lane (incl. idle bills while
    /// paused), kJ.
    pub energy_kj: f64,
}

/// One trial: a full session over the arrival schedule.
#[derive(Debug, Clone)]
pub struct FleetTrial {
    pub trial: usize,
    pub lanes: Vec<LaneOutcome>,
    /// Jain's fairness per epoch over lanes active in that epoch (mean
    /// per-lane throughput within the epoch).
    pub epoch_jfi: Vec<f64>,
    /// Host-truth energy / total delivered GB, J/GB (fixed power counted
    /// once per host, not once per lane).
    pub energy_per_gb_j: f64,
    /// Completion times of lanes that finished, seconds, ascending.
    pub completion_s: Vec<f64>,
    /// Yield-controller pauses taken / refusals issued this trial.
    pub pauses: usize,
    pub yields_refused: usize,
    /// Monitoring intervals actually stepped (≤ horizon; the trial ends
    /// early once every lane finished). Serialized in [`to_json`] since
    /// BENCH schema v2 so `sparta bench` and the CI perf-trend gate can
    /// report MIs/s per trial without re-deriving it. Deterministic
    /// (identical across loops and `--jobs` counts), so the byte-compare
    /// gates are unaffected.
    pub mis_run: usize,
    /// Host-truth per-rail energy breakdown (all hosts combined).
    pub rails: Option<RailEnergy>,
    /// Per-sender-host ledger rows — empty on single-host runs (whose
    /// JSON stays byte-identical to pre-cluster reports), one row per
    /// host on `--hosts N` cluster trials, summing to the cluster truth.
    pub hosts: Vec<HostEnergyRow>,
    /// Fault-plane counters (all zero unless the trial ran `--faults`):
    /// lanes declared faulted, retries released, lanes migrated off
    /// crashed hosts, and hosts quarantined by the end of the trial.
    pub faulted: usize,
    pub retried: usize,
    pub migrated: usize,
    pub quarantined_hosts: usize,
}

/// The full fleet report.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub schedule: String,
    pub scenario: String,
    pub methods: Vec<String>,
    pub horizon_mis: usize,
    pub observe_paused: bool,
    pub yield_policy: bool,
    /// Sender hosts per trial (1 = single-session fleet).
    pub hosts: usize,
    /// Fault preset name, when the run injected one.
    pub faults: Option<&'static str>,
    pub trials: Vec<FleetTrial>,
}

impl FleetReport {
    pub fn total_pauses(&self) -> usize {
        self.trials.iter().map(|t| t.pauses).sum()
    }

    pub fn mean_energy_per_gb_j(&self) -> f64 {
        stats::mean(&self.trials.iter().map(|t| t.energy_per_gb_j).collect::<Vec<_>>())
    }
}

/// Run `scale.trials()` independent fleet trials of `schedule`, cycling
/// lane optimizers through `methods` in arrival order, sharded over `jobs`
/// workers. Takes [`Paths`] (not a loaded context): workers each build
/// their own [`SpartaCtx`] over one shared read-only weight snapshot.
pub fn run(
    paths: &Paths,
    schedule: &ArrivalSchedule,
    methods: &[String],
    scale: Scale,
    seed: u64,
    jobs: usize,
    opts: FleetOpts,
) -> Result<FleetReport> {
    if methods.is_empty() {
        return Err(anyhow!("fleet needs at least one method"));
    }
    if opts.baseline_loop && opts.faults.is_some() {
        // The frozen pre-arena loop is the golden-replay oracle; it has no
        // fault plane, so injecting into it would silently diverge.
        return Err(anyhow!("--faults is not supported on the baseline loop"));
    }
    // Resolve the intra-step thread knob once against the trial sharding,
    // so every worker steps its cluster with the same (warned-about)
    // budget instead of re-deciding per trial.
    let step_threads = resolve_step_threads(opts.step_threads, opts.hosts, jobs);
    let opts = FleetOpts { step_threads, ..opts };
    let trials: Vec<usize> = (0..scale.trials()).collect();
    let snapshot = Arc::new(WeightSnapshot::load_dir(paths.weights())?);
    let worker_paths = paths.clone();
    let outs: Vec<Result<FleetTrial>> = runner::parallel_map_with(
        &trials,
        jobs,
        move || SpartaCtx::with_snapshot(worker_paths.clone(), snapshot.clone()),
        |worker_ctx, _i, &trial| -> Result<FleetTrial> {
            let ctx = worker_ctx
                .as_ref()
                .map_err(|e| anyhow!("loading worker context: {e:#}"))?;
            // Identity-derived: the trial seed depends only on
            // (base seed, schedule, trial index).
            let trial_seed =
                runner::cell_seed(seed, &format!("fleet/{}", schedule.name), trial as u64);
            run_trial(ctx, schedule, methods, trial, trial_seed, opts)
        },
    );
    let mut out_trials = Vec::new();
    for out in outs {
        out_trials.push(out?);
    }
    Ok(FleetReport {
        schedule: schedule.name.to_string(),
        scenario: schedule.scenario.name.to_string(),
        methods: methods.to_vec(),
        horizon_mis: schedule.horizon_mis,
        observe_paused: opts.observe_paused,
        yield_policy: opts.yield_policy,
        hosts: opts.hosts.max(1),
        faults: opts.faults.map(|f| f.name),
        trials: out_trials,
    })
}

/// The churn comparison behind `sparta fleet --compare-observe`: the same
/// schedule with the yield controller on, run blind vs with pause-cost
/// observation. Returns `(blind, observing)`.
pub fn run_observe_comparison(
    paths: &Paths,
    schedule: &ArrivalSchedule,
    methods: &[String],
    scale: Scale,
    seed: u64,
    jobs: usize,
) -> Result<(FleetReport, FleetReport)> {
    let blind = run(
        paths,
        schedule,
        methods,
        scale,
        seed,
        jobs,
        FleetOpts { observe_paused: false, yield_policy: true, ..FleetOpts::default() },
    )?;
    let observing = run(
        paths,
        schedule,
        methods,
        scale,
        seed,
        jobs,
        FleetOpts { observe_paused: true, yield_policy: true, ..FleetOpts::default() },
    )?;
    Ok((blind, observing))
}

/// One seeded trial over the schedule's arrival process: build the trial's
/// stepping scale — a single host-resolved [`Session`], or for `--hosts N`
/// an incast [`Cluster`] of per-host sessions — then drive it with
/// [`drive_trial`].
fn run_trial(
    ctx: &SpartaCtx,
    schedule: &ArrivalSchedule,
    methods: &[String],
    trial: usize,
    trial_seed: u64,
    opts: FleetOpts,
) -> Result<FleetTrial> {
    let hosts = opts.hosts.max(1);
    // Identity-derived fault history: depends only on (preset, trial
    // seed, hosts, horizon) — never on jobs or step threads.
    let fault_plan = opts.faults.map(|f| f.resolve(trial_seed, hosts, schedule.horizon_mis));
    if hosts > 1 {
        // N sender hosts into the scenario testbed's shared WAN and one
        // receiver-ingest stage (incast). Each host session gets its own
        // ledger pair; the receiver's fixed power is shared 1/N so the
        // cluster total pays it exactly once.
        let tb = &schedule.scenario.testbed;
        let mut cluster = Cluster::build(hosts, trial_seed, |h, host_seed| {
            let topo = Topology::incast_host(tb, hosts, INCAST_RX_OVER_WAN);
            let mut builder = Session::builder(tb.clone())
                .energy(tb.energy_hosts_of(h, hosts))
                .observe_paused(opts.observe_paused)
                .seed(host_seed);
            if opts.baseline_loop {
                builder = builder.substrate(Box::new(
                    crate::net::baseline::BaselineSim::from_topology(tb.clone(), &topo, host_seed),
                ));
            }
            builder.topology(topo).build()
        });
        cluster.set_step_threads(opts.step_threads.max(1));
        if let Some(plan) = fault_plan {
            cluster.install_faults(plan);
        }
        let mut out = drive_trial(ctx, schedule, methods, trial, trial_seed, opts, &mut cluster)?;
        out.quarantined_hosts = cluster.quarantined_hosts();
        // Host-resolved rows, plus the cluster-level conservation check:
        // per-host ledger truth sums to the cluster total the trial billed.
        let mut per_host_j = 0.0;
        out.hosts = cluster
            .hosts()
            .iter()
            .enumerate()
            .map(|(h, s)| {
                per_host_j += s.host_energy_j();
                HostEnergyRow {
                    name: format!("{}-tx{h}", tb.name),
                    energy_j: s.host_energy_j(),
                    rails: s.energy_rails(),
                }
            })
            .collect();
        let cluster_j = cluster.host_energy_j();
        assert!(
            (per_host_j - cluster_j).abs() <= 1e-9 * cluster_j.max(1.0),
            "cluster energy leaked: hosts {per_host_j} J vs cluster {cluster_j} J"
        );
        return Ok(out);
    }
    // Host-resolved accounting: every lane bills the scenario's shared
    // sender/receiver ledgers instead of a private lumped meter.
    let mut builder = schedule
        .scenario
        .session_host_resolved()
        .observe_paused(opts.observe_paused)
        .seed(trial_seed);
    if opts.baseline_loop {
        // Same topology, same seed, pre-arena loop: the bench "before"
        // side (and the golden suite's byte-identity oracle).
        builder = builder.substrate(Box::new(crate::net::baseline::BaselineSim::from_topology(
            schedule.scenario.testbed.clone(),
            &schedule.scenario.topology,
            trial_seed,
        )));
    }
    let mut session = builder.build();
    if let Some(plan) = fault_plan {
        session.install_faults(plan);
    }
    drive_trial(ctx, schedule, methods, trial, trial_seed, opts, &mut session)
}

/// Drive one trial over any [`Stepping`] scale — a single [`Session`] or a
/// sharded [`Cluster`] — admitting lanes as the arrival process fires.
/// Monomorphizes per scale, so the single-host path keeps its zero-alloc
/// stepping profile (§Perf in [`Session::step_into`]).
fn drive_trial<S: Stepping>(
    ctx: &SpartaCtx,
    schedule: &ArrivalSchedule,
    methods: &[String],
    trial: usize,
    trial_seed: u64,
    opts: FleetOpts,
    session: &mut S,
) -> Result<FleetTrial> {
    let arrivals = schedule.arrivals(trial_seed);
    // Capacity hint (§Perf): the arrival list is the expected lane count,
    // so lane tables and stream arenas grow once, not per admission.
    session.reserve_lanes(arrivals.len());

    // Per-lane trackers, indexed by LaneId (admission order).
    let mut admitted_mi: Vec<usize> = Vec::new();
    let mut admitted_s: Vec<f64> = Vec::new();
    let mut deadline: Vec<Option<usize>> = Vec::new();
    let mut names: Vec<String> = Vec::new();
    let mut ended: Vec<Option<(bool, f64, f64)>> = Vec::new(); // (completed, end_s, bytes)
    let mut running_bytes: Vec<f64> = Vec::new();
    // Per-epoch fairness comes from the shared telemetry sink now — the
    // fleet driver no longer duplicates the JFI bucketing.
    let mut fairness = FairnessSink::new(EPOCH_MIS);

    // Yield-controller state.
    let mut policy_paused_at: Vec<Option<usize>> = Vec::new();
    let mut yield_exempt: Vec<bool> = Vec::new();
    // A resumed lane may not be re-paused before this MI (guarantees a
    // YIELD_GAP_MIS running window between yields — without it a
    // just-resumed lane would be re-paused in the same tick and starve).
    let mut yield_cooldown_until: Vec<usize> = Vec::new();
    // Observed pause cost: (sum of paused-record energy, samples).
    let mut pause_cost: Vec<(f64, usize)> = Vec::new();
    let mut pauses = 0usize;
    let mut yields_refused = 0usize;
    // Fault-plane counters (stay zero on fault-free runs).
    let mut faulted = 0usize;
    let mut retried = 0usize;
    let mut migrated = 0usize;

    let mut next_arrival = 0usize;
    // One event buffer for the whole trial (§Perf: `step_into` keeps the
    // session's MI loop allocation-free at steady state).
    let mut events: Vec<Event> = Vec::new();
    for mi in 0..schedule.horizon_mis {
        while next_arrival < arrivals.len() && arrivals[next_arrival].at_mi <= mi {
            let a = &arrivals[next_arrival];
            let k = next_arrival;
            let method = &methods[k % methods.len()];
            // Lane seeding depends only on (trial seed, method, arrival index).
            let lane_seed = runner::cell_seed(trial_seed, method, k as u64);
            let (opt, engine, reward) = make_optimizer(ctx, method, lane_seed)?;
            let name = format!("{method}#{k}");
            session.admit(
                LaneSpec::new(opt, TransferJob::files(a.files, a.file_bytes))
                    .engine(engine)
                    .reward(reward)
                    .named(name.clone()),
            );
            admitted_mi.push(mi);
            admitted_s.push(session.time_s());
            deadline.push(a.max_lifetime_mis.map(|l| mi + l));
            names.push(name);
            ended.push(None);
            running_bytes.push(0.0);
            policy_paused_at.push(None);
            yield_exempt.push(false);
            yield_cooldown_until.push(0);
            pause_cost.push((0.0, 0));
            next_arrival += 1;
        }
        for (li, d) in deadline.iter_mut().enumerate() {
            if d.is_some_and(|dl| mi >= dl) {
                // Cancel returns false (and emits nothing) if the lane
                // already completed; either way the deadline is spent.
                session.cancel(LaneId(li));
                *d = None;
            }
        }
        if opts.yield_policy {
            run_yield_policy(
                session,
                mi,
                &mut policy_paused_at,
                &mut yield_exempt,
                &mut yield_cooldown_until,
                &pause_cost,
                &mut pauses,
                &mut yields_refused,
            );
        }
        session.step_into(&mut events);
        for ev in &events {
            fairness.on_event(ev);
            match ev {
                Event::MiCompleted { lane, record } => {
                    if record.paused {
                        // The lane's only window into what pausing costs.
                        if record.energy_j.is_finite() {
                            pause_cost[lane.0].0 += record.energy_j;
                            pause_cost[lane.0].1 += 1;
                        }
                    } else {
                        running_bytes[lane.0] = record.bytes_total;
                    }
                }
                Event::Completed { lane, time_s, bytes_delivered, .. } => {
                    ended[lane.0] = Some((true, *time_s, *bytes_delivered));
                }
                Event::Departed { lane, time_s, bytes_delivered, .. } => {
                    ended[lane.0] = Some((false, *time_s, *bytes_delivered));
                }
                Event::Faulted { .. } => faulted += 1,
                Event::Retrying { .. } => retried += 1,
                Event::Migrated { .. } => migrated += 1,
                _ => {}
            }
        }
        if next_arrival >= arrivals.len() && session.is_idle() {
            break;
        }
    }

    let final_s = session.time_s();
    let mut lanes = Vec::new();
    let mut total_bytes = 0.0;
    let mut attributed_j = 0.0;
    let mut completion_s = Vec::new();
    for li in 0..names.len() {
        let (completed, end_s, bytes) = match ended[li] {
            Some(e) => e,
            // Still running at the horizon.
            None => (false, final_s, running_bytes[li]),
        };
        // Attribution from the ledger directly: unlike the event totals it
        // also covers idle bills accrued after a lane's last observed MI.
        let energy_j = session.lane_energy_j(LaneId(li)).unwrap_or(0.0);
        let duration_s = end_s - admitted_s[li];
        if completed {
            completion_s.push(duration_s);
        }
        total_bytes += bytes;
        attributed_j += energy_j;
        lanes.push(LaneOutcome {
            name: names[li].clone(),
            admitted_mi: admitted_mi[li],
            completed,
            departed_early: !completed && ended[li].is_some(),
            duration_s,
            bytes_gb: bytes / 1e9,
            energy_kj: energy_j / 1000.0,
        });
    }
    completion_s.sort_by(f64::total_cmp);
    let epoch_jfi = fairness.epoch_jfi();
    // J/GB from host truth, and the conservation invariant: per-lane
    // attributed energy sums to the host-ledger total.
    let host_j = session.host_energy_j();
    assert!(
        (attributed_j - host_j).abs() <= 1e-9 * host_j.max(1.0),
        "energy attribution leaked: lanes {attributed_j} J vs host {host_j} J"
    );
    let energy_per_gb_j = if total_bytes > 0.0 { host_j / (total_bytes / 1e9) } else { 0.0 };
    crate::log_info!(
        "fleet {} trial {}: {} lanes, {} completed, jfi {:.3}, {:.0} J/GB, {} pauses",
        schedule.name,
        trial,
        lanes.len(),
        completion_s.len(),
        stats::mean(&epoch_jfi),
        energy_per_gb_j,
        pauses
    );
    Ok(FleetTrial {
        trial,
        lanes,
        epoch_jfi,
        energy_per_gb_j,
        completion_s,
        pauses,
        yields_refused,
        mis_run: session.mi(),
        rails: session.energy_rails(),
        hosts: Vec::new(),
        faulted,
        retried,
        migrated,
        // Filled by the cluster path in `run_trial`; a single session has
        // no hosts to quarantine.
        quarantined_hosts: 0,
    })
}

/// One tick of the contention-yield controller: resume lanes whose yield
/// gap expired, then — while more than [`YIELD_ACTIVE_TARGET`] lanes are
/// active — ask the youngest active lanes to pause. A resumed lane is
/// guaranteed a [`YIELD_GAP_MIS`] running window before it can be asked
/// again (pause/run alternation, not starvation). A lane consents only
/// while its observed pause-cost estimate is within
/// [`YIELD_COST_BUDGET_J`]; a refusal is permanent (the lane is exempt
/// from further asks).
#[allow(clippy::too_many_arguments)]
fn run_yield_policy<S: Stepping>(
    session: &mut S,
    mi: usize,
    policy_paused_at: &mut [Option<usize>],
    yield_exempt: &mut [bool],
    yield_cooldown_until: &mut [usize],
    pause_cost: &[(f64, usize)],
    pauses: &mut usize,
    yields_refused: &mut usize,
) {
    for (li, slot) in policy_paused_at.iter_mut().enumerate() {
        if slot.is_some_and(|t| mi >= t + YIELD_GAP_MIS) {
            // May fail if the lane was cancelled while paused; the slot is
            // spent either way.
            session.resume(LaneId(li));
            *slot = None;
            yield_cooldown_until[li] = mi + YIELD_GAP_MIS;
        }
    }
    let active: Vec<usize> = (0..policy_paused_at.len())
        .filter(|&li| session.status(LaneId(li)) == Some(LaneStatus::Active))
        .collect();
    if active.len() <= YIELD_ACTIVE_TARGET {
        return;
    }
    let mut excess = active.len() - YIELD_ACTIVE_TARGET;
    // Youngest first: the most recently admitted lanes yield.
    for &li in active.iter().rev() {
        if excess == 0 {
            break;
        }
        if yield_exempt[li] || policy_paused_at[li].is_some() || mi < yield_cooldown_until[li] {
            continue;
        }
        let (cost_sum, n) = pause_cost[li];
        let est_cost_j_per_mi = if n > 0 { cost_sum / n as f64 } else { 0.0 };
        if est_cost_j_per_mi <= YIELD_COST_BUDGET_J {
            if session.pause(LaneId(li)) {
                policy_paused_at[li] = Some(mi);
                *pauses += 1;
                excess -= 1;
            }
        } else {
            // The lane has seen its idle bills and refuses to be preempted
            // again — pause-cost observation makes it yield less eagerly.
            yield_exempt[li] = true;
            *yields_refused += 1;
        }
    }
}

/// Paper-style summary: one row per trial plus per-lane detail at verbose.
pub fn print(report: &FleetReport) {
    println!(
        "\nFleet — {} arrivals on '{}' ({} MI horizon, methods: {}{}{}{}{}):",
        report.schedule,
        report.scenario,
        report.horizon_mis,
        report.methods.join(","),
        if report.observe_paused { ", observe-paused" } else { "" },
        if report.yield_policy { ", yield policy" } else { "" },
        if report.hosts > 1 {
            format!(", {} incast hosts", report.hosts)
        } else {
            String::new()
        },
        match report.faults {
            Some(name) => format!(", faults: {name}"),
            None => String::new(),
        },
    );
    let mut table = Table::new(&[
        "trial",
        "lanes",
        "completed",
        "departed",
        "mean JFI",
        "J/GB",
        "pauses",
        "p50 done s",
        "p90 done s",
    ]);
    let pct = |xs: &[f64], q: f64| {
        if xs.is_empty() {
            "-".to_string()
        } else {
            format!("{:.0}", stats::percentile_sorted(xs, q))
        }
    };
    for t in &report.trials {
        let departed = t.lanes.iter().filter(|l| l.departed_early).count();
        table.row(vec![
            t.trial.to_string(),
            t.lanes.len().to_string(),
            t.completion_s.len().to_string(),
            departed.to_string(),
            format!("{:.3}", stats::mean(&t.epoch_jfi)),
            format!("{:.0}", t.energy_per_gb_j),
            t.pauses.to_string(),
            pct(&t.completion_s, 0.50),
            pct(&t.completion_s, 0.90),
        ]);
    }
    table.print();
    // Fault-plane recovery summary (chaos runs only).
    if let Some(name) = report.faults {
        let sum = |f: fn(&FleetTrial) -> usize| report.trials.iter().map(f).sum::<usize>();
        println!(
            "fault plane '{}': {} lane faults, {} retries, {} migrations, {} host quarantines",
            name,
            sum(|t| t.faulted),
            sum(|t| t.retried),
            sum(|t| t.migrated),
            sum(|t| t.quarantined_hosts),
        );
    }
    // Host-truth rail breakdown, averaged over trials.
    let rails: Vec<&RailEnergy> = report.trials.iter().filter_map(|t| t.rails.as_ref()).collect();
    if !rails.is_empty() {
        let n = rails.len() as f64;
        let avg = |f: fn(&RailEnergy) -> f64| rails.iter().map(|r| f(r)).sum::<f64>() / n / 1000.0;
        println!(
            "host rails (mean kJ/trial): cpu {:.1}, nic {:.1}, fixed {:.1}, idle {:.1}",
            avg(|r| r.cpu_j),
            avg(|r| r.nic_j),
            avg(|r| r.fixed_j),
            avg(|r| r.idle_j),
        );
    }
    // Host-resolved ledger truth, averaged over trials (cluster runs only).
    if report.hosts > 1 {
        let n = report.trials.len().max(1) as f64;
        let mut table = Table::new(&["host", "mean kJ/trial", "cpu", "nic", "fixed", "idle"]);
        for h in 0..report.hosts {
            let rows: Vec<&HostEnergyRow> =
                report.trials.iter().filter_map(|t| t.hosts.get(h)).collect();
            let Some(first) = rows.first() else { continue };
            let mean_kj = rows.iter().map(|r| r.energy_j).sum::<f64>() / n / 1000.0;
            let rail = |f: fn(&RailEnergy) -> f64| {
                let sum: f64 = rows.iter().filter_map(|r| r.rails.as_ref()).map(f).sum();
                format!("{:.1}", sum / n / 1000.0)
            };
            table.row(vec![
                first.name.clone(),
                format!("{mean_kj:.1}"),
                rail(|r| r.cpu_j),
                rail(|r| r.nic_j),
                rail(|r| r.fixed_j),
                rail(|r| r.idle_j),
            ]);
        }
        table.print();
    }
}

/// Side-by-side summary for `--compare-observe`.
pub fn print_comparison(blind: &FleetReport, observing: &FleetReport) {
    println!("\nPause-cost observation comparison ({} schedule):", blind.schedule);
    let mut table = Table::new(&["fleet", "pauses", "yields refused", "J/GB (mean)", "mean JFI"]);
    for (label, r) in [("blind", blind), ("observe-paused", observing)] {
        let jfi: Vec<f64> = r.trials.iter().flat_map(|t| t.epoch_jfi.clone()).collect();
        table.row(vec![
            label.to_string(),
            r.total_pauses().to_string(),
            r.trials.iter().map(|t| t.yields_refused).sum::<usize>().to_string(),
            format!("{:.0}", r.mean_energy_per_gb_j()),
            format!("{:.3}", stats::mean(&jfi)),
        ]);
    }
    table.print();
    println!(
        "lanes that observe their idle bills consent to {} pauses vs {} when blind",
        observing.total_pauses(),
        blind.total_pauses()
    );
}

/// Machine-readable report (for `--out` and the CI determinism check).
///
/// Byte-compat note: the report-level `hosts` field and the per-trial
/// `hosts` arrays are emitted only on cluster runs (`--hosts` > 1), so
/// single-host reports serialize byte-identically to pre-cluster SPARTA.
pub fn to_json(report: &FleetReport) -> Json {
    let mut top = vec![
        ("schedule", Json::from(report.schedule.clone())),
        ("scenario", Json::from(report.scenario.clone())),
        (
            "methods",
            Json::arr_str(&report.methods.iter().map(|m| m.as_str()).collect::<Vec<_>>()),
        ),
        ("horizon_mis", Json::from(report.horizon_mis)),
        ("epoch_mis", Json::from(EPOCH_MIS)),
        ("observe_paused", Json::from(report.observe_paused)),
        ("yield_policy", Json::from(report.yield_policy)),
    ];
    if report.hosts > 1 {
        top.push(("hosts", Json::from(report.hosts)));
    }
    // Like `hosts`: emitted only on chaos runs, so fault-free reports
    // stay byte-identical to pre-fault-plane SPARTA.
    if let Some(name) = report.faults {
        top.push(("faults", Json::from(name)));
    }
    top.push((
        "trials",
        Json::Arr(
            report
                .trials
                .iter()
                .map(|t| {
                    let mut o = vec![
                        ("trial", Json::from(t.trial)),
                        ("epoch_jfi", Json::arr_f64(&t.epoch_jfi)),
                        ("energy_per_gb_j", Json::from(t.energy_per_gb_j)),
                        ("completion_s", Json::arr_f64(&t.completion_s)),
                        ("pauses", Json::from(t.pauses)),
                        ("yields_refused", Json::from(t.yields_refused)),
                        ("mis_run", Json::from(t.mis_run)),
                    ];
                    if report.faults.is_some() {
                        o.push(("faulted", Json::from(t.faulted)));
                        o.push(("retried", Json::from(t.retried)));
                        o.push(("migrated", Json::from(t.migrated)));
                        o.push(("quarantined_hosts", Json::from(t.quarantined_hosts)));
                    }
                    if let Some(r) = &t.rails {
                        o.push(("energy_rails_j", rails_json(r)));
                    }
                    if !t.hosts.is_empty() {
                        o.push((
                            "hosts",
                            Json::Arr(
                                t.hosts
                                    .iter()
                                    .map(|h| {
                                        let mut ho = vec![
                                            ("name", Json::from(h.name.clone())),
                                            ("energy_j", Json::from(h.energy_j)),
                                        ];
                                        if let Some(r) = &h.rails {
                                            ho.push(("energy_rails_j", rails_json(r)));
                                        }
                                        Json::obj(ho)
                                    })
                                    .collect(),
                            ),
                        ));
                    }
                    o.push((
                        "lanes",
                        Json::Arr(
                            t.lanes
                                .iter()
                                .map(|l| {
                                    Json::obj(vec![
                                        ("name", Json::from(l.name.clone())),
                                        ("admitted_mi", Json::from(l.admitted_mi)),
                                        ("completed", Json::from(l.completed)),
                                        ("departed_early", Json::from(l.departed_early)),
                                        ("duration_s", Json::from(l.duration_s)),
                                        ("bytes_gb", Json::from(l.bytes_gb)),
                                        ("energy_kj", Json::from(l.energy_kj)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                    Json::obj(o)
                })
                .collect(),
        ),
    ));
    Json::obj(top)
}

/// The shared `energy_rails_j` object shape.
fn rails_json(r: &RailEnergy) -> Json {
    Json::obj(vec![
        ("cpu", Json::from(r.cpu_j)),
        ("nic", Json::from(r.nic_j)),
        ("fixed", Json::from(r.fixed_j)),
        ("idle", Json::from(r.idle_j)),
    ])
}
