//! Fig. 1: throughput and power vs (cc, p) under different background
//! traffic regimes (the motivation figure).
//!
//! Grid points are independent simulations, so they shard across worker
//! threads ([`super::runner`]); per-point seeds are pre-drawn in grid order
//! from the caller's seed, making the sweep bit-identical at any `jobs`
//! count (and to the seed repo's serial sweep).

use super::runner;
use crate::energy::PowerModel;
use crate::net::background::Background;
use crate::net::{NetworkSim, Substrate, Testbed};
use crate::scenarios::Scenario;
use crate::telemetry::Table;
use crate::util::json::Json;
use crate::util::Rng;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub regime: String,
    pub cc: u32,
    pub p: u32,
    pub throughput_gbps: f64,
    /// Mean dynamic power per MI, W (the paper's "energy per MI") — the
    /// lumped compat column, bit-identical to the pre-refactor sweep.
    pub power_w: f64,
    /// Host-truth rail decomposition of the same operating points (mean W
    /// per MI): CPU (stream bookkeeping + data touching), NIC per-bit,
    /// fixed engine residency — from the testbed's sender node class. On
    /// Efficient-class testbeds (FABRIC) the rails re-sum to `power_w`;
    /// heterogeneous classes (Chameleon's Xeons, CloudLab's EPYCs)
    /// deliberately diverge from the lumped compat column.
    pub cpu_w: f64,
    pub nic_w: f64,
    pub fixed_w: f64,
}

/// Per-MI means measured at one (cc, p) grid point.
struct Measured {
    throughput_gbps: f64,
    power_w: f64,
    cpu_w: f64,
    nic_w: f64,
    fixed_w: f64,
}

/// Measure one substrate at one (cc, p): warm-up, then average 15 MIs.
fn measure(mut sub: Box<dyn Substrate>, cc: u32, p: u32) -> Measured {
    let model = PowerModel::efficient();
    let host = sub.testbed().sender_host();
    let id = sub.add_flow(cc, p, None);
    // Warm-up past slow start, then measure.
    for _ in 0..12 {
        sub.run_mi(1.0);
    }
    let mut thr = 0.0;
    let mut pw = 0.0;
    let (mut cpu, mut nic, mut fixed) = (0.0, 0.0, 0.0);
    let mis = 15;
    for _ in 0..mis {
        let m = sub.run_mi(1.0)[id.0];
        thr += m.throughput_gbps;
        pw += model.power_w(m.active_streams, m.throughput_gbps);
        let (c, n, f) = host.rails_w(m.active_streams, m.throughput_gbps);
        cpu += c;
        nic += n;
        fixed += f;
    }
    let k = mis as f64;
    Measured {
        throughput_gbps: thr / k,
        power_w: pw / k,
        cpu_w: cpu / k,
        nic_w: nic / k,
        fixed_w: fixed / k,
    }
}

/// Sweep the (cc, p) grid under each background regime, sharded over `jobs`
/// workers.
pub fn sweep(
    testbed: &Testbed,
    grid: &[u32],
    regimes: &[&str],
    seed: u64,
    jobs: usize,
) -> Vec<SweepPoint> {
    // Pre-draw per-point seeds in grid order (matches the serial sweep).
    let mut rng = Rng::new(seed);
    let mut specs = Vec::new();
    for regime in regimes {
        for &cc in grid {
            for &p in grid {
                specs.push((regime.to_string(), cc, p, rng.next_u64()));
            }
        }
    }
    runner::parallel_map(&specs, jobs, |_, (regime, cc, p, point_seed)| {
        let bg = Background::regime(regime, testbed.capacity_gbps);
        let sim = NetworkSim::new(testbed.clone(), *point_seed).with_background(bg);
        let m = measure(Box::new(sim), *cc, *p);
        SweepPoint {
            regime: regime.clone(),
            cc: *cc,
            p: *p,
            throughput_gbps: m.throughput_gbps,
            power_w: m.power_w,
            cpu_w: m.cpu_w,
            nic_w: m.nic_w,
            fixed_w: m.fixed_w,
        }
    })
}

/// Sweep the (cc, p) grid under one registered scenario's conditions (the
/// scenario replaces the regime axis).
pub fn sweep_scenario(scenario: &Scenario, grid: &[u32], seed: u64, jobs: usize) -> Vec<SweepPoint> {
    let mut rng = Rng::new(seed);
    let mut specs = Vec::new();
    for &cc in grid {
        for &p in grid {
            specs.push((cc, p, rng.next_u64()));
        }
    }
    runner::parallel_map(&specs, jobs, |_, (cc, p, point_seed)| {
        let m = measure(scenario.substrate(*point_seed), *cc, *p);
        SweepPoint {
            regime: scenario.name.to_string(),
            cc: *cc,
            p: *p,
            throughput_gbps: m.throughput_gbps,
            power_w: m.power_w,
            cpu_w: m.cpu_w,
            nic_w: m.nic_w,
            fixed_w: m.fixed_w,
        }
    })
}

/// Machine-readable report (for `--out`; `--scenario all` concatenates the
/// registry's sweeps into one combined array, keyed by the `regime` field).
pub fn to_json(points: &[SweepPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|pt| {
                Json::obj(vec![
                    ("regime", Json::from(pt.regime.clone())),
                    ("cc", Json::from(pt.cc as usize)),
                    ("p", Json::from(pt.p as usize)),
                    ("throughput_gbps", Json::from(pt.throughput_gbps)),
                    ("power_w", Json::from(pt.power_w)),
                    ("cpu_w", Json::from(pt.cpu_w)),
                    ("nic_w", Json::from(pt.nic_w)),
                    ("fixed_w", Json::from(pt.fixed_w)),
                ])
            })
            .collect(),
    )
}

/// Render the sweep as the two Fig.-1 panels (throughput, power).
pub fn print(points: &[SweepPoint], grid: &[u32]) {
    let regimes: Vec<String> = {
        let mut r: Vec<String> = points.iter().map(|p| p.regime.clone()).collect();
        r.dedup();
        r
    };
    for metric in ["throughput (Gbps)", "power (W)"] {
        println!("\nFig 1 — {metric} vs (cc, p):");
        for regime in &regimes {
            let mut header = vec!["cc \\ p".to_string()];
            header.extend(grid.iter().map(|p| p.to_string()));
            let mut table = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
            for &cc in grid {
                let mut row = vec![cc.to_string()];
                for &p in grid {
                    let pt = points
                        .iter()
                        .find(|x| x.regime == *regime && x.cc == cc && x.p == p)
                        .unwrap();
                    let v = if metric.starts_with("throughput") { pt.throughput_gbps } else { pt.power_w };
                    row.push(format!("{v:.2}"));
                }
                table.row(row);
            }
            println!("background = {regime}:");
            table.print();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_reproduces_fig1_shape() {
        let tb = Testbed::chameleon();
        let pts = sweep(&tb, &[1, 4, 16], &["low", "high"], 11, 1);
        assert_eq!(pts.len(), 2 * 9);
        let get = |regime: &str, cc: u32, p: u32| {
            pts.iter().find(|x| x.regime == regime && x.cc == cc && x.p == p).unwrap().clone()
        };
        // (1,1) is ~1 Gbps; the optimum is several times better (paper: up
        // to 10x). Power grows strongly with stream count.
        let base = get("low", 1, 1);
        let mid = get("low", 4, 4);
        let big = get("low", 16, 16);
        assert!(base.throughput_gbps < 1.3, "base={}", base.throughput_gbps);
        assert!(mid.throughput_gbps > 4.0 * base.throughput_gbps);
        assert!(big.power_w > 2.0 * mid.power_w, "mid={} big={}", mid.power_w, big.power_w);
        // Heavy background depresses achievable throughput.
        let busy = get("high", 4, 4);
        assert!(busy.throughput_gbps < mid.throughput_gbps + 0.3);
    }

    #[test]
    fn sweep_is_thread_count_invariant() {
        let tb = Testbed::chameleon();
        let serial = sweep(&tb, &[1, 8], &["low"], 3, 1);
        let parallel = sweep(&tb, &[1, 8], &["low"], 3, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.throughput_gbps.to_bits(), b.throughput_gbps.to_bits());
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        }
    }

    #[test]
    fn scenario_sweep_respects_bottleneck() {
        let sc = Scenario::by_name("nic-limited").unwrap();
        let pts = sweep_scenario(&sc, &[2, 8], 5, 2);
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert!(p.throughput_gbps <= 4.0 + 1e-6, "{:?}", p);
            assert_eq!(p.regime, "nic-limited");
        }
    }

    /// On an Efficient-class testbed the host-truth rail columns re-sum to
    /// the lumped power column (the compat anchor); on Chameleon the Xeon
    /// calibration diverges from it by design.
    #[test]
    fn rail_columns_resum_to_lumped_power_on_efficient_class() {
        let tb = Testbed::fabric();
        let pts = sweep(&tb, &[1, 8], &["low"], 13, 2);
        for p in &pts {
            let resum = p.cpu_w + p.nic_w + p.fixed_w;
            assert!(
                (resum - p.power_w).abs() <= 1e-9 * p.power_w,
                "rails {resum} vs lumped {} at ({}, {})",
                p.power_w,
                p.cc,
                p.p
            );
            assert!(p.fixed_w > 0.0 && p.cpu_w > 0.0);
        }
        let xeon = sweep(&Testbed::chameleon(), &[8], &["low"], 13, 2);
        assert!(xeon
            .iter()
            .any(|p| (p.cpu_w + p.nic_w + p.fixed_w - p.power_w).abs() > 1e-3 * p.power_w));
    }
}
