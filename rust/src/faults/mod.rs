//! Seeded fault-injection plans: the chaos the fleet must survive.
//!
//! A [`crate::scenarios::Scenario`] fixes the *healthy* network conditions
//! and an [`crate::scenarios::ArrivalSchedule`] fixes the workload; a
//! [`FaultSchedule`] fixes what goes *wrong* on top of both — WAN links
//! flapping or degrading, sender hosts stalling or crashing outright,
//! individual lanes hitting stream errors. Every preset resolves into an
//! explicit, sorted [`FaultPlan`] from `(name, seed, hosts, horizon)`
//! exactly like the arrival presets resolve workloads, so the same fault
//! seed replays the same failure history — and therefore the same event
//! stream — at any `--jobs` and `--step-threads` count.
//!
//! The determinism contract has two halves:
//!
//! 1. **Seeded injection.** A plan is materialized up front by
//!    [`FaultSchedule::resolve`] with an identity-derived seed
//!    (`mix_seed(base, name, 0)` — the arrivals idiom); nothing about
//!    execution order, thread count or wall clock feeds back into it.
//! 2. **MI-boundary recovery.** Every fault op is *applied* at the MI
//!    boundary named by its `at_mi`, before the tick runs, and every
//!    recovery op (stall detection, retry, migration) likewise fires at
//!    boundaries — the simulator tick itself stays untouched, so the
//!    golden-replay byte-identity between the arena and baseline loops is
//!    preserved whenever no plan is installed.
//!
//! Select one with `--faults <name>` on `sparta fleet`, `sparta serve` or
//! `sparta bench`, or programmatically:
//!
//! ```
//! use sparta::faults::FaultSchedule;
//!
//! let sched = FaultSchedule::by_name("link-flap").unwrap();
//! let a = sched.resolve(42, 1, 360);
//! let b = sched.resolve(42, 1, 360);
//! assert_eq!(a.events, b.events); // same (schedule, seed) => same faults
//! assert!(!a.events.is_empty());
//! ```
//!
//! **Adding a fault kind** is three local steps: add an [`FaultOp`]
//! variant, teach the routing switch in `Session::apply_fault_op` (and
//! `Cluster::apply_fault_op` if it is host- or cluster-scoped) what it
//! does at an MI boundary, and emit it from a preset arm in
//! [`FaultSchedule::resolve`]. Nothing else changes: telemetry, serve and
//! the CLI only ever see the resulting `Faulted`/`Retrying`/`Migrated`
//! events.

use crate::util::rng::mix_seed;
use crate::util::Rng;

/// Consecutive no-progress MIs before the stall watchdog declares an
/// Active lane faulted.
pub const STALL_AFTER_MIS: u32 = 3;

/// "No progress" threshold, bytes per MI: anything under this is a stall
/// for watchdog purposes (a fully cut link still trickles control-sized
/// residue through the fluid model).
pub const STALL_EPS_BYTES: f64 = 4096.0;

/// Exponential retry backoff, MIs: 1, 2, 4, 8, 8, ... (capped).
pub fn backoff_mis(attempt: u32) -> usize {
    1usize << attempt.min(3)
}

/// Floor for a faulted segment's capacity scale. A scale of exactly zero
/// would send the droptail queue-delay math to infinity; this floor keeps
/// the link numerically alive while starving it hard enough to trip the
/// stall watchdog.
pub const MIN_SEGMENT_SCALE: f64 = 1e-6;

/// One injected failure (or recovery) op, applied at an MI boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultOp {
    /// Scale a named topology segment's capacity against its nominal
    /// value (`1.0` heals it). Routed to every host's substrate: in an
    /// incast cluster the WAN is a shared stage, so a WAN fault hits all
    /// senders' slices alike.
    SegmentScale { segment: &'static str, scale: f64 },
    /// Freeze one host's senders for `mis` monitoring intervals: all of
    /// its lanes offer zero demand, so the stall watchdog trips them into
    /// the faulted/retry cycle.
    HostStall { host: usize, mis: usize },
    /// Kill one host permanently. The cluster quarantines it and migrates
    /// its in-flight lanes to healthy hosts with bytes intact; single-host
    /// presets downgrade this to a stall at resolve time.
    HostCrash { host: usize },
    /// Break one lane's stream: the lane slot (modulo lanes admitted so
    /// far at fire time) is faulted immediately and re-enters through the
    /// retry/backoff path.
    StreamError { lane_slot: usize },
}

/// One scheduled fault: `op` applied at the `at_mi` boundary, before that
/// MI's tick.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at_mi: usize,
    pub op: FaultOp,
}

/// A resolved, sorted fault history for one trial — what a
/// [`crate::coordinator::Session`] or [`crate::coordinator::Cluster`]
/// actually installs. Events at the same MI apply in vector order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// A named, reproducible failure preset. The registry mirrors
/// [`crate::scenarios::ArrivalSchedule`]: look presets up by name, resolve
/// them with a seed, get the identical plan every time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSchedule {
    /// Registry name (`--faults <name>`).
    pub name: &'static str,
    /// One-line description for `sparta scenarios`.
    pub summary: &'static str,
}

/// Periodic presets stop emitting past this many MIs even when the run
/// horizon is longer (an open-ended `sparta serve` should not pre-plan
/// unbounded failure histories).
const PLAN_HORIZON_CAP_MIS: usize = 2000;

impl FaultSchedule {
    /// The registered failure presets.
    pub fn all() -> &'static [FaultSchedule] {
        &[
            FaultSchedule {
                name: "link-flap",
                summary: "WAN capacity collapses for 3-5 MIs every ~30 MIs, then heals",
            },
            FaultSchedule {
                name: "link-degrade",
                summary: "persistent WAN brownout: capacity drops to ~40% mid-run and stays",
            },
            FaultSchedule {
                name: "host-stall",
                summary: "sender hosts freeze for 5-8 MIs a few times per run",
            },
            FaultSchedule {
                name: "host-crash",
                summary: "up to two hosts die mid-run; lanes migrate to survivors (stall when single-host)",
            },
            FaultSchedule {
                name: "stream-error",
                summary: "individual lane streams break every ~24 MIs and retry with backoff",
            },
        ]
    }

    pub fn by_name(name: &str) -> Option<&'static FaultSchedule> {
        FaultSchedule::all().iter().find(|s| s.name == name)
    }

    /// Registry names, in registry order.
    pub fn names() -> Vec<&'static str> {
        FaultSchedule::all().iter().map(|s| s.name).collect()
    }

    /// Materialize the failure history for one trial. Deterministic: the
    /// same `(schedule, seed, hosts, horizon)` yields the same plan, with
    /// the schedule name joining the seed mix so two presets under the
    /// same trial seed draw different histories.
    pub fn resolve(&self, seed: u64, hosts: usize, horizon_mis: usize) -> FaultPlan {
        let mut rng = Rng::new(mix_seed(seed, self.name, 0));
        let hosts = hosts.max(1);
        let horizon = horizon_mis.clamp(1, PLAN_HORIZON_CAP_MIS);
        let mut events = Vec::new();
        match self.name {
            "link-flap" => {
                let mut at = 10 + rng.below(6);
                while at + 8 < horizon {
                    let dur = 3 + rng.below(3);
                    events.push(FaultEvent {
                        at_mi: at,
                        op: FaultOp::SegmentScale { segment: "wan", scale: 0.0 },
                    });
                    events.push(FaultEvent {
                        at_mi: at + dur,
                        op: FaultOp::SegmentScale { segment: "wan", scale: 1.0 },
                    });
                    at += 28 + rng.below(12);
                }
            }
            "link-degrade" => {
                let at = 12 + rng.below(8);
                if at < horizon {
                    events.push(FaultEvent {
                        at_mi: at,
                        op: FaultOp::SegmentScale { segment: "wan", scale: 0.4 },
                    });
                }
            }
            "host-stall" => {
                let stalls = 2 + rng.below(2);
                for k in 0..stalls {
                    let at = 12 + k * (horizon / (stalls + 1)).max(1) + rng.below(10);
                    if at + 2 >= horizon {
                        break;
                    }
                    events.push(FaultEvent {
                        at_mi: at,
                        op: FaultOp::HostStall { host: rng.below(hosts), mis: 5 + rng.below(4) },
                    });
                }
            }
            "host-crash" => {
                // Never more crashes than leave one survivor; on a
                // single host, downgrade to a recoverable stall so the
                // preset still means something for `--hosts 1`.
                let crashes = 2.min(hosts - 1);
                if crashes == 0 {
                    let at = (horizon / 3).max(8) + rng.below(8);
                    if at + 2 < horizon {
                        events.push(FaultEvent {
                            at_mi: at,
                            op: FaultOp::HostStall { host: 0, mis: 8 },
                        });
                    }
                } else {
                    // Distinct victims, host 0 spared so the round-robin
                    // admission path always has its first target alive.
                    let mut victims: Vec<usize> = (1..hosts).collect();
                    rng.shuffle(&mut victims);
                    for (k, &host) in victims.iter().take(crashes).enumerate() {
                        let at = ((k + 1) * horizon / (crashes + 2)).max(8) + rng.below(8);
                        if at + 2 >= horizon {
                            break;
                        }
                        events.push(FaultEvent { at_mi: at, op: FaultOp::HostCrash { host } });
                    }
                }
            }
            "stream-error" => {
                let mut at = 8 + rng.below(8);
                while at + 2 < horizon {
                    events.push(FaultEvent {
                        at_mi: at,
                        op: FaultOp::StreamError { lane_slot: rng.below(1024) },
                    });
                    at += 18 + rng.below(12);
                }
            }
            other => unreachable!("unregistered fault schedule '{other}'"),
        }
        events.sort_by_key(|e| e.at_mi);
        FaultPlan { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_and_names_are_unique() {
        let names = FaultSchedule::names();
        for want in ["link-flap", "link-degrade", "host-stall", "host-crash", "stream-error"] {
            assert!(names.contains(&want), "missing fault schedule '{want}'");
        }
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate fault schedule names");
        assert!(FaultSchedule::by_name("no-such-preset").is_none());
    }

    #[test]
    fn plans_are_seed_deterministic_sorted_and_in_horizon() {
        for sched in FaultSchedule::all() {
            for hosts in [1usize, 4] {
                let a = sched.resolve(7, hosts, 360);
                let b = sched.resolve(7, hosts, 360);
                assert_eq!(a, b, "{}: same seed must reproduce", sched.name);
                assert!(!a.events.is_empty(), "{}: empty plan at 360 MIs", sched.name);
                assert!(
                    a.events.windows(2).all(|w| w[0].at_mi <= w[1].at_mi),
                    "{}: plan out of order",
                    sched.name
                );
                assert!(
                    a.events.iter().all(|e| e.at_mi < 360),
                    "{}: fault past horizon",
                    sched.name
                );
            }
        }
    }

    #[test]
    fn seeds_diverge() {
        let flap = FaultSchedule::by_name("link-flap").unwrap();
        assert_ne!(flap.resolve(1, 1, 360).events, flap.resolve(2, 1, 360).events);
    }

    #[test]
    fn host_ops_stay_in_host_range() {
        for sched in FaultSchedule::all() {
            for hosts in [1usize, 2, 4, 8] {
                for e in sched.resolve(11, hosts, 360).events {
                    match e.op {
                        FaultOp::HostStall { host, .. } | FaultOp::HostCrash { host } => {
                            assert!(host < hosts, "{}: host {host} >= {hosts}", sched.name);
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    /// `host-crash` leaves at least one survivor (and spares host 0), and
    /// downgrades to a stall when there is nothing to fail over to.
    #[test]
    fn host_crash_never_kills_the_last_host() {
        let crash = FaultSchedule::by_name("host-crash").unwrap();
        for seed in 0..16u64 {
            let single = crash.resolve(seed, 1, 360);
            assert!(
                single.events.iter().all(|e| matches!(e.op, FaultOp::HostStall { .. })),
                "single-host crash must downgrade to stall"
            );
            for hosts in [2usize, 4, 8] {
                let plan = crash.resolve(seed, hosts, 360);
                let mut crashed: Vec<usize> = plan
                    .events
                    .iter()
                    .filter_map(|e| match e.op {
                        FaultOp::HostCrash { host } => Some(host),
                        _ => None,
                    })
                    .collect();
                assert!(!crashed.is_empty(), "no crash scheduled for {hosts} hosts");
                assert!(!crashed.contains(&0), "host 0 must be spared");
                crashed.sort_unstable();
                crashed.dedup();
                assert!(crashed.len() < hosts, "all hosts crashed");
            }
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        assert_eq!(
            (0..6).map(backoff_mis).collect::<Vec<_>>(),
            vec![1, 2, 4, 8, 8, 8]
        );
    }
}
