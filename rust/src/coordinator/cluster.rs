//! Cluster-scale fleets: many per-host [`Session`]s behind one stepping
//! surface.
//!
//! A [`Cluster`] shards transfer lanes across N sender-host sessions —
//! each host with its own substrate ([`crate::net::stream::StreamArena`]),
//! its own [`crate::energy::HostLedger`] pair and rail calibration — and
//! presents the same admit/step/control API as a single [`Session`]
//! (formally: both implement [`super::Stepping`], so `sparta fleet` drives
//! either without caring about scale).
//!
//! ## Incast model
//!
//! The hosts of an incast fleet (N senders → one receiver) share the WAN
//! and the receiver-ingest stage. Each host session simulates its *static
//! fair share* of those stages ([`Topology::incast_host`]: capacity, queue
//! and cross traffic all divided by N; the sender NIC stays private and
//! full-rate), so host simulations are fully independent. That
//! independence is what makes cluster runs exactly reproducible: there is
//! no cross-host event ordering to race on, and every host seed is
//! identity-derived from `(cluster seed, host index)` — runner-style — so
//! a fleet report is bit-identical at any `--jobs` count, which CI
//! enforces byte-for-byte on `sparta fleet --hosts 4`.
//!
//! ## Energy
//!
//! Each host session bills a private sender host (`<testbed>-tx<h>`) plus
//! a `1/N` slice of the single physical receiver
//! ([`crate::energy::HostSpec::share`]): residency rails (fixed power, NIC
//! LPI idle) divide by N while traffic-proportional rails ride with the
//! host's own lanes, so summing attribution over every host session pays
//! the receiver exactly once. Per-session conservation (Σ lane attribution
//! == ledger truth) therefore composes into the cluster invariant
//! Σ lanes == Σ per-host totals == [`Cluster::host_energy_j`], asserted
//! per trial by `sparta fleet` and under churn by `tests/energy_ledger.rs`.
//!
//! ## Stepping and lane identity
//!
//! [`Cluster::admit`] places lanes round-robin across hosts and returns
//! *global* [`LaneId`]s (admission order, same contract as a session).
//! [`Cluster::step_into`] advances every host by one MI in host order —
//! sessions run in lockstep, so `time_s`/`mi` agree everywhere — and
//! merges the per-host event streams into the caller's buffer with lane
//! ids rewritten to global. Record state buffers recycle back to their
//! owning host's pool ([`Session::recycle_record`]), keeping cluster
//! stepping allocation-free at steady state (§Perf in [`super::session`]).

use super::session::{Event, LaneId, LaneSpec, LaneStatus, MiRecord, Session, SessionState};
use crate::energy::RailEnergy;
use crate::net::{Testbed, Topology};
use crate::util::rng::mix_seed;

/// Receiver-ingest provisioning of [`Cluster::incast`] relative to WAN
/// capacity: below 1.0 the receiver, not the WAN, is the incast
/// bottleneck.
pub const INCAST_RX_OVER_WAN: f64 = 0.8;

/// N per-host [`Session`]s behind one [`super::Stepping`] surface (see the
/// module docs).
pub struct Cluster {
    hosts: Vec<Session>,
    /// Global lane id → (host index, host-local lane id).
    locus: Vec<(usize, LaneId)>,
    /// Per host: host-local lane index → global lane id.
    global_of: Vec<Vec<usize>>,
    /// Round-robin admission cursor.
    next_host: usize,
    /// Cluster MIs stepped (hosts run in lockstep).
    mi: usize,
    /// Reusable per-host event staging buffer (§Perf).
    scratch: Vec<Event>,
}

impl Cluster {
    /// Build an `n`-host cluster from a per-host session factory. Host `h`
    /// is handed the identity-derived seed `mix_seed(seed, "cluster/host",
    /// h)` — the factory must use it (not the raw cluster seed) so fleet
    /// results depend only on configuration, never on sharding.
    pub fn build(n: usize, seed: u64, mut host: impl FnMut(usize, u64) -> Session) -> Cluster {
        assert!(n > 0, "a cluster needs at least one host");
        let hosts: Vec<Session> =
            (0..n).map(|h| host(h, mix_seed(seed, "cluster/host", h as u64))).collect();
        Cluster {
            global_of: vec![Vec::new(); hosts.len()],
            hosts,
            locus: Vec::new(),
            next_host: 0,
            mi: 0,
            scratch: Vec::new(),
        }
    }

    /// The default incast fleet over a testbed: every sender host runs a
    /// private NIC into its fair share of the testbed WAN and of a
    /// receiver provisioned at [`INCAST_RX_OVER_WAN`] × WAN capacity
    /// (receiver-limited), with host-resolved energy accounting
    /// ([`Testbed::energy_hosts_of`]).
    pub fn incast(tb: &Testbed, n: usize, seed: u64) -> Cluster {
        Cluster::build(n, seed, |h, host_seed| {
            Session::builder(tb.clone())
                .topology(Topology::incast_host(tb, n, INCAST_RX_OVER_WAN))
                .energy(tb.energy_hosts_of(h, n))
                .seed(host_seed)
                .build()
        })
    }

    /// Admit a lane on the next host round-robin; returns its *global*
    /// lane id (admission order across the whole cluster).
    pub fn admit(&mut self, spec: LaneSpec) -> LaneId {
        let h = self.next_host;
        self.next_host = (self.next_host + 1) % self.hosts.len();
        let local = self.hosts[h].admit(spec);
        let global = LaneId(self.locus.len());
        self.locus.push((h, local));
        debug_assert_eq!(self.global_of[h].len(), local.0);
        self.global_of[h].push(global.0);
        global
    }

    /// Advance every host session by one monitoring interval (host order),
    /// merging their event streams — lane ids rewritten to global — into
    /// the caller-reused `events` buffer. The previous batch's record
    /// buffers are first routed back to their owning hosts' pools.
    pub fn step_into(&mut self, events: &mut Vec<Event>) {
        for ev in events.drain(..) {
            if let Event::MiCompleted { lane, record } = ev {
                let (h, _) = self.locus[lane.0];
                self.hosts[h].recycle_record(record);
            }
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        for h in 0..self.hosts.len() {
            self.hosts[h].step_into(&mut scratch);
            for mut ev in scratch.drain(..) {
                self.globalize(h, &mut ev);
                events.push(ev);
            }
        }
        self.scratch = scratch;
        self.mi += 1;
    }

    /// Rewrite a host-local event to cluster-global lane identity.
    fn globalize(&self, host: usize, ev: &mut Event) {
        match ev {
            Event::Admitted { lane, .. }
            | Event::MiCompleted { lane, .. }
            | Event::Paused { lane, .. }
            | Event::Resumed { lane, .. }
            | Event::Completed { lane, .. }
            | Event::Departed { lane, .. } => *lane = LaneId(self.global_of[host][lane.0]),
        }
    }

    fn resolve(&self, id: LaneId) -> Option<(usize, LaneId)> {
        self.locus.get(id.0).copied()
    }

    pub fn pause(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].pause(l))
    }

    pub fn resume(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].resume(l))
    }

    pub fn cancel(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].cancel(l))
    }

    pub fn status(&self, id: LaneId) -> Option<LaneStatus> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].status(l))
    }

    pub fn lane_name(&self, id: LaneId) -> Option<&str> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].lane_name(l))
    }

    /// True when every lane on every host has completed or departed.
    pub fn is_idle(&self) -> bool {
        self.hosts.iter().all(Session::is_idle)
    }

    /// Cluster MIs run so far (hosts step in lockstep).
    pub fn mi(&self) -> usize {
        self.mi
    }

    /// Simulated time, seconds (identical on every host — lockstep MIs).
    pub fn time_s(&self) -> f64 {
        self.hosts[0].time_s()
    }

    pub fn lane_count(&self) -> usize {
        self.locus.len()
    }

    pub fn lanes_in_flight(&self) -> usize {
        self.hosts.iter().map(Session::lanes_in_flight).sum()
    }

    pub fn host_count(&self) -> usize {
        self.hosts.len()
    }

    /// The per-host sessions, host order — for host-resolved reporting
    /// (`sparta fleet --hosts` reads each host's ledger truth here).
    pub fn hosts(&self) -> &[Session] {
        &self.hosts
    }

    /// Cluster energy truth: the sum of every host session's ledger total
    /// (each host already pays only its `1/N` receiver share), joules.
    pub fn host_energy_j(&self) -> f64 {
        self.hosts.iter().map(Session::host_energy_j).sum()
    }

    /// Energy attributed to one lane so far, joules.
    pub fn lane_energy_j(&self, id: LaneId) -> Option<f64> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].lane_energy_j(l))
    }

    /// Cluster-wide per-rail breakdown (None when any host runs the
    /// lumped compat rail).
    pub fn energy_rails(&self) -> Option<RailEnergy> {
        let mut acc = RailEnergy::default();
        for h in &self.hosts {
            acc.add(&h.energy_rails()?);
        }
        Some(acc)
    }

    /// One lane's per-rail attribution (None on the lumped compat rail).
    pub fn lane_energy_rails(&self, id: LaneId) -> Option<RailEnergy> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].lane_energy_rails(l))
    }

    /// Route a record's state buffer back to its owning host's pool (the
    /// cluster analogue of [`Session::recycle_record`], for drivers that
    /// keep events past the next step).
    pub fn recycle_record(&mut self, lane: LaneId, record: MiRecord) {
        if let Some((h, _)) = self.resolve(lane) {
            self.hosts[h].recycle_record(record);
        }
    }

    pub fn testbed(&self) -> &Testbed {
        self.hosts[0].testbed()
    }

    /// Capture the cluster's complete logical state at an MI boundary: the
    /// lockstep MI counter plus every host session's capture, host order.
    /// `None` under the same conditions as [`Session::export_state`] on any
    /// host. The lane placement (`locus`/`global_of`/round-robin cursor) is
    /// regenerated by replaying the admission sequence, so it is not part
    /// of the capture.
    pub fn export_state(&self) -> Option<ClusterState> {
        Some(ClusterState {
            mi: self.mi,
            hosts: self.hosts.iter().map(Session::export_state).collect::<Option<Vec<_>>>()?,
        })
    }

    /// Restore a [`Cluster::export_state`] capture into a cluster rebuilt
    /// with the same configuration, seed and admission sequence. Returns
    /// `false` on a shape mismatch (see [`Session::import_state`]).
    pub fn import_state(&mut self, state: &ClusterState) -> bool {
        if self.hosts.len() != state.hosts.len() {
            return false;
        }
        if !self.hosts.iter_mut().zip(&state.hosts).all(|(h, s)| h.import_state(s)) {
            return false;
        }
        self.mi = state.mi;
        true
    }
}

/// A captured [`Cluster`] (see [`Cluster::export_state`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    /// Cluster MIs stepped (hosts run in lockstep).
    pub mi: usize,
    /// One capture per host session, host order.
    pub hosts: Vec<SessionState>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticTool;
    use crate::transfer::TransferJob;

    fn lane(files: usize) -> LaneSpec {
        LaneSpec::new(Box::new(StaticTool::rclone()), TransferJob::files(files, 64 << 20))
    }

    fn incast3(seed: u64) -> Cluster {
        Cluster::incast(&Testbed::chameleon(), 3, seed)
    }

    #[test]
    fn round_robin_admission_returns_global_ids() {
        let mut c = incast3(7);
        for k in 0..7 {
            assert_eq!(c.admit(lane(4)), LaneId(k));
        }
        assert_eq!(c.lane_count(), 7);
        assert_eq!(c.host_count(), 3);
        // Round robin: hosts get 3/2/2 lanes.
        let per_host: Vec<usize> = c.hosts().iter().map(Session::lane_count).collect();
        assert_eq!(per_host, [3, 2, 2]);
        for k in 0..7 {
            assert_eq!(c.status(LaneId(k)), Some(LaneStatus::Active));
        }
        assert_eq!(c.status(LaneId(99)), None);
    }

    #[test]
    fn merged_events_carry_global_lane_ids() {
        let mut c = incast3(11);
        let n = 6;
        for _ in 0..n {
            c.admit(lane(2));
        }
        let mut events = Vec::new();
        let mut admitted = Vec::new();
        for _ in 0..4 {
            c.step_into(&mut events);
            for ev in &events {
                if let Event::Admitted { lane, .. } = ev {
                    admitted.push(lane.0);
                }
                assert!(ev.lane().0 < n, "event lane {} out of range", ev.lane().0);
            }
        }
        admitted.sort_unstable();
        assert_eq!(admitted, (0..n).collect::<Vec<_>>());
        assert_eq!(c.mi(), 4);
        assert!(c.time_s() > 0.0);
    }

    /// External control routes through global ids, and cluster energy
    /// truth equals the sum of per-host ledgers and of lane attribution.
    #[test]
    fn control_and_energy_route_through_global_ids() {
        let mut c = incast3(23);
        for _ in 0..6 {
            c.admit(lane(8));
        }
        let mut events = Vec::new();
        for _ in 0..3 {
            c.step_into(&mut events);
        }
        assert!(c.pause(LaneId(4)));
        c.step_into(&mut events);
        assert_eq!(c.status(LaneId(4)), Some(LaneStatus::Paused));
        assert!(c.resume(LaneId(4)));
        assert!(c.cancel(LaneId(5)));
        for _ in 0..3 {
            c.step_into(&mut events);
        }
        let per_host: f64 = c.hosts().iter().map(Session::host_energy_j).sum();
        let total = c.host_energy_j();
        assert!((per_host - total).abs() <= 1e-9 * total.max(1.0));
        let attributed: f64 =
            (0..c.lane_count()).map(|k| c.lane_energy_j(LaneId(k)).unwrap()).sum();
        assert!(
            (attributed - total).abs() <= 1e-9 * total.max(1.0),
            "lanes {attributed} J vs cluster {total} J"
        );
        let rails = c.energy_rails().expect("incast clusters are host-resolved");
        assert!((rails.total_j() - total).abs() <= 1e-6 * total.max(1.0));
    }

    /// The same configuration and seed reproduce the event stream exactly;
    /// host identity seeds derive from the cluster seed, not admission
    /// timing.
    #[test]
    fn cluster_runs_are_deterministic() {
        let run = |seed: u64| {
            let mut c = incast3(seed);
            for _ in 0..5 {
                c.admit(lane(3));
            }
            let mut events = Vec::new();
            let mut digest = Vec::new();
            for _ in 0..6 {
                c.step_into(&mut events);
                for ev in &events {
                    if let Event::MiCompleted { lane, record } = ev {
                        digest.push((lane.0, record.throughput_gbps.to_bits()));
                    }
                }
            }
            digest
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
