//! Cluster-scale fleets: many per-host [`Session`]s behind one stepping
//! surface.
//!
//! A [`Cluster`] shards transfer lanes across N sender-host sessions —
//! each host with its own substrate ([`crate::net::stream::StreamArena`]),
//! its own [`crate::energy::HostLedger`] pair and rail calibration — and
//! presents the same admit/step/control API as a single [`Session`]
//! (formally: both implement [`super::Stepping`], so `sparta fleet` drives
//! either without caring about scale).
//!
//! ## Incast model
//!
//! The hosts of an incast fleet (N senders → one receiver) share the WAN
//! and the receiver-ingest stage. Each host session simulates its *static
//! fair share* of those stages ([`Topology::incast_host`]: capacity, queue
//! and cross traffic all divided by N; the sender NIC stays private and
//! full-rate), so host simulations are fully independent. That
//! independence is what makes cluster runs exactly reproducible: there is
//! no cross-host event ordering to race on, and every host seed is
//! identity-derived from `(cluster seed, host index)` — runner-style — so
//! a fleet report is bit-identical at any `--jobs` count, which CI
//! enforces byte-for-byte on `sparta fleet --hosts 4`.
//!
//! ## Energy
//!
//! Each host session bills a private sender host (`<testbed>-tx<h>`) plus
//! a `1/N` slice of the single physical receiver
//! ([`crate::energy::HostSpec::share`]): residency rails (fixed power, NIC
//! LPI idle) divide by N while traffic-proportional rails ride with the
//! host's own lanes, so summing attribution over every host session pays
//! the receiver exactly once. Per-session conservation (Σ lane attribution
//! == ledger truth) therefore composes into the cluster invariant
//! Σ lanes == Σ per-host totals == [`Cluster::host_energy_j`], asserted
//! per trial by `sparta fleet` and under churn by `tests/energy_ledger.rs`.
//!
//! ## Stepping and lane identity
//!
//! [`Cluster::admit`] places lanes round-robin across hosts and returns
//! *global* [`LaneId`]s (admission order, same contract as a session).
//! [`Cluster::step_into`] advances every host by one MI — sessions run in
//! lockstep, so `time_s`/`mi` agree everywhere — and merges the per-host
//! event streams into the caller's buffer with lane ids rewritten to
//! global. Record state buffers recycle back to their owning host's pool
//! ([`Session::recycle_record`]), keeping cluster stepping allocation-free
//! at steady state (§Perf in [`super::session`]).
//!
//! ## §Perf: parallel intra-step execution
//!
//! Host independence (no shared mutable state, static WAN slices,
//! identity-derived seeds) means the per-MI host loop is embarrassingly
//! parallel. [`Cluster::set_step_threads`] turns it on: a persistent
//! worker pool — std threads spawned once per cluster, jobs dispatched
//! over channels, because per-step `thread::scope` spawning would dominate
//! at ~ms MI wall times — steps each host `Session` into a dedicated
//! per-host event buffer, and the coordinator then merges those buffers
//! **in host order** while rewriting lane ids to global. Because each
//! host's internal event order is whatever that host produced and the
//! merge order is fixed by host index (never completion order), the merged
//! stream is byte-identical to the serial loop at any thread count — the
//! same contract style as `experiments/runner.rs` trial sharding, and CI
//! enforces it byte-for-byte (`fleet --hosts 4 --step-threads 1` vs `4`).
//!
//! Contract details:
//! * **Per-host buffers.** Each host steps into its own `Vec<Event>`
//!   (pool-owned while in flight, cluster-owned between steps), so workers
//!   never contend on the caller's merge buffer. Buffers are recycled
//!   every MI; at steady state (no admissions, stable event volume) a
//!   pooled step performs no allocation per host worker, which a debug
//!   assertion enforces (`debug_assertions` builds only).
//! * **Host-order merge.** The coordinator collects all N results (it
//!   blocks until every host finished the MI), then drains buffers
//!   `0..N`. Worker scheduling can never reorder the merged stream.
//! * **No cross-host state sharing.** Workers receive a raw pointer to one
//!   distinct host session each; nothing else is shared. Record recycling
//!   ([`Event::MiCompleted`] buffers from the *previous* MI) is routed
//!   back to owning hosts by the coordinator **before** dispatch, so
//!   workers never touch another host's pools.
//! * **Snapshot / control synchronization.** `pause`/`resume`/`cancel`,
//!   [`Cluster::export_state`] and [`Cluster::import_state`] run between
//!   steps, when the pool is quiescent (every `step_into` call joins all N
//!   results before returning), so MI-boundary snapshot capture of a
//!   threaded cluster is identical to the serial cluster's — `serve`
//!   checkpoint/restore stays bit-exact at any thread count, including
//!   restoring at a *different* thread count (`tests/cluster_threaded.rs`).
//!
//! The knob rides through `sparta fleet/serve/bench --step-threads N`
//! (`0` = auto: one thread under outer `--jobs` trial sharding to avoid
//! oversubscription, else `min(hosts, cores)` — see
//! `experiments::fleet::resolve_step_threads`).

use super::session::{Event, LaneId, LaneSpec, LaneStatus, MiRecord, Session, SessionState};
use crate::energy::RailEnergy;
use crate::faults::{FaultEvent, FaultOp, FaultPlan};
use crate::net::{Testbed, Topology};
use crate::util::rng::mix_seed;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

/// Receiver-ingest provisioning of [`Cluster::incast`] relative to WAN
/// capacity: below 1.0 the receiver, not the WAN, is the incast
/// bottleneck.
pub const INCAST_RX_OVER_WAN: f64 = 0.8;

// The pooled step hands worker threads `*mut Session`; this is only sound
// if a Session can move between threads at all. Assert it at compile time
// so a non-Send field added to Session (or a lane optimizer losing the
// `Send` supertrait) fails here, not at a distance.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Session>()
};

/// A `*mut Session` that may cross a channel to a worker thread.
///
/// SAFETY: `Send` is sound because the coordinator upholds, for every job
/// in flight, that (a) each pointer targets a *distinct* element of
/// `Cluster::hosts`, (b) `Cluster::step_into` blocks until every result is
/// collected before returning (so the `Vec` is never reallocated, moved or
/// dropped while workers hold pointers into it), and (c) `Session: Send`
/// (asserted above), so mutating one from a worker thread is ordinary.
struct SendPtr(*mut Session);
unsafe impl Send for SendPtr {}

/// One dispatched host step: step the session one MI into `out`.
struct StepJob {
    host: usize,
    session: SendPtr,
    out: Vec<Event>,
}

/// A finished host step. `panicked` reports a caught worker panic — the
/// result is still sent so the coordinator's collect loop never deadlocks;
/// it re-panics after all hosts are accounted for.
struct StepResult {
    host: usize,
    out: Vec<Event>,
    panicked: bool,
}

/// Persistent worker pool for pooled cluster stepping (§Perf). Spawned
/// lazily on the first multi-threaded step, kept for the cluster's
/// lifetime; dropping it closes the job channel and joins every worker.
struct StepPool {
    /// `Some` while the pool is live; taken in `Drop` to close the channel.
    jobs: Option<Sender<StepJob>>,
    results: Receiver<StepResult>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl StepPool {
    fn new(threads: usize) -> StepPool {
        let (job_tx, job_rx) = channel::<StepJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel::<StepResult>();
        let workers = (0..threads)
            .map(|k| {
                let job_rx = Arc::clone(&job_rx);
                let res_tx = res_tx.clone();
                thread::Builder::new()
                    .name(format!("sparta-step-{k}"))
                    .spawn(move || loop {
                        // Hold the lock only for the dequeue, not the step.
                        let job = {
                            let rx = job_rx.lock().unwrap_or_else(|p| p.into_inner());
                            rx.recv()
                        };
                        let Ok(StepJob { host, session, mut out }) = job else {
                            return; // channel closed: pool is shutting down
                        };
                        let panicked = catch_unwind(AssertUnwindSafe(|| {
                            // SAFETY: see `SendPtr` — distinct host, backing
                            // Vec pinned until the coordinator collects us.
                            unsafe { (*session.0).step_into(&mut out) }
                        }))
                        .is_err();
                        let _ = res_tx.send(StepResult { host, out, panicked });
                    })
                    .expect("spawn cluster step worker")
            })
            .collect();
        StepPool { jobs: Some(job_tx), results: res_rx, workers }
    }

    fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for StepPool {
    fn drop(&mut self) {
        self.jobs.take(); // closing the channel makes every worker return
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// N per-host [`Session`]s behind one [`super::Stepping`] surface (see the
/// module docs).
pub struct Cluster {
    hosts: Vec<Session>,
    /// Global lane id → (host index, host-local lane id).
    locus: Vec<(usize, LaneId)>,
    /// Per host: host-local lane index → global lane id.
    global_of: Vec<Vec<usize>>,
    /// Round-robin admission cursor.
    next_host: usize,
    /// Cluster MIs stepped (hosts run in lockstep).
    mi: usize,
    /// Reusable per-host event staging buffer (§Perf, serial path).
    scratch: Vec<Event>,
    /// Intra-step worker count (1 = serial; capped at `hosts.len()`).
    step_threads: usize,
    /// Lazily-spawned worker pool; `None` until the first pooled step and
    /// after a `set_step_threads` change.
    pool: Option<StepPool>,
    /// Per-host event buffers cycled through the pool (§Perf).
    host_bufs: Vec<Vec<Event>>,
    /// Per-host buffer capacity after the last pooled step, for the
    /// steady-state allocation-free debug assertion.
    evt_cap: Vec<usize>,
    /// Per-host event-count high water mark across pooled steps.
    evt_hiwater: Vec<usize>,
    /// Set by `admit` (admissions emit events and may grow arenas), cleared
    /// each step: suppresses the allocation-free assertion for one MI.
    admits_since_step: bool,
    /// Fault plane armed ([`Cluster::install_faults`]): the cluster owns
    /// the plan and routes ops to hosts; every host runs its watchdog.
    faults_armed: bool,
    /// Seeded fault ops sorted by MI, applied as `mi` passes them.
    fault_plan: Vec<FaultEvent>,
    /// Next unapplied index into `fault_plan`.
    fault_next: usize,
    /// Per-host quarantine flag: a crashed host is never stepped again;
    /// its ledger stays frozen in the energy sums.
    crashed: Vec<bool>,
    /// Per-global-lane energy carried off crashed hosts (J) — added to the
    /// lane's live-host attribution so Σ lanes == Σ host ledgers survives
    /// migration.
    carried: Vec<f64>,
    /// Cluster-level events (`Migrated`) queued for the next merged step.
    fault_pending: Vec<Event>,
}

impl Cluster {
    /// Build an `n`-host cluster from a per-host session factory. Host `h`
    /// is handed the identity-derived seed `mix_seed(seed, "cluster/host",
    /// h)` — the factory must use it (not the raw cluster seed) so fleet
    /// results depend only on configuration, never on sharding.
    pub fn build(n: usize, seed: u64, mut host: impl FnMut(usize, u64) -> Session) -> Cluster {
        assert!(n > 0, "a cluster needs at least one host");
        let hosts: Vec<Session> =
            (0..n).map(|h| host(h, mix_seed(seed, "cluster/host", h as u64))).collect();
        Cluster {
            global_of: vec![Vec::new(); hosts.len()],
            host_bufs: (0..hosts.len()).map(|_| Vec::new()).collect(),
            evt_cap: vec![0; hosts.len()],
            evt_hiwater: vec![0; hosts.len()],
            crashed: vec![false; hosts.len()],
            hosts,
            locus: Vec::new(),
            next_host: 0,
            mi: 0,
            scratch: Vec::new(),
            step_threads: 1,
            pool: None,
            admits_since_step: false,
            faults_armed: false,
            fault_plan: Vec::new(),
            fault_next: 0,
            carried: Vec::new(),
            fault_pending: Vec::new(),
        }
    }

    /// The default incast fleet over a testbed: every sender host runs a
    /// private NIC into its fair share of the testbed WAN and of a
    /// receiver provisioned at [`INCAST_RX_OVER_WAN`] × WAN capacity
    /// (receiver-limited), with host-resolved energy accounting
    /// ([`Testbed::energy_hosts_of`]).
    pub fn incast(tb: &Testbed, n: usize, seed: u64) -> Cluster {
        Cluster::build(n, seed, |h, host_seed| {
            Session::builder(tb.clone())
                .topology(Topology::incast_host(tb, n, INCAST_RX_OVER_WAN))
                .energy(tb.energy_hosts_of(h, n))
                .seed(host_seed)
                .build()
        })
    }

    /// Set the intra-step worker count (§Perf). `threads <= 1` is the
    /// serial loop; higher values are capped at the host count when the
    /// pool spawns. Changing the count drops the old pool (workers join)
    /// and respawns lazily on the next step — the merged event stream is
    /// byte-identical at any value, so this is purely a wall-clock knob
    /// and is deliberately *not* part of the logical configuration
    /// (snapshots don't record it; restore at any thread count).
    pub fn set_step_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        if threads != self.step_threads {
            self.step_threads = threads;
            self.pool = None;
        }
    }

    /// Current intra-step worker setting (1 = serial).
    pub fn step_threads(&self) -> usize {
        self.step_threads
    }

    /// Capacity hints for an expected total of `n` lanes (e.g. a fleet
    /// schedule's arrival count): reserves the global lane maps and each
    /// host's lane table + stream arena, so 100k-lane admit storms don't
    /// grow cluster-level tables one push at a time.
    pub fn reserve_lanes(&mut self, n: usize) {
        self.locus.reserve(n);
        let per_host = n / self.hosts.len() + 1;
        for (host, map) in self.hosts.iter_mut().zip(&mut self.global_of) {
            map.reserve(per_host);
            host.reserve_lanes(per_host);
        }
    }

    /// Admit a lane on the next host round-robin (skipping quarantined
    /// hosts — a degraded cluster keeps taking admissions); returns its
    /// *global* lane id (admission order across the whole cluster).
    pub fn admit(&mut self, spec: LaneSpec) -> LaneId {
        // At least one host is always healthy (`crash_host` spares the
        // last one), so this cursor walk terminates.
        while self.crashed[self.next_host] {
            self.next_host = (self.next_host + 1) % self.hosts.len();
        }
        let h = self.next_host;
        self.next_host = (self.next_host + 1) % self.hosts.len();
        let local = self.hosts[h].admit(spec);
        let global = LaneId(self.locus.len());
        self.locus.push((h, local));
        self.carried.push(0.0);
        debug_assert_eq!(self.global_of[h].len(), local.0);
        self.global_of[h].push(global.0);
        self.admits_since_step = true;
        global
    }

    /// Install a seeded fault plan ([`crate::faults`]) at cluster level:
    /// the cluster applies each op at its scheduled MI boundary — segment
    /// faults fan out to every healthy host's substrate, stalls and stream
    /// errors route to the owning host, and [`FaultOp::HostCrash`] becomes
    /// quarantine-and-migrate ([`Cluster::crash_host`]). Every host's
    /// stall watchdog is armed. An armed cluster is no longer
    /// checkpointable ([`Cluster::export_state`]).
    pub fn install_faults(&mut self, plan: FaultPlan) {
        for host in &mut self.hosts {
            host.arm_faults();
        }
        self.fault_plan = plan.events;
        self.fault_next = 0;
        self.faults_armed = true;
    }

    /// Whether the fault plane is armed on this cluster.
    pub fn faults_armed(&self) -> bool {
        self.faults_armed
    }

    /// Number of hosts currently quarantined (crashed and migrated away).
    pub fn quarantined_hosts(&self) -> usize {
        self.crashed.iter().filter(|&&c| c).count()
    }

    /// Apply plan ops that have come due — runs in the coordinator thread
    /// at the top of every step, before any host advances, so fault timing
    /// and the merged-stream position of fault events are pure functions
    /// of the MI index (byte-identical at any `--jobs`/`--step-threads`).
    fn apply_due_faults(&mut self) {
        while self.fault_next < self.fault_plan.len()
            && self.fault_plan[self.fault_next].at_mi <= self.mi
        {
            let op = self.fault_plan[self.fault_next].op.clone();
            self.fault_next += 1;
            match &op {
                FaultOp::SegmentScale { .. } => {
                    // Every host simulates its own share of the faulted
                    // segment; fan the scale out to all healthy hosts.
                    for h in 0..self.hosts.len() {
                        if !self.crashed[h] {
                            self.hosts[h].apply_fault_op(&op);
                        }
                    }
                }
                FaultOp::HostStall { host, .. } => {
                    let h = host % self.hosts.len();
                    if !self.crashed[h] {
                        self.hosts[h].apply_fault_op(&op);
                    }
                }
                FaultOp::StreamError { lane_slot } => {
                    // Route by global lane id so the victim is independent
                    // of host sharding.
                    if !self.locus.is_empty() {
                        let gid = lane_slot % self.locus.len();
                        let (h, l) = self.locus[gid];
                        if !self.crashed[h] {
                            self.hosts[h].fault_lane(l, "stream-error");
                        }
                    }
                }
                FaultOp::HostCrash { host } => {
                    self.crash_host(host % self.hosts.len());
                }
            }
        }
    }

    /// Quarantine a host and migrate its in-flight lanes onto healthy
    /// hosts: each non-terminal lane is lifted out with its optimizer,
    /// job progress and trackers ([`Session::extract_lane`]) and
    /// re-admitted on the least-loaded healthy host (ties break to the
    /// lowest index), keeping its *global* lane id. Energy attributed on
    /// the dead host is carried so Σ lane attribution still equals the
    /// cluster ledger truth (the frozen ledger stays in the sum). The
    /// last healthy host can never be crashed. Emits one
    /// [`Event::Migrated`] per moved lane into the next merged step.
    pub fn crash_host(&mut self, h: usize) {
        if h >= self.hosts.len() || self.crashed[h] {
            return;
        }
        if self.crashed.iter().filter(|&&c| !c).count() <= 1 {
            return; // never kill the last healthy host
        }
        self.crashed[h] = true;
        let time_s = self.time_s();
        for local in 0..self.hosts[h].lane_count() {
            let gid = self.global_of[h][local];
            let Some(m) = self.hosts[h].extract_lane(LaneId(local)) else {
                continue; // already terminal on the dead host
            };
            self.carried[gid] += m.energy_j;
            let target = self.least_loaded_healthy_host();
            let nlocal = self.hosts[target].admit_migrated(m);
            self.locus[gid] = (target, nlocal);
            debug_assert_eq!(self.global_of[target].len(), nlocal.0);
            self.global_of[target].push(gid);
            self.fault_pending.push(Event::Migrated {
                lane: LaneId(gid),
                mi: self.mi,
                time_s,
                from_host: h,
                to_host: target,
            });
        }
        // Re-admissions grow arenas on the target hosts.
        self.admits_since_step = true;
    }

    /// The healthy host with the fewest in-flight lanes (lowest index on
    /// ties) — the deterministic migration target.
    fn least_loaded_healthy_host(&self) -> usize {
        (0..self.hosts.len())
            .filter(|&h| !self.crashed[h])
            .min_by_key(|&h| self.hosts[h].lanes_in_flight())
            .expect("at least one healthy host")
    }

    /// Advance every host session by one monitoring interval, merging
    /// their event streams — lane ids rewritten to global, **host order**
    /// regardless of thread count — into the caller-reused `events`
    /// buffer. The previous batch's record buffers are first routed back
    /// to their owning hosts' pools (before dispatch, so pooled workers
    /// never touch another host's pools — §Perf).
    pub fn step_into(&mut self, events: &mut Vec<Event>) {
        for ev in events.drain(..) {
            if let Event::MiCompleted { lane, record } = ev {
                let (h, _) = self.locus[lane.0];
                self.hosts[h].recycle_record(record);
            }
        }
        if self.faults_armed {
            self.apply_due_faults();
        }
        // Cluster-level events (migrations off crashed hosts) lead the
        // merged stream — a fixed position, independent of thread count.
        events.append(&mut self.fault_pending);
        let threads = self.step_threads.min(self.hosts.len());
        if threads <= 1 {
            let mut scratch = std::mem::take(&mut self.scratch);
            for h in 0..self.hosts.len() {
                if self.crashed[h] {
                    continue; // quarantined: frozen, never stepped again
                }
                self.hosts[h].step_into(&mut scratch);
                for mut ev in scratch.drain(..) {
                    self.globalize(h, &mut ev);
                    events.push(ev);
                }
            }
            self.scratch = scratch;
        } else {
            self.step_pooled(threads, events);
        }
        self.admits_since_step = false;
        self.mi += 1;
    }

    /// The pooled step: dispatch one job per host to the persistent worker
    /// pool, block until all N results are back, then merge in host order
    /// (§Perf).
    fn step_pooled(&mut self, threads: usize, events: &mut Vec<Event>) {
        let n = self.hosts.len();
        if self.pool.as_ref().map(StepPool::threads) != Some(threads) {
            self.pool = Some(StepPool::new(threads));
        }
        // Take the pool out so dispatching can borrow `self.hosts` mutably.
        let pool = self.pool.take().expect("pool just ensured above");
        let jobs = pool.jobs.as_ref().expect("pool job channel open");
        let base = self.hosts.as_mut_ptr();
        let mut dispatched = 0;
        for h in 0..n {
            if self.crashed[h] {
                continue; // quarantined: frozen, never stepped again
            }
            let out = std::mem::take(&mut self.host_bufs[h]);
            // SAFETY: each job gets a distinct host index, and we recv all
            // dispatched results below before `self.hosts` can move again.
            let session = SendPtr(unsafe { base.add(h) });
            jobs.send(StepJob { host: h, session, out }).expect("step worker pool alive");
            dispatched += 1;
        }
        let mut panicked_hosts = Vec::new();
        for _ in 0..dispatched {
            let r = pool.results.recv().expect("step worker pool alive");
            if r.panicked {
                panicked_hosts.push(r.host);
            }
            self.host_bufs[r.host] = r.out;
        }
        self.pool = Some(pool);
        // A panicking host no longer aborts the fleet: quarantine it and
        // migrate its lanes, exactly like an injected crash. Its partial
        // events for this MI are dropped (the panic left them mid-write);
        // the `Migrated` announcements join the next merged step. Sorted
        // so multi-host panics quarantine in deterministic order.
        panicked_hosts.sort_unstable();
        for h in panicked_hosts {
            self.host_bufs[h].clear();
            if self.crashed.iter().filter(|&&c| !c).count() <= 1 {
                // Nowhere left to migrate: the fleet is genuinely dead.
                panic!("the last healthy host panicked during a pooled cluster step");
            }
            self.crash_host(h);
        }
        for h in 0..n {
            let mut buf = std::mem::take(&mut self.host_bufs[h]);
            // Steady state (no admissions, event volume at or below the
            // high water mark) must not have grown the buffer: pooled
            // stepping is allocation-free per host worker.
            if !self.admits_since_step && buf.len() <= self.evt_hiwater[h] {
                debug_assert!(
                    self.evt_cap[h] == 0 || buf.capacity() == self.evt_cap[h],
                    "host {h} event buffer reallocated at steady state \
                     ({} -> {} cap)",
                    self.evt_cap[h],
                    buf.capacity()
                );
            }
            self.evt_hiwater[h] = self.evt_hiwater[h].max(buf.len());
            self.evt_cap[h] = buf.capacity();
            for mut ev in buf.drain(..) {
                self.globalize(h, &mut ev);
                events.push(ev);
            }
            self.host_bufs[h] = buf;
        }
    }

    /// Rewrite a host-local event to cluster-global lane identity.
    fn globalize(&self, host: usize, ev: &mut Event) {
        match ev {
            Event::Admitted { lane, .. }
            | Event::MiCompleted { lane, .. }
            | Event::Paused { lane, .. }
            | Event::Resumed { lane, .. }
            | Event::Completed { lane, .. }
            | Event::Departed { lane, .. }
            | Event::Faulted { lane, .. }
            | Event::Retrying { lane, .. }
            | Event::Migrated { lane, .. } => *lane = LaneId(self.global_of[host][lane.0]),
        }
    }

    fn resolve(&self, id: LaneId) -> Option<(usize, LaneId)> {
        self.locus.get(id.0).copied()
    }

    pub fn pause(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].pause(l))
    }

    pub fn resume(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].resume(l))
    }

    pub fn cancel(&mut self, id: LaneId) -> bool {
        self.resolve(id).is_some_and(|(h, l)| self.hosts[h].cancel(l))
    }

    pub fn status(&self, id: LaneId) -> Option<LaneStatus> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].status(l))
    }

    pub fn lane_name(&self, id: LaneId) -> Option<&str> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].lane_name(l))
    }

    /// True when every lane on every healthy host has completed or
    /// departed (quarantined hosts hold only tombstones — their in-flight
    /// lanes migrated away).
    pub fn is_idle(&self) -> bool {
        self.hosts
            .iter()
            .enumerate()
            .all(|(h, host)| self.crashed[h] || host.is_idle())
    }

    /// Cluster MIs run so far (hosts step in lockstep).
    pub fn mi(&self) -> usize {
        self.mi
    }

    /// Simulated time, seconds (identical on every healthy host — lockstep
    /// MIs; quarantined hosts' clocks freeze at their crash MI).
    pub fn time_s(&self) -> f64 {
        self.hosts
            .iter()
            .enumerate()
            .find(|(h, _)| !self.crashed[*h])
            .map(|(_, host)| host.time_s())
            .unwrap_or_else(|| self.hosts[0].time_s())
    }

    pub fn lane_count(&self) -> usize {
        self.locus.len()
    }

    pub fn lanes_in_flight(&self) -> usize {
        self.hosts.iter().map(Session::lanes_in_flight).sum()
    }

    pub fn host_count(&self) -> usize {
        self.hosts.len()
    }

    /// The per-host sessions, host order — for host-resolved reporting
    /// (`sparta fleet --hosts` reads each host's ledger truth here).
    pub fn hosts(&self) -> &[Session] {
        &self.hosts
    }

    /// Cluster energy truth: the sum of every host session's ledger total
    /// (each host already pays only its `1/N` receiver share), joules.
    pub fn host_energy_j(&self) -> f64 {
        self.hosts.iter().map(Session::host_energy_j).sum()
    }

    /// Energy attributed to one lane so far, joules. For a lane migrated
    /// off a crashed host this is its live-host attribution plus the
    /// portion frozen on the host it left (`carried`), so per-lane totals
    /// keep summing to the cluster ledger truth through crashes.
    pub fn lane_energy_j(&self, id: LaneId) -> Option<f64> {
        let (h, l) = self.resolve(id)?;
        let live = self.hosts[h].lane_energy_j(l)?;
        Some(live + self.carried.get(id.0).copied().unwrap_or(0.0))
    }

    /// Cluster-wide per-rail breakdown (None when any host runs the
    /// lumped compat rail).
    pub fn energy_rails(&self) -> Option<RailEnergy> {
        let mut acc = RailEnergy::default();
        for h in &self.hosts {
            acc.add(&h.energy_rails()?);
        }
        Some(acc)
    }

    /// One lane's per-rail attribution (None on the lumped compat rail).
    pub fn lane_energy_rails(&self, id: LaneId) -> Option<RailEnergy> {
        self.resolve(id).and_then(|(h, l)| self.hosts[h].lane_energy_rails(l))
    }

    /// Route a record's state buffer back to its owning host's pool (the
    /// cluster analogue of [`Session::recycle_record`], for drivers that
    /// keep events past the next step).
    pub fn recycle_record(&mut self, lane: LaneId, record: MiRecord) {
        if let Some((h, _)) = self.resolve(lane) {
            self.hosts[h].recycle_record(record);
        }
    }

    pub fn testbed(&self) -> &Testbed {
        self.hosts[0].testbed()
    }

    /// Capture the cluster's complete logical state at an MI boundary: the
    /// lockstep MI counter plus every host session's capture, host order.
    /// `None` under the same conditions as [`Session::export_state`] on any
    /// host. The lane placement (`locus`/`global_of`/round-robin cursor) is
    /// regenerated by replaying the admission sequence, so it is not part
    /// of the capture — and neither is `step_threads`, which never affects
    /// the logical state (§Perf: the pool is quiescent between steps, so
    /// capture needs no synchronization beyond being called at a boundary).
    /// An armed or degraded (quarantined-host) cluster refuses to
    /// checkpoint — fault state lives outside the snapshot codec.
    pub fn export_state(&self) -> Option<ClusterState> {
        if self.faults_armed || self.crashed.iter().any(|&c| c) {
            return None;
        }
        Some(ClusterState {
            mi: self.mi,
            hosts: self.hosts.iter().map(Session::export_state).collect::<Option<Vec<_>>>()?,
        })
    }

    /// Restore a [`Cluster::export_state`] capture into a cluster rebuilt
    /// with the same configuration, seed and admission sequence. Returns
    /// `false` on a shape mismatch (see [`Session::import_state`]).
    pub fn import_state(&mut self, state: &ClusterState) -> bool {
        if self.hosts.len() != state.hosts.len() {
            return false;
        }
        if !self.hosts.iter_mut().zip(&state.hosts).all(|(h, s)| h.import_state(s)) {
            return false;
        }
        self.mi = state.mi;
        true
    }
}

/// A captured [`Cluster`] (see [`Cluster::export_state`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    /// Cluster MIs stepped (hosts run in lockstep).
    pub mi: usize,
    /// One capture per host session, host order.
    pub hosts: Vec<SessionState>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticTool;
    use crate::transfer::TransferJob;

    fn lane(files: usize) -> LaneSpec {
        LaneSpec::new(Box::new(StaticTool::rclone()), TransferJob::files(files, 64 << 20))
    }

    fn incast3(seed: u64) -> Cluster {
        Cluster::incast(&Testbed::chameleon(), 3, seed)
    }

    #[test]
    fn round_robin_admission_returns_global_ids() {
        let mut c = incast3(7);
        for k in 0..7 {
            assert_eq!(c.admit(lane(4)), LaneId(k));
        }
        assert_eq!(c.lane_count(), 7);
        assert_eq!(c.host_count(), 3);
        // Round robin: hosts get 3/2/2 lanes.
        let per_host: Vec<usize> = c.hosts().iter().map(Session::lane_count).collect();
        assert_eq!(per_host, [3, 2, 2]);
        for k in 0..7 {
            assert_eq!(c.status(LaneId(k)), Some(LaneStatus::Active));
        }
        assert_eq!(c.status(LaneId(99)), None);
    }

    #[test]
    fn merged_events_carry_global_lane_ids() {
        let mut c = incast3(11);
        let n = 6;
        for _ in 0..n {
            c.admit(lane(2));
        }
        let mut events = Vec::new();
        let mut admitted = Vec::new();
        for _ in 0..4 {
            c.step_into(&mut events);
            for ev in &events {
                if let Event::Admitted { lane, .. } = ev {
                    admitted.push(lane.0);
                }
                assert!(ev.lane().0 < n, "event lane {} out of range", ev.lane().0);
            }
        }
        admitted.sort_unstable();
        assert_eq!(admitted, (0..n).collect::<Vec<_>>());
        assert_eq!(c.mi(), 4);
        assert!(c.time_s() > 0.0);
    }

    /// External control routes through global ids, and cluster energy
    /// truth equals the sum of per-host ledgers and of lane attribution.
    #[test]
    fn control_and_energy_route_through_global_ids() {
        let mut c = incast3(23);
        for _ in 0..6 {
            c.admit(lane(8));
        }
        let mut events = Vec::new();
        for _ in 0..3 {
            c.step_into(&mut events);
        }
        assert!(c.pause(LaneId(4)));
        c.step_into(&mut events);
        assert_eq!(c.status(LaneId(4)), Some(LaneStatus::Paused));
        assert!(c.resume(LaneId(4)));
        assert!(c.cancel(LaneId(5)));
        for _ in 0..3 {
            c.step_into(&mut events);
        }
        let per_host: f64 = c.hosts().iter().map(Session::host_energy_j).sum();
        let total = c.host_energy_j();
        assert!((per_host - total).abs() <= 1e-9 * total.max(1.0));
        let attributed: f64 =
            (0..c.lane_count()).map(|k| c.lane_energy_j(LaneId(k)).unwrap()).sum();
        assert!(
            (attributed - total).abs() <= 1e-9 * total.max(1.0),
            "lanes {attributed} J vs cluster {total} J"
        );
        let rails = c.energy_rails().expect("incast clusters are host-resolved");
        assert!((rails.total_j() - total).abs() <= 1e-6 * total.max(1.0));
    }

    /// The same configuration and seed reproduce the event stream exactly;
    /// host identity seeds derive from the cluster seed, not admission
    /// timing.
    #[test]
    fn cluster_runs_are_deterministic() {
        let run = |seed: u64| {
            let mut c = incast3(seed);
            for _ in 0..5 {
                c.admit(lane(3));
            }
            let mut events = Vec::new();
            let mut digest = Vec::new();
            for _ in 0..6 {
                c.step_into(&mut events);
                for ev in &events {
                    if let Event::MiCompleted { lane, record } = ev {
                        digest.push((lane.0, record.throughput_gbps.to_bits()));
                    }
                }
            }
            digest
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    /// Replay one churn script (admissions mid-run, pause/resume/cancel)
    /// at a given thread count and digest the full merged event stream
    /// bit-exactly.
    fn churn_digest(threads: usize) -> Vec<(usize, String)> {
        let mut c = Cluster::incast(&Testbed::chameleon(), 4, 99);
        c.set_step_threads(threads);
        for _ in 0..6 {
            c.admit(lane(3));
        }
        let mut events = Vec::new();
        let mut digest = Vec::new();
        for mi in 0..12 {
            if mi == 2 {
                c.admit(lane(2));
                c.admit(lane(2));
            }
            if mi == 3 {
                assert!(c.pause(LaneId(1)));
            }
            if mi == 5 {
                assert!(c.resume(LaneId(1)));
                assert!(c.cancel(LaneId(6)));
            }
            c.step_into(&mut events);
            for ev in &events {
                let bits = match ev {
                    Event::MiCompleted { record, .. } => format!(
                        "mi thr={:016x} e={:016x}",
                        record.throughput_gbps.to_bits(),
                        record.energy_total_j.to_bits()
                    ),
                    other => format!("{other:?}"),
                };
                digest.push((ev.lane().0, bits));
            }
        }
        digest
    }

    /// §Perf contract: the pooled step's host-order merge is byte-identical
    /// to the serial loop under churn, at several thread counts (including
    /// more threads than hosts, which caps at the host count).
    #[test]
    fn pooled_step_matches_serial_bit_for_bit() {
        let serial = churn_digest(1);
        assert_eq!(serial, churn_digest(2));
        assert_eq!(serial, churn_digest(4));
        assert_eq!(serial, churn_digest(16));
    }

    /// Changing the thread count mid-run (pool respawn) never perturbs the
    /// stream: run half serial, switch to pooled, and compare against the
    /// all-serial run.
    #[test]
    fn thread_count_can_change_mid_run() {
        let run = |switch: Option<usize>| {
            let mut c = incast3(31);
            for _ in 0..5 {
                c.admit(lane(3));
            }
            let mut events = Vec::new();
            let mut digest = Vec::new();
            for mi in 0..10 {
                if Some(mi) == switch {
                    c.set_step_threads(3);
                }
                c.step_into(&mut events);
                for ev in &events {
                    if let Event::MiCompleted { lane, record } = ev {
                        digest.push((lane.0, record.throughput_gbps.to_bits()));
                    }
                }
            }
            digest
        };
        assert_eq!(run(None), run(Some(5)));
    }

    /// Snapshot capture at an MI boundary of a pooled cluster restores
    /// into a serial cluster (and vice versa) with an identical tail —
    /// thread count is not logical state.
    #[test]
    fn pooled_snapshot_restores_into_serial_cluster() {
        let tail = |head_threads: usize, tail_threads: usize| {
            let mut c = incast3(57);
            c.set_step_threads(head_threads);
            for _ in 0..5 {
                c.admit(lane(4));
            }
            let mut events = Vec::new();
            for _ in 0..4 {
                c.step_into(&mut events);
            }
            events.clear();
            let state = c.export_state().expect("boundary capture");
            let mut r = incast3(57);
            r.set_step_threads(tail_threads);
            for _ in 0..5 {
                r.admit(lane(4));
            }
            assert!(r.import_state(&state));
            assert_eq!(r.mi(), 4);
            let mut digest = Vec::new();
            for _ in 0..6 {
                r.step_into(&mut events);
                for ev in &events {
                    if let Event::MiCompleted { lane, record } = ev {
                        digest.push((lane.0, record.throughput_gbps.to_bits()));
                    }
                }
            }
            digest
        };
        assert_eq!(tail(3, 1), tail(1, 3));
    }

    /// An injected host crash quarantines the host and migrates its
    /// in-flight lanes: every admitted lane still completes with all its
    /// bytes, and Σ per-lane energy == cluster ledger truth at 1e-9.
    #[test]
    fn host_crash_migrates_lanes_and_conserves_bytes_and_energy() {
        use crate::faults::{FaultEvent, FaultOp, FaultPlan};
        let mut c = Cluster::incast(&Testbed::chameleon(), 4, 41);
        c.install_faults(FaultPlan {
            events: vec![FaultEvent { at_mi: 3, op: FaultOp::HostCrash { host: 2 } }],
        });
        let n = 8;
        let mut totals = Vec::new();
        for _ in 0..n {
            let job = TransferJob::files(16, 256 << 20);
            totals.push(job.total_bytes());
            c.admit(LaneSpec::new(Box::new(StaticTool::rclone()), job));
        }
        let mut events = Vec::new();
        let mut migrated = Vec::new();
        let mut completed = vec![None; n];
        for _ in 0..400 {
            c.step_into(&mut events);
            for ev in &events {
                match ev {
                    Event::Migrated { lane, from_host, to_host, .. } => {
                        assert_eq!(*from_host, 2);
                        assert_ne!(*to_host, 2);
                        migrated.push(lane.0);
                    }
                    Event::Completed { lane, bytes_delivered, .. } => {
                        completed[lane.0] = Some(*bytes_delivered);
                    }
                    _ => {}
                }
            }
            if c.is_idle() {
                break;
            }
        }
        assert!(c.is_idle(), "fleet never drained after the crash");
        assert_eq!(c.quarantined_hosts(), 1);
        // Host 2 held 2 of the 8 round-robin lanes; both must have moved.
        assert_eq!(migrated, vec![2, 6]);
        for (k, done) in completed.iter().enumerate() {
            let bytes = done.expect("every admitted lane must complete despite the crash");
            assert!(
                bytes >= totals[k] * 0.999,
                "lane {k} lost bytes across migration: {bytes} < {}",
                totals[k]
            );
        }
        let total = c.host_energy_j();
        let attributed: f64 =
            (0..c.lane_count()).map(|k| c.lane_energy_j(LaneId(k)).unwrap()).sum();
        assert!(
            (attributed - total).abs() <= 1e-9 * total.max(1.0),
            "migration broke energy conservation: lanes {attributed} J vs cluster {total} J"
        );
    }

    /// The crash-and-migrate stream is byte-identical at any intra-step
    /// thread count: quarantine happens in the coordinator at a fixed MI
    /// boundary, never inside a worker.
    #[test]
    fn crash_recovery_is_thread_count_invariant() {
        use crate::faults::{FaultEvent, FaultOp, FaultPlan};
        let run = |threads: usize| {
            let mut c = Cluster::incast(&Testbed::chameleon(), 4, 43);
            c.set_step_threads(threads);
            c.install_faults(FaultPlan {
                events: vec![
                    FaultEvent { at_mi: 2, op: FaultOp::HostCrash { host: 1 } },
                    FaultEvent { at_mi: 5, op: FaultOp::HostCrash { host: 3 } },
                ],
            });
            for _ in 0..8 {
                c.admit(lane(8));
            }
            let mut events = Vec::new();
            let mut digest = Vec::new();
            for _ in 0..30 {
                c.step_into(&mut events);
                for ev in &events {
                    let bits = match ev {
                        Event::MiCompleted { record, .. } => format!(
                            "mi thr={:016x} e={:016x}",
                            record.throughput_gbps.to_bits(),
                            record.energy_total_j.to_bits()
                        ),
                        other => format!("{other:?}"),
                    };
                    digest.push((ev.lane().0, bits));
                }
            }
            assert_eq!(c.quarantined_hosts(), 2);
            digest
        };
        let serial = run(1);
        assert_eq!(serial, run(4));
    }

    /// Armed or degraded clusters refuse to checkpoint.
    #[test]
    fn armed_cluster_is_not_checkpointable() {
        use crate::faults::FaultPlan;
        let mut c = incast3(91);
        c.admit(lane(4));
        let mut events = Vec::new();
        c.step_into(&mut events);
        assert!(c.export_state().is_some());
        c.install_faults(FaultPlan::default());
        assert!(c.export_state().is_none());
    }

    /// `reserve_lanes` is a pure capacity hint: admissions and stepping
    /// after a reservation match an unreserved run exactly.
    #[test]
    fn reserve_lanes_does_not_perturb_runs() {
        let run = |reserve: bool| {
            let mut c = incast3(77);
            if reserve {
                c.reserve_lanes(64);
            }
            for _ in 0..6 {
                c.admit(lane(3));
            }
            let mut events = Vec::new();
            let mut digest = Vec::new();
            for _ in 0..5 {
                c.step_into(&mut events);
                for ev in &events {
                    if let Event::MiCompleted { lane, record } = ev {
                        digest.push((lane.0, record.throughput_gbps.to_bits()));
                    }
                }
            }
            digest
        };
        assert_eq!(run(true), run(false));
    }
}
