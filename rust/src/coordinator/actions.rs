//! The paper's five-action space over (cc, p), with parameter clipping.

/// Discrete action index, 0..5 (§3.3.2 of the paper).
pub type ActionId = usize;

/// Number of discrete actions.
pub const N_ACTIONS: usize = 5;

/// (∆cc, ∆p) per action id: 0 = hold, 1 = +1/+1, 2 = −1/−1, 3 = +2/+2,
/// 4 = −2/−2.
pub const ACTIONS: [(i32, i32); N_ACTIONS] = [(0, 0), (1, 1), (-1, -1), (2, 2), (-2, -2)];

/// Concurrency/parallelism bounds (Eq. 9); actions are clipped into them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParamBounds {
    pub cc_min: u32,
    pub cc_max: u32,
    pub p_min: u32,
    pub p_max: u32,
    /// Initial setting at transfer start (the paper uses a midpoint, e.g. (4,4)).
    pub cc0: u32,
    pub p0: u32,
}

impl Default for ParamBounds {
    fn default() -> Self {
        ParamBounds { cc_min: 1, cc_max: 16, p_min: 1, p_max: 16, cc0: 4, p0: 4 }
    }
}

impl ParamBounds {
    /// Apply an action id to (cc, p), clipping into bounds.
    pub fn apply(&self, cc: u32, p: u32, action: ActionId) -> (u32, u32) {
        let (dcc, dp) = ACTIONS[action];
        let cc = (cc as i64 + dcc as i64).clamp(self.cc_min as i64, self.cc_max as i64) as u32;
        let p = (p as i64 + dp as i64).clamp(self.p_min as i64, self.p_max as i64) as u32;
        (cc, p)
    }

    /// Clamp an arbitrary (cc, p) into bounds (used by baselines).
    pub fn clamp(&self, cc: u32, p: u32) -> (u32, u32) {
        (cc.clamp(self.cc_min, self.cc_max), p.clamp(self.p_min, self.p_max))
    }

    /// Map DDPG's continuous actor output (x₁, x₂) ∈ [−2, 2]² onto the five
    /// discrete actions by flooring/capping the mean delta (§3.3.2: the
    /// continuous outputs "are then floored or capped to map them into one
    /// of the five discrete actions").
    pub fn continuous_to_action(x1: f32, x2: f32) -> ActionId {
        let mean = (x1 + x2) / 2.0;
        let delta = mean.round().clamp(-2.0, 2.0) as i32;
        match delta {
            0 => 0,
            1 => 1,
            -1 => 2,
            2 => 3,
            _ => 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actions_match_paper_table() {
        assert_eq!(ACTIONS[0], (0, 0));
        assert_eq!(ACTIONS[1], (1, 1));
        assert_eq!(ACTIONS[2], (-1, -1));
        assert_eq!(ACTIONS[3], (2, 2));
        assert_eq!(ACTIONS[4], (-2, -2));
    }

    #[test]
    fn apply_moves_and_clips() {
        let b = ParamBounds::default();
        assert_eq!(b.apply(4, 4, 1), (5, 5));
        assert_eq!(b.apply(4, 4, 4), (2, 2));
        assert_eq!(b.apply(1, 1, 2), (1, 1)); // clipped at min
        assert_eq!(b.apply(16, 16, 3), (16, 16)); // clipped at max
        assert_eq!(b.apply(15, 15, 3), (16, 16));
    }

    #[test]
    fn clamp_bounds_arbitrary_values() {
        let b = ParamBounds::default();
        assert_eq!(b.clamp(0, 99), (1, 16));
    }

    #[test]
    fn continuous_mapping_covers_all_actions() {
        assert_eq!(ParamBounds::continuous_to_action(0.1, -0.1), 0);
        assert_eq!(ParamBounds::continuous_to_action(1.0, 1.0), 1);
        assert_eq!(ParamBounds::continuous_to_action(-1.0, -0.9), 2);
        assert_eq!(ParamBounds::continuous_to_action(2.0, 1.9), 3);
        assert_eq!(ParamBounds::continuous_to_action(-2.0, -2.0), 4);
        // Saturation beyond the range maps to the extreme actions.
        assert_eq!(ParamBounds::continuous_to_action(9.0, 9.0), 3);
        assert_eq!(ParamBounds::continuous_to_action(-9.0, -9.0), 4);
    }

    #[test]
    fn every_action_stays_in_bounds_property() {
        // Hand-rolled property test: all (cc, p, action) combinations stay
        // within bounds after apply().
        let b = ParamBounds { cc_min: 1, cc_max: 12, p_min: 2, p_max: 9, cc0: 4, p0: 4 };
        for cc in 1..=12 {
            for p in 2..=9 {
                for a in 0..N_ACTIONS {
                    let (ncc, np) = b.apply(cc, p, a);
                    assert!((b.cc_min..=b.cc_max).contains(&ncc));
                    assert!((b.p_min..=b.p_max).contains(&np));
                }
            }
        }
    }
}
