//! The step-driven transfer session — the coordinator's public API.
//!
//! The paper's agents pause/resume transfer threads as shared-network
//! conditions change, which only matters when transfers *come and go*. A
//! [`Session`] therefore exposes the transfer lifecycle instead of a
//! run-to-completion batch call:
//!
//! * [`Session::admit`] adds a lane (a transfer application: job + engine +
//!   reward + [`Optimizer`]) at any point — before the first MI or mid-run;
//! * [`Session::step`] advances exactly one monitoring interval and returns
//!   the [`Event`]s it produced (`Admitted`, `MiCompleted`, `Completed`, …);
//! * [`Session::pause`] / [`Session::resume`] / [`Session::cancel`] are the
//!   external control knobs (an operator or workload generator, as opposed
//!   to the per-lane optimizer's own (cc, p) pause/resume decisions);
//! * events stream into any [`TelemetrySink`] instead of accumulating
//!   inside the coordinator — [`crate::telemetry::ReportSink`] rebuilds the
//!   classic [`super::RunReport`] from the stream, and
//!   [`Session::run_to_completion`] plus the [`super::Controller`] compat
//!   wrapper reproduce the pre-redesign batch behavior bit-for-bit.
//!
//! Determinism contract: a session is fully determined by its builder
//! configuration, its seed and the sequence of `admit`/`pause`/`resume`/
//! `cancel`/`step` calls — the same sequence replays the same event stream
//! bit-for-bit (ledger-account seeding is derived from the admission index,
//! never from call timing).
//!
//! Energy accounting goes through one shared [`crate::energy::EnergyPlane`]:
//! the default lumped compat rail reproduces the seed-era per-lane billing
//! bit-for-bit, while [`SessionBuilder::energy`] switches to host-resolved
//! ledgers (sender + receiver [`crate::energy::HostLedger`]s from the
//! testbed's host definitions) where colocated lanes share fixed power and
//! paused lanes are billed the idle rail. [`SessionBuilder::observe_paused`]
//! additionally surfaces those idle bills as zero-throughput [`MiRecord`]s
//! so optimizers can learn preemption costs.
//!
//! §Perf: stepping is allocation-free at steady state, **including record
//! emission**. The per-MI metric, activity, bill and decision buffers are
//! pooled on the session and the substrate is driven through
//! [`crate::net::Substrate::run_mi_into`]; [`Session::step_into`] writes
//! events into a caller-reused buffer (the fleet driver's path),
//! [`Session::step_with`] recycles an internal one, and [`Session::step`]
//! is the allocating compat wrapper. [`MiRecord::state`] vectors are
//! copy-on-sink from a session-owned pool: each record's state buffer is
//! popped from the pool at emission, and when a previously emitted batch
//! is cleared on the session's step paths the buffers are reclaimed into
//! the pool — a sink that wants to keep a record past the step clones it
//! (as the report/event sinks already do), so recycling never aliases
//! live data. Lane names are interned as `Arc<str>` once at admission, so
//! events and reports share the same backing string.

use super::actions::ParamBounds;
use super::reward::{RewardConfig, RewardKind, RewardTracker, TrackerState};
use super::state::{FeatureWindow, Observation, WindowState};
use super::{Decision, MiContext, Optimizer};
use crate::energy::{EnergyConfig, EnergyPlane, LaneActivity, LaneBill, LedgerState, RailEnergy};
use crate::faults::{backoff_mis, FaultEvent, FaultOp, FaultPlan, STALL_AFTER_MIS, STALL_EPS_BYTES};
use crate::net::background::Background;
use crate::net::{FlowId, MiMetrics, NetworkSim, SimState, Substrate, Testbed, Topology};
use crate::telemetry::TelemetrySink;
use crate::transfer::{EngineProfile, TransferJob};
use std::sync::Arc;

/// MI budget used by the compat wrapper and the CLI when no explicit cap is
/// given (matches the pre-redesign controller default).
pub const DEFAULT_MAX_MIS: usize = 3000;

/// Opaque handle for one admitted lane (index in admission order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LaneId(pub usize);

/// Everything recorded about one lane during one monitoring interval.
#[derive(Debug, Clone, PartialEq)]
pub struct MiRecord {
    pub mi: usize,
    pub time_s: f64,
    pub throughput_gbps: f64,
    pub plr: f64,
    pub rtt_s: f64,
    pub energy_j: f64,
    pub cc: u32,
    pub p: u32,
    /// Windowed objective metric (utility score / T-per-E).
    pub metric: f64,
    /// Shaped reward handed to the optimizer.
    pub reward: f64,
    /// Discrete action taken *at the end of* this MI (None for baselines
    /// that set (cc, p) directly).
    pub action: Option<usize>,
    /// Flattened state window after ingesting this MI.
    pub state: Vec<f32>,
    /// Running total of bytes the lane's job has delivered after this MI —
    /// lets streaming sinks track progress without holding lane state.
    pub bytes_total: f64,
    /// Running total of energy attributed to this lane after this MI (0.0
    /// on testbeds without energy counters, where `energy_j` is NaN).
    pub energy_total_j: f64,
    /// True for the zero-throughput records an externally-paused lane
    /// emits when the session observes paused MIs (idle energy, no bytes).
    pub paused: bool,
    /// Per-rail breakdown of `energy_j` (None on the lumped compat rail).
    pub rails: Option<RailEnergy>,
}

/// What a lane is doing right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneStatus {
    /// Transferring: observes, learns and decides every MI.
    Active,
    /// Externally paused: demand forced to zero, no observations, resumable.
    Paused,
    /// Tripped by the fault plane ([`crate::faults`]): the stall watchdog
    /// or an injected stream error took the lane offline. Demand is zero
    /// and no observations flow; the session retries it automatically with
    /// exponential backoff, preserving every byte already delivered.
    Faulted,
    /// Job delivered every byte.
    Completed,
    /// Cancelled before completion (left the session).
    Departed,
}

/// One entry of the session's event stream, MI-granular.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A lane joined the session (possibly mid-run). The name is interned
    /// at admission — cloning the event shares the backing string.
    Admitted { lane: LaneId, name: Arc<str>, mi: usize, time_s: f64 },
    /// A lane observed one monitoring interval.
    MiCompleted { lane: LaneId, record: MiRecord },
    /// A lane was externally paused.
    Paused { lane: LaneId, mi: usize, time_s: f64 },
    /// A paused lane was resumed.
    Resumed { lane: LaneId, mi: usize, time_s: f64 },
    /// A lane's job delivered every byte.
    Completed { lane: LaneId, mi: usize, time_s: f64, bytes_delivered: f64, total_energy_j: f64 },
    /// A lane was cancelled before completing.
    Departed { lane: LaneId, mi: usize, time_s: f64, bytes_delivered: f64, total_energy_j: f64 },
    /// The fault plane took a lane offline (`fault` names the cause:
    /// `"stall"` for the watchdog, `"stream-error"` for injected stream
    /// faults). Bytes already delivered are preserved; a `Retrying` event
    /// follows after the backoff window.
    Faulted { lane: LaneId, mi: usize, time_s: f64, fault: &'static str },
    /// A faulted lane came back online after its exponential-backoff
    /// window (`attempt` counts consecutive faults since last progress).
    Retrying { lane: LaneId, mi: usize, time_s: f64, attempt: u32 },
    /// A lane was moved off a crashed host onto a healthy one with its
    /// optimizer state, job progress and energy attribution intact. The
    /// lane id is its stable global id — unchanged by the move.
    Migrated { lane: LaneId, mi: usize, time_s: f64, from_host: usize, to_host: usize },
}

impl Event {
    /// The lane this event concerns.
    pub fn lane(&self) -> LaneId {
        match self {
            Event::Admitted { lane, .. }
            | Event::MiCompleted { lane, .. }
            | Event::Paused { lane, .. }
            | Event::Resumed { lane, .. }
            | Event::Completed { lane, .. }
            | Event::Departed { lane, .. }
            | Event::Faulted { lane, .. }
            | Event::Retrying { lane, .. }
            | Event::Migrated { lane, .. } => *lane,
        }
    }
}

/// Everything one lane needs at admission: the optimizer plus its job,
/// engine profile and reward shaping.
pub struct LaneSpec {
    pub optimizer: Box<dyn Optimizer>,
    pub job: TransferJob,
    pub engine: EngineProfile,
    pub reward: RewardKind,
    /// Display name for reports; defaults to the optimizer's name.
    pub name: Option<String>,
}

impl LaneSpec {
    pub fn new(optimizer: Box<dyn Optimizer>, job: TransferJob) -> LaneSpec {
        LaneSpec {
            optimizer,
            job,
            engine: EngineProfile::efficient(),
            reward: RewardKind::ThroughputEnergy,
            name: None,
        }
    }

    pub fn engine(mut self, e: EngineProfile) -> Self {
        self.engine = e;
        self
    }

    pub fn reward(mut self, k: RewardKind) -> Self {
        self.reward = k;
        self
    }

    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }
}

/// Pop a pooled state buffer (or allocate while the pool warms up) and
/// copy `state` into it — the emission half of the copy-on-sink contract
/// on [`MiRecord::state`] (§Perf in the module docs).
fn pooled_state_copy(pool: &mut Vec<Vec<f32>>, state: &[f32]) -> Vec<f32> {
    let mut buf = pool.pop().unwrap_or_default();
    buf.clear();
    buf.extend_from_slice(state);
    buf
}

struct SessionLane {
    name: Arc<str>,
    flow: FlowId,
    optimizer: Box<dyn Optimizer>,
    job: TransferJob,
    window: FeatureWindow,
    reward: RewardTracker,
    /// Kept past admission so a crashed host's lanes can be re-admitted
    /// elsewhere with the same I/O cap and power model (migration).
    engine: EngineProfile,
    cc: u32,
    p: u32,
    has_pending_decision: bool,
    status: LaneStatus,
    /// Consecutive low-progress MIs seen by the stall watchdog (armed
    /// sessions only; see [`crate::faults`]).
    stall_mis: u32,
    /// MI at which a faulted lane returns to `Active`.
    retry_at_mi: usize,
    /// Consecutive faults since the lane last made progress — indexes the
    /// exponential backoff.
    attempt: u32,
}

/// Builder for [`Session`] (same knobs the pre-redesign controller took,
/// plus the energy-accounting mode and the paused-MI observation knob).
pub struct SessionBuilder {
    testbed: Testbed,
    background: Option<Background>,
    topology: Option<Topology>,
    substrate: Option<Box<dyn Substrate>>,
    mi_s: f64,
    bounds: ParamBounds,
    reward_cfg: RewardConfig,
    seed: u64,
    history: usize,
    energy: EnergyConfig,
    observe_paused: bool,
}

impl SessionBuilder {
    pub fn background(mut self, bg: Background) -> Self {
        self.background = Some(bg);
        self
    }

    /// Run over an explicitly constructed substrate instead of building a
    /// [`NetworkSim`] from the testbed/topology — the injection point for
    /// alternate backends (an emulator- or kernel-backed substrate, or the
    /// frozen [`crate::net::baseline::BaselineSim`] the golden-replay
    /// suite and `sparta bench` drive). `topology`/`background` are
    /// ignored when a substrate is injected; the session reads its testbed
    /// from the substrate.
    pub fn substrate(mut self, sub: Box<dyn Substrate>) -> Self {
        self.substrate = Some(sub);
        self
    }

    /// Run over a multi-segment path instead of the testbed's single
    /// bottleneck (see [`crate::net::Topology`]; scenario presets use this).
    pub fn topology(mut self, t: Topology) -> Self {
        self.topology = Some(t);
        self
    }

    pub fn mi(mut self, seconds: f64) -> Self {
        self.mi_s = seconds;
        self
    }

    pub fn bounds(mut self, b: ParamBounds) -> Self {
        self.bounds = b;
        self
    }

    pub fn reward_cfg(mut self, c: RewardConfig) -> Self {
        self.reward_cfg = c;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// State-window length n (MIs).
    pub fn history(mut self, n: usize) -> Self {
        self.history = n;
        self
    }

    /// Energy-accounting mode. Default is the lumped compat rail (per-lane
    /// seed-era billing, bit-identical reports); pass
    /// [`EnergyConfig::Hosts`] — e.g. from
    /// [`crate::net::Testbed::energy_hosts`] — for host-truth rails shared
    /// by all colocated lanes.
    pub fn energy(mut self, cfg: EnergyConfig) -> Self {
        self.energy = cfg;
        self
    }

    /// When set, externally-paused lanes emit zero-throughput
    /// [`MiRecord`]s carrying their idle-rail energy, and the decision
    /// pending at pause time is credited with the first paused MI's reward
    /// — so optimizers see the cost of preemption instead of a silent gap.
    pub fn observe_paused(mut self, on: bool) -> Self {
        self.observe_paused = on;
        self
    }

    pub fn build(self) -> Session {
        // An injected substrate wins; otherwise the builder owns the one
        // Testbed and moves it into the simulator (no per-session clones).
        let sim: Box<dyn Substrate> = match self.substrate {
            Some(sub) => sub,
            None => {
                let mut sim = match &self.topology {
                    Some(t) => NetworkSim::from_topology(self.testbed, t, self.seed),
                    None => NetworkSim::new(self.testbed, self.seed),
                };
                if let Some(bg) = self.background {
                    sim = sim.with_background(bg);
                }
                Box::new(sim)
            }
        };
        let has_energy = sim.testbed().has_energy_counters;
        Session {
            sim,
            has_energy,
            mi_s: self.mi_s,
            bounds: self.bounds,
            reward_cfg: self.reward_cfg,
            seed: self.seed,
            history: self.history,
            mi: 0,
            lanes: Vec::new(),
            pending: Vec::new(),
            energy: EnergyPlane::new(self.energy, self.seed),
            observe_paused: self.observe_paused,
            faults_armed: false,
            fault_plan: Vec::new(),
            fault_next: 0,
            stall_until_mi: 0,
            metrics_buf: Vec::new(),
            events_buf: Vec::new(),
            activity_buf: Vec::new(),
            bills_buf: Vec::new(),
            decisions_buf: Vec::new(),
            state_pool: Vec::new(),
        }
    }
}

/// The MI control loop over one network substrate, driven step by step.
pub struct Session {
    sim: Box<dyn Substrate>,
    /// Cached `sim.testbed().has_energy_counters` (read every MI).
    has_energy: bool,
    mi_s: f64,
    bounds: ParamBounds,
    reward_cfg: RewardConfig,
    seed: u64,
    history: usize,
    /// Next monitoring-interval index (number of MIs run so far).
    mi: usize,
    lanes: Vec<SessionLane>,
    /// Admission/control events queued since the last `step`.
    pending: Vec<Event>,
    /// Shared energy accounting for every lane (lumped compat rail, or a
    /// sender + receiver host-ledger pair).
    energy: EnergyPlane,
    observe_paused: bool,
    /// Fault plane armed ([`Session::install_faults`] /
    /// [`Session::arm_faults`]): the stall watchdog runs and the session
    /// is no longer checkpointable. Never set on default sessions, so the
    /// fault-free path stays bit-identical to the seed.
    faults_armed: bool,
    /// Seeded fault ops sorted by MI, applied as `mi` passes them.
    fault_plan: Vec<FaultEvent>,
    /// Next unapplied index into `fault_plan`.
    fault_next: usize,
    /// Injected host stall: all demand collapses to zero before this MI.
    stall_until_mi: usize,
    // §Perf: pooled per-step buffers — stepping allocates nothing at
    // steady state (see the module docs).
    metrics_buf: Vec<MiMetrics>,
    events_buf: Vec<Event>,
    activity_buf: Vec<LaneActivity>,
    bills_buf: Vec<Option<LaneBill>>,
    decisions_buf: Vec<(usize, Decision)>,
    /// Free-list of `MiRecord::state` buffers: emission pops (falling
    /// back to a fresh alloc only while the pool warms up), and clearing
    /// an emitted batch on the step paths reclaims (see the module docs).
    state_pool: Vec<Vec<f32>>,
}

impl Session {
    pub fn builder(testbed: Testbed) -> SessionBuilder {
        SessionBuilder {
            testbed,
            background: None,
            topology: None,
            substrate: None,
            mi_s: 1.0,
            bounds: ParamBounds::default(),
            reward_cfg: RewardConfig::default(),
            seed: 1,
            history: 8,
            energy: EnergyConfig::Lumped,
            observe_paused: false,
        }
    }

    /// Admit a transfer lane (legal before the first MI or mid-run); the
    /// returned id is its index in admission order.
    pub fn admit(&mut self, spec: LaneSpec) -> LaneId {
        let LaneSpec { mut optimizer, job, engine, reward, name } = spec;
        let (cc0, p0) = optimizer.start(&self.bounds);
        let (cc0, p0) = self.bounds.clamp(cc0, p0);
        let io = engine.task_io_gbps(self.sim.testbed().task_io_gbps);
        let flow = self.sim.add_flow(cc0, p0, Some(io));
        let window = FeatureWindow::new(self.history, self.bounds.cc_max, self.bounds.p_max);
        // Ledger-account seeding derives from the admission index (the
        // seed-era meter formula, unchanged), so replaying the same
        // admission sequence reproduces the same energy noise.
        let meter_seed = self.seed.wrapping_mul(0x9E37).wrapping_add(self.lanes.len() as u64);
        self.energy.open_lane(&engine.power, meter_seed);
        // Intern once; the event and the lane share the backing string.
        let name: Arc<str> = match name {
            Some(n) => Arc::from(n),
            None => Arc::from(optimizer.name()),
        };
        let id = LaneId(self.lanes.len());
        self.pending.push(Event::Admitted {
            lane: id,
            name: Arc::clone(&name),
            mi: self.mi,
            time_s: self.sim.time_s(),
        });
        self.lanes.push(SessionLane {
            name,
            flow,
            optimizer,
            job,
            window,
            reward: RewardTracker::new(reward, self.reward_cfg),
            engine,
            cc: cc0,
            p: p0,
            has_pending_decision: false,
            status: LaneStatus::Active,
            stall_mis: 0,
            retry_at_mi: 0,
            attempt: 0,
        });
        id
    }

    /// Externally pause a lane: its demand drops to zero next MI and it
    /// stops observing/learning until resumed. Returns false if the lane is
    /// unknown or not active.
    pub fn pause(&mut self, id: LaneId) -> bool {
        let Some(lane) = self.lanes.get_mut(id.0) else {
            return false;
        };
        if lane.status != LaneStatus::Active {
            return false;
        }
        lane.status = LaneStatus::Paused;
        if !self.observe_paused {
            // Drop any pending decision: the first post-resume observation
            // must not be credited to an action chosen before the pause
            // gap. (With `observe_paused`, the pending decision is instead
            // credited with the first paused MI's collapsed reward — the
            // preemption-cost signal.)
            lane.has_pending_decision = false;
        }
        self.sim.set_demand_cap(lane.flow, 0.0);
        self.pending.push(Event::Paused { lane: id, mi: self.mi, time_s: self.sim.time_s() });
        true
    }

    /// Resume an externally paused lane. Returns false if it is not paused.
    pub fn resume(&mut self, id: LaneId) -> bool {
        let Some(lane) = self.lanes.get_mut(id.0) else {
            return false;
        };
        if lane.status != LaneStatus::Paused {
            return false;
        }
        lane.status = LaneStatus::Active;
        self.pending.push(Event::Resumed { lane: id, mi: self.mi, time_s: self.sim.time_s() });
        true
    }

    /// Cancel a lane before completion (it departs the session; its flow's
    /// demand drops to zero). Faulted lanes may be cancelled — an operator
    /// can give up on a retry loop. Returns false if it already ended.
    pub fn cancel(&mut self, id: LaneId) -> bool {
        let Some(lane) = self.lanes.get_mut(id.0) else {
            return false;
        };
        if !matches!(lane.status, LaneStatus::Active | LaneStatus::Paused | LaneStatus::Faulted) {
            return false;
        }
        lane.status = LaneStatus::Departed;
        self.sim.set_demand_cap(lane.flow, 0.0);
        self.pending.push(Event::Departed {
            lane: id,
            mi: self.mi,
            time_s: self.sim.time_s(),
            bytes_delivered: lane.job.delivered_bytes(),
            total_energy_j: self.energy.lane_total_j(id.0),
        });
        true
    }

    /// Advance exactly one monitoring interval, writing the events it
    /// produced (queued admission/control events first, in call order)
    /// into the caller-reused `events` buffer.
    ///
    /// This is the session's **one stepping primitive** (§Perf): the
    /// allocation-free path every driver funnels through. The two
    /// siblings are thin conveniences over it — [`Session::step_with`]
    /// streams the same events into a [`TelemetrySink`] from an internal
    /// pooled buffer, and [`Session::step`] is the allocating compat
    /// wrapper. Fleet and [`crate::coordinator::Cluster`] hold one buffer
    /// across all MIs and call this directly.
    pub fn step_into(&mut self, events: &mut Vec<Event>) {
        self.reclaim_events(events);
        if self.faults_armed {
            self.apply_due_faults();
        }
        events.append(&mut self.pending);
        self.step_mi(events);
    }

    /// Install a seeded fault plan ([`crate::faults::FaultSchedule::resolve`])
    /// and arm the stall watchdog. Single-host drivers (fleet, `serve` with
    /// one host) call this; clusters keep the plan at cluster level and only
    /// [`Session::arm_faults`] each host. Ops apply at the MI boundaries of
    /// [`Session::step_into`] as `mi` passes their scheduled index, so the
    /// same plan replays the same event stream at any parallelism. An armed
    /// session is no longer checkpointable ([`Session::export_state`]).
    pub fn install_faults(&mut self, plan: FaultPlan) {
        self.fault_plan = plan.events;
        self.fault_next = 0;
        self.faults_armed = true;
    }

    /// Arm the stall watchdog and retry machinery without installing a
    /// plan — cluster hosts run in this mode; the cluster owns the plan and
    /// routes each op to its host via [`Session::apply_fault_op`].
    pub fn arm_faults(&mut self) {
        self.faults_armed = true;
    }

    /// Whether the fault plane is armed on this session.
    pub fn faults_armed(&self) -> bool {
        self.faults_armed
    }

    /// Apply plan ops that have come due and bring retries back online —
    /// runs at the top of every `step_into` on an armed session, before the
    /// MI executes, so fault timing is a pure function of the MI index.
    fn apply_due_faults(&mut self) {
        while self.fault_next < self.fault_plan.len()
            && self.fault_plan[self.fault_next].at_mi <= self.mi
        {
            let op = self.fault_plan[self.fault_next].op.clone();
            self.fault_next += 1;
            self.apply_fault_op(&op);
        }
        self.release_retries();
    }

    /// Return faulted lanes whose backoff window has elapsed to `Active`,
    /// queueing a [`Event::Retrying`] for each. Also called by the cluster
    /// at each MI boundary on hosts it armed without a plan.
    pub(crate) fn release_retries(&mut self) {
        let time_s = self.sim.time_s();
        let mi = self.mi;
        for (li, lane) in self.lanes.iter_mut().enumerate() {
            if lane.status == LaneStatus::Faulted && mi >= lane.retry_at_mi {
                lane.status = LaneStatus::Active;
                lane.stall_mis = 0;
                self.pending.push(Event::Retrying {
                    lane: LaneId(li),
                    mi,
                    time_s,
                    attempt: lane.attempt,
                });
            }
        }
    }

    /// Apply one fault op to this session at an MI boundary. Draws no
    /// randomness; queued events land in `pending` exactly like external
    /// control calls, so they merge into the stream deterministically.
    pub(crate) fn apply_fault_op(&mut self, op: &FaultOp) {
        match op {
            FaultOp::SegmentScale { segment, scale } => {
                // Unsupported substrates (the frozen baseline) report
                // false; callers gate faults off the baseline path, so a
                // miss here is a plan/topology mismatch, not an error.
                let _ = self.sim.fault_segment(segment, *scale);
            }
            FaultOp::HostStall { mis, .. } => {
                self.stall_until_mi = self.stall_until_mi.max(self.mi + mis);
            }
            FaultOp::HostCrash { .. } => {
                // A single-host session cannot fail over
                // ([`crate::faults::FaultSchedule::resolve`] downgrades
                // crashes for it); a stray crash op degrades to a stall.
                self.stall_until_mi = self.stall_until_mi.max(self.mi + 8);
            }
            FaultOp::StreamError { lane_slot } => {
                if !self.lanes.is_empty() {
                    let li = lane_slot % self.lanes.len();
                    self.fault_lane(LaneId(li), "stream-error");
                }
            }
        }
    }

    /// Take a lane offline with the given cause, scheduling its retry by
    /// exponential backoff. No-op (false) unless the lane is `Active`.
    pub(crate) fn fault_lane(&mut self, id: LaneId, fault: &'static str) -> bool {
        let time_s = self.sim.time_s();
        let mi = self.mi;
        let Some(lane) = self.lanes.get_mut(id.0) else {
            return false;
        };
        if lane.status != LaneStatus::Active {
            return false;
        }
        lane.status = LaneStatus::Faulted;
        lane.has_pending_decision = false;
        lane.stall_mis = 0;
        lane.attempt += 1;
        lane.retry_at_mi = mi + backoff_mis(lane.attempt - 1);
        self.sim.set_demand_cap(lane.flow, 0.0);
        self.pending.push(Event::Faulted { lane: id, mi, time_s, fault });
        true
    }

    /// Drain `events`, reclaiming every contained record's state buffer
    /// into the session pool — the clearing half of the copy-on-sink
    /// contract (§Perf in the module docs). Safe because the drained
    /// events are dropped here: any consumer that kept a record cloned
    /// it, so the reclaimed buffers have no other owner.
    fn reclaim_events(&mut self, events: &mut Vec<Event>) {
        for ev in events.drain(..) {
            if let Event::MiCompleted { record, .. } = ev {
                let mut buf = record.state;
                buf.clear();
                self.state_pool.push(buf);
            }
        }
    }

    /// Capacity hint for an expected total of `n` lanes: reserves the
    /// lane table and the substrate's flow tables/stream arena up front
    /// (see [`crate::net::Substrate::reserve_flows`]), so large admit
    /// storms (100k-lane fleets) don't grow hot vectors one push at a
    /// time. Purely a capacity hint — never affects results.
    pub fn reserve_lanes(&mut self, n: usize) {
        self.lanes.reserve(n);
        self.sim.reserve_flows(n);
    }

    /// Return a previously-emitted record's state buffer to the session
    /// pool. [`Session::step_into`] reclaims buffers it finds in the
    /// passed-in `events`; a driver that *moved* events elsewhere (the
    /// [`crate::coordinator::Cluster`] merges per-host streams into one
    /// buffer) hands each record back through here instead, keeping
    /// cluster stepping allocation-free at steady state.
    pub fn recycle_record(&mut self, record: MiRecord) {
        let mut buf = record.state;
        buf.clear();
        self.state_pool.push(buf);
    }

    /// Advance exactly one monitoring interval and return the events it
    /// produced — a thin allocating wrapper over [`Session::step_into`].
    ///
    /// **Deprecated for external drivers:** this allocates a fresh `Vec`
    /// (and fresh record-state buffers) every MI. Hot-path drivers —
    /// fleet, [`crate::coordinator::Cluster`], anything stepping many
    /// sessions — should hold one buffer and call [`Session::step_into`]
    /// (or [`Session::step_with`] to stream into a sink). `step` stays for
    /// interactive/doc-example use and the batch compat wrapper.
    pub fn step(&mut self) -> Vec<Event> {
        let mut events = Vec::new();
        self.step_into(&mut events);
        events
    }

    /// [`Session::step`], streaming the events into `sink` through an
    /// internal pooled buffer (no per-step allocation).
    pub fn step_with(&mut self, sink: &mut dyn TelemetrySink) {
        let mut events = std::mem::take(&mut self.events_buf);
        self.step_into(&mut events);
        for ev in &events {
            sink.on_event(ev);
        }
        // Keep the sunk events in the buffer: the next step's
        // `reclaim_events` recycles their record-state buffers into the
        // pool (a plain clear here would leak them back to the allocator).
        self.events_buf = events;
    }

    /// Compat driver: step until every lane completed/departed or `max_mis`
    /// MIs have run, streaming all events into `sink`. Reproduces the
    /// pre-redesign `Controller::run_all` loop bit-for-bit when all lanes
    /// are admitted up front.
    pub fn run_to_completion(&mut self, max_mis: usize, sink: &mut dyn TelemetrySink) {
        while self.mi < max_mis {
            if self.is_idle() {
                break;
            }
            self.step_with(sink);
        }
        // Flush control events queued after the last step (e.g. a trailing
        // cancel), so the sink sees the complete stream.
        for ev in std::mem::take(&mut self.pending) {
            sink.on_event(&ev);
        }
    }

    /// One monitoring interval: demand caps → substrate MI → one energy
    /// settlement across all in-flight lanes → per-lane
    /// observe/learn/decide → apply decisions. The active-lane body mirrors
    /// the pre-redesign batch loop exactly (same arithmetic, same call
    /// order, per-lane noise RNGs), which is what keeps the lumped compat
    /// path bit-identical.
    fn step_mi(&mut self, events: &mut Vec<Event>) {
        let has_energy = self.has_energy;
        // Cap demand of nearly-finished lanes so they don't overshoot;
        // paused/faulted/ended lanes hold zero demand. During an injected
        // host stall every lane's demand collapses to zero — transfer
        // threads stay alive but move no bytes, which is what trips the
        // stall watchdog below.
        let host_stalled = self.faults_armed && self.mi < self.stall_until_mi;
        for lane in &self.lanes {
            if host_stalled || lane.status != LaneStatus::Active {
                self.sim.set_demand_cap(lane.flow, 0.0);
            } else {
                let cap = lane.job.remaining_bytes() * 8.0 / self.mi_s / 1e9;
                self.sim.set_demand_cap(lane.flow, cap.max(0.05));
            }
        }
        // Pooled buffers (taken/restored around the lane loop so the
        // borrow checker sees them as locals): §Perf, no per-MI allocs.
        let mut metrics = std::mem::take(&mut self.metrics_buf);
        self.sim.run_mi_into(self.mi_s, &mut metrics);
        let time_s = self.sim.time_s();
        let mi = self.mi;
        // Settle the energy plane once for this MI over every in-flight
        // lane: active lanes bill their curve/rails, paused lanes the idle
        // rail (always in host-resolved mode — host truth — and, on the
        // lumped rail, only when paused MIs are observed).
        let mut bills = std::mem::take(&mut self.bills_buf);
        bills.clear();
        bills.resize(self.lanes.len(), None);
        if has_energy {
            let mut activity = std::mem::take(&mut self.activity_buf);
            activity.clear();
            activity.extend(
                self.lanes
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| {
                        matches!(
                            l.status,
                            LaneStatus::Active | LaneStatus::Paused | LaneStatus::Faulted
                        )
                    })
                    .map(|(li, l)| {
                        let m = &metrics[l.flow.0];
                        // Faulted lanes bill like paused ones: still on the
                        // host, idle rail — so Σ per-lane attribution stays
                        // equal to the host totals through fault windows.
                        let paused = l.status != LaneStatus::Active;
                        LaneActivity {
                            lane: li,
                            // Paused lanes park their transfer threads: no
                            // streams, no bytes.
                            streams: if paused { 0 } else { m.active_streams },
                            throughput_gbps: if paused { 0.0 } else { m.throughput_gbps },
                            bytes: if paused { 0.0 } else { m.bytes_delivered },
                            duration_s: m.duration_s,
                            paused,
                        }
                    }),
            );
            for b in self.energy.settle_mi(&activity, self.mi_s, self.observe_paused) {
                bills[b.lane] = Some(b);
            }
            activity.clear();
            self.activity_buf = activity;
        }
        let observe_paused = self.observe_paused;
        let faults_armed = self.faults_armed;
        let mut decisions = std::mem::take(&mut self.decisions_buf);
        decisions.clear();
        for (li, lane) in self.lanes.iter_mut().enumerate() {
            // Paused lanes only observe (and only behind the knob); the
            // whole decision machinery stays active-only.
            if lane.status == LaneStatus::Paused && observe_paused {
                let m = &metrics[lane.flow.0];
                let energy = match &bills[li] {
                    Some(b) => b.energy_j,
                    None => f64::NAN,
                };
                let obs = Observation {
                    throughput_gbps: 0.0,
                    plr: m.plr,
                    rtt_s: m.rtt_s,
                    energy_j: energy,
                    cc: lane.cc,
                    p: lane.p,
                    duration_s: m.duration_s,
                };
                lane.window.push(&obs);
                let out = lane.reward.update(&obs);
                if lane.has_pending_decision {
                    // The action pending at pause time is credited with
                    // the collapsed reward of the first paused MI — this
                    // is how optimizers see the cost of preemption.
                    lane.optimizer.learn(out.reward, lane.window.state(), false);
                    lane.has_pending_decision = false;
                }
                events.push(Event::MiCompleted {
                    lane: LaneId(li),
                    record: MiRecord {
                        mi,
                        time_s,
                        throughput_gbps: 0.0,
                        plr: m.plr,
                        rtt_s: m.rtt_s,
                        energy_j: energy,
                        cc: lane.cc,
                        p: lane.p,
                        metric: out.metric,
                        reward: out.reward,
                        action: None,
                        state: pooled_state_copy(&mut self.state_pool, lane.window.state()),
                        bytes_total: lane.job.delivered_bytes(),
                        energy_total_j: self.energy.lane_total_j(li),
                        paused: true,
                        rails: bills[li].as_ref().and_then(|b| b.rails),
                    },
                });
                continue;
            }
            if lane.status != LaneStatus::Active {
                continue;
            }
            let m = &metrics[lane.flow.0];
            lane.job.advance(m.bytes_delivered);
            let energy = match &bills[li] {
                Some(b) => b.energy_j,
                None => f64::NAN,
            };
            let obs = Observation {
                throughput_gbps: m.throughput_gbps,
                plr: m.plr,
                rtt_s: m.rtt_s,
                energy_j: energy,
                cc: lane.cc,
                p: lane.p,
                duration_s: m.duration_s,
            };
            lane.window.push(&obs);
            let out = lane.reward.update(&obs);
            let done_now = lane.job.is_complete();
            if lane.has_pending_decision {
                lane.optimizer.learn(out.reward, lane.window.state(), done_now);
            }
            // Stall watchdog (armed sessions only, so the default path is
            // untouched): consecutive near-zero-progress MIs fault the
            // lane; any real progress resets both the counter and the
            // backoff ladder.
            let mut tripped = false;
            if faults_armed && !done_now {
                if m.bytes_delivered < STALL_EPS_BYTES {
                    lane.stall_mis += 1;
                    tripped = lane.stall_mis >= STALL_AFTER_MIS;
                } else {
                    lane.stall_mis = 0;
                    lane.attempt = 0;
                }
            }
            let mut action = None;
            if done_now {
                lane.status = LaneStatus::Completed;
                lane.has_pending_decision = false;
            } else if tripped {
                // The MI that tripped still emits its record below (the
                // observation is real); the lane then sits out
                // `backoff_mis(attempt)` MIs before `release_retries`
                // brings it back. Bytes delivered so far are untouched.
                lane.status = LaneStatus::Faulted;
                lane.has_pending_decision = false;
                lane.stall_mis = 0;
                lane.attempt += 1;
                lane.retry_at_mi = mi + 1 + backoff_mis(lane.attempt - 1);
            } else {
                let ctx = MiContext {
                    state: lane.window.state(),
                    obs: &obs,
                    cc: lane.cc,
                    p: lane.p,
                    bounds: &self.bounds,
                    mi_index: mi,
                };
                let d = lane.optimizer.decide(&ctx);
                action = d.action;
                decisions.push((li, d));
                lane.has_pending_decision = true;
            }
            events.push(Event::MiCompleted {
                lane: LaneId(li),
                record: MiRecord {
                    mi,
                    time_s,
                    throughput_gbps: m.throughput_gbps,
                    plr: m.plr,
                    rtt_s: m.rtt_s,
                    energy_j: energy,
                    cc: lane.cc,
                    p: lane.p,
                    metric: out.metric,
                    reward: out.reward,
                    action,
                    state: pooled_state_copy(&mut self.state_pool, lane.window.state()),
                    bytes_total: lane.job.delivered_bytes(),
                    energy_total_j: self.energy.lane_total_j(li),
                    paused: false,
                    rails: bills[li].as_ref().and_then(|b| b.rails),
                },
            });
            if done_now {
                events.push(Event::Completed {
                    lane: LaneId(li),
                    mi,
                    time_s,
                    bytes_delivered: lane.job.delivered_bytes(),
                    total_energy_j: self.energy.lane_total_j(li),
                });
            } else if tripped {
                events.push(Event::Faulted { lane: LaneId(li), mi, time_s, fault: "stall" });
            }
        }
        // Apply decisions after all lanes observed this MI.
        for (li, dec) in decisions.drain(..) {
            let (cc, p) = self.bounds.clamp(dec.cc, dec.p);
            let lane = &mut self.lanes[li];
            if cc != lane.cc || p != lane.p {
                self.sim.set_cc_p(lane.flow, cc, p);
                lane.cc = cc;
                lane.p = p;
            }
        }
        self.decisions_buf = decisions;
        self.bills_buf = bills;
        self.metrics_buf = metrics;
        self.mi += 1;
    }

    /// True when every admitted lane has completed or departed (vacuously
    /// true for an empty session).
    pub fn is_idle(&self) -> bool {
        self.lanes
            .iter()
            .all(|l| matches!(l.status, LaneStatus::Completed | LaneStatus::Departed))
    }

    /// Monitoring intervals run so far (the next `step` runs MI `mi()`).
    pub fn mi(&self) -> usize {
        self.mi
    }

    /// Simulated time elapsed, seconds.
    pub fn time_s(&self) -> f64 {
        self.sim.time_s()
    }

    /// Number of admitted lanes (any status).
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// Lanes currently active, paused or faulted (still in the system).
    pub fn lanes_in_flight(&self) -> usize {
        self.lanes
            .iter()
            .filter(|l| {
                matches!(l.status, LaneStatus::Active | LaneStatus::Paused | LaneStatus::Faulted)
            })
            .count()
    }

    /// Host-truth energy integrated so far across both end hosts, joules
    /// (0.0 on testbeds without energy counters). On the lumped compat
    /// rail this equals the sum of per-lane meters, as before; in
    /// host-resolved mode it is the once-per-host integration the per-lane
    /// attributions sum to.
    pub fn host_energy_j(&self) -> f64 {
        self.energy.host_total_j()
    }

    /// Energy attributed to one lane so far, joules. Includes idle-rail
    /// billing accrued while paused even when paused MIs are not observed.
    pub fn lane_energy_j(&self, id: LaneId) -> Option<f64> {
        if id.0 < self.lanes.len() {
            Some(self.energy.lane_total_j(id.0))
        } else {
            None
        }
    }

    /// Combined per-rail energy breakdown across both hosts (None on the
    /// lumped compat rail).
    pub fn energy_rails(&self) -> Option<RailEnergy> {
        self.energy.rails_total()
    }

    /// One lane's per-rail attribution (None on the lumped compat rail).
    pub fn lane_energy_rails(&self, id: LaneId) -> Option<RailEnergy> {
        if id.0 < self.lanes.len() {
            self.energy.lane_rails(id.0)
        } else {
            None
        }
    }

    /// Whether energy accounting is host-resolved (rails) rather than the
    /// lumped compat curve.
    pub fn energy_host_resolved(&self) -> bool {
        self.energy.host_resolved()
    }

    /// Whether paused lanes emit zero-throughput observation records.
    pub fn observes_paused(&self) -> bool {
        self.observe_paused
    }

    pub fn status(&self, id: LaneId) -> Option<LaneStatus> {
        self.lanes.get(id.0).map(|l| l.status)
    }

    pub fn lane_name(&self, id: LaneId) -> Option<&str> {
        self.lanes.get(id.0).map(|l| l.name.as_ref())
    }

    /// Capture the session's complete logical state at an MI boundary, for
    /// checkpointing (`sparta serve` snapshots). Returns `None` when the
    /// substrate cannot checkpoint itself ([`Substrate::save_state`] is
    /// `None` — e.g. the frozen baseline sim) or when control events are
    /// still queued (`admit`/`pause`/… called since the last step) — a
    /// capture between a control call and its step would lose those events
    /// — or when the fault plane is armed: fault state (watchdog counters,
    /// backoff schedules, degraded segment capacities) is deliberately
    /// outside the snapshot codec, so a faulted run is not checkpointable.
    pub fn export_state(&self) -> Option<SessionState> {
        if !self.pending.is_empty() || self.faults_armed {
            return None;
        }
        let sim = self.sim.save_state()?;
        Some(SessionState {
            mi: self.mi,
            lanes: self
                .lanes
                .iter()
                .map(|l| LaneState {
                    status: l.status,
                    cc: l.cc,
                    p: l.p,
                    has_pending_decision: l.has_pending_decision,
                    delivered_bytes: l.job.delivered_bytes(),
                    window: l.window.export_state(),
                    reward: l.reward.export_state(),
                    optimizer: l.optimizer.state_vec(),
                })
                .collect(),
            energy: self.energy.export_state(),
            sim,
        })
    }

    /// Restore a [`Session::export_state`] capture into a session rebuilt
    /// with the same builder configuration, seed and admission sequence
    /// (the replay-then-inject restore contract: constructors and `admit`
    /// calls rebuild every rebuild-time constant, this injects the mutable
    /// state). The replayed admissions' queued `Admitted` events are
    /// discarded — they already streamed before the capture. Subsequent
    /// stepping is bit-identical to the captured session's. Returns `false`
    /// (session partially untouched) on a shape mismatch.
    pub fn import_state(&mut self, state: &SessionState) -> bool {
        if self.lanes.len() != state.lanes.len() {
            return false;
        }
        if !self.sim.load_state(&state.sim) || !self.energy.import_state(&state.energy) {
            return false;
        }
        for (lane, ls) in self.lanes.iter_mut().zip(&state.lanes) {
            lane.status = ls.status;
            lane.cc = ls.cc;
            lane.p = ls.p;
            lane.has_pending_decision = ls.has_pending_decision;
            // A fresh job's credit is zero, so one advance restores the
            // delivered total bit-exactly (0.0 + x == x).
            lane.job.advance(ls.delivered_bytes);
            lane.window.import_state(&ls.window);
            lane.reward.import_state(&ls.reward);
            lane.optimizer.restore_state(&ls.optimizer);
        }
        self.mi = state.mi;
        self.pending.clear();
        true
    }

    /// Lift a non-terminal lane out of this session for re-admission on
    /// another host ([`MigratedLane`]; the cluster's crash-recovery path).
    /// The slot left behind becomes an inert `Departed` tombstone — its
    /// flow holds zero demand and its energy account stays frozen on this
    /// host's ledger (the caller carries the returned `energy_j` so global
    /// attribution survives the move). No events are emitted; the cluster
    /// announces the move itself. Returns `None` for unknown or already
    /// terminal lanes.
    pub(crate) fn extract_lane(&mut self, id: LaneId) -> Option<MigratedLane> {
        use crate::baselines::StaticTool;
        let lane = self.lanes.get_mut(id.0)?;
        if matches!(lane.status, LaneStatus::Completed | LaneStatus::Departed) {
            return None;
        }
        let energy_j = self.energy.lane_total_j(id.0);
        let status = lane.status;
        let optimizer = std::mem::replace(
            &mut lane.optimizer,
            Box::new(StaticTool::efficient_static(1, 1)),
        );
        let job = std::mem::replace(&mut lane.job, TransferJob::files(1, 0));
        let window = std::mem::replace(
            &mut lane.window,
            FeatureWindow::new(1, self.bounds.cc_max, self.bounds.p_max),
        );
        let reward = std::mem::replace(
            &mut lane.reward,
            RewardTracker::new(RewardKind::ThroughputEnergy, self.reward_cfg),
        );
        let out = MigratedLane {
            name: Arc::clone(&lane.name),
            engine: lane.engine.clone(),
            optimizer,
            job,
            window,
            reward,
            cc: lane.cc,
            p: lane.p,
            status,
            energy_j,
        };
        lane.status = LaneStatus::Departed;
        lane.has_pending_decision = false;
        let flow = lane.flow;
        self.sim.set_demand_cap(flow, 0.0);
        Some(out)
    }

    /// Re-admit a lane lifted off a crashed host: a fresh flow and energy
    /// account on this host, the carried optimizer/job/window/reward state
    /// continuing exactly where it left off. Emits no `Admitted` event —
    /// the lane never left the fleet, it only changed hosts. Paused lanes
    /// stay paused; faulted lanes come back `Active` (the migration *is*
    /// their retry).
    pub(crate) fn admit_migrated(&mut self, m: MigratedLane) -> LaneId {
        let (cc, p) = self.bounds.clamp(m.cc, m.p);
        let io = m.engine.task_io_gbps(self.sim.testbed().task_io_gbps);
        let flow = self.sim.add_flow(cc, p, Some(io));
        let window_slot = self.lanes.len();
        let meter_seed = self.seed.wrapping_mul(0x9E37).wrapping_add(window_slot as u64);
        self.energy.open_lane(&m.engine.power, meter_seed);
        let status = if m.status == LaneStatus::Paused {
            self.sim.set_demand_cap(flow, 0.0);
            LaneStatus::Paused
        } else {
            LaneStatus::Active
        };
        let id = LaneId(window_slot);
        self.lanes.push(SessionLane {
            name: m.name,
            flow,
            optimizer: m.optimizer,
            job: m.job,
            window: m.window,
            reward: m.reward,
            engine: m.engine,
            cc,
            p,
            has_pending_decision: false,
            status,
            stall_mis: 0,
            retry_at_mi: 0,
            attempt: 0,
        });
        id
    }

    pub fn bounds(&self) -> &ParamBounds {
        &self.bounds
    }

    pub fn testbed(&self) -> &Testbed {
        self.sim.testbed()
    }
}

/// One lane lifted out of a crashed host ([`Session::extract_lane`]),
/// carrying everything a healthy host needs to continue it bit-for-bit at
/// the control level: identity, the live optimizer, job progress, feature
/// window, reward tracker and the last applied `(cc, p)`.
pub(crate) struct MigratedLane {
    name: Arc<str>,
    engine: EngineProfile,
    optimizer: Box<dyn Optimizer>,
    job: TransferJob,
    window: FeatureWindow,
    reward: RewardTracker,
    cc: u32,
    p: u32,
    status: LaneStatus,
    /// Energy attributed to the lane on the host it left — frozen there;
    /// the cluster adds it to the lane's new-host account when reporting.
    pub(crate) energy_j: f64,
}

/// A captured [`Session`] at an MI boundary (see [`Session::export_state`]).
/// Rebuild-time constants — builder config, seed, lane names, optimizer
/// construction, flow/account wiring — are not part of the capture; they
/// are regenerated by replaying the admission sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    /// Monitoring intervals run so far.
    pub mi: usize,
    /// One entry per admitted lane, in admission order.
    pub lanes: Vec<LaneState>,
    /// Energy-plane ledgers, in ledger order.
    pub energy: Vec<LedgerState>,
    /// The substrate capture.
    pub sim: SimState,
}

/// One lane's captured mutable state (see [`SessionState`]).
#[derive(Debug, Clone, PartialEq)]
pub struct LaneState {
    pub status: LaneStatus,
    pub cc: u32,
    pub p: u32,
    pub has_pending_decision: bool,
    /// The job's delivered-byte total (restored via one `advance`).
    pub delivered_bytes: f64,
    pub window: WindowState,
    pub reward: TrackerState,
    /// The optimizer's [`Optimizer::state_vec`] capture.
    pub optimizer: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticTool;
    use crate::telemetry::EventLog;

    fn quick_job() -> TransferJob {
        // 2 GB: cannot complete within one MI on a 10 Gbps testbed (hard
        // capacity bound 1.25 GB/MI), so pause/cancel timing is safe.
        TransferJob::files(8, 256 << 20)
    }

    fn static_spec() -> LaneSpec {
        LaneSpec::new(Box::new(StaticTool::efficient_static(4, 4)), quick_job())
    }

    #[test]
    fn step_streams_admission_then_mi_events() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(3)
            .build();
        let id = s.admit(static_spec());
        let events = s.step();
        assert!(matches!(events[0], Event::Admitted { lane, .. } if lane == id));
        let is_mi0 = match &events[1] {
            Event::MiCompleted { lane, record } => *lane == id && record.mi == 0,
            _ => false,
        };
        assert!(is_mi0);
        assert_eq!(s.mi(), 1);
    }

    /// The record-state pool actually recycles: after the first reclaim,
    /// repeated `step_into` over a reused buffer emits records whose
    /// state buffers come from the pool (pool size stays bounded by the
    /// per-step record count instead of growing), and the emitted values
    /// are identical to the allocating `step()` path on a twin session.
    #[test]
    fn state_pool_recycles_record_buffers() {
        let build = |seed: u64| {
            let mut s = Session::builder(Testbed::chameleon())
                .background(Background::Idle)
                .seed(seed)
                .build();
            s.admit(static_spec());
            s.admit(static_spec());
            s
        };
        let mut pooled = build(9);
        let mut alloc = build(9);
        let mut events = Vec::new();
        for step in 0..12 {
            pooled.step_into(&mut events);
            let fresh = alloc.step();
            assert_eq!(events.len(), fresh.len(), "step {step}: event counts diverged");
            for (a, b) in events.iter().zip(fresh.iter()) {
                assert_eq!(a, b, "step {step}: pooled path diverged from allocating path");
            }
            // Two lanes → at most two records reclaimed per step; the pool
            // never holds more than one step's worth of buffers.
            assert!(pooled.state_pool.len() <= 2, "pool grew: {}", pooled.state_pool.len());
        }
        // Reclaiming the final step's events by hand closes the loop:
        // every record buffer comes back to the pool, cleared.
        let n_records =
            events.iter().filter(|e| matches!(e, Event::MiCompleted { .. })).count();
        let before = pooled.state_pool.len();
        pooled.reclaim_events(&mut events);
        assert!(events.is_empty());
        assert_eq!(pooled.state_pool.len(), before + n_records);
        assert!(pooled.state_pool.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn lane_completes_with_terminal_event() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(3)
            .build();
        let id = s.admit(static_spec());
        let mut log = EventLog::default();
        s.run_to_completion(DEFAULT_MAX_MIS, &mut log);
        assert_eq!(s.status(id), Some(LaneStatus::Completed));
        let completed = log.events.iter().any(|e| {
            matches!(e, Event::Completed { lane, bytes_delivered, .. }
                if *lane == id && *bytes_delivered > 0.0)
        });
        assert!(completed, "no Completed event in stream");
        assert!(s.is_idle());
    }

    #[test]
    fn mid_run_admission_is_legal_and_fair() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(5)
            .build();
        let first = s.admit(static_spec());
        for _ in 0..10 {
            s.step();
        }
        let late = s.admit(LaneSpec::new(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
        ));
        let events = s.step();
        let late_ok = match &events[0] {
            Event::Admitted { lane, mi, time_s, .. } => {
                *lane == late && *mi == 10 && *time_s > 0.0
            }
            _ => false,
        };
        assert!(late_ok);
        let mut log = EventLog::default();
        s.run_to_completion(DEFAULT_MAX_MIS, &mut log);
        assert_eq!(s.status(first), Some(LaneStatus::Completed));
        assert_eq!(s.status(late), Some(LaneStatus::Completed));
    }

    #[test]
    fn pause_resume_gates_progress() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(7)
            .build();
        let id = s.admit(static_spec());
        s.step();
        assert!(s.pause(id));
        assert!(!s.pause(id), "double pause must be rejected");
        assert_eq!(s.status(id), Some(LaneStatus::Paused));
        // While paused, the lane produces no MI records.
        let paused_events = s.step();
        assert!(paused_events
            .iter()
            .all(|e| !matches!(e, Event::MiCompleted { .. })));
        assert!(s.resume(id));
        let resumed_events = s.step();
        assert!(resumed_events
            .iter()
            .any(|e| matches!(e, Event::MiCompleted { .. })));
    }

    #[test]
    fn cancel_departs_with_partial_bytes() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(9)
            .build();
        // Big enough that three MIs cannot finish it.
        let job = TransferJob::files(64, 256 << 20);
        let total = job.total_bytes();
        let id = s.admit(LaneSpec::new(Box::new(StaticTool::efficient_static(4, 4)), job));
        for _ in 0..3 {
            s.step();
        }
        assert!(s.cancel(id));
        assert!(!s.cancel(id), "double cancel must be rejected");
        let events = s.step();
        let departed = events.iter().find_map(|e| match e {
            Event::Departed { lane, bytes_delivered, .. } if *lane == id => {
                Some(*bytes_delivered)
            }
            _ => None,
        });
        let bytes = departed.expect("no Departed event");
        assert!(bytes > 0.0 && bytes < total);
        assert!(s.is_idle());
    }

    #[test]
    fn empty_session_is_idle_and_steps_advance_time() {
        let mut s = Session::builder(Testbed::chameleon()).build();
        assert!(s.is_idle());
        s.step();
        assert!(s.time_s() > 0.0);
        assert_eq!(s.lane_count(), 0);
    }

    /// With `observe_paused`, a paused lane emits zero-throughput records
    /// carrying idle-rail energy — the preemption-cost signal.
    #[test]
    fn observed_pause_emits_idle_records_with_rails() {
        let tb = Testbed::chameleon();
        let mut s = Session::builder(tb.clone())
            .background(Background::Idle)
            .energy(tb.energy_hosts())
            .observe_paused(true)
            .seed(3)
            .build();
        let id = s.admit(static_spec());
        s.step();
        assert!(s.pause(id));
        let events = s.step();
        // Borrow the record out of the event — sinks get `&Event`, so
        // nothing on this path needs to clone an `MiRecord`.
        let rec = events
            .iter()
            .find_map(|e| match e {
                Event::MiCompleted { lane, record } if *lane == id => Some(record),
                _ => None,
            })
            .expect("observed paused lane must emit a record");
        assert!(rec.paused);
        assert_eq!(rec.throughput_gbps, 0.0);
        assert!(rec.energy_j > 0.0 && rec.energy_j < 80.0, "idle bill {}", rec.energy_j);
        let rails = rec.rails.expect("host-resolved record carries rails");
        assert!(rails.idle_j > 0.0 && rails.fixed_j > 0.0);
        assert_eq!(rails.cpu_j, 0.0);
    }

    /// Without the knob, a paused lane stays silent (compat) but — in
    /// host-resolved mode — its account still accrues idle energy, so
    /// pausing is never modeled as free.
    #[test]
    fn unobserved_pause_still_bills_idle_on_host_rails() {
        let tb = Testbed::chameleon();
        let mut s = Session::builder(tb.clone())
            .background(Background::Idle)
            .energy(tb.energy_hosts())
            .seed(5)
            .build();
        let id = s.admit(static_spec());
        s.step();
        assert!(s.pause(id));
        let before = s.lane_energy_j(id).unwrap();
        let events = s.step();
        assert!(events.iter().all(|e| !matches!(e, Event::MiCompleted { .. })));
        let after = s.lane_energy_j(id).unwrap();
        assert!(after > before, "paused lane accrued no idle energy");
        // Conservation: the lane's attribution is the whole host total.
        assert!((s.host_energy_j() - after).abs() <= 1e-9 * after);
    }

    /// Arming the fault plane without any plan (the cluster-host mode) must
    /// leave a healthy run bit-identical: the watchdog only counts, and a
    /// progressing lane never trips it.
    #[test]
    fn armed_fault_free_run_matches_unarmed_bit_for_bit() {
        let build = |armed: bool| {
            let mut s = Session::builder(Testbed::chameleon())
                .background(Background::Idle)
                .seed(11)
                .build();
            if armed {
                s.arm_faults();
            }
            s.admit(static_spec());
            s
        };
        let mut armed = build(true);
        let mut plain = build(false);
        for step in 0..20 {
            assert_eq!(armed.step(), plain.step(), "step {step}: armed path diverged");
        }
        assert_eq!(armed.is_idle(), plain.is_idle());
    }

    /// An injected host stall starves every lane, the watchdog faults them
    /// after [`STALL_AFTER_MIS`] dead MIs, retries back off exponentially,
    /// and the job still completes with every byte once the stall lifts.
    #[test]
    fn host_stall_faults_then_retries_and_completes() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(13)
            .build();
        s.install_faults(FaultPlan {
            events: vec![FaultEvent { at_mi: 2, op: FaultOp::HostStall { host: 0, mis: 6 } }],
        });
        let job = TransferJob::files(16, 256 << 20);
        let total = job.total_bytes();
        let id = s.admit(LaneSpec::new(Box::new(StaticTool::efficient_static(4, 4)), job));
        let mut log = EventLog::default();
        s.run_to_completion(DEFAULT_MAX_MIS, &mut log);
        let faulted = log
            .events
            .iter()
            .any(|e| matches!(e, Event::Faulted { lane, fault, .. } if *lane == id && *fault == "stall"));
        assert!(faulted, "stall watchdog never tripped");
        let retried = log
            .events
            .iter()
            .any(|e| matches!(e, Event::Retrying { lane, attempt, .. } if *lane == id && *attempt >= 1));
        assert!(retried, "faulted lane never retried");
        let delivered = log.events.iter().find_map(|e| match e {
            Event::Completed { lane, bytes_delivered, .. } if *lane == id => Some(*bytes_delivered),
            _ => None,
        });
        let delivered = delivered.expect("lane never completed after the stall lifted");
        assert!(delivered >= total * 0.999, "bytes lost across fault: {delivered} < {total}");
        assert_eq!(s.status(id), Some(LaneStatus::Completed));
    }

    /// Injected stream errors fault the targeted lane at the MI boundary
    /// and the retry ladder brings it back without losing progress.
    #[test]
    fn stream_error_faults_lane_and_preserves_bytes() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(17)
            .build();
        s.install_faults(FaultPlan {
            events: vec![FaultEvent { at_mi: 1, op: FaultOp::StreamError { lane_slot: 0 } }],
        });
        let job = TransferJob::files(16, 256 << 20);
        let total = job.total_bytes();
        let id = s.admit(LaneSpec::new(Box::new(StaticTool::efficient_static(4, 4)), job));
        let mut log = EventLog::default();
        s.run_to_completion(DEFAULT_MAX_MIS, &mut log);
        let fault_mi = log.events.iter().find_map(|e| match e {
            Event::Faulted { lane, mi, fault, .. } if *lane == id => {
                assert_eq!(*fault, "stream-error");
                Some(*mi)
            }
            _ => None,
        });
        assert_eq!(fault_mi, Some(1), "stream error must land at its scheduled MI");
        let retry_mi = log.events.iter().find_map(|e| match e {
            Event::Retrying { lane, mi, .. } if *lane == id => Some(*mi),
            _ => None,
        });
        assert_eq!(retry_mi, Some(2), "first backoff window is one MI");
        let delivered = log.events.iter().find_map(|e| match e {
            Event::Completed { lane, bytes_delivered, .. } if *lane == id => Some(*bytes_delivered),
            _ => None,
        });
        assert!(delivered.expect("lane completed") >= total * 0.999);
    }

    /// Armed sessions refuse to checkpoint: fault state lives outside the
    /// snapshot codec.
    #[test]
    fn armed_session_is_not_checkpointable() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(19)
            .build();
        s.admit(static_spec());
        s.step();
        assert!(s.export_state().is_some(), "healthy session must checkpoint");
        s.arm_faults();
        assert!(s.export_state().is_none(), "armed session must refuse to checkpoint");
    }

    /// A lane lifted out of one session and re-admitted into another keeps
    /// its job progress: the migration path conserves bytes end to end.
    #[test]
    fn extract_and_readmit_conserves_lane_bytes() {
        let mut a = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(23)
            .build();
        let job = TransferJob::files(16, 256 << 20);
        let total = job.total_bytes();
        let id = a.admit(LaneSpec::new(Box::new(StaticTool::efficient_static(4, 4)), job));
        for _ in 0..3 {
            a.step();
        }
        let m = a.extract_lane(id).expect("in-flight lane must extract");
        let moved_bytes = m.job.delivered_bytes();
        assert!(moved_bytes > 0.0, "no progress before migration");
        assert!(m.energy_j >= 0.0);
        assert_eq!(a.status(id), Some(LaneStatus::Departed), "tombstone left behind");
        assert!(a.extract_lane(id).is_none(), "tombstone must not extract twice");
        let mut b = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(29)
            .build();
        let nid = b.admit_migrated(m);
        assert_eq!(b.lane_name(nid), a.lane_name(id), "identity survives the move");
        let mut log = EventLog::default();
        b.run_to_completion(DEFAULT_MAX_MIS, &mut log);
        assert!(
            log.events.iter().all(|e| !matches!(e, Event::Admitted { .. })),
            "migration must not re-announce admission"
        );
        let delivered = log.events.iter().find_map(|e| match e {
            Event::Completed { lane, bytes_delivered, .. } if *lane == nid => {
                Some(*bytes_delivered)
            }
            _ => None,
        });
        let delivered = delivered.expect("migrated lane completed");
        assert!(
            delivered >= total * 0.999 && delivered >= moved_bytes,
            "bytes lost in migration: {delivered} of {total}"
        );
    }

    /// The lumped compat rail (the default) reports no rail breakdown and
    /// bills paused lanes nothing unless observed — the seed behavior.
    #[test]
    fn lumped_default_has_no_rails_and_free_silent_pauses() {
        let mut s = Session::builder(Testbed::chameleon())
            .background(Background::Idle)
            .seed(7)
            .build();
        assert!(!s.energy_host_resolved());
        let id = s.admit(static_spec());
        let events = s.step();
        let has_rails = events
            .iter()
            .any(|e| matches!(e, Event::MiCompleted { record, .. } if record.rails.is_some()));
        assert!(!has_rails);
        assert!(s.pause(id));
        let before = s.lane_energy_j(id).unwrap();
        s.step();
        assert_eq!(s.lane_energy_j(id).unwrap(), before);
    }
}
