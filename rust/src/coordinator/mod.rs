//! The SPARTA coordinator — the paper's system contribution.
//!
//! Each monitoring interval (MI) the coordinator:
//! 1. collects end-host metrics from the network substrate (goodput, packet
//!    loss rate, RTT) and the energy meter,
//! 2. extracts the paper's state features (`plr`, `rtt_gradient`,
//!    `rtt_ratio`, `cc`, `p`) into a sliding window of `n` observations,
//! 3. asks the active [`Optimizer`] (a DRL agent or a baseline) for a
//!    decision in the five-action space (∆cc, ∆p ∈ {0, ±1, ±2}),
//! 4. applies it by pausing/resuming transfer threads, and
//! 5. computes the F&E or T/E reward and feeds it back for learning.
//!
//! The public API is the [`Stepping`] surface: lanes are admitted
//! (possibly mid-run) with `admit`, each buffer-taking `step_into`
//! advances one MI and streams [`Event`]s, and external
//! pause/resume/cancel model transfers that come and go. Two scales
//! implement it — the single-host [`Session`] ([`session`]) and the
//! multi-host [`Cluster`] ([`cluster`]), which shards lanes across many
//! per-host sessions over incast topologies — so fleet drivers run
//! unchanged from one host to a datacenter. The batch [`Controller`]
//! ([`controller`]) is the compat wrapper: fixed lanes, run to
//! completion, [`RunReport`] rebuilt from the event stream by
//! [`crate::telemetry::ReportSink`] — bit-identical to the pre-redesign
//! behavior, so every figure regenerates unchanged.

use crate::energy::RailEnergy;

pub mod actions;
pub mod cluster;
pub mod controller;
pub mod reward;
pub mod session;
pub mod state;

pub use actions::{ActionId, ParamBounds, ACTIONS, N_ACTIONS};
pub use cluster::{Cluster, ClusterState, INCAST_RX_OVER_WAN};
pub use controller::{Controller, ControllerBuilder, LaneReport, RunReport};
pub use reward::{RewardConfig, RewardKind, RewardTracker, TrackerState};
pub use session::{
    Event, LaneId, LaneSpec, LaneState, LaneStatus, MiRecord, Session, SessionBuilder,
    SessionState, DEFAULT_MAX_MIS,
};
pub use state::{FeatureWindow, Observation, WindowState, FEATURES};

/// The unified stepping surface: one host ([`Session`]) or a sharded fleet
/// of hosts ([`Cluster`]) behind the same admit / step-into-buffer /
/// external-control / energy-truth API.
///
/// Drivers written against this trait — `sparta fleet` is the canonical
/// one — run unchanged at any scale, and monomorphize, so the single-host
/// path keeps its zero-alloc stepping profile. The buffer-taking
/// [`Stepping::step_into`] is the one stepping primitive; the allocating
/// [`Stepping::step`] default exists for interactive/doc use only.
pub trait Stepping {
    /// Admit a lane (legal mid-run); returns its id in admission order.
    fn admit(&mut self, spec: LaneSpec) -> LaneId;

    /// Advance one monitoring interval, writing produced events into the
    /// caller-reused buffer (see [`Session::step_into`]).
    fn step_into(&mut self, events: &mut Vec<Event>);

    /// Capacity hint for an expected total of `n` lanes (e.g. a fleet
    /// schedule's arrival count). Purely advisory — never affects results
    /// — so the default is a no-op; [`Session`] and [`Cluster`] reserve
    /// their lane tables and stream arenas (§Perf: 100k-lane admits).
    fn reserve_lanes(&mut self, _n: usize) {}

    /// Externally pause an active lane. False if it wasn't pausable.
    fn pause(&mut self, id: LaneId) -> bool;

    /// Resume an externally-paused lane. False if it wasn't paused.
    fn resume(&mut self, id: LaneId) -> bool;

    /// Cancel a lane before completion. False if it already ended.
    fn cancel(&mut self, id: LaneId) -> bool;

    fn status(&self, id: LaneId) -> Option<LaneStatus>;

    /// True when every admitted lane has completed or departed.
    fn is_idle(&self) -> bool;

    /// Monitoring intervals run so far.
    fn mi(&self) -> usize;

    /// Simulated time elapsed, seconds.
    fn time_s(&self) -> f64;

    fn lane_count(&self) -> usize;

    /// Ledger-truth energy integrated so far (all hosts), joules.
    fn host_energy_j(&self) -> f64;

    /// Energy attributed to one lane so far, joules.
    fn lane_energy_j(&self, id: LaneId) -> Option<f64>;

    /// Per-rail energy breakdown, all hosts combined (None on the lumped
    /// compat rail).
    fn energy_rails(&self) -> Option<RailEnergy>;

    /// Allocating convenience over [`Stepping::step_into`] — fine for
    /// examples and tests, deprecated-in-docs for hot-path drivers.
    fn step(&mut self) -> Vec<Event> {
        let mut events = Vec::new();
        self.step_into(&mut events);
        events
    }
}

impl Stepping for Session {
    fn admit(&mut self, spec: LaneSpec) -> LaneId {
        Session::admit(self, spec)
    }

    fn step_into(&mut self, events: &mut Vec<Event>) {
        Session::step_into(self, events)
    }

    fn reserve_lanes(&mut self, n: usize) {
        Session::reserve_lanes(self, n)
    }

    fn pause(&mut self, id: LaneId) -> bool {
        Session::pause(self, id)
    }

    fn resume(&mut self, id: LaneId) -> bool {
        Session::resume(self, id)
    }

    fn cancel(&mut self, id: LaneId) -> bool {
        Session::cancel(self, id)
    }

    fn status(&self, id: LaneId) -> Option<LaneStatus> {
        Session::status(self, id)
    }

    fn is_idle(&self) -> bool {
        Session::is_idle(self)
    }

    fn mi(&self) -> usize {
        Session::mi(self)
    }

    fn time_s(&self) -> f64 {
        Session::time_s(self)
    }

    fn lane_count(&self) -> usize {
        Session::lane_count(self)
    }

    fn host_energy_j(&self) -> f64 {
        Session::host_energy_j(self)
    }

    fn lane_energy_j(&self, id: LaneId) -> Option<f64> {
        Session::lane_energy_j(self, id)
    }

    fn energy_rails(&self) -> Option<RailEnergy> {
        Session::energy_rails(self)
    }
}

impl Stepping for Cluster {
    fn admit(&mut self, spec: LaneSpec) -> LaneId {
        Cluster::admit(self, spec)
    }

    fn step_into(&mut self, events: &mut Vec<Event>) {
        Cluster::step_into(self, events)
    }

    fn reserve_lanes(&mut self, n: usize) {
        Cluster::reserve_lanes(self, n)
    }

    fn pause(&mut self, id: LaneId) -> bool {
        Cluster::pause(self, id)
    }

    fn resume(&mut self, id: LaneId) -> bool {
        Cluster::resume(self, id)
    }

    fn cancel(&mut self, id: LaneId) -> bool {
        Cluster::cancel(self, id)
    }

    fn status(&self, id: LaneId) -> Option<LaneStatus> {
        Cluster::status(self, id)
    }

    fn is_idle(&self) -> bool {
        Cluster::is_idle(self)
    }

    fn mi(&self) -> usize {
        Cluster::mi(self)
    }

    fn time_s(&self) -> f64 {
        Cluster::time_s(self)
    }

    fn lane_count(&self) -> usize {
        Cluster::lane_count(self)
    }

    fn host_energy_j(&self) -> f64 {
        Cluster::host_energy_j(self)
    }

    fn lane_energy_j(&self, id: LaneId) -> Option<f64> {
        Cluster::lane_energy_j(self, id)
    }

    fn energy_rails(&self) -> Option<RailEnergy> {
        Cluster::energy_rails(self)
    }
}

/// A (cc, p) decision returned by an optimizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub cc: u32,
    pub p: u32,
    /// The discrete action index that produced this decision, when the
    /// optimizer uses the paper's five-action space (used for transition
    /// logging and emulator training).
    pub action: Option<ActionId>,
}

/// Everything an optimizer may inspect when deciding.
pub struct MiContext<'a> {
    /// Flattened feature window, length `window * FEATURES` (oldest first).
    pub state: &'a [f32],
    /// Latest raw observation.
    pub obs: &'a Observation,
    pub cc: u32,
    pub p: u32,
    pub bounds: &'a ParamBounds,
    /// Monitoring-interval index within the session (0-based; lanes
    /// admitted mid-run see the session-global index).
    pub mi_index: usize,
}

/// A transfer-parameter optimizer: a DRL agent or a baseline tool policy.
///
/// `Send` is a supertrait so a whole [`Session`] (which boxes one optimizer
/// per lane) can be stepped on a [`Cluster`] worker thread; optimizers are
/// never *shared* across threads, only moved with their owning host.
pub trait Optimizer: Send {
    fn name(&self) -> &str;

    /// Initial (cc, p) at transfer start.
    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32);

    /// Decide the next (cc, p) given the current state window.
    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision;

    /// Reward feedback for the *previous* decision, with the resulting state.
    /// Learning optimizers train here; static tools ignore it.
    fn learn(&mut self, _reward: f64, _next_state: &[f32], _done: bool) {}

    /// Whether this optimizer keeps adapting online (affects Table-1 style
    /// accounting of online tuning energy).
    fn is_learning(&self) -> bool {
        false
    }

    /// The optimizer's mutable decision state as a flat `f64` vector, for
    /// checkpointing. Paired with [`Optimizer::restore_state`]: a fresh
    /// optimizer built with the same constructor arguments, `start`-ed and
    /// then restored, must decide exactly as the captured one would. The
    /// empty default is correct for stateless policies (e.g. static tools).
    fn state_vec(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Restore a [`Optimizer::state_vec`] capture. The default ignores it
    /// (stateless policies).
    fn restore_state(&mut self, _state: &[f64]) {}
}
