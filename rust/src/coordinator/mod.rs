//! The SPARTA coordinator — the paper's system contribution.
//!
//! Each monitoring interval (MI) the coordinator:
//! 1. collects end-host metrics from the network substrate (goodput, packet
//!    loss rate, RTT) and the energy meter,
//! 2. extracts the paper's state features (`plr`, `rtt_gradient`,
//!    `rtt_ratio`, `cc`, `p`) into a sliding window of `n` observations,
//! 3. asks the active [`Optimizer`] (a DRL agent or a baseline) for a
//!    decision in the five-action space (∆cc, ∆p ∈ {0, ±1, ±2}),
//! 4. applies it by pausing/resuming transfer threads, and
//! 5. computes the F&E or T/E reward and feeds it back for learning.
//!
//! The public API is the step-driven [`Session`] ([`session`]): lanes are
//! admitted (possibly mid-run) with [`Session::admit`], each
//! [`Session::step`] advances one MI and streams [`Event`]s into any
//! [`crate::telemetry::TelemetrySink`], and external
//! pause/resume/cancel model transfers that come and go. The batch
//! [`Controller`] ([`controller`]) is the compat wrapper: fixed lanes, run
//! to completion, [`RunReport`] rebuilt from the event stream by
//! [`crate::telemetry::ReportSink`] — bit-identical to the pre-redesign
//! behavior, so every figure regenerates unchanged.

pub mod actions;
pub mod controller;
pub mod reward;
pub mod session;
pub mod state;

pub use actions::{ActionId, ParamBounds, ACTIONS, N_ACTIONS};
pub use controller::{Controller, ControllerBuilder, LaneReport, RunReport};
pub use reward::{RewardConfig, RewardKind, RewardTracker};
pub use session::{
    Event, LaneId, LaneSpec, LaneStatus, MiRecord, Session, SessionBuilder, DEFAULT_MAX_MIS,
};
pub use state::{FeatureWindow, Observation, FEATURES};

/// A (cc, p) decision returned by an optimizer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub cc: u32,
    pub p: u32,
    /// The discrete action index that produced this decision, when the
    /// optimizer uses the paper's five-action space (used for transition
    /// logging and emulator training).
    pub action: Option<ActionId>,
}

/// Everything an optimizer may inspect when deciding.
pub struct MiContext<'a> {
    /// Flattened feature window, length `window * FEATURES` (oldest first).
    pub state: &'a [f32],
    /// Latest raw observation.
    pub obs: &'a Observation,
    pub cc: u32,
    pub p: u32,
    pub bounds: &'a ParamBounds,
    /// Monitoring-interval index within the session (0-based; lanes
    /// admitted mid-run see the session-global index).
    pub mi_index: usize,
}

/// A transfer-parameter optimizer: a DRL agent or a baseline tool policy.
pub trait Optimizer {
    fn name(&self) -> &str;

    /// Initial (cc, p) at transfer start.
    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32);

    /// Decide the next (cc, p) given the current state window.
    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision;

    /// Reward feedback for the *previous* decision, with the resulting state.
    /// Learning optimizers train here; static tools ignore it.
    fn learn(&mut self, _reward: f64, _next_state: &[f32], _done: bool) {}

    /// Whether this optimizer keeps adapting online (affects Table-1 style
    /// accounting of online tuning energy).
    fn is_learning(&self) -> bool {
        false
    }
}
