//! DRL state-space construction (§3.3.1 of the paper).
//!
//! The agent never sees raw throughput or energy (those are the optimization
//! targets); it sees stable congestion indicators extracted per MI:
//!
//! * `plr` — packet loss rate,
//! * `rtt_gradient` — relative RTT change between consecutive MIs,
//! * `rtt_ratio` — current mean RTT over the session's minimum mean RTT,
//! * `cc`, `p` — the agent's own (normalized) settings, so the policy can
//!   learn how past parameter choices shaped the present state.
//!
//! The state is the window of the last `n` feature vectors (Eq. 8).

/// Features per monitoring interval (Eq. 7).
pub const FEATURES: usize = 5;

/// Raw per-MI observation, as produced by the substrates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub throughput_gbps: f64,
    pub plr: f64,
    pub rtt_s: f64,
    /// Energy consumed during this MI (J); NaN when counters are absent
    /// (FABRIC), in which case T/E rewards are undefined on that testbed.
    pub energy_j: f64,
    pub cc: u32,
    pub p: u32,
    pub duration_s: f64,
}

/// Sliding feature window turning observations into the flattened DRL state.
#[derive(Debug, Clone)]
pub struct FeatureWindow {
    window: usize,
    cc_max: f32,
    p_max: f32,
    rtt_min_s: f64,
    prev_rtt_s: Option<f64>,
    /// Flattened ring of feature vectors, oldest first, length window*FEATURES.
    buf: Vec<f32>,
}

impl FeatureWindow {
    /// `window` = n, the number of MIs the state spans; `cc_max`/`p_max`
    /// normalize the parameter features into [0, 1].
    pub fn new(window: usize, cc_max: u32, p_max: u32) -> FeatureWindow {
        assert!(window >= 1);
        FeatureWindow {
            window,
            cc_max: cc_max as f32,
            p_max: p_max as f32,
            rtt_min_s: f64::MAX,
            prev_rtt_s: None,
            buf: vec![0.0; window * FEATURES],
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Dimension of the flattened state.
    pub fn state_len(&self) -> usize {
        self.window * FEATURES
    }

    /// Ingest one observation; returns the feature vector for this MI.
    pub fn push(&mut self, obs: &Observation) -> [f32; FEATURES] {
        self.rtt_min_s = self.rtt_min_s.min(obs.rtt_s);
        let gradient = match self.prev_rtt_s {
            None => 0.0,
            Some(prev) => ((obs.rtt_s - prev) / prev).clamp(-1.0, 1.0),
        };
        self.prev_rtt_s = Some(obs.rtt_s);
        let ratio = (obs.rtt_s / self.rtt_min_s).min(8.0);
        let x = [
            obs.plr.clamp(0.0, 1.0) as f32,
            gradient as f32,
            ratio as f32,
            obs.cc as f32 / self.cc_max,
            obs.p as f32 / self.p_max,
        ];
        // Shift left one feature vector, append the new one.
        self.buf.copy_within(FEATURES.., 0);
        let start = (self.window - 1) * FEATURES;
        self.buf[start..].copy_from_slice(&x);
        x
    }

    /// The flattened state s_t = (x_{t-n+1}, ..., x_t), oldest first.
    pub fn state(&self) -> &[f32] {
        &self.buf
    }

    /// Session-minimum mean RTT seen so far.
    pub fn rtt_min_s(&self) -> f64 {
        self.rtt_min_s
    }

    /// Reset for a new episode (keeps window size and normalizers).
    pub fn reset(&mut self) {
        self.rtt_min_s = f64::MAX;
        self.prev_rtt_s = None;
        self.buf.fill(0.0);
    }

    /// Capture the window's mutable state for checkpointing (the size and
    /// normalizers are rebuild-time constants).
    pub fn export_state(&self) -> WindowState {
        WindowState {
            rtt_min_s: self.rtt_min_s,
            prev_rtt_s: self.prev_rtt_s,
            buf: self.buf.clone(),
        }
    }

    /// Restore a [`FeatureWindow::export_state`] capture into a window
    /// rebuilt with the same size and normalizers.
    pub fn import_state(&mut self, state: &WindowState) {
        self.rtt_min_s = state.rtt_min_s;
        self.prev_rtt_s = state.prev_rtt_s;
        self.buf = state.buf.clone();
    }
}

/// A captured [`FeatureWindow`]: the session-minimum RTT (possibly still
/// the `f64::MAX` sentinel), the previous RTT sample, and the flattened
/// feature ring.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowState {
    pub rtt_min_s: f64,
    pub prev_rtt_s: Option<f64>,
    pub buf: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(plr: f64, rtt: f64, cc: u32, p: u32) -> Observation {
        Observation {
            throughput_gbps: 5.0,
            plr,
            rtt_s: rtt,
            energy_j: 100.0,
            cc,
            p,
            duration_s: 1.0,
        }
    }

    #[test]
    fn first_push_has_zero_gradient_unit_ratio() {
        let mut w = FeatureWindow::new(4, 16, 16);
        let x = w.push(&obs(0.01, 0.032, 4, 4));
        assert_eq!(x[1], 0.0);
        assert!((x[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gradient_reflects_rtt_change() {
        let mut w = FeatureWindow::new(4, 16, 16);
        w.push(&obs(0.0, 0.032, 4, 4));
        let x = w.push(&obs(0.0, 0.048, 4, 4)); // +50%
        assert!((x[1] - 0.5).abs() < 1e-6);
        let x = w.push(&obs(0.0, 0.024, 4, 4)); // -50%
        assert!((x[1] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn ratio_uses_session_minimum() {
        let mut w = FeatureWindow::new(4, 16, 16);
        w.push(&obs(0.0, 0.040, 4, 4));
        w.push(&obs(0.0, 0.032, 4, 4)); // new minimum
        let x = w.push(&obs(0.0, 0.064, 4, 4));
        assert!((x[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn params_normalized() {
        let mut w = FeatureWindow::new(2, 16, 8);
        let x = w.push(&obs(0.0, 0.03, 8, 8));
        assert!((x[3] - 0.5).abs() < 1e-6);
        assert!((x[4] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn window_shifts_oldest_out() {
        let mut w = FeatureWindow::new(2, 16, 16);
        w.push(&obs(0.10, 0.03, 1, 1));
        w.push(&obs(0.20, 0.03, 2, 2));
        w.push(&obs(0.30, 0.03, 3, 3));
        let s = w.state();
        // Oldest remaining is the 0.20 observation.
        assert!((s[0] - 0.20).abs() < 1e-6);
        assert!((s[FEATURES] - 0.30).abs() < 1e-6);
    }

    #[test]
    fn state_len_and_reset() {
        let mut w = FeatureWindow::new(8, 16, 16);
        assert_eq!(w.state_len(), 8 * FEATURES);
        w.push(&obs(0.5, 0.03, 4, 4));
        w.reset();
        assert!(w.state().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradient_clipped_to_unit() {
        let mut w = FeatureWindow::new(2, 16, 16);
        w.push(&obs(0.0, 0.010, 4, 4));
        let x = w.push(&obs(0.0, 0.500, 4, 4)); // +4900%
        assert_eq!(x[1], 1.0);
    }
}
