//! Reward formulations (§3.2, §3.3.3 of the paper).
//!
//! Two objectives share one difference-based shaping function f(·):
//!
//! * **F&E (fairness & efficiency)** — utility U(T, L) = T / K^(cc·p) − T·L·B
//!   (Eq. 3/10): rewards throughput, penalizes stream hoarding and loss.
//! * **T/E (throughput-focused energy)** — R̄ = mean(T)·SC / max(E) over the
//!   window (Eq. 13/14): throughput per unit energy.
//!
//! f(cur, prev) returns +x on improvement beyond ε, −y on regression beyond
//! ε, else 0 (§3.3.3 "Difference-Based Reward Update").
//!
//! The energy `E` the T/E metric consumes is the lane's **attributed**
//! energy from the shared host ledger (its share of the host truth —
//! equal-share fixed power, stream-proportional CPU, byte-proportional
//! NIC; see [`crate::energy::HostLedger`]), not a privately-metered lumped
//! curve — so colocated lanes optimize against what they actually cost the
//! host, and a paused lane's observed idle bill depresses the metric.

use super::state::Observation;
use std::collections::VecDeque;

/// Which objective the agent optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RewardKind {
    /// Fairness & Efficiency (Eq. 4) — SPARTA-FE.
    FairnessEfficiency,
    /// Throughput-focused energy efficiency (Eq. 5) — SPARTA-T.
    ThroughputEnergy,
}

impl RewardKind {
    pub fn short(&self) -> &'static str {
        match self {
            RewardKind::FairnessEfficiency => "FE",
            RewardKind::ThroughputEnergy => "TE",
        }
    }

    pub fn by_name(name: &str) -> Option<RewardKind> {
        match name.to_ascii_lowercase().as_str() {
            "fe" | "f&e" | "fairness" => Some(RewardKind::FairnessEfficiency),
            "te" | "t/e" | "energy" => Some(RewardKind::ThroughputEnergy),
            _ => None,
        }
    }
}

/// Constants of the reward machinery (plain scalars — `Copy`, so
/// per-lane trackers take a copy instead of cloning through an allocation
/// path on every admit).
#[derive(Debug, Clone, Copy)]
pub struct RewardConfig {
    /// K in U = T/K^(cc·p): per-stream utility discount (> 1).
    pub k: f64,
    /// B in U: loss penalty weight.
    pub b: f64,
    /// SC scaling constant of the T/E metric.
    pub sc: f64,
    /// ε dead-band of the difference update, relative to |prev|.
    pub epsilon: f64,
    /// +x reward on improvement.
    pub x: f64,
    /// −y reward on regression (stored positive).
    pub y: f64,
    /// Averaging window n (MIs).
    pub window: usize,
}

impl Default for RewardConfig {
    fn default() -> Self {
        // K = 1.02, B = 25 reproduce the paper's §3.4 example: at
        // (cc, p) = (7, 7), T = 8.32 Gbps, L = 0 the utility score is ≈ 3.0.
        RewardConfig { k: 1.02, b: 25.0, sc: 10.0, epsilon: 0.03, x: 1.0, y: 1.0, window: 4 }
    }
}

/// The paper's utility function U(T, L) (Eq. 3/10).
pub fn utility(cfg: &RewardConfig, throughput_gbps: f64, plr: f64, cc: u32, p: u32) -> f64 {
    let n_streams = (cc as f64) * (p as f64);
    throughput_gbps / cfg.k.powf(n_streams) - throughput_gbps * plr * cfg.b
}

/// Difference-based reward shaping f(cur, prev) (§3.3.3).
pub fn diff_reward(cfg: &RewardConfig, cur: f64, prev: f64) -> f64 {
    let scale = prev.abs().max(1e-6);
    let delta = (cur - prev) / scale;
    if delta > cfg.epsilon {
        cfg.x
    } else if delta < -cfg.epsilon {
        -cfg.y
    } else {
        0.0
    }
}

/// Output of one reward update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RewardOut {
    /// The windowed objective metric (Ū_t or R̄_t) — the "utility score"
    /// that transition logs record.
    pub metric: f64,
    /// The shaped reward r_t handed to the agent.
    pub reward: f64,
}

/// Stateful reward computer for one transfer lane.
#[derive(Debug, Clone)]
pub struct RewardTracker {
    pub kind: RewardKind,
    pub cfg: RewardConfig,
    hist_util: VecDeque<f64>,
    hist_thr: VecDeque<f64>,
    hist_energy: VecDeque<f64>,
    prev_metric: Option<f64>,
}

impl RewardTracker {
    pub fn new(kind: RewardKind, cfg: RewardConfig) -> RewardTracker {
        RewardTracker {
            kind,
            cfg,
            hist_util: VecDeque::new(),
            hist_thr: VecDeque::new(),
            hist_energy: VecDeque::new(),
            prev_metric: None,
        }
    }

    /// Ingest one MI observation, returning the metric and shaped reward.
    pub fn update(&mut self, obs: &Observation) -> RewardOut {
        let w = self.cfg.window;
        let metric = match self.kind {
            RewardKind::FairnessEfficiency => {
                let u = utility(&self.cfg, obs.throughput_gbps, obs.plr, obs.cc, obs.p);
                push_cap(&mut self.hist_util, u, w);
                mean(&self.hist_util)
            }
            RewardKind::ThroughputEnergy => {
                push_cap(&mut self.hist_thr, obs.throughput_gbps, w);
                // Energy per MI; missing counters (NaN) degrade to
                // throughput-only signal with unit energy.
                let e = if obs.energy_j.is_nan() { 1.0 } else { obs.energy_j.max(1e-9) };
                push_cap(&mut self.hist_energy, e, w);
                let t_bar = mean(&self.hist_thr);
                let e_max = self.hist_energy.iter().cloned().fold(f64::MIN, f64::max);
                t_bar * self.cfg.sc / e_max
            }
        };
        let reward = match self.prev_metric {
            None => 0.0,
            Some(prev) => diff_reward(&self.cfg, metric, prev),
        };
        self.prev_metric = Some(metric);
        RewardOut { metric, reward }
    }

    pub fn reset(&mut self) {
        self.hist_util.clear();
        self.hist_thr.clear();
        self.hist_energy.clear();
        self.prev_metric = None;
    }

    /// Capture the tracker's mutable state for checkpointing (`kind` and
    /// `cfg` are rebuild-time constants).
    pub fn export_state(&self) -> TrackerState {
        TrackerState {
            hist_util: self.hist_util.iter().copied().collect(),
            hist_thr: self.hist_thr.iter().copied().collect(),
            hist_energy: self.hist_energy.iter().copied().collect(),
            prev_metric: self.prev_metric,
        }
    }

    /// Restore a [`RewardTracker::export_state`] capture.
    pub fn import_state(&mut self, state: &TrackerState) {
        self.hist_util = state.hist_util.iter().copied().collect();
        self.hist_thr = state.hist_thr.iter().copied().collect();
        self.hist_energy = state.hist_energy.iter().copied().collect();
        self.prev_metric = state.prev_metric;
    }
}

/// A captured [`RewardTracker`]: the three metric histories (oldest first)
/// and the previous windowed metric.
#[derive(Debug, Clone, PartialEq)]
pub struct TrackerState {
    pub hist_util: Vec<f64>,
    pub hist_thr: Vec<f64>,
    pub hist_energy: Vec<f64>,
    pub prev_metric: Option<f64>,
}

fn push_cap(q: &mut VecDeque<f64>, v: f64, cap: usize) {
    q.push_back(v);
    while q.len() > cap {
        q.pop_front();
    }
}

fn mean(q: &VecDeque<f64>) -> f64 {
    if q.is_empty() { 0.0 } else { q.iter().sum::<f64>() / q.len() as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(thr: f64, plr: f64, e: f64, cc: u32, p: u32) -> Observation {
        Observation {
            throughput_gbps: thr,
            plr,
            rtt_s: 0.032,
            energy_j: e,
            cc,
            p,
            duration_s: 1.0,
        }
    }

    #[test]
    fn utility_matches_paper_example() {
        // §3.4 example log line: T = 8.32 Gbps, L = 0, cc = p = 7, score 3.0.
        let cfg = RewardConfig::default();
        let u = utility(&cfg, 8.32, 0.0, 7, 7);
        assert!((u - 3.0).abs() < 0.25, "u={u}");
    }

    #[test]
    fn utility_penalizes_loss() {
        let cfg = RewardConfig::default();
        let clean = utility(&cfg, 8.0, 0.0, 4, 4);
        let lossy = utility(&cfg, 8.0, 0.02, 4, 4);
        assert!(lossy < clean);
    }

    #[test]
    fn utility_penalizes_stream_hoarding() {
        let cfg = RewardConfig::default();
        // Same throughput with many more streams is worth less (fairness).
        let lean = utility(&cfg, 8.0, 0.0, 4, 4);
        let hog = utility(&cfg, 8.0, 0.0, 16, 16);
        assert!(hog < lean * 0.2, "lean={lean} hog={hog}");
    }

    #[test]
    fn diff_reward_signs() {
        let cfg = RewardConfig::default();
        assert_eq!(diff_reward(&cfg, 1.10, 1.00), cfg.x);
        assert_eq!(diff_reward(&cfg, 0.90, 1.00), -cfg.y);
        assert_eq!(diff_reward(&cfg, 1.001, 1.000), 0.0); // within ε
    }

    #[test]
    fn fe_tracker_rewards_improvement() {
        let mut t = RewardTracker::new(RewardKind::FairnessEfficiency, RewardConfig::default());
        t.update(&obs(2.0, 0.0, 100.0, 4, 4));
        // Large jump in throughput -> positive reward.
        let out = t.update(&obs(6.0, 0.0, 100.0, 4, 4));
        assert_eq!(out.reward, 1.0);
    }

    #[test]
    fn te_tracker_rewards_energy_efficiency() {
        let cfg = RewardConfig { window: 1, ..RewardConfig::default() };
        let mut t = RewardTracker::new(RewardKind::ThroughputEnergy, cfg);
        t.update(&obs(5.0, 0.0, 200.0, 8, 8));
        // Same throughput at half the energy -> improvement.
        let out = t.update(&obs(5.0, 0.0, 100.0, 4, 4));
        assert_eq!(out.reward, 1.0);
        // Same throughput at much higher energy -> regression.
        let out = t.update(&obs(5.0, 0.0, 400.0, 16, 16));
        assert_eq!(out.reward, -1.0);
    }

    #[test]
    fn te_tracker_handles_missing_counters() {
        let mut t = RewardTracker::new(RewardKind::ThroughputEnergy, RewardConfig::default());
        let out = t.update(&obs(5.0, 0.0, f64::NAN, 4, 4));
        assert!(out.metric.is_finite());
    }

    #[test]
    fn first_update_reward_zero() {
        let mut t = RewardTracker::new(RewardKind::FairnessEfficiency, RewardConfig::default());
        let out = t.update(&obs(5.0, 0.0, 100.0, 4, 4));
        assert_eq!(out.reward, 0.0);
    }

    #[test]
    fn windowed_metric_smooths() {
        let cfg = RewardConfig { window: 4, ..RewardConfig::default() };
        let mut t = RewardTracker::new(RewardKind::FairnessEfficiency, cfg.clone());
        for _ in 0..4 {
            t.update(&obs(8.0, 0.0, 100.0, 4, 4));
        }
        // One noisy bad MI barely moves the 4-MI average.
        let out = t.update(&obs(7.2, 0.0, 100.0, 4, 4));
        assert_eq!(out.reward, 0.0, "metric={}", out.metric);
    }
}
