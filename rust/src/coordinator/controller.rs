//! Batch compatibility layer over the step-driven [`Session`] API.
//!
//! [`Controller`] is the pre-redesign run-to-completion surface: fix every
//! lane up front, call [`Controller::run_all`], get a [`RunReport`]. It is
//! now a thin wrapper — lanes are admitted into a [`Session`], the run
//! drives [`Session::run_to_completion`], and the report is rebuilt from
//! the event stream by [`crate::telemetry::ReportSink`]. The wrapper
//! reproduces the batch-era numbers bit-for-bit (the session's MI body is
//! the old loop, verbatim), so every figure/table regenerates unchanged
//! while new code targets [`Session`] directly for dynamic admission,
//! churn workloads and streaming telemetry.

use super::reward::{RewardConfig, RewardKind};
use super::session::{LaneSpec, Session, SessionBuilder, DEFAULT_MAX_MIS};
use super::{actions::ParamBounds, MiRecord, Optimizer};
use crate::energy::RailEnergy;
use crate::net::background::Background;
use crate::net::{Testbed, Topology};
use crate::telemetry::ReportSink;
use crate::transfer::{EngineProfile, TransferJob};
use crate::util::stats;

/// Per-lane results of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneReport {
    pub name: String,
    pub records: Vec<MiRecord>,
    pub completed: bool,
    pub duration_s: f64,
    pub total_energy_j: f64,
    pub bytes_delivered: f64,
}

impl LaneReport {
    /// Mean goodput over the lane's active MIs, Gbps.
    pub fn avg_throughput_gbps(&self) -> f64 {
        stats::mean(&self.records.iter().map(|r| r.throughput_gbps).collect::<Vec<_>>())
    }

    pub fn avg_plr(&self) -> f64 {
        stats::mean(&self.records.iter().map(|r| r.plr).collect::<Vec<_>>())
    }

    pub fn total_reward(&self) -> f64 {
        self.records.iter().map(|r| r.reward).sum()
    }

    /// Energy per delivered gigabyte, J/GB.
    pub fn energy_per_gb(&self) -> f64 {
        if self.bytes_delivered <= 0.0 {
            return 0.0;
        }
        self.total_energy_j / (self.bytes_delivered / 1e9)
    }

    /// Per-rail energy attributed to this lane, summed over its records
    /// (None on the lumped compat rail, where records carry no breakdown).
    pub fn rail_totals(&self) -> Option<RailEnergy> {
        let mut total = RailEnergy::default();
        let mut any = false;
        for r in &self.records {
            if let Some(rails) = &r.rails {
                total.add(rails);
                any = true;
            }
        }
        if any {
            Some(total)
        } else {
            None
        }
    }

    pub fn throughput_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.throughput_gbps).collect()
    }
}

/// Results of a full run (all lanes). `PartialEq` supports the
/// bit-identical-reports guarantee of the parallel trial runner.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub lanes: Vec<LaneReport>,
    pub duration_s: f64,
    /// Per-MI Jain's fairness index across lanes active in that MI.
    pub jfi_series: Vec<f64>,
}

impl RunReport {
    /// Convenience for single-lane runs.
    pub fn lane(&self) -> &LaneReport {
        &self.lanes[0]
    }

    pub fn avg_throughput_gbps(&self) -> f64 {
        self.lane().avg_throughput_gbps()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.lanes.iter().map(|l| l.total_energy_j).sum()
    }

    pub fn avg_jfi(&self) -> f64 {
        stats::mean(&self.jfi_series)
    }
}

/// Builder for [`Controller`] (the batch-era knobs, unchanged).
pub struct ControllerBuilder {
    inner: SessionBuilder,
    max_mis: usize,
    // Single-lane convenience state.
    job: Option<TransferJob>,
    reward_kind: RewardKind,
    engine: EngineProfile,
}

impl ControllerBuilder {
    pub fn background(mut self, bg: Background) -> Self {
        self.inner = self.inner.background(bg);
        self
    }

    /// Run over a multi-segment path instead of the testbed's single
    /// bottleneck (see [`crate::net::Topology`]; scenario presets use this).
    pub fn topology(mut self, t: Topology) -> Self {
        self.inner = self.inner.topology(t);
        self
    }

    pub fn mi(mut self, seconds: f64) -> Self {
        self.inner = self.inner.mi(seconds);
        self
    }

    pub fn bounds(mut self, b: ParamBounds) -> Self {
        self.inner = self.inner.bounds(b);
        self
    }

    pub fn reward_cfg(mut self, c: RewardConfig) -> Self {
        self.inner = self.inner.reward_cfg(c);
        self
    }

    /// See [`SessionBuilder::substrate`] — run over an explicitly built
    /// substrate (alternate backends; the golden-replay suite and `sparta
    /// bench` inject the frozen pre-arena loop here).
    pub fn substrate(mut self, sub: Box<dyn crate::net::Substrate>) -> Self {
        self.inner = self.inner.substrate(sub);
        self
    }

    pub fn max_mis(mut self, n: usize) -> Self {
        self.max_mis = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.inner = self.inner.seed(s);
        self
    }

    /// State-window length n (MIs).
    pub fn history(mut self, n: usize) -> Self {
        self.inner = self.inner.history(n);
        self
    }

    pub fn job(mut self, j: TransferJob) -> Self {
        self.job = Some(j);
        self
    }

    pub fn reward(mut self, k: RewardKind) -> Self {
        self.reward_kind = k;
        self
    }

    pub fn engine(mut self, e: EngineProfile) -> Self {
        self.engine = e;
        self
    }

    pub fn build(self) -> Controller {
        Controller {
            session: self.inner.build(),
            max_mis: self.max_mis,
            sink: ReportSink::new(),
            default_job: self.job,
            default_reward: self.reward_kind,
            default_engine: self.engine,
        }
    }
}

/// Run-to-completion wrapper over a [`Session`].
pub struct Controller {
    session: Session,
    max_mis: usize,
    /// Persistent across `run`/`run_all` calls so sequential batch runs
    /// accumulate every lane's history, like the pre-redesign controller.
    sink: ReportSink,
    default_job: Option<TransferJob>,
    default_reward: RewardKind,
    default_engine: EngineProfile,
}

impl Controller {
    pub fn builder(testbed: Testbed) -> ControllerBuilder {
        ControllerBuilder {
            inner: Session::builder(testbed),
            max_mis: DEFAULT_MAX_MIS,
            job: None,
            reward_kind: RewardKind::ThroughputEnergy,
            engine: EngineProfile::efficient(),
        }
    }

    /// Add a transfer lane; returns its index.
    pub fn add_lane(
        &mut self,
        optimizer: Box<dyn Optimizer>,
        job: TransferJob,
        engine: EngineProfile,
        reward_kind: RewardKind,
    ) -> usize {
        self.session
            .admit(LaneSpec::new(optimizer, job).engine(engine).reward(reward_kind))
            .0
    }

    /// Single-lane convenience: add `optimizer` with the builder's default
    /// job/engine/reward and run to completion.
    pub fn run(&mut self, optimizer: Box<dyn Optimizer>, _seed: u64) -> RunReport {
        let job = self.default_job.clone().expect("builder .job() not set");
        let engine = self.default_engine.clone();
        let kind = self.default_reward;
        self.add_lane(optimizer, job, engine, kind);
        self.run_all()
    }

    /// Run every lane until completion (or `max_mis` further MIs). Each
    /// call gets a fresh MI budget and the report accumulates every lane
    /// ever admitted, so sequential `run()` calls behave like the
    /// pre-redesign batch controller.
    pub fn run_all(&mut self) -> RunReport {
        let budget = self.session.mi() + self.max_mis;
        self.session.run_to_completion(budget, &mut self.sink);
        self.sink.clone().finish(self.session.time_s())
    }

    /// The underlying step-driven session (for callers that start batch
    /// and then need dynamic admission or external pause/resume). Events
    /// are streamed, not stored: anything consumed through a direct
    /// `session().step()` call here will not reappear in a later
    /// [`Controller::run_all`] report — drive the session yourself with a
    /// [`crate::telemetry::ReportSink`] if you need the full history.
    pub fn session(&mut self) -> &mut Session {
        &mut self.session
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticTool;

    fn quick_job() -> TransferJob {
        // 8 x 256 MB — completes in tens of simulated seconds at Gbps rates.
        TransferJob::files(8, 256 << 20)
    }

    #[test]
    fn static_tool_completes_job() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .job(quick_job())
            .seed(3)
            .build();
        let report = ctl.run(Box::new(StaticTool::rclone()), 3);
        let lane = report.lane();
        assert!(lane.completed, "transfer did not complete");
        assert!(lane.avg_throughput_gbps() > 1.0);
        assert!(lane.total_energy_j > 0.0);
        assert!((lane.bytes_delivered - 8.0 * (256u64 << 20) as f64).abs() < 1.0);
    }

    #[test]
    fn cc_p_held_static_by_static_tool() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .job(quick_job())
            .build();
        let report = ctl.run(Box::new(StaticTool::rclone()), 1);
        for r in &report.lane().records {
            assert_eq!((r.cc, r.p), (4, 4));
        }
    }

    #[test]
    fn fabric_reports_nan_energy() {
        let mut ctl = Controller::builder(Testbed::fabric())
            .background(Background::Idle)
            .job(quick_job())
            .build();
        let report = ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 1);
        assert!(report.lane().records.iter().all(|r| r.energy_j.is_nan()));
        assert_eq!(report.lane().total_energy_j, 0.0);
    }

    #[test]
    fn two_lanes_share_and_both_finish() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .max_mis(4000)
            .build();
        ctl.add_lane(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        );
        ctl.add_lane(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        );
        let report = ctl.run_all();
        assert!(report.lanes.iter().all(|l| l.completed));
        assert!(report.avg_jfi() > 0.8, "jfi={}", report.avg_jfi());
    }

    #[test]
    fn controller_runs_over_multi_segment_topology() {
        let tb = Testbed::chameleon();
        let topo = Topology::three_stage(&tb, 5.0, tb.capacity_gbps);
        let mut ctl = Controller::builder(tb)
            .topology(topo)
            .background(Background::Idle)
            .job(quick_job())
            .seed(9)
            .build();
        let report = ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 9);
        assert!(report.lane().completed);
        // The 5 Gbps NIC stage caps the transfer below the 10 Gbps WAN.
        assert!(report.lane().avg_throughput_gbps() <= 5.05);
    }

    #[test]
    fn report_durations_monotone_with_job_size() {
        let run = |files: usize| {
            let mut ctl = Controller::builder(Testbed::chameleon())
                .background(Background::Idle)
                .job(TransferJob::files(files, 256 << 20))
                .seed(5)
                .build();
            ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 5).lane().duration_s
        };
        assert!(run(16) > run(4));
    }

    /// Sequential `run()` calls on one controller accumulate every lane's
    /// full history, like the pre-redesign batch API.
    #[test]
    fn sequential_runs_accumulate_full_reports() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .job(quick_job())
            .seed(13)
            .build();
        let r1 = ctl.run(Box::new(StaticTool::rclone()), 13);
        assert_eq!(r1.lanes.len(), 1);
        assert!(r1.lane().completed);
        let r2 = ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 13);
        assert_eq!(r2.lanes.len(), 2);
        assert!(r2.lanes.iter().all(|l| l.completed), "first lane ghosted");
        assert_eq!(r2.lanes[0].name, "rclone");
        assert_eq!(r2.lanes[0].records, r1.lanes[0].records);
    }

    /// The compat wrapper exposes the session: a batch-built controller can
    /// still admit lanes dynamically through it. Events consumed by the
    /// direct `step()` calls are gone from the later `run_all` report (the
    /// stream is not replayed), so the first lane's job must be big enough
    /// (16 GB vs the 1.25 GB/MI capacity bound) that it cannot complete —
    /// and thus emit its terminal event — inside the discarded steps.
    #[test]
    fn session_escape_hatch_admits_mid_run() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .max_mis(4000)
            .build();
        ctl.add_lane(
            Box::new(StaticTool::efficient_static(4, 4)),
            TransferJob::files(64, 256 << 20),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        );
        for _ in 0..5 {
            ctl.session().step();
        }
        ctl.session().admit(LaneSpec::new(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
        ));
        let report = ctl.run_all();
        assert_eq!(report.lanes.len(), 2);
        assert!(report.lanes.iter().all(|l| l.completed));
    }
}
