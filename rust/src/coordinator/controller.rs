//! The monitoring-interval control loop.
//!
//! A [`Controller`] owns one network substrate (held as `Box<dyn Substrate>`,
//! so single-bottleneck testbeds, multi-segment scenario topologies and any
//! future substrate all drive the same loop) and any number of *lanes*
//! (transfer applications): each lane couples a transfer job, an engine
//! profile, an energy meter, a reward tracker and an [`Optimizer`]. Each MI
//! the controller advances the shared network, updates every lane's state
//! window, feeds rewards back to learning optimizers, and applies their
//! (cc, p) decisions via pause/resume.

use super::actions::ParamBounds;
use super::reward::{RewardConfig, RewardKind, RewardTracker};
use super::state::{FeatureWindow, Observation};
use super::{Decision, MiContext, Optimizer};
use crate::energy::EnergyMeter;
use crate::net::background::Background;
use crate::net::{FlowId, NetworkSim, Substrate, Testbed, Topology};
use crate::transfer::{EngineProfile, TransferJob};
use crate::util::stats;

/// Everything recorded about one lane during one monitoring interval.
#[derive(Debug, Clone, PartialEq)]
pub struct MiRecord {
    pub mi: usize,
    pub time_s: f64,
    pub throughput_gbps: f64,
    pub plr: f64,
    pub rtt_s: f64,
    pub energy_j: f64,
    pub cc: u32,
    pub p: u32,
    /// Windowed objective metric (utility score / T-per-E).
    pub metric: f64,
    /// Shaped reward handed to the optimizer.
    pub reward: f64,
    /// Discrete action taken *at the end of* this MI (None for baselines
    /// that set (cc, p) directly).
    pub action: Option<usize>,
    /// Flattened state window after ingesting this MI.
    pub state: Vec<f32>,
}

/// Per-lane results of a run.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneReport {
    pub name: String,
    pub records: Vec<MiRecord>,
    pub completed: bool,
    pub duration_s: f64,
    pub total_energy_j: f64,
    pub bytes_delivered: f64,
}

impl LaneReport {
    /// Mean goodput over the lane's active MIs, Gbps.
    pub fn avg_throughput_gbps(&self) -> f64 {
        stats::mean(&self.records.iter().map(|r| r.throughput_gbps).collect::<Vec<_>>())
    }

    pub fn avg_plr(&self) -> f64 {
        stats::mean(&self.records.iter().map(|r| r.plr).collect::<Vec<_>>())
    }

    pub fn total_reward(&self) -> f64 {
        self.records.iter().map(|r| r.reward).sum()
    }

    /// Energy per delivered gigabyte, J/GB.
    pub fn energy_per_gb(&self) -> f64 {
        if self.bytes_delivered <= 0.0 {
            return 0.0;
        }
        self.total_energy_j / (self.bytes_delivered / 1e9)
    }

    pub fn throughput_series(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.throughput_gbps).collect()
    }
}

/// Results of a full run (all lanes). `PartialEq` supports the
/// bit-identical-reports guarantee of the parallel trial runner.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub lanes: Vec<LaneReport>,
    pub duration_s: f64,
    /// Per-MI Jain's fairness index across lanes active in that MI.
    pub jfi_series: Vec<f64>,
}

impl RunReport {
    /// Convenience for single-lane runs.
    pub fn lane(&self) -> &LaneReport {
        &self.lanes[0]
    }

    pub fn avg_throughput_gbps(&self) -> f64 {
        self.lane().avg_throughput_gbps()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.lanes.iter().map(|l| l.total_energy_j).sum()
    }

    pub fn avg_jfi(&self) -> f64 {
        stats::mean(&self.jfi_series)
    }
}

struct Lane {
    flow: FlowId,
    optimizer: Box<dyn Optimizer>,
    job: TransferJob,
    window: FeatureWindow,
    reward: RewardTracker,
    meter: EnergyMeter,
    cc: u32,
    p: u32,
    has_pending_decision: bool,
    records: Vec<MiRecord>,
    done: bool,
    done_at_s: f64,
}

/// Builder for [`Controller`].
pub struct ControllerBuilder {
    testbed: Testbed,
    background: Option<Background>,
    topology: Option<Topology>,
    mi_s: f64,
    bounds: ParamBounds,
    reward_cfg: RewardConfig,
    max_mis: usize,
    seed: u64,
    history: usize,
    // Single-lane convenience state.
    job: Option<TransferJob>,
    reward_kind: RewardKind,
    engine: EngineProfile,
}

impl ControllerBuilder {
    pub fn background(mut self, bg: Background) -> Self {
        self.background = Some(bg);
        self
    }

    /// Run over a multi-segment path instead of the testbed's single
    /// bottleneck (see [`crate::net::Topology`]; scenario presets use this).
    pub fn topology(mut self, t: Topology) -> Self {
        self.topology = Some(t);
        self
    }

    pub fn mi(mut self, seconds: f64) -> Self {
        self.mi_s = seconds;
        self
    }

    pub fn bounds(mut self, b: ParamBounds) -> Self {
        self.bounds = b;
        self
    }

    pub fn reward_cfg(mut self, c: RewardConfig) -> Self {
        self.reward_cfg = c;
        self
    }

    pub fn max_mis(mut self, n: usize) -> Self {
        self.max_mis = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// State-window length n (MIs).
    pub fn history(mut self, n: usize) -> Self {
        self.history = n;
        self
    }

    pub fn job(mut self, j: TransferJob) -> Self {
        self.job = Some(j);
        self
    }

    pub fn reward(mut self, k: RewardKind) -> Self {
        self.reward_kind = k;
        self
    }

    pub fn engine(mut self, e: EngineProfile) -> Self {
        self.engine = e;
        self
    }

    pub fn build(self) -> Controller {
        let mut sim = match &self.topology {
            Some(t) => NetworkSim::from_topology(self.testbed.clone(), t, self.seed),
            None => NetworkSim::new(self.testbed.clone(), self.seed),
        };
        if let Some(bg) = self.background.clone() {
            sim = sim.with_background(bg);
        }
        Controller {
            sim: Box::new(sim),
            testbed: self.testbed,
            mi_s: self.mi_s,
            bounds: self.bounds,
            reward_cfg: self.reward_cfg,
            max_mis: self.max_mis,
            seed: self.seed,
            history: self.history,
            lanes: Vec::new(),
            default_job: self.job,
            default_reward: self.reward_kind,
            default_engine: self.engine,
        }
    }
}

/// The MI control loop over one network substrate.
pub struct Controller {
    sim: Box<dyn Substrate>,
    testbed: Testbed,
    mi_s: f64,
    pub bounds: ParamBounds,
    reward_cfg: RewardConfig,
    max_mis: usize,
    seed: u64,
    history: usize,
    lanes: Vec<Lane>,
    default_job: Option<TransferJob>,
    default_reward: RewardKind,
    default_engine: EngineProfile,
}

impl Controller {
    pub fn builder(testbed: Testbed) -> ControllerBuilder {
        ControllerBuilder {
            testbed,
            background: None,
            topology: None,
            mi_s: 1.0,
            bounds: ParamBounds::default(),
            reward_cfg: RewardConfig::default(),
            max_mis: 3000,
            seed: 1,
            history: 8,
            job: None,
            reward_kind: RewardKind::ThroughputEnergy,
            engine: EngineProfile::efficient(),
        }
    }

    /// Add a transfer lane; returns its index.
    pub fn add_lane(
        &mut self,
        mut optimizer: Box<dyn Optimizer>,
        job: TransferJob,
        engine: EngineProfile,
        reward_kind: RewardKind,
    ) -> usize {
        let (cc0, p0) = optimizer.start(&self.bounds);
        let (cc0, p0) = self.bounds.clamp(cc0, p0);
        let io = engine.task_io_gbps(self.testbed.task_io_gbps);
        let flow = self.sim.add_flow(cc0, p0, Some(io));
        let window = FeatureWindow::new(self.history, self.bounds.cc_max, self.bounds.p_max);
        let meter_seed = self.seed.wrapping_mul(0x9E37).wrapping_add(self.lanes.len() as u64);
        let lane = Lane {
            flow,
            optimizer,
            job,
            window,
            reward: RewardTracker::new(reward_kind, self.reward_cfg.clone()),
            meter: EnergyMeter::new(engine.power.clone(), meter_seed),
            cc: cc0,
            p: p0,
            has_pending_decision: false,
            records: Vec::new(),
            done: false,
            done_at_s: 0.0,
        };
        self.lanes.push(lane);
        self.lanes.len() - 1
    }

    /// Single-lane convenience: add `optimizer` with the builder's default
    /// job/engine/reward and run to completion.
    pub fn run(&mut self, optimizer: Box<dyn Optimizer>, _seed: u64) -> RunReport {
        let job = self.default_job.clone().expect("builder .job() not set");
        let engine = self.default_engine.clone();
        let kind = self.default_reward;
        self.add_lane(optimizer, job, engine, kind);
        self.run_all()
    }

    /// Run every lane until completion (or `max_mis`).
    pub fn run_all(&mut self) -> RunReport {
        let has_energy = self.testbed.has_energy_counters;
        for mi in 0..self.max_mis {
            if self.lanes.iter().all(|l| l.done) {
                break;
            }
            // Cap demand of nearly-finished lanes so they don't overshoot.
            for lane in &self.lanes {
                if lane.done {
                    self.sim.set_demand_cap(lane.flow, 0.0);
                } else {
                    let cap = lane.job.remaining_bytes() * 8.0 / self.mi_s / 1e9;
                    self.sim.set_demand_cap(lane.flow, cap.max(0.05));
                }
            }
            let metrics = self.sim.run_mi(self.mi_s);
            let time_s = self.sim.time_s();
            let mut decisions: Vec<Option<(usize, Decision)>> = Vec::new();
            for (li, lane) in self.lanes.iter_mut().enumerate() {
                if lane.done {
                    decisions.push(None);
                    continue;
                }
                let m = &metrics[lane.flow.0];
                lane.job.advance(m.bytes_delivered);
                let energy = if has_energy {
                    lane.meter.record_mi(m.active_streams, m.throughput_gbps, m.duration_s)
                } else {
                    f64::NAN
                };
                let obs = Observation {
                    throughput_gbps: m.throughput_gbps,
                    plr: m.plr,
                    rtt_s: m.rtt_s,
                    energy_j: energy,
                    cc: lane.cc,
                    p: lane.p,
                    duration_s: m.duration_s,
                };
                lane.window.push(&obs);
                let out = lane.reward.update(&obs);
                let done_now = lane.job.is_complete();
                if lane.has_pending_decision {
                    lane.optimizer.learn(out.reward, lane.window.state(), done_now);
                }
                let mut action = None;
                if done_now {
                    lane.done = true;
                    lane.done_at_s = time_s;
                    lane.has_pending_decision = false;
                } else {
                    let ctx = MiContext {
                        state: lane.window.state(),
                        obs: &obs,
                        cc: lane.cc,
                        p: lane.p,
                        bounds: &self.bounds,
                        mi_index: mi,
                    };
                    let d = lane.optimizer.decide(&ctx);
                    action = d.action;
                    decisions.push(Some((li, d)));
                    lane.has_pending_decision = true;
                }
                if done_now {
                    decisions.push(None);
                }
                lane.records.push(MiRecord {
                    mi,
                    time_s,
                    throughput_gbps: m.throughput_gbps,
                    plr: m.plr,
                    rtt_s: m.rtt_s,
                    energy_j: energy,
                    cc: lane.cc,
                    p: lane.p,
                    metric: out.metric,
                    reward: out.reward,
                    action,
                    state: lane.window.state().to_vec(),
                });
            }
            // Apply decisions after all lanes observed this MI.
            for d in decisions.into_iter().flatten() {
                let (li, dec) = d;
                let (cc, p) = self.bounds.clamp(dec.cc, dec.p);
                let lane = &mut self.lanes[li];
                if cc != lane.cc || p != lane.p {
                    self.sim.set_cc_p(lane.flow, cc, p);
                    lane.cc = cc;
                    lane.p = p;
                }
            }
        }
        self.report()
    }

    fn report(&self) -> RunReport {
        let mut lanes = Vec::new();
        for lane in &self.lanes {
            lanes.push(LaneReport {
                name: lane.optimizer.name().to_string(),
                records: lane.records.clone(),
                completed: lane.done,
                duration_s: if lane.done {
                    lane.done_at_s
                } else {
                    self.sim.time_s()
                },
                total_energy_j: lane.meter.total_j(),
                bytes_delivered: lane.job.delivered_bytes(),
            });
        }
        // JFI per MI over lanes active at that MI.
        let max_len = lanes.iter().map(|l| l.records.len()).max().unwrap_or(0);
        let mut jfi_series = Vec::with_capacity(max_len);
        for i in 0..max_len {
            let thrs: Vec<f64> = lanes
                .iter()
                .filter_map(|l| l.records.get(i).map(|r| r.throughput_gbps))
                .collect();
            jfi_series.push(stats::jain_fairness(&thrs));
        }
        RunReport { lanes, duration_s: self.sim.time_s(), jfi_series }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticTool;

    fn quick_job() -> TransferJob {
        // 8 x 256 MB — completes in tens of simulated seconds at Gbps rates.
        TransferJob::files(8, 256 << 20)
    }

    #[test]
    fn static_tool_completes_job() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .job(quick_job())
            .seed(3)
            .build();
        let report = ctl.run(Box::new(StaticTool::rclone()), 3);
        let lane = report.lane();
        assert!(lane.completed, "transfer did not complete");
        assert!(lane.avg_throughput_gbps() > 1.0);
        assert!(lane.total_energy_j > 0.0);
        assert!((lane.bytes_delivered - 8.0 * (256u64 << 20) as f64).abs() < 1.0);
    }

    #[test]
    fn cc_p_held_static_by_static_tool() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .job(quick_job())
            .build();
        let report = ctl.run(Box::new(StaticTool::rclone()), 1);
        for r in &report.lane().records {
            assert_eq!((r.cc, r.p), (4, 4));
        }
    }

    #[test]
    fn fabric_reports_nan_energy() {
        let mut ctl = Controller::builder(Testbed::fabric())
            .background(Background::Idle)
            .job(quick_job())
            .build();
        let report = ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 1);
        assert!(report.lane().records.iter().all(|r| r.energy_j.is_nan()));
        assert_eq!(report.lane().total_energy_j, 0.0);
    }

    #[test]
    fn two_lanes_share_and_both_finish() {
        let mut ctl = Controller::builder(Testbed::chameleon())
            .background(Background::Idle)
            .max_mis(4000)
            .build();
        ctl.add_lane(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        );
        ctl.add_lane(
            Box::new(StaticTool::efficient_static(4, 4)),
            quick_job(),
            EngineProfile::efficient(),
            RewardKind::ThroughputEnergy,
        );
        let report = ctl.run_all();
        assert!(report.lanes.iter().all(|l| l.completed));
        assert!(report.avg_jfi() > 0.8, "jfi={}", report.avg_jfi());
    }

    #[test]
    fn controller_runs_over_multi_segment_topology() {
        let tb = Testbed::chameleon();
        let topo = Topology::three_stage(&tb, 5.0, tb.capacity_gbps);
        let mut ctl = Controller::builder(tb)
            .topology(topo)
            .background(Background::Idle)
            .job(quick_job())
            .seed(9)
            .build();
        let report = ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 9);
        assert!(report.lane().completed);
        // The 5 Gbps NIC stage caps the transfer below the 10 Gbps WAN.
        assert!(report.lane().avg_throughput_gbps() <= 5.05);
    }

    #[test]
    fn report_durations_monotone_with_job_size() {
        let run = |files: usize| {
            let mut ctl = Controller::builder(Testbed::chameleon())
                .background(Background::Idle)
                .job(TransferJob::files(files, 256 << 20))
                .seed(5)
                .build();
            ctl.run(Box::new(StaticTool::efficient_static(4, 4)), 5).lane().duration_s
        };
        assert!(run(16) > run(4));
    }
}
