//! The emulated training environment (§3.4 of the paper).
//!
//! Real online DRL training would pay for every exploratory monitoring
//! interval with wall-clock time and wasted energy. Instead, SPARTA:
//!
//! 1. runs a short *exploration* phase against the real substrate, logging a
//!    per-MI transition line (the paper's log format) —
//!    `<ts> -- INFO: Throughput:8.32Gbps lossRate:0 parallelism:7
//!    concurrency:7 score:3.0 rtt:34.6ms energy:80.0J`;
//! 2. clusters the `(state, action)` pairs with k-means, each centroid
//!    representing a recurring "network scenario";
//! 3. replays training episodes against a *lookup environment* that, for the
//!    agent's `(x_t, a_t)`, finds the nearest cluster and uniformly samples
//!    one of its recorded outcomes — variability included, physics not
//!    re-simulated.

pub mod cluster_env;
pub mod env;
pub mod kmeans;
pub mod transition;

pub use cluster_env::ClusterEnv;
pub use env::{Env, StepOut};
pub use kmeans::KMeans;
pub use transition::{transitions_from_records, Transition, TransitionStore};
