//! Cluster-lookup training environment (§3.4 steps 1–3).

use super::env::{Env, StepOut};
use super::kmeans::KMeans;
use super::transition::Transition;
use crate::coordinator::{
    FeatureWindow, Observation, ParamBounds, RewardConfig, RewardKind, RewardTracker, FEATURES,
};
use crate::util::Rng;

/// Emulated environment built from logged transitions.
pub struct ClusterEnv {
    transitions: Vec<Transition>,
    km: KMeans,
    members: Vec<Vec<usize>>,
    bounds: ParamBounds,
    window: FeatureWindow,
    reward: RewardTracker,
    episode_len: usize,
    rng: Rng,
    // Episode state.
    cc: u32,
    p: u32,
    cur_features: [f32; FEATURES],
    steps: usize,
}

impl ClusterEnv {
    /// Cluster `transitions` into `k` scenarios and build the lookup env.
    pub fn new(
        transitions: Vec<Transition>,
        k: usize,
        bounds: ParamBounds,
        reward_kind: RewardKind,
        history: usize,
        episode_len: usize,
        seed: u64,
    ) -> ClusterEnv {
        assert!(!transitions.is_empty(), "ClusterEnv needs at least one transition");
        let dim = FEATURES + 1;
        let mut points = Vec::with_capacity(transitions.len() * dim);
        for t in &transitions {
            points.extend_from_slice(&t.cluster_key());
        }
        let km = KMeans::fit(&points, dim, k, 40, seed ^ 0xD00D);
        let members = km.members();
        let window = FeatureWindow::new(history, bounds.cc_max, bounds.p_max);
        ClusterEnv {
            transitions,
            km,
            members,
            bounds,
            window,
            reward: RewardTracker::new(reward_kind, RewardConfig::default()),
            episode_len,
            rng: Rng::new(seed),
            cc: 4,
            p: 4,
            cur_features: [0.0; FEATURES],
            steps: 0,
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.km.k
    }

    pub fn n_transitions(&self) -> usize {
        self.transitions.len()
    }

    /// Observation assembled from a sampled transition outcome at (cc, p).
    fn obs_from(&self, t: &Transition, cc: u32, p: u32) -> Observation {
        Observation {
            throughput_gbps: t.throughput_gbps,
            plr: t.plr,
            rtt_s: t.rtt_s,
            energy_j: t.energy_j,
            cc,
            p,
            duration_s: 1.0,
        }
    }

    /// Track the emulated features: take the sampled next-state congestion
    /// signals but pin the (cc, p) dimensions to the values we actually hold
    /// (lookup noise must not corrupt the parameter trajectory).
    fn update_features(&mut self, sampled: &Transition) {
        self.cur_features = sampled.next_features;
        self.cur_features[3] = self.cc as f32 / self.bounds.cc_max as f32;
        self.cur_features[4] = self.p as f32 / self.bounds.p_max as f32;
    }
}

impl Env for ClusterEnv {
    fn reset(&mut self) -> Vec<f32> {
        self.window.reset();
        self.reward.reset();
        self.steps = 0;
        // Initialization: random recorded state (§3.4 "Initialization").
        let idx = self.rng.below(self.transitions.len());
        let t = self.transitions[idx].clone();
        let (cc, p) = self.bounds.clamp(t.cc, t.p);
        self.cc = cc;
        self.p = p;
        self.update_features(&t);
        let obs = self.obs_from(&t, cc, p);
        self.window.push(&obs);
        self.reward.update(&obs);
        self.window.state().to_vec()
    }

    fn step(&mut self, action: usize) -> StepOut {
        // Apply the action to our (cc, p) with clipping.
        let (cc, p) = self.bounds.apply(self.cc, self.p, action);
        self.cc = cc;
        self.p = p;

        // Action selection + uniform sampling (§3.4 steps 2–3).
        let mut query = self.cur_features.to_vec();
        query.push(action as f32 / 4.0);
        let cluster = self.km.assign(&query);
        let pool = &self.members[cluster];
        let sampled_idx = if pool.is_empty() {
            self.rng.below(self.transitions.len())
        } else {
            pool[self.rng.below(pool.len())]
        };
        let t = self.transitions[sampled_idx].clone();

        self.update_features(&t);
        let obs = self.obs_from(&t, cc, p);
        self.window.push(&obs);
        let out = self.reward.update(&obs);
        self.steps += 1;
        StepOut {
            state: self.window.state().to_vec(),
            reward: out.reward,
            done: self.steps >= self.episode_len,
            throughput_gbps: t.throughput_gbps,
            energy_j: t.energy_j,
        }
    }

    fn state_len(&self) -> usize {
        self.window.state_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic transition set: throughput rises with cc·p up to 36 streams
    /// then collapses; energy rises with streams.
    fn synth_transitions(n: usize, seed: u64) -> Vec<Transition> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for _ in 0..n {
            let cc = 1 + rng.below(16) as u32;
            let p = 1 + rng.below(16) as u32;
            let action = rng.below(5);
            let streams = (cc * p) as f64;
            let thr = if streams <= 36.0 {
                0.25 * streams
            } else {
                (9.0 - 0.01 * (streams - 36.0)).max(1.0)
            };
            let plr = if streams > 60.0 { 0.01 } else { 0.0 };
            let f = |cc: u32, p: u32| -> [f32; FEATURES] {
                [plr as f32, 0.0, 1.0, cc as f32 / 16.0, p as f32 / 16.0]
            };
            out.push(Transition {
                features: f(cc, p),
                action,
                next_features: f(cc, p),
                throughput_gbps: thr + rng.normal_mean_sd(0.0, 0.2),
                plr,
                rtt_s: 0.032,
                energy_j: 2.0 * (18.0 + 0.85 * streams.powf(0.9) + 6.0 * thr),
                score: thr / 2.0,
                cc,
                p,
            });
        }
        out
    }

    fn env(seed: u64) -> ClusterEnv {
        ClusterEnv::new(
            synth_transitions(2000, seed),
            32,
            ParamBounds::default(),
            RewardKind::ThroughputEnergy,
            8,
            64,
            seed,
        )
    }

    #[test]
    fn reset_returns_state_of_right_shape() {
        let mut e = env(1);
        let s = e.reset();
        assert_eq!(s.len(), 8 * FEATURES);
        assert_eq!(e.state_len(), s.len());
    }

    #[test]
    fn episode_terminates_at_length() {
        let mut e = env(2);
        e.reset();
        let mut done = false;
        for i in 0..64 {
            let out = e.step(0);
            done = out.done;
            if i < 63 {
                assert!(!done);
            }
        }
        assert!(done);
    }

    #[test]
    fn actions_move_cc_p_features() {
        let mut e = env(3);
        e.reset();
        let before = (e.cc, e.p);
        e.step(3); // +2/+2
        let after = (e.cc, e.p);
        assert!(after.0 >= before.0 && after.1 >= before.1);
        // State window's newest (cc, p) features reflect the tracked params.
        let s = e.window.state();
        let newest = &s[s.len() - FEATURES..];
        assert!((newest[3] - after.0 as f32 / 16.0).abs() < 1e-6);
    }

    #[test]
    fn sampled_outcomes_track_stream_count() {
        // At small cc·p the emulator should report small throughput, at the
        // sweet spot (~36 streams) larger throughput.
        let mut e = env(4);
        e.reset();
        e.cc = 2;
        e.p = 2;
        e.cur_features[3] = 2.0 / 16.0;
        e.cur_features[4] = 2.0 / 16.0;
        let small: f64 = (0..30).map(|_| e.step(0).throughput_gbps).sum::<f64>() / 30.0;
        e.cc = 6;
        e.p = 6;
        e.cur_features[3] = 6.0 / 16.0;
        e.cur_features[4] = 6.0 / 16.0;
        let sweet: f64 = (0..30).map(|_| e.step(0).throughput_gbps).sum::<f64>() / 30.0;
        assert!(sweet > small + 2.0, "small={small:.2} sweet={sweet:.2}");
    }

    #[test]
    fn deterministic_with_seed() {
        let run = |seed| {
            let mut e = env(seed);
            e.reset();
            (0..50).map(|i| e.step(i % 5).throughput_gbps).sum::<f64>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
