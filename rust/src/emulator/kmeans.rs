//! Seeded Lloyd's k-means with k-means++ initialization.
//!
//! Clusters transition keys `(x_t, a_t)` into recurring "network scenarios"
//! (§3.4). The assignment step can be delegated to the AOT-compiled Pallas
//! `kmeans_assign` kernel (see `python/compile/kernels/kmeans.py`); the
//! default implementation below is pure Rust so the emulator also works
//! before artifacts are built. `benches/micro.rs` compares the two.

use crate::util::Rng;

/// Fitted k-means model.
#[derive(Debug, Clone)]
pub struct KMeans {
    /// Flattened centroids, row-major [k × dim].
    pub centroids: Vec<f32>,
    pub k: usize,
    pub dim: usize,
    /// Cluster membership of each training point.
    pub assignments: Vec<usize>,
}

impl KMeans {
    /// Fit with at most `iters` Lloyd iterations. Points are row-major
    /// [n × dim]. Panics on empty input or k == 0.
    pub fn fit(points: &[f32], dim: usize, k: usize, iters: usize, seed: u64) -> KMeans {
        assert!(dim > 0 && k > 0);
        let n = points.len() / dim;
        assert!(n > 0, "kmeans on empty data");
        assert_eq!(points.len(), n * dim);
        let k = k.min(n);
        let mut rng = Rng::new(seed);

        // k-means++ seeding.
        let mut centroids = Vec::with_capacity(k * dim);
        let first = rng.below(n);
        centroids.extend_from_slice(&points[first * dim..(first + 1) * dim]);
        let mut d2: Vec<f64> = (0..n)
            .map(|i| sq_dist(&points[i * dim..(i + 1) * dim], &centroids[0..dim]))
            .collect();
        for _ in 1..k {
            let idx = rng.weighted(&d2);
            let c0 = centroids.len();
            centroids.extend_from_slice(&points[idx * dim..(idx + 1) * dim]);
            let new_c = &centroids[c0..c0 + dim];
            for i in 0..n {
                let d = sq_dist(&points[i * dim..(i + 1) * dim], new_c);
                if d < d2[i] {
                    d2[i] = d;
                }
            }
        }

        // Lloyd iterations.
        let mut assignments = vec![0usize; n];
        for _ in 0..iters {
            let mut changed = false;
            for i in 0..n {
                let a = nearest(&points[i * dim..(i + 1) * dim], &centroids, k, dim);
                if a != assignments[i] {
                    assignments[i] = a;
                    changed = true;
                }
            }
            // Recompute centroids.
            let mut sums = vec![0.0f64; k * dim];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let a = assignments[i];
                counts[a] += 1;
                for j in 0..dim {
                    sums[a * dim + j] += points[i * dim + j] as f64;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    // Re-seed an empty cluster at a random point.
                    let idx = rng.below(n);
                    for j in 0..dim {
                        centroids[c * dim + j] = points[idx * dim + j];
                    }
                    changed = true;
                } else {
                    for j in 0..dim {
                        centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        // Final assignment pass so memberships match the final centroids.
        for i in 0..n {
            assignments[i] = nearest(&points[i * dim..(i + 1) * dim], &centroids, k, dim);
        }
        KMeans { centroids, k, dim, assignments }
    }

    /// Index of the nearest centroid to `x`.
    pub fn assign(&self, x: &[f32]) -> usize {
        nearest(x, &self.centroids, self.k, self.dim)
    }

    /// Members of each cluster (indices into the training set).
    pub fn members(&self) -> Vec<Vec<usize>> {
        let mut m = vec![Vec::new(); self.k];
        for (i, &a) in self.assignments.iter().enumerate() {
            m[a].push(i);
        }
        m
    }

    /// Mean within-cluster squared distance (inertia / n).
    pub fn inertia(&self, points: &[f32]) -> f64 {
        let n = points.len() / self.dim;
        let mut total = 0.0;
        for i in 0..n {
            let a = self.assignments[i];
            total += sq_dist(
                &points[i * self.dim..(i + 1) * self.dim],
                &self.centroids[a * self.dim..(a + 1) * self.dim],
            );
        }
        total / n as f64
    }
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum()
}

fn nearest(x: &[f32], centroids: &[f32], k: usize, dim: usize) -> usize {
    let mut best = 0;
    let mut best_d = f64::MAX;
    for c in 0..k {
        let d = sq_dist(x, &centroids[c * dim..(c + 1) * dim]);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs in 2-D.
    fn blobs(seed: u64) -> (Vec<f32>, usize) {
        let mut rng = Rng::new(seed);
        let centers = [(0.0, 0.0), (10.0, 10.0), (-10.0, 8.0)];
        let mut pts = Vec::new();
        for _ in 0..300 {
            let (cx, cy) = centers[rng.below(3)];
            pts.push((cx + rng.normal()) as f32);
            pts.push((cy + rng.normal()) as f32);
        }
        (pts, 2)
    }

    #[test]
    fn recovers_blob_centers() {
        let (pts, dim) = blobs(1);
        let km = KMeans::fit(&pts, dim, 3, 50, 7);
        // Every centroid should be near one of the true centers.
        let truth = [(0.0, 0.0), (10.0, 10.0), (-10.0, 8.0)];
        for c in 0..3 {
            let (x, y) = (km.centroids[c * 2] as f64, km.centroids[c * 2 + 1] as f64);
            let near = truth
                .iter()
                .any(|&(tx, ty)| ((x - tx).powi(2) + (y - ty).powi(2)).sqrt() < 2.0);
            assert!(near, "centroid {c} at ({x:.1},{y:.1}) not near any blob");
        }
    }

    #[test]
    fn assign_is_consistent_with_fit() {
        let (pts, dim) = blobs(2);
        let km = KMeans::fit(&pts, dim, 3, 50, 7);
        for i in 0..pts.len() / dim {
            assert_eq!(km.assign(&pts[i * dim..(i + 1) * dim]), km.assignments[i]);
        }
    }

    #[test]
    fn members_partition_everything() {
        let (pts, dim) = blobs(3);
        let km = KMeans::fit(&pts, dim, 5, 30, 11);
        let members = km.members();
        let total: usize = members.iter().map(Vec::len).sum();
        assert_eq!(total, pts.len() / dim);
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let pts = vec![0.0f32, 0.0, 1.0, 1.0];
        let km = KMeans::fit(&pts, 2, 10, 10, 1);
        assert_eq!(km.k, 2);
    }

    #[test]
    fn deterministic_for_same_seed() {
        let (pts, dim) = blobs(4);
        let a = KMeans::fit(&pts, dim, 4, 25, 9);
        let b = KMeans::fit(&pts, dim, 4, 25, 9);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn more_clusters_reduce_inertia() {
        let (pts, dim) = blobs(5);
        let k2 = KMeans::fit(&pts, dim, 2, 40, 3).inertia(&pts);
        let k6 = KMeans::fit(&pts, dim, 6, 40, 3).inertia(&pts);
        assert!(k6 < k2);
    }
}
