//! The training-environment abstraction shared by the cluster-lookup
//! emulator and the live network simulator.

/// Result of one environment step.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// Flattened state window after the step.
    pub state: Vec<f32>,
    /// Shaped reward for the action just taken.
    pub reward: f64,
    /// Episode termination.
    pub done: bool,
    /// Outcome metrics (for telemetry): goodput, energy of the step's MI.
    pub throughput_gbps: f64,
    pub energy_j: f64,
}

/// A DRL training environment over the paper's five-action space.
pub trait Env {
    /// Begin a new episode; returns the initial state window.
    fn reset(&mut self) -> Vec<f32>;

    /// Apply a discrete action (0..5) and advance one monitoring interval.
    fn step(&mut self, action: usize) -> StepOut;

    /// Flattened state length (window × features).
    fn state_len(&self) -> usize;
}
