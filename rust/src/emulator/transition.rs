//! State-action transition records and the paper's log-line format.

use crate::coordinator::{MiRecord, FEATURES};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One logged transition: (x_t, a_t, x_{t+1}) plus the outcome metrics of
/// the interval that followed the action.
#[derive(Debug, Clone, PartialEq)]
pub struct Transition {
    /// Feature vector x_t (plr, rtt_gradient, rtt_ratio, cc, p — normalized).
    pub features: [f32; FEATURES],
    /// Discrete action a_t.
    pub action: usize,
    /// Feature vector x_{t+1}.
    pub next_features: [f32; FEATURES],
    /// Outcome of the following MI.
    pub throughput_gbps: f64,
    pub plr: f64,
    pub rtt_s: f64,
    pub energy_j: f64,
    /// Utility score of the following MI (the log line's `score`).
    pub score: f64,
    /// Raw (cc, p) after the action.
    pub cc: u32,
    pub p: u32,
}

impl Transition {
    /// Render the paper's transfer-log line for this transition's outcome.
    pub fn log_line(&self, timestamp: f64) -> String {
        format!(
            "{:.6} -- INFO: Throughput:{:.2}Gbps lossRate:{} parallelism:{} concurrency:{} score:{:.1} rtt:{:.1}ms energy:{:.1}J",
            timestamp,
            self.throughput_gbps,
            trim_float(self.plr),
            self.p,
            self.cc,
            self.score,
            self.rtt_s * 1000.0,
            if self.energy_j.is_nan() { 0.0 } else { self.energy_j },
        )
    }

    /// The clustering key: (x_t, a_t) with the action normalized to [0, 1].
    pub fn cluster_key(&self) -> Vec<f32> {
        let mut k = self.features.to_vec();
        k.push(self.action as f32 / 4.0);
        k
    }
}

fn trim_float(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else {
        format!("{x:.4}")
    }
}

/// Reconstruct transitions from a lane's consecutive MI records. A record
/// holding action `a` pairs with the *next* record's state and outcome.
pub fn transitions_from_records(records: &[MiRecord]) -> Vec<Transition> {
    let mut out = Vec::new();
    for pair in records.windows(2) {
        let (cur, next) = (&pair[0], &pair[1]);
        let Some(action) = cur.action else { continue };
        let f = last_features(&cur.state);
        let nf = last_features(&next.state);
        out.push(Transition {
            features: f,
            action,
            next_features: nf,
            throughput_gbps: next.throughput_gbps,
            plr: next.plr,
            rtt_s: next.rtt_s,
            energy_j: next.energy_j,
            score: next.metric,
            cc: next.cc,
            p: next.p,
        });
    }
    out
}

fn last_features(state: &[f32]) -> [f32; FEATURES] {
    let mut f = [0.0; FEATURES];
    let start = state.len() - FEATURES;
    f.copy_from_slice(&state[start..]);
    f
}

/// Binary transition store: fixed-width little-endian records. The textual
/// paper-format lines are also written alongside (`.log`) for inspection.
pub struct TransitionStore;

const REC_F32: usize = FEATURES * 2 + 1 /*action*/ + 5 /*outcome*/ + 2 /*cc,p*/;

impl TransitionStore {
    /// Save transitions as `<path>.bin` plus a human-readable `<path>.log`.
    pub fn save(path: &Path, transitions: &[Transition]) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut bytes = Vec::with_capacity(transitions.len() * REC_F32 * 4);
        let mut log = String::new();
        for (i, t) in transitions.iter().enumerate() {
            let mut rec: Vec<f32> = Vec::with_capacity(REC_F32);
            rec.extend_from_slice(&t.features);
            rec.push(t.action as f32);
            rec.extend_from_slice(&t.next_features);
            rec.push(t.throughput_gbps as f32);
            rec.push(t.plr as f32);
            rec.push(t.rtt_s as f32);
            rec.push(if t.energy_j.is_nan() { -1.0 } else { t.energy_j as f32 });
            rec.push(t.score as f32);
            rec.push(t.cc as f32);
            rec.push(t.p as f32);
            debug_assert_eq!(rec.len(), REC_F32);
            for x in rec {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            log.push_str(&t.log_line(1707718539.0 + i as f64));
            log.push('\n');
        }
        std::fs::write(path.with_extension("bin"), bytes)
            .with_context(|| format!("writing {}", path.display()))?;
        std::fs::write(path.with_extension("log"), log)?;
        Ok(())
    }

    /// Load transitions from `<path>.bin`.
    pub fn load(path: &Path) -> Result<Vec<Transition>> {
        let bin = path.with_extension("bin");
        let bytes = std::fs::read(&bin).with_context(|| format!("reading {}", bin.display()))?;
        let stride = REC_F32 * 4;
        if bytes.len() % stride != 0 {
            return Err(anyhow!("{}: truncated transition store", bin.display()));
        }
        let mut out = Vec::with_capacity(bytes.len() / stride);
        for rec in bytes.chunks_exact(stride) {
            let f: Vec<f32> = rec
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let mut features = [0.0; FEATURES];
            features.copy_from_slice(&f[0..FEATURES]);
            let mut next_features = [0.0; FEATURES];
            next_features.copy_from_slice(&f[FEATURES + 1..FEATURES + 1 + FEATURES]);
            let o = FEATURES * 2 + 1;
            out.push(Transition {
                features,
                action: f[FEATURES] as usize,
                next_features,
                throughput_gbps: f[o] as f64,
                plr: f[o + 1] as f64,
                rtt_s: f[o + 2] as f64,
                energy_j: if f[o + 3] < 0.0 { f64::NAN } else { f[o + 3] as f64 },
                score: f[o + 4] as f64,
                cc: f[o + 5] as u32,
                p: f[o + 6] as u32,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(i: usize) -> Transition {
        Transition {
            features: [0.01, 0.1, 1.2, 0.4, 0.4],
            action: i % 5,
            next_features: [0.02, -0.1, 1.3, 0.5, 0.5],
            throughput_gbps: 8.32,
            plr: 0.0,
            rtt_s: 0.0346,
            energy_j: 80.0,
            score: 3.0,
            cc: 7,
            p: 7,
        }
    }

    #[test]
    fn log_line_matches_paper_format() {
        let line = sample(0).log_line(1707718539.468927);
        assert!(line.contains("Throughput:8.32Gbps"));
        assert!(line.contains("lossRate:0"));
        assert!(line.contains("parallelism:7 concurrency:7"));
        assert!(line.contains("score:3.0"));
        assert!(line.contains("rtt:34.6ms"));
        assert!(line.contains("energy:80.0J"));
    }

    #[test]
    fn store_roundtrip() {
        let path = std::env::temp_dir().join("sparta_transitions_test/t");
        let ts: Vec<Transition> = (0..10).map(sample).collect();
        TransitionStore::save(&path, &ts).unwrap();
        let back = TransitionStore::load(&path).unwrap();
        assert_eq!(back.len(), 10);
        assert_eq!(back[3].action, 3);
        assert!((back[0].throughput_gbps - 8.32).abs() < 1e-5);
        assert_eq!(back[0].cc, 7);
    }

    #[test]
    fn nan_energy_survives_roundtrip() {
        let path = std::env::temp_dir().join("sparta_transitions_test2/t");
        let mut t = sample(0);
        t.energy_j = f64::NAN;
        TransitionStore::save(&path, &[t]).unwrap();
        let back = TransitionStore::load(&path).unwrap();
        assert!(back[0].energy_j.is_nan());
    }

    #[test]
    fn cluster_key_includes_action() {
        let t = sample(2);
        let k = t.cluster_key();
        assert_eq!(k.len(), FEATURES + 1);
        assert!((k[FEATURES] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn from_records_pairs_consecutive() {
        use crate::coordinator::MiRecord;
        let rec = |mi: usize, action: Option<usize>, thr: f64| MiRecord {
            mi,
            time_s: mi as f64,
            throughput_gbps: thr,
            plr: 0.0,
            rtt_s: 0.03,
            energy_j: 50.0,
            cc: 4,
            p: 4,
            metric: thr / 2.0,
            reward: 0.0,
            action,
            state: vec![mi as f32; 2 * FEATURES],
            bytes_total: (mi + 1) as f64 * 1e9,
            energy_total_j: (mi + 1) as f64 * 50.0,
            paused: false,
            rails: None,
        };
        let records = vec![rec(0, Some(1), 2.0), rec(1, Some(2), 3.0), rec(2, None, 4.0)];
        let ts = transitions_from_records(&records);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].action, 1);
        assert_eq!(ts[0].throughput_gbps, 3.0);
        assert_eq!(ts[1].action, 2);
        assert_eq!(ts[1].throughput_gbps, 4.0);
    }
}
