//! # SPARTA — Smart Parameter Adaptation via Reinforcement learning for data Transfer Acceleration
//!
//! A reproduction of *"Optimizing Data Transfer Performance and Energy Efficiency
//! with Deep Reinforcement Learning"* (Jamil et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the transfer coordinator: the monitoring-interval
//!   control loop, the five-action concurrency/parallelism tuner, the F&E and T/E
//!   reward machinery, the DRL agents (DQN, DRQN, PPO, R_PPO, DDPG), the
//!   cluster-lookup emulated training environment, the state-of-the-art baselines
//!   (rclone/escp-style static tools, Falcon_MP, 2-phase), and the simulated
//!   substrates the paper's testbeds provided: a fluid-model TCP/CUBIC wide-area
//!   network ([`net`]) and a RAPL-like, host-scoped, component-resolved
//!   energy accounting layer ([`energy`]: CPU/NIC/fixed-idle rails on a
//!   shared per-host ledger, with a bit-identical lumped compat rail).
//! * **Layer 2 (python/compile, build-time only)** — the agents' policy/value
//!   networks and Adam update steps as pure JAX functions, AOT-lowered to HLO
//!   text artifacts that this crate loads through the PJRT CPU client.
//! * **Layer 1 (python/compile/kernels, build-time only)** — Pallas kernels for
//!   the dense/LSTM hot paths and the emulator's k-means assignment, validated
//!   against pure-jnp oracles.
//!
//! Python never runs on the transfer path: `make artifacts` lowers everything
//! once, and the `sparta` binary is self-contained afterwards.
//!
//! ## Architecture: sessions, substrates, scenarios, experiments
//!
//! The coordinator's public API is the step-driven
//! [`coordinator::Session`]: transfer lanes are *admitted* (before the
//! first MI or mid-run), each [`coordinator::Session::step`] advances one
//! monitoring interval and streams MI-granular [`coordinator::Event`]s
//! (`Admitted`, `MiCompleted`, `Paused`, `Resumed`, `Completed`,
//! `Departed`) into any [`telemetry::TelemetrySink`], and external
//! `pause`/`resume`/`cancel` model transfers that come and go. The batch
//! [`Controller`] survives as a thin compat wrapper whose
//! [`telemetry::ReportSink`]-rebuilt reports are bit-identical to the
//! pre-redesign numbers, so every figure regenerates unchanged.
//!
//! The control plane never touches a concrete simulator: [`Session`],
//! the live training environment and the experiments all drive a
//! [`net::Substrate`] trait object. [`net::NetworkSim`] implements it over a
//! multi-segment [`net::Topology`] (sender NIC → shared WAN → receiver I/O,
//! each an independent droptail link), so flows can bottleneck at any stage.
//! The [`scenarios`] registry names ≥6 seeded presets over these topologies
//! (`calm`, `diurnal-bg`, `bursty-incast`, `lossy-wan`, `receiver-limited`,
//! `nic-limited`, `contended-peers`, plus the paper's testbeds) — select
//! one with `--scenario <name>` on the CLI. On top of the session API,
//! [`scenarios::ArrivalSchedule`] presets (`churn-light`, `churn-heavy`,
//! `flash-crowd`, plus the wall-clock-indexed `open-loop` and
//! `timed-burst`) describe seeded Poisson/trace arrival processes, and
//! `sparta fleet` ([`experiments::fleet`]) runs N agents joining/leaving a
//! shared bottleneck, reporting per-epoch Jain's fairness
//! ([`telemetry::FairnessSink`]), host-truth energy per delivered GB with
//! per-rail breakdowns (fixed power paid once per host — see
//! [`energy::HostLedger`]), and completion-time distributions; paused
//! lanes are billed the idle rail, observable to optimizers behind
//! `--observe-paused`.
//!
//! Above the single-host session sits the cluster layer
//! ([`coordinator::Cluster`]): `sparta fleet --hosts N` shards the lane
//! fleet round-robin across N per-host [`Session`]s — each sender host
//! with its own [`energy::HostLedger`], rail calibration, and stream
//! arena — joined through an N-senders→one-receiver incast topology
//! ([`net::Topology::incast_host`]: private sender NICs feeding
//! fair-share slices of the shared WAN and receiver stages, see
//! [`net::SegmentSpec::shared_slice`]). Host simulations stay fully
//! independent, so host seeds are identity-derived and cluster reports
//! are byte-identical at any `--jobs` count; receiver residency is
//! shared once cluster-wide via [`energy::HostSpec::share`], and fleet
//! reports resolve energy per host and per rail with Σ per-host
//! attribution equal to the cluster total. Everything that steps — a
//! [`Session`] or a [`Cluster`] — presents the same unified
//! [`coordinator::Stepping`] surface (admit / `step_into` / pause /
//! resume / cancel / energy queries), so drivers like the fleet loop are
//! written once and monomorphize over either. Because host simulations
//! share nothing, the cluster can also step them *concurrently*: a
//! persistent worker pool ([`coordinator::Cluster::set_step_threads`],
//! `--step-threads N` on fleet/serve/bench) fans each MI out over the
//! hosts and merges the per-host event buffers in host order, so the
//! stream stays byte-identical to serial at any thread count — §Perf in
//! [`coordinator::cluster`] has the full argument.
//!
//! Where `sparta fleet` replays a whole workload batch-style, `sparta
//! serve` ([`serve`]) keeps a fleet *resident*: a daemon owns a
//! [`Session`] or [`Cluster`] behind a Unix-socket control plane
//! (line-delimited JSON — `admit`, `pause`/`resume`/`cancel`, `status`,
//! `snapshot`, `subscribe`, `shutdown`), a pacer steps it in scaled or
//! real time (`--time-scale`), and wall-clock-indexed arrival schedules
//! drive open-loop load. Every control op lands on an MI boundary, so a
//! served run is replayable; the flagship consequence is bit-identical
//! checkpoint/restore ([`serve::ServeSnapshot`]): snapshot, kill the
//! daemon, `sparta serve --restore`, and the concatenated event stream
//! is byte-for-byte what the uninterrupted run would have emitted.
//!
//! Failure is a first-class, *seeded* input ([`faults`]): a
//! [`faults::FaultSchedule`] preset (`link-flap`, `link-degrade`,
//! `host-stall`, `host-crash`, `stream-error` — `--faults <name>` on
//! fleet/serve/bench) resolves into an explicit [`faults::FaultPlan`]
//! from an identity-derived seed, exactly as arrival schedules resolve
//! workloads. Segment faults rescale a named topology stage's capacity at
//! an MI boundary ([`net::Substrate::fault_segment`]); a per-lane stall
//! watchdog in [`Session`] detects starved lanes and cycles them through
//! `Faulted` → exponential-backoff → `Retrying` with already-transferred
//! bytes intact; and a host crash turns the cluster's former
//! panic-and-abort path into quarantine-and-migrate — the dead host's
//! in-flight lanes are extracted (optimizer state, job progress, window
//! and reward trackers) and re-admitted on healthy hosts, with
//! `Event::Migrated` marking the move and the dead host's frozen ledger
//! still counted so Σ per-host energy equals the cluster total. The
//! determinism contract is the same two rules everywhere: faults are
//! seeded data, and every recovery op lands on an MI boundary — so a
//! faulted run's event stream is byte-identical at any `--jobs` and
//! `--step-threads`, and the fault-free path is byte-identical to a build
//! without the fault plane at all. A fleet with faults installed is not
//! checkpointable (`export_state` returns `None`); `sparta serve` keeps
//! running in degraded mode instead and reports fault/retry/migration
//! counters over `status`.
//!
//! Scenarios are the *training* substrate too, not just an evaluation toy:
//! [`experiments::train_pipeline`] takes a [`experiments::TrainSource`]
//! (bare testbed or registered scenario), explores and fine-tunes under it,
//! and saves scenario-scoped weight files (`rppo_te@lossy-wan`); `sparta
//! generalize` trains per scenario and deploys every trained policy on
//! every registered scenario, printing the cross-scenario generalization
//! matrix ([`experiments::generalize`]).
//!
//! The hot path is arena-backed (§Perf): [`net::NetworkSim`] keeps all
//! stream state in a flat struct-of-arrays [`net::stream::StreamArena`]
//! and ticks only active streams, and the buffer-taking entry points are
//! the *required* surface — [`net::Substrate::run_mi_into`] is the one
//! method substrates implement (`run_mi` is a default allocating
//! wrapper), and [`coordinator::Session::step_into`] /
//! [`coordinator::Cluster::step_into`] recycle event buffers across MIs
//! (`step()` is a convenience wrapper). `sparta bench` records the perf
//! trajectory as `BENCH_*.json` — the fleet churn-heavy scale curve at
//! 16/64/256 lanes single-host plus 1024/4096-lane incast cluster points
//! (8/16 hosts, headline in host-MIs/s), timed against the frozen
//! pre-arena loop ([`net::baseline::BaselineSim`]), which
//! `tests/golden_replay.rs` also holds byte-identical to the arena loop,
//! so speedups can never smuggle in result changes. Schema v4
//! (`BENCH_8.json`) extends the curve to giant 16384×32 and 65536×64
//! incast points — past BaselineSim's wall-clock budget, so those rows
//! instead carry a threaded-vs-serial column: the pooled cluster step
//! timed against the serial loop, with report-byte identity required
//! before the speedup is recorded, and the trend gate ratchets whichever
//! ratio a point carries.
//!
//! Trained weights split into a write path ([`runtime::WeightStore`]) and a
//! read path ([`runtime::WeightSnapshot`]): evaluation loads every weight
//! file once into an `Arc`-shared immutable snapshot, so every grid
//! experiment (Fig. 1/4/5/6/7, Table 1, the generalize matrix) shards its
//! cells over worker threads ([`experiments::runner`], `--jobs N`) without
//! ever touching the weights directory concurrently. Per-cell seeding is
//! identity-derived, so reports are bit-identical at any thread count — CI
//! enforces this byte-for-byte on the real CLI path. On checkouts without
//! AOT artifacts, the pure-Rust `linq` fallback core
//! ([`agents::LinQAgent`]) keeps the whole train → snapshot → evaluate
//! pipeline runnable.
//!
//! [`Controller`]: coordinator::Controller
//! [`Session`]: coordinator::Session
//! [`Cluster`]: coordinator::Cluster
//!
//! ## Quick tour
//!
//! Step-driven session: admit a transfer under the "receiver-limited"
//! scenario (cloudlab WAN behind an 8 Gbps receiver I/O stage), step it MI
//! by MI, and rebuild the summary report from the event stream.
//! `Scenario::by_name` resolves any registered preset, including the plain
//! testbeds ("chameleon", "cloudlab", "fabric").
//!
//! ```no_run
//! use sparta::scenarios::Scenario;
//! use sparta::transfer::TransferJob;
//! use sparta::coordinator::{LaneSpec, RewardKind, DEFAULT_MAX_MIS};
//! use sparta::telemetry::ReportSink;
//! use sparta::baselines::StaticTool;
//!
//! let sc = Scenario::by_name("receiver-limited").unwrap();
//! let mut session = sc.session().seed(0xC0FFEE).build();
//! session.admit(
//!     LaneSpec::new(Box::new(StaticTool::rclone()), TransferJob::files(50, 1 << 30))
//!         .reward(RewardKind::ThroughputEnergy),
//! );
//! let mut sink = ReportSink::new();
//! session.run_to_completion(DEFAULT_MAX_MIS, &mut sink);
//! let report = sink.finish(session.time_s());
//! println!("avg throughput {:.2} Gbps", report.avg_throughput_gbps());
//! ```
//!
//! Mid-run admission and external control — the dynamic workloads the
//! batch API structurally excluded (see `sparta fleet`):
//!
//! ```no_run
//! use sparta::coordinator::{LaneSpec, Session};
//! use sparta::net::Testbed;
//! use sparta::transfer::TransferJob;
//! use sparta::baselines::StaticTool;
//!
//! let mut session = Session::builder(Testbed::chameleon()).seed(7).build();
//! let first = session.admit(LaneSpec::new(
//!     Box::new(StaticTool::efficient_static(4, 4)),
//!     TransferJob::files(64, 1 << 30),
//! ));
//! for _ in 0..10 { session.step(); }          // events stream out per MI
//! let late = session.admit(LaneSpec::new(     // joins the shared bottleneck
//!     Box::new(StaticTool::rclone()),
//!     TransferJob::files(16, 1 << 30),
//! ));
//! session.pause(first);                        // external control plane
//! session.step();
//! session.resume(first);
//! session.cancel(late);                        // departs before finishing
//! ```
//!
//! A resident service with live admissions and bit-identical
//! checkpoint/restore — the in-process core behind `sparta serve`
//! (the daemon adds a Unix-socket control plane and a pacer around
//! this same engine):
//!
//! ```no_run
//! use sparta::config::Paths;
//! use sparta::experiments::SpartaCtx;
//! use sparta::serve::{AdmitRec, OpKind, ServeEngine, ServeSnapshot};
//! use sparta::serve::ServeSpec;
//!
//! let ctx = SpartaCtx::load(Paths::resolve()).unwrap();
//! let spec = ServeSpec {
//!     scenario: "chameleon".to_string(),
//!     schedule: Some("open-loop".to_string()), // wall-clock Poisson load
//!     methods: vec!["falcon_mp".to_string(), "2-phase".to_string()],
//!     hosts: 1,
//!     seed: 42,
//!     mi_s: 1.0,
//!     max_mis: 360,
//!     observe_paused: false,
//!     faults: None,             // or Some("link-flap".into()) for a chaos drill
//! };
//! let mut engine = ServeEngine::new(ctx, spec, 1).unwrap(); // 1 = serial stepping
//! let mut events = Vec::new();
//! for _ in 0..60 { engine.step(&mut events).unwrap(); }
//! // An operator walks up mid-run:
//! engine.enqueue(OpKind::Admit(AdmitRec {
//!     method: "rclone".to_string(),
//!     files: 8,
//!     file_bytes: 128 << 20,
//!     name: None,               // resolved deterministically at execution
//!     seed: None,
//!     max_lifetime_mis: Some(40),
//! }), None).unwrap();
//! let snap = engine.snapshot().unwrap();    // full logical state, versioned
//! snap.save("service.snap.json".as_ref()).unwrap();
//! // ...kill the process; later, byte-identical resumption:
//! let ctx = SpartaCtx::load(Paths::resolve()).unwrap();
//! let snap = ServeSnapshot::load("service.snap.json".as_ref()).unwrap();
//! let mut engine = ServeEngine::restore(ctx, snap, 1).unwrap();
//! for _ in 0..300 { engine.step(&mut events).unwrap(); }
//! ```
//!
//! Scenario-aware training and the cross-scenario generalization matrix
//! (runs on a fresh checkout — the `linq` fallback core needs no AOT
//! artifacts):
//!
//! ```no_run
//! use sparta::config::Paths;
//! use sparta::coordinator::RewardKind;
//! use sparta::experiments::{generalize, Scale};
//! use sparta::scenarios::Scenario;
//!
//! let report = generalize::run(
//!     &Paths::resolve(),
//!     "linq",
//!     RewardKind::ThroughputEnergy,
//!     &Scenario::all(),   // train one policy per registered scenario...
//!     &Scenario::all(),   // ...and deploy each on every scenario
//!     Scale::Quick,
//!     42,
//!     4,                  // worker threads; reports are bit-identical at any count
//! ).unwrap();
//! generalize::print(&report);
//! ```
//!
//! Perf trajectory — time the fleet churn-heavy scale curve (including
//! the incast cluster points and the giant threaded 16k–65k-lane points)
//! on the arena loop and the frozen pre-arena baseline, and write
//! `BENCH_8.json` (`sparta bench --quick` on the CLI; add `--against
//! BENCH_8.json` for the CI perf-trend ratchet):
//!
//! ```no_run
//! use sparta::config::Paths;
//! use sparta::experiments::bench;
//!
//! let opts = bench::BenchOpts { quick: true, ..Default::default() };
//! let report = bench::run(&Paths::resolve(), opts).unwrap();
//! bench::print(&report); // s/trial, MIs/s and speedup per lane count
//! ```

pub mod agents;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod emulator;
pub mod energy;
pub mod experiments;
pub mod faults;
pub mod net;
pub mod runtime;
pub mod scenarios;
pub mod serve;
pub mod telemetry;
pub mod trainer;
pub mod transfer;
pub mod util;

/// Crate version, re-exported for the CLI `info` subcommand.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
