//! # SPARTA — Smart Parameter Adaptation via Reinforcement learning for data Transfer Acceleration
//!
//! A reproduction of *"Optimizing Data Transfer Performance and Energy Efficiency
//! with Deep Reinforcement Learning"* (Jamil et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the transfer coordinator: the monitoring-interval
//!   control loop, the five-action concurrency/parallelism tuner, the F&E and T/E
//!   reward machinery, the DRL agents (DQN, DRQN, PPO, R_PPO, DDPG), the
//!   cluster-lookup emulated training environment, the state-of-the-art baselines
//!   (rclone/escp-style static tools, Falcon_MP, 2-phase), and the simulated
//!   substrates the paper's testbeds provided: a fluid-model TCP/CUBIC wide-area
//!   network ([`net`]) and a RAPL-like end-system energy meter ([`energy`]).
//! * **Layer 2 (python/compile, build-time only)** — the agents' policy/value
//!   networks and Adam update steps as pure JAX functions, AOT-lowered to HLO
//!   text artifacts that this crate loads through the PJRT CPU client.
//! * **Layer 1 (python/compile/kernels, build-time only)** — Pallas kernels for
//!   the dense/LSTM hot paths and the emulator's k-means assignment, validated
//!   against pure-jnp oracles.
//!
//! Python never runs on the transfer path: `make artifacts` lowers everything
//! once, and the `sparta` binary is self-contained afterwards.
//!
//! ## Architecture: substrates, scenarios, experiments
//!
//! The control plane never touches a concrete simulator: [`Controller`],
//! the live training environment and the experiments all drive a
//! [`net::Substrate`] trait object. [`net::NetworkSim`] implements it over a
//! multi-segment [`net::Topology`] (sender NIC → shared WAN → receiver I/O,
//! each an independent droptail link), so flows can bottleneck at any stage.
//! The [`scenarios`] registry names ≥6 seeded presets over these topologies
//! (`calm`, `diurnal-bg`, `bursty-incast`, `lossy-wan`, `receiver-limited`,
//! `nic-limited`, `contended-peers`, plus the paper's testbeds) — select
//! one with `--scenario <name>` on the CLI.
//!
//! Scenarios are the *training* substrate too, not just an evaluation toy:
//! [`experiments::train_pipeline`] takes a [`experiments::TrainSource`]
//! (bare testbed or registered scenario), explores and fine-tunes under it,
//! and saves scenario-scoped weight files (`rppo_te@lossy-wan`); `sparta
//! generalize` trains per scenario and deploys every trained policy on
//! every registered scenario, printing the cross-scenario generalization
//! matrix ([`experiments::generalize`]).
//!
//! Trained weights split into a write path ([`runtime::WeightStore`]) and a
//! read path ([`runtime::WeightSnapshot`]): evaluation loads every weight
//! file once into an `Arc`-shared immutable snapshot, so every grid
//! experiment (Fig. 1/4/5/6/7, Table 1, the generalize matrix) shards its
//! cells over worker threads ([`experiments::runner`], `--jobs N`) without
//! ever touching the weights directory concurrently. Per-cell seeding is
//! identity-derived, so reports are bit-identical at any thread count — CI
//! enforces this byte-for-byte on the real CLI path. On checkouts without
//! AOT artifacts, the pure-Rust `linq` fallback core
//! ([`agents::LinQAgent`]) keeps the whole train → snapshot → evaluate
//! pipeline runnable.
//!
//! [`Controller`]: coordinator::Controller
//!
//! ## Quick tour
//!
//! ```no_run
//! use sparta::scenarios::Scenario;
//! use sparta::transfer::TransferJob;
//! use sparta::coordinator::RewardKind;
//! use sparta::baselines::StaticTool;
//!
//! // Simulate an rclone-style static transfer of 50 x 1 GiB under the
//! // "receiver-limited" scenario (cloudlab WAN behind an 8 Gbps receiver
//! // I/O stage). `Scenario::by_name` resolves any registered preset,
//! // including the plain testbeds ("chameleon", "cloudlab", "fabric").
//! let sc = Scenario::by_name("receiver-limited").unwrap();
//! let mut ctl = sc.controller()
//!     .job(TransferJob::files(50, 1 << 30))
//!     .reward(RewardKind::ThroughputEnergy)
//!     .build();
//! let report = ctl.run(Box::new(StaticTool::rclone()), 0xC0FFEE);
//! println!("avg throughput {:.2} Gbps", report.avg_throughput_gbps());
//! ```
//!
//! Scenario-aware training and the cross-scenario generalization matrix
//! (runs on a fresh checkout — the `linq` fallback core needs no AOT
//! artifacts):
//!
//! ```no_run
//! use sparta::config::Paths;
//! use sparta::coordinator::RewardKind;
//! use sparta::experiments::{generalize, Scale};
//! use sparta::scenarios::Scenario;
//!
//! let report = generalize::run(
//!     &Paths::resolve(),
//!     "linq",
//!     RewardKind::ThroughputEnergy,
//!     &Scenario::all(),   // train one policy per registered scenario...
//!     &Scenario::all(),   // ...and deploy each on every scenario
//!     Scale::Quick,
//!     42,
//!     4,                  // worker threads; reports are bit-identical at any count
//! ).unwrap();
//! generalize::print(&report);
//! ```

pub mod agents;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod emulator;
pub mod energy;
pub mod experiments;
pub mod net;
pub mod runtime;
pub mod scenarios;
pub mod telemetry;
pub mod trainer;
pub mod transfer;
pub mod util;

/// Crate version, re-exported for the CLI `info` subcommand.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
