//! # SPARTA — Smart Parameter Adaptation via Reinforcement learning for data Transfer Acceleration
//!
//! A reproduction of *"Optimizing Data Transfer Performance and Energy Efficiency
//! with Deep Reinforcement Learning"* (Jamil et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the transfer coordinator: the monitoring-interval
//!   control loop, the five-action concurrency/parallelism tuner, the F&E and T/E
//!   reward machinery, the DRL agents (DQN, DRQN, PPO, R_PPO, DDPG), the
//!   cluster-lookup emulated training environment, the state-of-the-art baselines
//!   (rclone/escp-style static tools, Falcon_MP, 2-phase), and the simulated
//!   substrates the paper's testbeds provided: a fluid-model TCP/CUBIC wide-area
//!   network ([`net`]) and a RAPL-like end-system energy meter ([`energy`]).
//! * **Layer 2 (python/compile, build-time only)** — the agents' policy/value
//!   networks and Adam update steps as pure JAX functions, AOT-lowered to HLO
//!   text artifacts that this crate loads through the PJRT CPU client.
//! * **Layer 1 (python/compile/kernels, build-time only)** — Pallas kernels for
//!   the dense/LSTM hot paths and the emulator's k-means assignment, validated
//!   against pure-jnp oracles.
//!
//! Python never runs on the transfer path: `make artifacts` lowers everything
//! once, and the `sparta` binary is self-contained afterwards.
//!
//! ## Quick tour
//!
//! ```no_run
//! use sparta::net::{Testbed, NetworkSim};
//! use sparta::transfer::TransferJob;
//! use sparta::coordinator::{Controller, RewardKind};
//! use sparta::baselines::StaticTool;
//!
//! // Simulate an rclone-style static transfer of 50 x 1 GiB on the
//! // Chameleon (TACC->UC, 10 Gbps) testbed preset.
//! let tb = Testbed::chameleon();
//! let mut ctl = Controller::builder(tb)
//!     .job(TransferJob::files(50, 1 << 30))
//!     .reward(RewardKind::ThroughputEnergy)
//!     .build();
//! let report = ctl.run(Box::new(StaticTool::rclone()), 0xC0FFEE);
//! println!("avg throughput {:.2} Gbps", report.avg_throughput_gbps());
//! ```

pub mod agents;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod emulator;
pub mod energy;
pub mod experiments;
pub mod net;
pub mod runtime;
pub mod telemetry;
pub mod trainer;
pub mod transfer;
pub mod util;

/// Crate version, re-exported for the CLI `info` subcommand.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
