//! The 2-phase historical-model optimizer, deployed without historical logs.
//!
//! Nine & Kosar's two-phase model ([11] in the paper) normally mines
//! historical transfer logs offline (phase 1) and refines online (phase 2).
//! The paper's evaluation "did not have historical datasets in our testbed
//! setup, so we initialized it from a midpoint range of concurrency and
//! parallelism" — which is what this implementation reproduces: a short
//! coarse probe over a midpoint-biased candidate set standing in for the
//! offline model's suggestion, then hold-with-occasional-recheck.

use crate::coordinator::reward::{utility, RewardConfig};
use crate::coordinator::{Decision, MiContext, Optimizer, ParamBounds};

/// Candidate probe offsets around the midpoint (phase-1 surrogate).
const PROBE_OFFSETS: [(i32, i32); 5] = [(0, 0), (-2, -2), (2, 2), (-2, 2), (2, -2)];

#[derive(Debug, Clone)]
pub struct TwoPhase {
    cfg: RewardConfig,
    probe_mis: usize,
    /// Probe candidates (cc, p) and their measured mean utilities.
    candidates: Vec<(u32, u32)>,
    scores: Vec<f64>,
    current: usize,
    acc: f64,
    acc_n: usize,
    /// Phase 2: index of the chosen setting; recheck countdown.
    chosen: Option<usize>,
    recheck_in: usize,
}

impl TwoPhase {
    pub fn new() -> TwoPhase {
        TwoPhase {
            cfg: RewardConfig::default(),
            probe_mis: 4,
            candidates: Vec::new(),
            scores: Vec::new(),
            current: 0,
            acc: 0.0,
            acc_n: 0,
            chosen: None,
            recheck_in: 0,
        }
    }

    fn midpoint(bounds: &ParamBounds) -> (u32, u32) {
        ((bounds.cc_min + bounds.cc_max) / 2, (bounds.p_min + bounds.p_max) / 2)
    }
}

impl Default for TwoPhase {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for TwoPhase {
    fn name(&self) -> &str {
        "2-phase"
    }

    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32) {
        let (mc, mp) = Self::midpoint(bounds);
        self.candidates = PROBE_OFFSETS
            .iter()
            .map(|&(dc, dp)| {
                bounds.clamp(
                    (mc as i64 + dc as i64).max(1) as u32,
                    (mp as i64 + dp as i64).max(1) as u32,
                )
            })
            .collect();
        self.scores = vec![f64::MIN; self.candidates.len()];
        self.current = 0;
        self.chosen = None;
        self.candidates[0]
    }

    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision {
        let u = utility(&self.cfg, ctx.obs.throughput_gbps, ctx.obs.plr, ctx.cc, ctx.p);
        self.acc += u;
        self.acc_n += 1;

        if let Some(best) = self.chosen {
            // Phase 2: hold, with an occasional re-probe of the runner-up.
            self.recheck_in = self.recheck_in.saturating_sub(1);
            if self.recheck_in == 0 {
                self.chosen = None;
                self.current = 0;
                self.scores.fill(f64::MIN);
                self.acc = 0.0;
                self.acc_n = 0;
                let (cc, p) = self.candidates[0];
                return Decision { cc, p, action: None };
            }
            let (cc, p) = self.candidates[best];
            return Decision { cc, p, action: None };
        }

        // Phase 1 surrogate: cycle through candidates, score each.
        if self.acc_n >= self.probe_mis {
            self.scores[self.current] = self.acc / self.acc_n as f64;
            self.acc = 0.0;
            self.acc_n = 0;
            self.current += 1;
            if self.current >= self.candidates.len() {
                // All probed: choose the best and enter phase 2.
                let best = self
                    .scores
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                self.chosen = Some(best);
                self.recheck_in = 120;
                let (cc, p) = self.candidates[best];
                return Decision { cc, p, action: None };
            }
        }
        let (cc, p) = self.candidates[self.current];
        Decision { cc, p, action: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Observation;

    #[test]
    fn starts_at_midpoint() {
        let mut t = TwoPhase::new();
        let (cc, p) = t.start(&ParamBounds::default());
        assert_eq!((cc, p), (8, 8));
    }

    #[test]
    fn settles_on_best_candidate() {
        let mut t = TwoPhase::new();
        let bounds = ParamBounds::default();
        let (mut cc, mut p) = t.start(&bounds);
        let state = vec![0.0f32; 40];
        // Surface rewarding smaller stream counts: best candidate = (6, 6).
        for mi in 0..60 {
            let thr = 9.0 - 0.05 * ((cc * p) as f64 - 36.0).abs();
            let obs = Observation {
                throughput_gbps: thr.max(0.1),
                plr: 0.0,
                rtt_s: 0.03,
                energy_j: 100.0,
                cc,
                p,
                duration_s: 1.0,
            };
            let ctx = MiContext { state: &state, obs: &obs, cc, p, bounds: &bounds, mi_index: mi };
            let d = t.decide(&ctx);
            cc = d.cc;
            p = d.p;
        }
        assert_eq!((cc, p), (6, 6), "cc={cc} p={p}");
    }

    #[test]
    fn candidates_respect_bounds() {
        let mut t = TwoPhase::new();
        let bounds = ParamBounds { cc_min: 1, cc_max: 3, p_min: 1, p_max: 3, cc0: 2, p0: 2 };
        t.start(&bounds);
        for &(cc, p) in &t.candidates {
            assert!(cc >= 1 && cc <= 3 && p >= 1 && p <= 3);
        }
    }
}
