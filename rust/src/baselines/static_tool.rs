//! Static-configuration transfer tools (rclone, escp).

use crate::coordinator::{Decision, MiContext, Optimizer, ParamBounds};

/// A tool that fixes (cc, p) for the whole session.
#[derive(Debug, Clone)]
pub struct StaticTool {
    name: String,
    cc: u32,
    p: u32,
}

impl StaticTool {
    /// rclone with its default (cc, p) = (4, 4).
    pub fn rclone() -> StaticTool {
        StaticTool { name: "rclone".into(), cc: 4, p: 4 }
    }

    /// escp with (cc, p) = (4, 4).
    pub fn escp() -> StaticTool {
        StaticTool { name: "escp".into(), cc: 4, p: 4 }
    }

    /// An efficient engine pinned at an arbitrary setting (used for sweeps).
    pub fn efficient_static(cc: u32, p: u32) -> StaticTool {
        StaticTool { name: format!("static({cc},{p})"), cc, p }
    }
}

impl Optimizer for StaticTool {
    fn name(&self) -> &str {
        &self.name
    }

    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32) {
        bounds.clamp(self.cc, self.p)
    }

    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision {
        let (cc, p) = ctx.bounds.clamp(self.cc, self.p);
        Decision { cc, p, action: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Observation;

    #[test]
    fn never_moves() {
        let mut t = StaticTool::rclone();
        let bounds = ParamBounds::default();
        assert_eq!(t.start(&bounds), (4, 4));
        let obs = Observation {
            throughput_gbps: 1.0,
            plr: 0.5,
            rtt_s: 0.03,
            energy_j: 10.0,
            cc: 4,
            p: 4,
            duration_s: 1.0,
        };
        let state = vec![0.0f32; 40];
        let ctx = MiContext { state: &state, obs: &obs, cc: 4, p: 4, bounds: &bounds, mi_index: 9 };
        let d = t.decide(&ctx);
        assert_eq!((d.cc, d.p), (4, 4));
        assert!(d.action.is_none());
        assert!(!t.is_learning());
    }

    #[test]
    fn clamped_into_bounds() {
        let mut t = StaticTool::efficient_static(64, 64);
        let bounds = ParamBounds::default();
        assert_eq!(t.start(&bounds), (16, 16));
    }
}
