//! Falcon_MP: online gradient-descent tuning of concurrency and parallelism.
//!
//! Falcon ([15] in the paper) probes the utility U(T, L) around the current
//! setting and hill-climbs: it holds a setting for a probe window, compares
//! the averaged utility against the previous setting, keeps moving while
//! utility improves and reverses otherwise, alternating between the cc and p
//! axes. It starts from a baseline configuration, which is why the paper
//! observes it "requires multiple gradient-descent steps to converge".

use crate::coordinator::reward::{utility, RewardConfig};
use crate::coordinator::{Decision, MiContext, Optimizer, ParamBounds};

/// Online probing gradient optimizer (Falcon_MP).
#[derive(Debug, Clone)]
pub struct FalconMp {
    cfg: RewardConfig,
    /// MIs to average per probe point.
    probe_mis: usize,
    // Current and previous probe state.
    cc: u32,
    p: u32,
    prev_utility: Option<f64>,
    acc: f64,
    acc_n: usize,
    /// +1 or -1: direction of travel on the current axis.
    direction: i32,
    /// Which axis moves next: false = cc, true = p.
    axis_p: bool,
    /// Consecutive reversals — used to settle into hold mode.
    reversals: u32,
    holding: bool,
    hold_left: usize,
}

impl FalconMp {
    pub fn new() -> FalconMp {
        FalconMp {
            cfg: RewardConfig::default(),
            probe_mis: 3,
            cc: 2,
            p: 2,
            prev_utility: None,
            acc: 0.0,
            acc_n: 0,
            direction: 1,
            axis_p: false,
            reversals: 0,
            holding: false,
            hold_left: 0,
        }
    }

    fn step_axis(&mut self, bounds: &ParamBounds) {
        if self.axis_p {
            let np = (self.p as i64 + self.direction as i64)
                .clamp(bounds.p_min as i64, bounds.p_max as i64) as u32;
            if np == self.p {
                self.direction = -self.direction; // bounced off a bound
            }
            self.p = np;
        } else {
            let ncc = (self.cc as i64 + self.direction as i64)
                .clamp(bounds.cc_min as i64, bounds.cc_max as i64) as u32;
            if ncc == self.cc {
                self.direction = -self.direction;
            }
            self.cc = ncc;
        }
        self.axis_p = !self.axis_p;
    }
}

impl Default for FalconMp {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for FalconMp {
    fn name(&self) -> &str {
        "falcon_mp"
    }

    fn start(&mut self, bounds: &ParamBounds) -> (u32, u32) {
        // Baseline configuration, not the midpoint (§4: "starts from a
        // baseline configuration and uses gradient descent").
        self.cc = bounds.cc_min.max(2);
        self.p = bounds.p_min.max(2);
        (self.cc, self.p)
    }

    fn decide(&mut self, ctx: &MiContext<'_>) -> Decision {
        let u = utility(&self.cfg, ctx.obs.throughput_gbps, ctx.obs.plr, ctx.cc, ctx.p);
        self.acc += u;
        self.acc_n += 1;

        if self.holding {
            self.hold_left = self.hold_left.saturating_sub(1);
            if self.hold_left == 0 {
                // Periodically re-probe: conditions may have changed.
                self.holding = false;
                self.reversals = 0;
                self.prev_utility = None;
                self.acc = u;
                self.acc_n = 1;
            }
            return Decision { cc: self.cc, p: self.p, action: None };
        }

        if self.acc_n >= self.probe_mis {
            let avg = self.acc / self.acc_n as f64;
            match self.prev_utility {
                None => {
                    // First probe done; take the first step.
                    self.step_axis(ctx.bounds);
                }
                Some(prev) => {
                    if avg + 1e-9 < prev {
                        // Worse: reverse direction, count the reversal.
                        self.direction = -self.direction;
                        self.reversals += 1;
                        if self.reversals >= 4 {
                            // Oscillating around the optimum: hold for a while.
                            self.holding = true;
                            self.hold_left = 30;
                        } else {
                            self.step_axis(ctx.bounds);
                        }
                    } else {
                        self.reversals = 0;
                        self.step_axis(ctx.bounds);
                    }
                }
            }
            self.prev_utility = Some(avg);
            self.acc = 0.0;
            self.acc_n = 0;
        }
        Decision { cc: self.cc, p: self.p, action: None }
    }

    fn state_vec(&self) -> Vec<f64> {
        vec![
            self.cc as f64,
            self.p as f64,
            if self.prev_utility.is_some() { 1.0 } else { 0.0 },
            self.prev_utility.unwrap_or(0.0),
            self.acc,
            self.acc_n as f64,
            self.direction as f64,
            if self.axis_p { 1.0 } else { 0.0 },
            self.reversals as f64,
            if self.holding { 1.0 } else { 0.0 },
            self.hold_left as f64,
        ]
    }

    fn restore_state(&mut self, state: &[f64]) {
        let [cc, p, has_prev, prev, acc, acc_n, direction, axis_p, reversals, holding, hold_left] =
            state
        else {
            return;
        };
        self.cc = *cc as u32;
        self.p = *p as u32;
        self.prev_utility = (*has_prev != 0.0).then_some(*prev);
        self.acc = *acc;
        self.acc_n = *acc_n as usize;
        self.direction = *direction as i32;
        self.axis_p = *axis_p != 0.0;
        self.reversals = *reversals as u32;
        self.holding = *holding != 0.0;
        self.hold_left = *hold_left as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Observation;

    fn ctx_obs(thr: f64, plr: f64, cc: u32, p: u32) -> Observation {
        Observation {
            throughput_gbps: thr,
            plr,
            rtt_s: 0.03,
            energy_j: 100.0,
            cc,
            p,
            duration_s: 1.0,
        }
    }

    /// Drive Falcon against a synthetic concave utility surface peaking at
    /// cc = p = 8 and check it climbs toward the peak.
    #[test]
    fn climbs_synthetic_hill() {
        let mut f = FalconMp::new();
        let bounds = ParamBounds::default();
        let (mut cc, mut p) = f.start(&bounds);
        let state = vec![0.0f32; 40];
        for mi in 0..400 {
            // Throughput peaks at cc=p=8, no loss anywhere.
            let thr = 10.0 - 0.08 * ((cc as f64 - 8.0).powi(2) + (p as f64 - 8.0).powi(2));
            let obs = ctx_obs(thr.max(0.5), 0.0, cc, p);
            let ctx = MiContext { state: &state, obs: &obs, cc, p, bounds: &bounds, mi_index: mi };
            let d = f.decide(&ctx);
            cc = d.cc;
            p = d.p;
        }
        // Falcon maximizes U(T, L) = T/K^(cc·p) − T·L·B, not raw throughput:
        // on this surface the utility peak sits near (4, 4)–(5, 5), below
        // the raw-throughput peak at (8, 8).
        assert!(
            (3..=8).contains(&cc) && (3..=8).contains(&p),
            "did not climb: cc={cc} p={p}"
        );
    }

    #[test]
    fn starts_from_baseline_not_midpoint() {
        let mut f = FalconMp::new();
        let (cc, p) = f.start(&ParamBounds::default());
        assert!(cc <= 2 && p <= 2);
    }

    #[test]
    fn respects_bounds() {
        let mut f = FalconMp::new();
        let bounds = ParamBounds { cc_min: 1, cc_max: 4, p_min: 1, p_max: 4, cc0: 2, p0: 2 };
        let (mut cc, mut p) = f.start(&bounds);
        let state = vec![0.0f32; 40];
        for mi in 0..200 {
            // Monotone-increasing utility drives Falcon upward until clipped.
            let obs = ctx_obs((cc * p) as f64, 0.0, cc, p);
            let ctx = MiContext { state: &state, obs: &obs, cc, p, bounds: &bounds, mi_index: mi };
            let d = f.decide(&ctx);
            cc = d.cc;
            p = d.p;
            assert!(cc >= 1 && cc <= 4 && p >= 1 && p <= 4);
        }
    }
}
