//! Baseline optimizers the paper compares against (§4).
//!
//! * [`StaticTool`] — rclone / escp: fixed (cc, p) = (4, 4) for the session.
//! * [`FalconMp`] — Falcon_MP: online gradient-descent tuning of (cc, p)
//!   from a baseline configuration, optimizing the same utility U(T, L).
//! * [`TwoPhase`] — the 2-phase historical-model optimizer, deployed (as in
//!   the paper) without historical logs: midpoint initialization plus a
//!   coarse-then-hold search.

pub mod falcon;
pub mod static_tool;
pub mod two_phase;

pub use falcon::FalconMp;
pub use static_tool::StaticTool;
pub use two_phase::TwoPhase;
